"""Distribution tests needing >1 device run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count (tests in THIS process keep
the default single device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_small_mesh_train_step_runs():
    """Real sharded execution (not just lowering) on a 4x2 host mesh."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.common import set_batch_axes
        from repro import sharding as shd
        from repro.train import TrainConfig, init_train_state, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("llama3.2-1b", smoke=True)
        api = build_model(cfg)
        set_batch_axes(("data",))
        state = init_train_state(api, jax.random.PRNGKey(0))
        state_sh = shd.make_param_shardings(cfg, mesh, jax.eval_shape(lambda: state))
        state = jax.device_put(state, state_sh)
        step = make_train_step(api, TrainConfig(accum_steps=2))
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 64)), jnp.int32)
        b_sh = shd.batch_sharding(mesh, jax.eval_shape(lambda: {"tokens": toks}))
        batch = jax.device_put({"tokens": toks}, b_sh)
        with mesh:
            jstep = jax.jit(step, in_shardings=(state_sh, b_sh),
                            out_shardings=(state_sh, NamedSharding(mesh, P())))
            state, m = jstep(state, batch)
            state, m = jstep(state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("loss", loss)
    """))


def test_dryrun_cell_multi_pod_small():
    """The dry-run machinery on a (2,2,2) multi-pod mesh with a smoke arch."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.common import set_batch_axes
        from repro import sharding as shd
        from repro.train import TrainConfig, make_train_step, train_state_specs
        from repro.configs.shapes import ShapeSpec, batch_specs

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen3-8b", smoke=True)
        api = build_model(cfg)
        set_batch_axes(shd._batch_axes_for(mesh, 8))
        shape = ShapeSpec("t", 64, 8, "train")
        with mesh:
            state_shape = train_state_specs(api)
            state_sh = shd.make_param_shardings(cfg, mesh, state_shape)
            bspec = batch_specs(cfg, shape)
            b_sh = shd.batch_sharding(mesh, bspec)
            step = make_train_step(api, TrainConfig())
            lowered = jax.jit(step, in_shardings=(state_sh, b_sh),
                              out_shardings=(state_sh, NamedSharding(mesh, P()))
                              ).lower(state_shape, bspec)
            compiled = lowered.compile()
        print("mem", compiled.memory_analysis().temp_size_in_bytes)
        from repro.launch import hlo
        s = hlo.summarize(compiled.as_text())
        assert s["collective_bytes"] > 0
        print("collectives ok", s["collective_counts"])
    """))


def test_pod_sync_int8_compression():
    """Cross-pod compressed sync: pods converge to the mean delta; error
    feedback keeps long-run bias near zero."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.sync import make_pod_sync, init_error_state, quantize_int8, dequantize_int8

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        sync = make_pod_sync(mesh)
        # params differ per pod: shard a leading axis of 2 over "pod"
        base = np.linspace(-1, 1, 2 * 4 * 4).reshape(2, 4, 4).astype(np.float32)
        delta = np.stack([np.full((4, 4), 0.5, np.float32),
                          np.full((4, 4), -0.1, np.float32)])
        params = {"w": jnp.asarray(base + delta)}
        anchor = {"w": jnp.asarray(base)}
        err = init_error_state(params)
        spec = {"w": P("pod", None, None)}
        with mesh:
            new_params, new_err = sync(params, anchor, err, spec)
        got = np.asarray(new_params["w"])
        want = base + delta.mean(axis=0)   # pmean of deltas
        np.testing.assert_allclose(got, want, atol=0.01)
        # error feedback: residual equals quantization error
        q, s = quantize_int8(jnp.asarray(delta[0]))
        assert float(jnp.max(jnp.abs(jnp.asarray(new_err["w"][0])))) <= float(s) + 1e-6
        print("pod sync ok")
    """))


def test_cluster_parallel_sourcing_executes():
    """Sharded cluster-wide candidate sourcing runs and matches the
    unsharded argmax."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.cluster_parallel import (make_distributed_source,
            distributed_source_inputs, _source_best)
        from repro.core.preemption_jax import Request
        from repro.core.topology import RTX4090_SERVER
        from functools import partial

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        req = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
        args = distributed_source_inputs(RTX4090_SERVER, 256, 8, 2, req,
                                         rng=np.random.default_rng(3))
        fn = make_distributed_source(mesh, RTX4090_SERVER, req, alpha=0.5)
        score, node, combo = fn(*args)
        ref = partial(_source_best, request=req, alpha=0.5)(*[jnp.asarray(a) for a in args])
        assert float(score) == float(ref[0]), (score, ref[0])
        assert int(node) == int(ref[1])
        print("distributed sourcing ok:", float(score), int(node), int(combo))
    """))


def test_sharded_engine_decision_parity_randomized():
    """imp_sharded on a real 8-device mesh is bit-identical to imp_batched
    over randomized plan / commit / rollback / plan_batch sequences."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    print(run_py(f"""
        import random, sys
        sys.path.insert(0, {tests_dir!r})
        import jax
        assert len(jax.devices()) == 8, jax.devices()
        from test_fused_sourcing import random_cluster, _decision_key, WL3
        from repro.core import TopoScheduler

        rng = random.Random(7)
        for trial in range(5):
            seed = rng.randrange(10_000)
            nodes = rng.choice((5, 11, 13))   # 11/13 force node-axis padding
            seqs = {{}}
            for engine in ("imp_batched", "imp_sharded"):
                cluster = random_cluster(seed, nodes=nodes)
                sched = TopoScheduler(cluster, engine=engine)
                ops = random.Random(seed)
                seq = []
                for step in range(8):
                    wl = WL3[ops.choice("BCD")]
                    txn = sched.plan(wl, allow_normal=True)
                    seq.append(_decision_key(txn.decision))
                    if txn.decision.kind != "reject":
                        r = ops.random()
                        if r < 0.5:
                            txn.commit()
                        elif r < 0.75:
                            txn.commit()
                            txn.rollback()
                txns = sched.plan_batch(
                    [WL3[ops.choice("BC")] for _ in range(4)])
                seq.extend(_decision_key(t.decision) for t in txns)
                seqs[engine] = seq
            assert seqs["imp_batched"] == seqs["imp_sharded"], (seed, nodes)
            print("trial", trial, "seed", seed, "nodes", nodes, "ok")
        print("randomized sharded parity ok")
    """))


def test_sharded_engine_day_cycle_parity():
    """A short co-location day-cycle segment produces the identical hour
    rows under imp_sharded and imp_batched (same preemptions, same
    scheduled performance) on the 8-device mesh."""
    print(run_py("""
        import dataclasses
        import jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core.colocation import ColocationConfig, ColocationSim

        reports = {}
        for engine in ("imp_batched", "imp_sharded"):
            cfg = ColocationConfig(num_nodes=12, seed=5, engine=engine,
                                   horizon_hours=5.0)
            sim = ColocationSim(cfg)
            reports[engine] = sim.run()
        a, b = reports["imp_batched"], reports["imp_sharded"]
        assert len(a.hours) == len(b.hours)
        for ra, rb in zip(a.hours, b.hours):
            da, db = dataclasses.asdict(ra), dataclasses.asdict(rb)
            assert da == db, (da, db)
        assert a.preemptions == b.preemptions
        assert a.scheduled_perf == b.scheduled_perf
        print("day-cycle parity ok:", a.preemptions, "preemptions,",
              len(a.hours), "hours")
    """))


def test_sharded_state_layout_and_scatter():
    """The sharded cluster state pads the node axis to the mesh size,
    spreads every stacked tensor across all 8 devices, and keeps the
    sharding stable through delta syncs (scatter) and full rebuilds."""
    print(run_py("""
        import jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core import Cluster, RTX4090_SERVER, table3_workloads
        from repro.core.cluster_parallel import ShardedDeviceClusterState
        from repro.core.simulator import SimConfig, build_saturated_cluster

        cluster = build_saturated_cluster(SimConfig(num_nodes=13, seed=2))
        dcs = cluster.device_state(sharded=True)
        assert isinstance(dcs, ShardedDeviceClusterState)
        dcs.sync()
        assert dcs.n_rows == 16 and dcs.nodestate.shape[1] == 16
        for name in ("nodestate", "victims", "drain"):
            arr = getattr(dcs, name)
            devs = {s.device.id for s in arr.addressable_shards}
            assert len(devs) == 8, (name, devs)
        before = dcs.nodestate.sharding
        # delta path: evict one instance -> dirty row -> scatter
        uid = next(iter(cluster.instances))
        cluster.evict(uid)
        dcs.sync()
        assert dcs.nodestate.sharding == before, dcs.nodestate.sharding
        # full-rebuild path (majority-dirty fallback) keeps the layout too
        dcs._dirty.update(range(cluster.num_nodes))
        dcs.sync()
        assert dcs.nodestate.sharding == before, dcs.nodestate.sharding
        print("sharded layout stable across scatter + rebuild")
    """))
