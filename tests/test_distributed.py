"""Distribution tests needing >1 device run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count (tests in THIS process keep
the default single device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_small_mesh_train_step_runs():
    """Real sharded execution (not just lowering) on a 4x2 host mesh."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.common import set_batch_axes
        from repro import sharding as shd
        from repro.train import TrainConfig, init_train_state, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("llama3.2-1b", smoke=True)
        api = build_model(cfg)
        set_batch_axes(("data",))
        state = init_train_state(api, jax.random.PRNGKey(0))
        state_sh = shd.make_param_shardings(cfg, mesh, jax.eval_shape(lambda: state))
        state = jax.device_put(state, state_sh)
        step = make_train_step(api, TrainConfig(accum_steps=2))
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 64)), jnp.int32)
        b_sh = shd.batch_sharding(mesh, jax.eval_shape(lambda: {"tokens": toks}))
        batch = jax.device_put({"tokens": toks}, b_sh)
        with mesh:
            jstep = jax.jit(step, in_shardings=(state_sh, b_sh),
                            out_shardings=(state_sh, NamedSharding(mesh, P())))
            state, m = jstep(state, batch)
            state, m = jstep(state, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("loss", loss)
    """))


def test_dryrun_cell_multi_pod_small():
    """The dry-run machinery on a (2,2,2) multi-pod mesh with a smoke arch."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.launch.dryrun as dr
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.common import set_batch_axes
        from repro import sharding as shd
        from repro.train import TrainConfig, make_train_step, train_state_specs
        from repro.configs.shapes import ShapeSpec, batch_specs

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("qwen3-8b", smoke=True)
        api = build_model(cfg)
        set_batch_axes(shd._batch_axes_for(mesh, 8))
        shape = ShapeSpec("t", 64, 8, "train")
        with mesh:
            state_shape = train_state_specs(api)
            state_sh = shd.make_param_shardings(cfg, mesh, state_shape)
            bspec = batch_specs(cfg, shape)
            b_sh = shd.batch_sharding(mesh, bspec)
            step = make_train_step(api, TrainConfig())
            lowered = jax.jit(step, in_shardings=(state_sh, b_sh),
                              out_shardings=(state_sh, NamedSharding(mesh, P()))
                              ).lower(state_shape, bspec)
            compiled = lowered.compile()
        print("mem", compiled.memory_analysis().temp_size_in_bytes)
        from repro.launch import hlo
        s = hlo.summarize(compiled.as_text())
        assert s["collective_bytes"] > 0
        print("collectives ok", s["collective_counts"])
    """))


def test_pod_sync_int8_compression():
    """Cross-pod compressed sync: pods converge to the mean delta; error
    feedback keeps long-run bias near zero."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.sync import make_pod_sync, init_error_state, quantize_int8, dequantize_int8

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        sync = make_pod_sync(mesh)
        # params differ per pod: shard a leading axis of 2 over "pod"
        base = np.linspace(-1, 1, 2 * 4 * 4).reshape(2, 4, 4).astype(np.float32)
        delta = np.stack([np.full((4, 4), 0.5, np.float32),
                          np.full((4, 4), -0.1, np.float32)])
        params = {"w": jnp.asarray(base + delta)}
        anchor = {"w": jnp.asarray(base)}
        err = init_error_state(params)
        spec = {"w": P("pod", None, None)}
        with mesh:
            new_params, new_err = sync(params, anchor, err, spec)
        got = np.asarray(new_params["w"])
        want = base + delta.mean(axis=0)   # pmean of deltas
        np.testing.assert_allclose(got, want, atol=0.01)
        # error feedback: residual equals quantization error
        q, s = quantize_int8(jnp.asarray(delta[0]))
        assert float(jnp.max(jnp.abs(jnp.asarray(new_err["w"][0])))) <= float(s) + 1e-6
        print("pod sync ok")
    """))


def test_cluster_parallel_sourcing_executes():
    """Sharded cluster-wide candidate sourcing runs and matches the
    unsharded argmax."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.cluster_parallel import (make_distributed_source,
            distributed_source_inputs, _source_best)
        from repro.core.preemption_jax import Request
        from repro.core.topology import RTX4090_SERVER
        from functools import partial

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        req = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
        args = distributed_source_inputs(RTX4090_SERVER, 256, 8, 2, req,
                                         rng=np.random.default_rng(3))
        fn = make_distributed_source(mesh, RTX4090_SERVER, req, alpha=0.5)
        score, node, combo = fn(*args)
        ref = partial(_source_best, request=req, alpha=0.5)(*[jnp.asarray(a) for a in args])
        assert float(score) == float(ref[0]), (score, ref[0])
        assert int(node) == int(ref[1])
        print("distributed sourcing ok:", float(score), int(node), int(combo))
    """))
