"""Equivalence-class + top-K shortlist sourcing: decision parity vs the
full sweep, fingerprint/representative maintenance, and mode semantics.

The shortlisted path must be *bit-identical* to the exact all-nodes subset
sweep in guaranteed mode — every test here pins that against the
``*_full`` oracle engines (same code with the shortlist front-end off).
``shortlist_k`` is forced tiny (4–8) so the prescreen actually prunes on
clusters far below the production default of 128.
"""
import random

import numpy as np
import pytest

from repro.core import (Cluster, ColocationConfig, ShortlistConfig, SPECS,
                        TopoScheduler, run_day_cycle, table3_workloads)
from repro.core.cluster import SourcingContext
from repro.core.placement import Placement
from repro.core.simulator import SimConfig, build_saturated_cluster
from repro.core.workload import WorkloadSpec

WL3 = {w.name: w for w in table3_workloads()}


def _decision_key(dec):
    return (dec.kind, dec.node, dec.victims,
            None if dec.placement is None else dec.placement.tier,
            dec.hit)


def _sat(num_nodes=24, seed=0):
    return build_saturated_cluster(SimConfig(num_nodes=num_nodes, seed=seed))


def _random_cluster(seed: int, spec, nodes: int = 6) -> Cluster:
    rng = random.Random(seed)
    cluster = Cluster(spec, nodes)
    for node in range(nodes):
        free = list(range(min(8, spec.num_gpus)))
        rng.shuffle(free)
        while free:
            if len(free) >= 2 and rng.random() < 0.4:
                g = [free.pop(), free.pop()]
                wl = WL3["C"]
            else:
                g = [free.pop()]
                wl = WL3["D"]
            if rng.random() < 0.2:
                continue
            mask = sum(1 << x for x in g)
            cluster.bind(wl, node, Placement(mask, mask, 0))
    return cluster


# ---------------------------------------------------------------------------------
# Guaranteed-mode decision parity vs the full-sweep oracle
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11, 42])
@pytest.mark.parametrize("wl_name", ["A", "B", "C"])
def test_shortlist_single_plan_parity(seed, wl_name):
    decs = {}
    for engine, k in (("imp_batched", 6), ("imp_batched_full", 0)):
        sched = TopoScheduler(_sat(seed=seed), engine=engine, shortlist_k=k)
        decs[engine] = _decision_key(
            sched.plan(WL3[wl_name], allow_normal=False).decision)
    assert len(set(decs.values())) == 1, (seed, wl_name, decs)


@pytest.mark.parametrize("alpha", [0.0, 0.3, 0.5, 1.0])
def test_shortlist_parity_across_alpha(alpha):
    """The prescreen upper bound folds alpha into both of its terms; sweep
    it so tie-heavy regimes (alpha=0 and 1) hit the certainty check."""
    for seed in (1, 9):
        decs = {}
        for engine, k in (("imp_batched", 4), ("imp_batched_full", 0)):
            sched = TopoScheduler(_sat(seed=seed), engine=engine,
                                  alpha=alpha, shortlist_k=k)
            decs[engine] = _decision_key(
                sched.plan(WL3["B"], allow_normal=False).decision)
        assert len(set(decs.values())) == 1, (seed, alpha, decs)


@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_shortlist_parity_across_skus(spec_name):
    """Every SKU: the popcount tier tables baked into the prescreen must
    match the exact sweep's on all three server shapes.  Workloads are
    sized per-SKU (cores must be CoreGroup multiples)."""
    spec = SPECS[spec_name]
    cg = spec.coregroup_size
    victim = WorkloadSpec("v", priority=100, gpus_per_instance=1,
                          cores_per_instance=cg, preemptible=True)
    preemptor = WorkloadSpec("P", priority=1000, gpus_per_instance=2,
                             cores_per_instance=2 * cg, preemptible=False)
    rng = random.Random(7)

    def build():
        cluster = Cluster(spec, 6)
        for node in range(6):
            for g in range(spec.num_gpus):
                if rng.random() < 0.2:
                    continue
                cluster.bind(victim, node, Placement(1 << g, 1 << g, 0))
        return cluster

    state = rng.getstate()
    decs = {}
    for engine, k in (("imp_batched", 4), ("imp_batched_full", 0)):
        rng.setstate(state)
        sched = TopoScheduler(build(), engine=engine, shortlist_k=k)
        decs[engine] = _decision_key(
            sched.plan(preemptor, allow_normal=False).decision)
    assert len(set(decs.values())) == 1, (spec_name, decs)


def test_shortlist_parity_across_commit_sequences():
    """Commits mutate fingerprints incrementally; the rep set and prescreen
    must track them and keep agreeing with the full sweep."""
    seqs = {}
    for engine, k in (("imp_batched", 6), ("imp_batched_full", 0)):
        sched = TopoScheduler(_sat(seed=2), engine=engine, shortlist_k=k)
        seq = []
        for name in ("B", "C", "B", "B", "C", "B"):
            txn = sched.plan(WL3[name])
            seq.append(_decision_key(txn.decision))
            if txn.decision.kind != "rejected":
                txn.commit()
        seqs[engine] = seq
    assert seqs["imp_batched"] == seqs["imp_batched_full"]


def test_shortlist_parity_across_rollback():
    """Rollback restores prior placements with the original uids; the
    refreshed fingerprints must return to the pre-commit classes and the
    replans must match the oracle exactly."""
    seqs = {}
    for engine, k in (("imp_batched", 6), ("imp_batched_full", 0)):
        sched = TopoScheduler(_sat(seed=4), engine=engine, shortlist_k=k)
        seq = []
        txn = sched.plan(WL3["B"], allow_normal=False)
        seq.append(_decision_key(txn.decision))
        txn.commit()
        txn.rollback()
        for name in ("B", "C", "B"):
            t = sched.plan(WL3[name])
            seq.append(_decision_key(t.decision))
            if t.decision.kind != "rejected":
                t.commit()
        seqs[engine] = seq
    assert seqs["imp_batched"] == seqs["imp_batched_full"]


def test_shortlist_parity_in_plan_batch():
    """Batch sessions route patched (view-delta) nodes through the forced-
    row promotion: each patched node and a surviving member of its old
    class join the rep set, so the prescreen stays exact mid-batch."""
    batch = [WL3[n] for n in ("B", "B", "C", "B", "C", "B")]
    keys = {}
    for engine, k in (("imp_batched", 6), ("imp_batched_full", 0)):
        sched = TopoScheduler(_sat(seed=6), engine=engine, shortlist_k=k)
        keys[engine] = [_decision_key(t.decision)
                        for t in sched.plan_batch(batch)]
    assert keys["imp_batched"] == keys["imp_batched_full"]


def test_shortlist_parity_plan_batch_with_commits():
    seqs = {}
    for engine, k in (("imp_batched", 6), ("imp_batched_full", 0)):
        sched = TopoScheduler(_sat(seed=8), engine=engine, shortlist_k=k)
        seq = []
        for names in (("B", "C", "B"), ("C", "B", "B")):
            txns = sched.plan_batch([WL3[n] for n in names])
            for t in txns:
                if t.decision.kind != "rejected":
                    t.commit()
            seq.extend(_decision_key(t.decision) for t in txns)
        seqs[engine] = seq
    assert seqs["imp_batched"] == seqs["imp_batched_full"]


def test_shortlist_sharded_parity():
    """imp_sharded with the shard-local prescreen vs its full-sweep twin
    (runs on however many devices the host exposes, including one)."""
    seqs = {}
    for engine, k in (("imp_sharded", 6), ("imp_sharded_full", 0)):
        sched = TopoScheduler(_sat(seed=5), engine=engine, shortlist_k=k)
        seq = []
        for name in ("B", "C", "B", "C"):
            txn = sched.plan(WL3[name])
            seq.append(_decision_key(txn.decision))
            if txn.decision.kind != "rejected":
                txn.commit()
        seq.extend(_decision_key(t.decision)
                   for t in sched.plan_batch([WL3["B"]] * 4))
        seqs[engine] = seq
    assert seqs["imp_sharded"] == seqs["imp_sharded_full"]


# ---------------------------------------------------------------------------------
# Fingerprints and equivalence classes
# ---------------------------------------------------------------------------------

def test_fingerprint_incremental_matches_fresh():
    """After arbitrary commits, the incrementally-maintained fingerprints
    must equal a from-scratch rebuild's (same O(delta) invariant the rest
    of SourcingContext pins)."""
    cluster = _sat(seed=3)
    ctx = cluster.sourcing_context()
    ctx.refresh()
    sched = TopoScheduler(cluster, engine="imp_batched", shortlist_k=6)
    for name in ("B", "C", "B", "C"):
        txn = sched.plan(WL3[name])
        if txn.decision.kind != "rejected":
            txn.commit()
    ctx.refresh()
    fresh = SourcingContext(cluster)
    fresh.refresh()
    assert np.array_equal(ctx.fp, fresh.fp)


def test_fingerprint_identical_rows_collide_only_when_identical():
    """Nodes with identical resident rows share a fingerprint; binding one
    instance anywhere splits that node out of its class."""
    cluster = Cluster(SPECS["rtx4090"], 8)
    ctx = cluster.sourcing_context()
    ctx.refresh()
    assert len(set(ctx.fp.tolist())) == 1  # all-empty nodes: one class
    cluster.bind(WL3["D"], 3, Placement(1, 1, 0))
    ctx.refresh()
    fps = ctx.fp.tolist()
    assert len(set(fps)) == 2
    assert fps.count(fps[3]) == 1


def test_rep_classes_one_lowest_index_rep_per_class():
    cluster = _random_cluster(1, SPECS["rtx4090"], nodes=10)
    dcs = cluster.device_state().sync()
    rep, rep_dev = dcs.rep_classes()
    n = cluster.num_nodes
    fp = dcs.mirror.fp[:n]
    # exactly one rep per distinct fingerprint, and it's the lowest index
    assert int(rep[:n].sum()) == len(set(fp.tolist()))
    for v in set(fp.tolist()):
        members = np.nonzero(fp == v)[0]
        assert rep[members[0]] and not rep[members[1:]].any()
    # cache: same version -> same arrays; new version -> recomputed
    rep2, _ = dcs.rep_classes()
    assert rep2 is rep
    free = [(nd, cluster.free_masks(nd)) for nd in range(n)]
    node, (fg, fc) = next((nd, m) for nd, m in free if m[0] & m[1])
    bit = (fg & fc) & -(fg & fc)     # lowest jointly-free GPU/CG pair
    cluster.bind(WL3["D"], node, Placement(bit, bit, 0))
    dcs.sync()
    rep3, _ = dcs.rep_classes()
    assert rep3 is not rep


# ---------------------------------------------------------------------------------
# Modes, knobs, routing
# ---------------------------------------------------------------------------------

def test_shortlist_best_effort_mode_returns_valid_plans():
    """Best-effort skips the certainty fallback: decisions must still be
    executable (commit cleanly), just not necessarily sweep-identical."""
    sched = TopoScheduler(_sat(seed=0), engine="imp_batched",
                          shortlist_k=4, shortlist_mode="best_effort")
    for name in ("B", "C", "B"):
        txn = sched.plan(WL3[name])
        if txn.decision.kind != "rejected":
            dec = txn.commit()
            assert dec.instance is not None


def test_shortlist_disabled_below_k():
    """Clusters at or below K rows skip the prescreen entirely (nothing to
    prune) — construction must not fail and plans must match the oracle."""
    a = TopoScheduler(_sat(num_nodes=8, seed=0), engine="imp_batched",
                      shortlist_k=128)
    b = TopoScheduler(_sat(num_nodes=8, seed=0), engine="imp_batched_full")
    assert (_decision_key(a.plan(WL3["B"]).decision)
            == _decision_key(b.plan(WL3["B"]).decision))


def test_shortlist_config_validation():
    with pytest.raises(ValueError):
        ShortlistConfig(k=128, mode="bogus")
    with pytest.raises(ValueError):
        ShortlistConfig(k=0)


def test_auto_engine_resolves_by_node_count():
    lo = TopoScheduler(_sat(num_nodes=8, seed=0), engine="auto")
    assert lo._provenance["engine"] == "imp_batched"
    assert lo._provenance["auto"] is True
    hi = TopoScheduler(_sat(num_nodes=24, seed=0), engine="auto",
                       auto_threshold=16)
    assert hi._provenance["engine"] == "imp_sharded"
    assert hi._provenance["auto_threshold"] == 16


def test_decision_carries_sourcing_provenance():
    sched = TopoScheduler(_sat(num_nodes=8, seed=0), engine="auto",
                          shortlist_k=64, shortlist_mode="guaranteed")
    dec = sched.plan(WL3["B"]).decision
    prov = dec.sourcing_provenance
    assert prov["engine"] == "imp_batched" and prov["auto"] is True
    assert prov["shortlist_k"] == 64
    assert prov["shortlist_mode"] == "guaranteed"
    # provenance is excluded from equality: parity comparisons stay valid
    assert "sourcing_provenance" not in repr(dec)


# ---------------------------------------------------------------------------------
# Day cycle under the shortlist front-end
# ---------------------------------------------------------------------------------

def test_day_cycle_guaranteed_shortlist_matches_full_sweep():
    """A short seeded day-cycle segment: guaranteed-mode shortlisting must
    reproduce the full sweep's day bit-for-bit (same preemptions, hits,
    placements, scheduled perf)."""
    base = dict(num_nodes=12, seed=7, horizon_hours=4.0, warmup=False,
                shortlist_k=6)
    sl = run_day_cycle(ColocationConfig(engine="imp_batched", **base))
    full = run_day_cycle(ColocationConfig(engine="imp_batched_full", **base))
    assert sl.preemptions == full.preemptions
    assert sl.hits == full.hits
    assert sl.placements == full.placements
    assert sl.scheduled_perf == pytest.approx(full.scheduled_perf)
    assert sl.offline_goodput == pytest.approx(full.offline_goodput)
