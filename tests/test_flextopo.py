"""FlexTopo graph CRD: Table 2 schema, allocation state, serialization."""
import pytest

from repro.core.flextopo import ALLOCATED, FAILED, FREE, FlexTopo
from repro.core.topology import RTX4090_SERVER


def test_table2_schema():
    t = FlexTopo(RTX4090_SERVER, "node-1")
    g = t.graph
    kinds = {}
    for _, _, data in g.edges(data=True):
        kinds[data["kind"]] = kinds.get(data["kind"], 0) + 1
    # host: socket-coregroup; contain: cg-core; localized: cg-numa; nearby: gpu-numa
    assert kinds["host"] == 8
    assert kinds["contain"] == 64
    assert kinds["localized"] == 8
    assert kinds["nearby"] == 8
    gpu0 = g.nodes[("gpu", 0)]
    assert gpu0["model"] == "NVIDIA RTX 4090"
    assert gpu0["memory_capacity_mb"] == 24_000
    assert gpu0["status"] == FREE and gpu0["used_by"] is None


def test_allocate_release_roundtrip():
    t = FlexTopo(RTX4090_SERVER)
    t.allocate("pod-a", gpus=[0, 1], coregroups=[0, 1])
    assert t.gpu_status(0) == ALLOCATED
    assert t.graph.nodes[("gpu", 0)]["used_by"] == "pod-a"
    assert t.graph.nodes[("core", 0)]["status"] == ALLOCATED
    m = t.as_masks()
    assert m.free_gpu_mask == 0b11111100
    assert m.free_cg_mask == 0b11111100
    im = t.instance_masks("pod-a")
    assert im.free_gpu_mask == 0b11 and im.free_cg_mask == 0b11
    with pytest.raises(ValueError):
        t.allocate("pod-b", gpus=[0], coregroups=[])
    t.release("pod-a")
    assert t.as_masks().free_gpu_mask == 0xFF
    assert t.graph.nodes[("core", 0)]["status"] == FREE


def test_crd_serialization_roundtrip():
    t = FlexTopo(RTX4090_SERVER, "node-7")
    t.allocate("pod-x", gpus=[3], coregroups=[3])
    crd = t.to_crd()
    assert crd["kind"] == "FlexTopo"
    assert crd["status"]["gpus"][3]["usedBy"] == "pod-x"
    assert crd["status"]["gpus"][3]["numaID"] == 3
    t2 = FlexTopo.from_crd(crd, RTX4090_SERVER)
    assert t2.as_masks() == t.as_masks()
    assert t2.graph.nodes[("core", 24)]["status"] == ALLOCATED


def test_gpu_failure_changes_masks():
    t = FlexTopo(RTX4090_SERVER)
    t.fail_gpu(5)
    assert t.gpu_status(5) == FAILED
    assert t.as_masks().free_gpu_mask == 0xFF & ~(1 << 5)
    t.repair_gpu(5)
    assert t.as_masks().free_gpu_mask == 0xFF
