"""Prefill/decode equivalence: token-by-token decode must reproduce the
logits of a fresh prefill over the extended sequence — the strongest
correctness check of every cache implementation (full KV, ring/SWA KV,
rwkv matrix state, rg-lru state + conv state, enc-dec cross-KV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import cache_capacity, get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine

DECODE_ARCHS = ["llama3.2-1b", "qwen3-8b", "mixtral-8x7b", "rwkv6-7b",
                "recurrentgemma-9b", "seamless-m4t-medium", "paligemma-3b"]


def _fixed_modality(cfg, B):
    """Frames/patch embeddings generated ONCE from a dedicated stream (they
    must be identical between the decode chain and every reference prefill)."""
    rng = np.random.default_rng(1234)
    if cfg.is_encdec:
        return {"frames": jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), cfg.compute_dtype)}
    if cfg.frontend == "patch":
        return {"prefix_embeds": jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            cfg.compute_dtype)}
    return {}


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(42)
    B, S, T = 2, 24, 4
    toks = rng.integers(1, cfg.vocab, (B, S + T), dtype=np.int32)
    modality = _fixed_modality(cfg, B)
    # decode positions are ABSOLUTE sequence positions (patch prefix included)
    # and the cache must hold prefix + text
    pos0 = cfg.frontend_len if cfg.frontend == "patch" else 0
    cap = cache_capacity(cfg, pos0 + S + T)

    prefill = jax.jit(lambda p, b, t: api.prefill(p, b, t),
                      static_argnums=(2,))
    logits, caches = prefill(params,
                             {"tokens": jnp.asarray(toks[:, :S]), **modality},
                             cap)
    for t in range(S, S + T):
        ref_logits, _ = prefill(
            params, {"tokens": jnp.asarray(toks[:, :t + 1]), **modality}, cap)
        logits, caches = jax.jit(api.decode_step)(
            params, caches, jnp.asarray(toks[:, t]), jnp.int32(pos0 + t))
        a = np.asarray(logits, np.float32)
        b = np.asarray(ref_logits, np.float32)
        # bf16 compute: compare loosely
        np.testing.assert_allclose(a, b, atol=0.08, rtol=0.08,
                                   err_msg=f"{arch} step {t}")


def test_swa_window_limits_receptive_field():
    """Single-layer SWA: the last token's logits depend ONLY on the final W
    tokens (with >1 layer the receptive field grows to L*W, so 1 layer is
    the clean check of the windowed mask + ring cache)."""
    import dataclasses

    # dense variant: capacity-based MoE couples tokens through expert
    # overflow ordering, which breaks strict receptive-field equality
    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              n_layers=1, moe=None)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    B, S = 1, 24
    W = cfg.swa_window                               # 16
    t1 = rng.integers(1, cfg.vocab, (B, S), dtype=np.int32)
    t2 = t1.copy()
    t2[:, : S - W] = rng.integers(1, cfg.vocab, (B, S - W))
    cap = cache_capacity(cfg, S)
    prefill = jax.jit(lambda p, b: api.prefill(p, b, cap))
    l1, _ = prefill(params, {"tokens": jnp.asarray(t1)})
    l2, _ = prefill(params, {"tokens": jnp.asarray(t2)})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-3,
                               rtol=1e-3)


def test_request_queue_partial_batch_flush():
    """Head-of-line fix: a sub-batch tail waits for a full batch only up to
    ``flush_after`` seconds of head age, then flushes partial; ``flush=True``
    forces it out immediately."""
    from repro.serving import RequestQueue

    now = {"t": 0.0}
    q = RequestQueue(batch_size=4, seq_len=32, flush_after=5.0,
                     clock=lambda: now["t"])
    reqs = [Request(rid=i, prompt=np.array([1, 2], np.int32),
                    max_new_tokens=1) for i in range(6)]
    q.submit(reqs[0])
    q.submit(reqs[1])
    assert q.next_batch() is None, "partial batch held back while young"
    now["t"] = 4.9
    assert q.next_batch() is None
    now["t"] = 5.0
    batch = q.next_batch()
    assert batch is not None and [r.rid for r in batch] == [0, 1], \
        "head age past flush_after releases the partial batch"
    # a full batch goes out regardless of age
    now["t"] = 10.0
    for r in reqs[2:6]:
        q.submit(r)
    assert [r.rid for r in q.next_batch()] == [2, 3, 4, 5]
    # flush=True forces a young partial out (the ServeEngine.run drain)
    q.submit(Request(rid=9, prompt=np.array([1], np.int32), max_new_tokens=1))
    assert [r.rid for r in q.next_batch(flush=True)] == [9]
    assert q.next_batch(flush=True) is None, "empty queue stays None"
    # flush_after=0 keeps the legacy eager behavior
    eager = RequestQueue(batch_size=4, seq_len=32)
    eager.submit(Request(rid=11, prompt=np.array([1], np.int32),
                         max_new_tokens=1))
    assert [r.rid for r in eager.next_batch()] == [11]


def test_serve_engine_end_to_end():
    cfg = get_config("llama3.2-1b", smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, params, batch_size=2, seq_len=32)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 12,
                                               dtype=np.int32),
                    max_new_tokens=5) for i in range(5)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.output) == 5 for r in done)
    assert engine.stats["tokens"] > 0
    # deterministic greedy decode: same prompt -> same output
    r_a = Request(rid=10, prompt=done[0].prompt, max_new_tokens=5)
    engine.run([r_a])
    assert r_a.output == done[0].output
