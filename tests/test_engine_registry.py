"""Engine registry: resolution, clear errors, and cross-engine agreement."""
import random

import pytest

from repro.core import (Cluster, RTX4090_SERVER, TopoScheduler,
                        UnknownEngineError, get_engine, register_engine,
                        registered_engines, table3_workloads)
from repro.core.placement import Placement

WL3 = {w.name: w for w in table3_workloads()}


def small_cluster(seed: int = 0, nodes: int = 4) -> Cluster:
    """4-node cluster of C/D instances with holes — preemption territory."""
    rng = random.Random(seed)
    cluster = Cluster(RTX4090_SERVER, nodes)
    for node in range(nodes):
        free = list(range(8))
        rng.shuffle(free)
        while free:
            if len(free) >= 2 and rng.random() < 0.4:
                g = [free.pop(), free.pop()]
                wl = WL3["C"]
            else:
                g = [free.pop()]
                wl = WL3["D"]
            if rng.random() < 0.2:
                continue  # leave a hole
            mask = sum(1 << x for x in g)
            cluster.bind(wl, node, Placement(mask, mask, 0))
    return cluster


def test_unknown_engine_raises_listing_registered():
    with pytest.raises(UnknownEngineError) as exc:
        get_engine("definitely_not_an_engine")
    msg = str(exc.value)
    for name in ("godel", "imp", "imp_batched", "imp_pallas"):
        assert name in msg
    # also a ValueError, so legacy except-clauses still catch it
    assert isinstance(exc.value, ValueError)


def test_scheduler_rejects_unknown_engine_at_construction():
    cluster = Cluster(RTX4090_SERVER, 1)
    with pytest.raises(UnknownEngineError):
        TopoScheduler(cluster, engine="tpyo")


def test_registry_contains_all_paper_engines():
    names = registered_engines()
    for name in ("godel", "exhaustive", "imp", "imp_jax", "imp_batched",
                 "imp_pallas"):
        assert name in names


def test_scheduler_docstring_derives_from_registry():
    """Satellite: the documented engine list can no longer drift."""
    import repro.core.scheduler as sched_mod

    for name in registered_engines():
        assert name in sched_mod.__doc__


def test_custom_engine_registration_roundtrip():
    from repro.core.preemption import flextopo_imp

    @register_engine("registry_test_engine")
    def my_engine(cluster, workload, node):
        return flextopo_imp(cluster, workload, node)

    try:
        assert "registry_test_engine" in registered_engines()
        cluster = small_cluster(3)
        sched = TopoScheduler(cluster, engine="registry_test_engine")
        ref = TopoScheduler(cluster, engine="imp")
        dec = sched.plan(WL3["B"], allow_normal=False).decision
        refdec = ref.plan(WL3["B"], allow_normal=False).decision
        assert (dec.kind, dec.node, dec.victims) == \
            (refdec.kind, refdec.node, refdec.victims)
    finally:
        from repro.core import engines as engines_mod

        engines_mod._REGISTRY.pop("registry_test_engine", None)


@pytest.mark.parametrize("seed", [0, 7, 42])
@pytest.mark.parametrize("wl_name", ["A", "B", "C"])
def test_all_engines_agree_on_feasibility(seed, wl_name):
    """Hit/miss decisions may differ across engines (the baseline is
    topology-blind); bind FEASIBILITY may not: either every engine finds a
    valid plan for the preemptor or none does, and every committed placement
    must actually fit the freed resources (commit validates)."""
    wl = WL3[wl_name]
    kinds = {}
    for engine in registered_engines():
        cluster = small_cluster(seed)
        sched = TopoScheduler(cluster, engine=engine)
        txn = sched.plan(wl)
        kinds[engine] = txn.decision.rejected
        dec = txn.commit()      # raises TransactionError on an invalid bind
        if not dec.rejected:
            assert dec.instance.uid in cluster.instances
            for v in dec.evicted:
                assert v.uid not in cluster.instances
    assert len(set(kinds.values())) == 1, f"feasibility disagreement: {kinds}"
