"""Fused single-dispatch sourcing: winner parity, incremental arrays,
overflow fallback, and the pallas running-argmax outputs.

No hypothesis dependency: these must run in minimal environments too (the
fused path is the default ``imp_batched`` engine).
"""
import random

import numpy as np
import pytest

from repro.core import (Cluster, MAX_DENSE_VICTIMS, RTX4090_SERVER,
                        ServerSpec, TopoScheduler, table3_workloads)
from repro.core.cluster import SourcingContext
from repro.core.placement import Placement
from repro.core.workload import TopoPolicy, WorkloadSpec

WL3 = {w.name: w for w in table3_workloads()}
PARITY_ENGINES = ("imp", "imp_jax", "imp_batched_legacy", "imp_batched")


def random_cluster(seed: int, nodes: int = 5) -> Cluster:
    rng = random.Random(seed)
    cluster = Cluster(RTX4090_SERVER, nodes)
    for node in range(nodes):
        free = list(range(8))
        rng.shuffle(free)
        while free:
            if len(free) >= 2 and rng.random() < 0.4:
                g = [free.pop(), free.pop()]
                wl = WL3["C"]
            else:
                g = [free.pop()]
                wl = WL3["D"]
            if rng.random() < 0.2:
                continue  # leave a hole
            mask = sum(1 << x for x in g)
            cluster.bind(wl, node, Placement(mask, mask, 0))
    return cluster


def _decision_key(dec):
    return (dec.kind, dec.node, dec.victims,
            None if dec.placement is None else dec.placement.tier,
            dec.hit)


@pytest.mark.parametrize("seed", [0, 3, 7, 11, 42, 1234])
@pytest.mark.parametrize("wl_name", ["A", "B", "C"])
def test_fused_matches_legacy_and_python(seed, wl_name):
    """The fused engine's on-device Eq. 2 winner IS select_best's winner:
    same node, same victim set, same tier as every exact engine."""
    decs = {}
    for engine in PARITY_ENGINES:
        cluster = random_cluster(seed)
        sched = TopoScheduler(cluster, engine=engine)
        decs[engine] = _decision_key(
            sched.plan(WL3[wl_name], allow_normal=False).decision)
    assert len(set(decs.values())) == 1, f"winner disagreement: {decs}"


@pytest.mark.parametrize("alpha", [0.0, 0.3, 1.0])
def test_fused_parity_across_alpha(alpha):
    """Eq. 1's priority/topology weighting happens on device for the fused
    engine — sweep alpha to cover the tie-break branches."""
    for seed in (1, 9, 77):
        decs = {}
        for engine in PARITY_ENGINES:
            cluster = random_cluster(seed)
            sched = TopoScheduler(cluster, engine=engine, alpha=alpha)
            decs[engine] = _decision_key(
                sched.plan(WL3["B"], allow_normal=False).decision)
        assert len(set(decs.values())) == 1, (seed, alpha, decs)


def test_fused_parity_in_plan_batch():
    """Later plans in a batch see earlier planned evictions/binds through
    the copy-on-write view; the vmapped batch session masks those delta
    nodes out of its precomputed tensors and re-sources only them, and
    must still agree with the legacy engine."""
    batch = [WL3["B"], WL3["B"], WL3["C"], WL3["B"]]
    keys = {}
    for engine in ("imp_batched_legacy", "imp_batched"):
        cluster = random_cluster(21, nodes=4)
        sched = TopoScheduler(cluster, engine=engine)
        keys[engine] = [_decision_key(t.decision)
                        for t in sched.plan_batch(batch)]
    assert keys["imp_batched_legacy"] == keys["imp_batched"]


@pytest.mark.parametrize("alpha", [0.0, 0.3, 0.5, 1.0])
def test_vmapped_plan_batch_parity_across_engines_and_alpha(alpha):
    """Acceptance pin: the vmapped `plan_batch` produces bitwise-identical
    decisions (node, victims, tier, hit) vs imp, imp_jax and the legacy
    engine across the alpha sweep — 8 requests against one snapshot."""
    batch = [WL3[n] for n in ("B", "B", "C", "B", "C", "C", "B", "D")]
    for seed in (3, 42):
        keys = {}
        for engine in PARITY_ENGINES:
            cluster = random_cluster(seed)
            sched = TopoScheduler(cluster, engine=engine, alpha=alpha)
            keys[engine] = [_decision_key(t.decision)
                            for t in sched.plan_batch(batch)]
        assert len(set(map(tuple, keys.values()))) == 1, (seed, alpha, keys)


def test_vmapped_plan_batch_parity_across_commit_sequences():
    """Acceptance pin: repeated plan_batch → commit-all rounds stay
    decision-identical across engines (the resident state must track every
    commit incrementally, and each round's session snapshots it)."""
    seqs = {}
    for engine in ("imp", "imp_batched_legacy", "imp_batched"):
        cluster = random_cluster(17)
        sched = TopoScheduler(cluster, engine=engine)
        seq = []
        for names in (("B", "C", "B"), ("C", "B"), ("B", "B", "C")):
            txns = sched.plan_batch([WL3[n] for n in names])
            for t in txns:
                t.commit()
            seq.extend(_decision_key(t.decision) for t in txns)
        seqs[engine] = seq
    assert len(set(map(tuple, seqs.values()))) == 1, seqs


def test_vmapped_plan_batch_matches_sequential_single_plans():
    """The batch session and the single-request resident path must agree
    candidate-for-candidate (same shared view, same decisions AND the same
    true evaluated-candidate counts)."""
    batch = [WL3["B"], WL3["C"], WL3["B"], WL3["B"]]
    from repro.core.cluster import ClusterView

    cluster_a = random_cluster(29)
    sched_a = TopoScheduler(cluster_a, engine="imp_batched")
    batched = sched_a.plan_batch(batch)

    cluster_b = random_cluster(29)
    sched_b = TopoScheduler(cluster_b, engine="imp_batched")
    view = ClusterView(cluster_b)
    singles = [sched_b.plan(wl, view=view) for wl in batch]

    assert ([_decision_key(t.decision) for t in batched]
            == [_decision_key(t.decision) for t in singles])
    assert ([t.decision.num_candidates for t in batched]
            == [t.decision.num_candidates for t in singles])


def test_fused_filter_rejects_identically_to_host_filter():
    """Guaranteed Filtering fused into the dispatch must reject exactly when
    the host filter loop does — here nothing on the cluster is preemptible
    below the preemptor, so every engine must return kind=rejected."""
    blocker = WorkloadSpec("hi", priority=9000, gpus_per_instance=2,
                           cores_per_instance=16, preemptible=False)
    cluster = Cluster(RTX4090_SERVER, 2)
    for node in range(2):
        for i in range(4):
            mask = 0b11 << (2 * i)
            cluster.bind(blocker, node, Placement(mask, mask, 0))
    for engine in PARITY_ENGINES:
        dec = TopoScheduler(cluster, engine=engine).plan(
            WL3["B"], allow_normal=False).decision
        assert dec.rejected, engine


def test_fused_parity_across_commits():
    """Sequential commit-then-plan: the context must incrementally track the
    mutations the commits make (dirty-node refresh, not a full rebuild)."""
    seqs = {}
    for engine in ("imp", "imp_batched"):
        cluster = random_cluster(5, nodes=4)
        sched = TopoScheduler(cluster, engine=engine)
        seq = []
        for wl_name in ("B", "C", "B", "B", "C"):
            dec = sched.plan(WL3[wl_name], allow_normal=False).commit()
            seq.append(_decision_key(dec))
        seqs[engine] = seq
    assert seqs["imp"] == seqs["imp_batched"]


# ---------------------------------------------------------------------------------
# SourcingContext invalidation semantics
# ---------------------------------------------------------------------------------

def _context_state(ctx):
    return {name: getattr(ctx, name).copy()
            for name in ("free_gpu", "free_cg", "vg", "vc", "vp", "vu",
                         "rank", "stored", "count", "overflow", "next_prio")}


def _assert_rows_equal(incremental, fresh):
    assert np.array_equal(incremental.stored, fresh.stored)
    for name, arr in _context_state(fresh).items():
        got = getattr(incremental, name)
        if arr.ndim == 2 and name != "stored":
            # slots beyond `count` are padding: compare stored content only
            assert np.array_equal(got[fresh.stored], arr[fresh.stored]), name
        else:
            assert np.array_equal(got, arr), name


def test_sourcing_context_tracks_mutations_incrementally():
    cluster = random_cluster(13, nodes=4)
    ctx = cluster.sourcing_context()
    ctx.refresh()
    # commit a preemption through the scheduler: evictions + a bind
    sched = TopoScheduler(cluster, engine="imp_batched")
    txn = sched.plan(WL3["B"], allow_normal=False)
    txn.commit()
    assert ctx._dirty, "commit must mark nodes dirty via invalidate_node"
    ctx.refresh()
    fresh = SourcingContext(cluster)
    fresh.refresh()
    _assert_rows_equal(ctx, fresh)
    # rollback restores the exact prior rows
    txn.rollback()
    ctx.refresh()
    fresh2 = SourcingContext(cluster)
    fresh2.refresh()
    _assert_rows_equal(ctx, fresh2)


def test_sourcing_context_rank_orders_uids():
    cluster = random_cluster(3, nodes=2)
    ctx = cluster.sourcing_context()
    ctx.refresh()
    for node in range(cluster.num_nodes):
        cnt = int(ctx.count[node])
        uids = ctx.vu[node, :cnt]
        ranks = ctx.rank[node, :cnt]
        if cnt:
            assert sorted(ranks) == list(range(cnt))
            assert np.array_equal(np.argsort(np.argsort(uids)), ranks)


# ---------------------------------------------------------------------------------
# Overflow (> MAX_DENSE_VICTIMS victims on one node) falls back, not crashes
# ---------------------------------------------------------------------------------

BIG_CG_SERVER = ServerSpec(
    name="bigcg", num_sockets=2, num_numa=8, num_cores=192, num_gpus=8,
    coregroup_size=8)   # 24 CoreGroups: room for > 16 victims on one node

CPU_JOB = WorkloadSpec("cpu-only", priority=200, gpus_per_instance=0,
                       cores_per_instance=8, preemptible=True,
                       numa_policy=TopoPolicy.NONE,
                       socket_policy=TopoPolicy.NONE, critical=False,
                       kind="offline")


def _overflow_cluster() -> Cluster:
    """One node with 18 preemptible victims (> MAX_DENSE_VICTIMS): GPUs held
    by 4 C instances, plus 14 cpu-only jobs."""
    cluster = Cluster(BIG_CG_SERVER, 1)
    for i in range(4):
        gmask = 0b11 << (2 * i)
        cmask = 0b11 << (2 * i)
        cluster.bind(WL3["C"], 0, Placement(gmask, cmask, 0))
    for i in range(14):
        cmask = 1 << (8 + i)
        cluster.bind(CPU_JOB, 0, Placement(0, cmask, 0))
    return cluster


def _wide_cluster() -> Cluster:
    """Node 0 holds 10 victims (wide m=16 bucket, NOT overflow); node 1 is a
    normal narrow node — exercises the per-bucket dispatch grouping."""
    cluster = Cluster(BIG_CG_SERVER, 2)
    for i in range(4):
        gmask = 0b11 << (2 * i)
        cmask = 0b11 << (2 * i)
        cluster.bind(WL3["C"], 0, Placement(gmask, cmask, 0))
    for i in range(6):
        cluster.bind(CPU_JOB, 0, Placement(0, 1 << (8 + i), 0))
    for i in range(6):
        cluster.bind(WL3["D"], 1, Placement(1 << i, 1 << i, 0))
    return cluster


def test_wide_bucket_nodes_dispatch_separately_with_parity():
    cluster = _wide_cluster()
    assert 8 < len(cluster.victims_on(0, WL3["B"].priority)) <= MAX_DENSE_VICTIMS
    want = _decision_key(TopoScheduler(_wide_cluster(), engine="imp")
                         .plan(WL3["B"], allow_normal=False).decision)
    got = _decision_key(TopoScheduler(cluster, engine="imp_batched")
                        .plan(WL3["B"], allow_normal=False).decision)
    assert got == want


def test_cross_tier_exact_score_tie_breaks_by_victim_count():
    """Adversarial Eq. 1 tie across tiers: (tier 0, prio_sum 2, k=1) and
    (tier 1, prio_sum 1, k=2) both score exactly 0.75 at alpha=0.5.
    select_best breaks the tie by fewer victims; the fused device chain
    must not let its priority-sum refinement pick the other node."""
    blocker = WorkloadSpec("blk", priority=5000, gpus_per_instance=7,
                           cores_per_instance=56, preemptible=False)
    v_lo = WorkloadSpec("v2", priority=2, gpus_per_instance=1,
                        cores_per_instance=8, preemptible=True)
    v_a = WorkloadSpec("v0", priority=0, gpus_per_instance=1,
                       cores_per_instance=0, preemptible=True)
    v_b = WorkloadSpec("v1", priority=1, gpus_per_instance=0,
                       cores_per_instance=8, preemptible=True)
    preemptor = WorkloadSpec("P", priority=1000, gpus_per_instance=1,
                             cores_per_instance=8, preemptible=False,
                             numa_policy=TopoPolicy.BEST_EFFORT)

    def build():
        cluster = Cluster(RTX4090_SERVER, 2)
        # node 0: evicting the prio-2 victim frees gpu0+cg0 (NUMA 0, tier 0)
        cluster.bind(v_lo, 0, Placement(1 << 0, 1 << 0, 0))
        cluster.bind(blocker, 0, Placement(0xFE, 0xFE, 0))
        # node 1: two victims (prio 0 + prio 1) free gpu0 + cg1 — same
        # socket, different NUMA: tier 1 at prio_sum 1
        cluster.bind(v_a, 1, Placement(1 << 0, 0, 0))
        cluster.bind(v_b, 1, Placement(0, 1 << 1, 0))
        cluster.bind(blocker, 1, Placement(0xFE, 0xFD, 0))
        return cluster

    decs = {}
    for engine in ("imp", "imp_batched_legacy", "imp_batched"):
        sched = TopoScheduler(build(), engine=engine, alpha=0.5)
        decs[engine] = _decision_key(
            sched.plan(preemptor, allow_normal=False).decision)
    assert len(set(decs.values())) == 1, decs
    assert decs["imp_batched"][1] == 0        # fewer victims -> node 0
    assert len(decs["imp_batched"][2]) == 1


def test_fused_num_candidates_matches_legacy():
    """The device counts every feasible min-k subset; the decision must
    report that count, not the shortlist length."""
    for seed in (0, 7):
        decs = {}
        for engine in ("imp_batched_legacy", "imp_batched"):
            cluster = random_cluster(seed)
            sched = TopoScheduler(cluster, engine=engine)
            decs[engine] = sched.plan(WL3["B"], allow_normal=False).decision
        assert (decs["imp_batched"].num_candidates
                == decs["imp_batched_legacy"].num_candidates > 0)


def test_truncated_row_stays_dense_when_eligible_victims_fit():
    """A node with > MAX_DENSE_VICTIMS preemptible instances whose ELIGIBLE
    victims (priority < preemptor) fit the stored prefix must stay on the
    fused fast path, not fall back to per-node python sourcing."""
    from repro.core.preemption_jax import split_fused_nodes

    cpu500 = WorkloadSpec("cpu500", priority=500, gpus_per_instance=0,
                          cores_per_instance=8, preemptible=True,
                          numa_policy=TopoPolicy.NONE,
                          socket_policy=TopoPolicy.NONE, critical=False)
    blocker = WorkloadSpec("blk", priority=5000, gpus_per_instance=6,
                           cores_per_instance=48, preemptible=False)
    mid = WorkloadSpec("mid", priority=300, gpus_per_instance=1,
                       cores_per_instance=8, preemptible=False)

    def build():
        cluster = Cluster(BIG_CG_SERVER, 1)
        for i in range(2):
            cluster.bind(WL3["D"], 0, Placement(1 << i, 1 << i, 0))
        cluster.bind(blocker, 0, Placement(0xFC, 0xFC, 0))
        for i in range(16):
            cluster.bind(cpu500, 0, Placement(0, 1 << (8 + i), 0))
        return cluster

    cluster = build()
    assert len([i for i in cluster.instances_on(0) if i.preemptible]) \
        > MAX_DENSE_VICTIMS
    dcs = cluster.device_state().sync()
    split = split_fused_nodes(dcs, {}, mid.priority)
    # truncated row, still dense: no python fallback, no 2^16 re-dispatch
    assert split.overflow == [] and split.wide == []
    want = _decision_key(TopoScheduler(build(), engine="imp")
                         .plan(mid, allow_normal=False).decision)
    got = _decision_key(TopoScheduler(cluster, engine="imp_batched")
                        .plan(mid, allow_normal=False).decision)
    assert got == want == ("preempted", 0, got[2], got[3], got[4])


@pytest.mark.parametrize("engine",
                         ["imp_batched", "imp_batched_legacy", "imp_pallas"])
def test_overflow_node_falls_back_instead_of_crashing(engine):
    from repro.core.preemption import flextopo_imp

    cluster = _overflow_cluster()
    assert len(cluster.victims_on(0, WL3["B"].priority)) > MAX_DENSE_VICTIMS
    ref_cluster = _overflow_cluster()
    ref = TopoScheduler(ref_cluster, engine="imp")
    want = _decision_key(ref.plan(WL3["B"], allow_normal=False).decision)
    sched = TopoScheduler(cluster, engine=engine)
    got = _decision_key(sched.plan(WL3["B"], allow_normal=False).decision)
    assert got == want
    assert flextopo_imp(cluster, WL3["B"], 0)  # sanity: preemption feasible


# ---------------------------------------------------------------------------------
# Pallas running argmax + interpret flag plumbing
# ---------------------------------------------------------------------------------

def test_pallas_running_argmax_matches_host_reduction():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.topo_score import (K_INFEASIBLE, TopoRequest,
                                          topo_score_argmax_pallas)

    spec = RTX4090_SERVER
    rng = np.random.default_rng(7)
    n = 1500   # > one (8, 128) tile, not a tile multiple
    cg = rng.integers(0, spec.all_gpu_mask + 1, n).astype(np.int32)
    cc = rng.integers(0, spec.all_cg_mask + 1, n).astype(np.int32)
    pr = rng.integers(0, 3000, n).astype(np.int32)
    kk = rng.integers(0, 6, n).astype(np.int32)
    req = TopoRequest(2, 2, 1, alpha=0.5)
    tier, score, kmin, btier, bscore, bidx = topo_score_argmax_pallas(
        jnp.asarray(cg), jnp.asarray(cc), jnp.asarray(pr), jnp.asarray(kk),
        spec, req)
    tier, score = np.asarray(tier), np.asarray(score)
    kmin, btier = np.asarray(kmin), np.asarray(btier)
    bscore, bidx = np.asarray(bscore), np.asarray(bidx)
    tile = 8 * 128
    for t in range(len(kmin)):
        lo, hi = t * tile, min((t + 1) * tile, n)
        feas = tier[lo:hi] < 3
        if not feas.any():
            assert kmin[t] == K_INFEASIBLE
            continue
        k_t = kk[lo:hi][feas].min()
        assert kmin[t] == k_t
        sel = feas & (kk[lo:hi] == k_t)
        t_t = tier[lo:hi][sel].min()
        assert btier[t] == t_t
        sel &= tier[lo:hi] == t_t
        s_t = score[lo:hi][sel].max()
        assert bscore[t] == pytest.approx(s_t)
        sel &= score[lo:hi] == s_t
        assert bidx[t] == lo + int(np.nonzero(sel)[0][0])


def test_pallas_filtering_mask_input_masks_lanes():
    """Lanes zeroed by the kernel's filtering-mask input must report tier 3
    / -inf score and never win the per-tile argmax."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.topo_score import (K_INFEASIBLE, TopoRequest,
                                          topo_score_argmax_pallas)

    spec = RTX4090_SERVER
    rng = np.random.default_rng(11)
    n = 1500
    cg = rng.integers(0, spec.all_gpu_mask + 1, n).astype(np.int32)
    cc = rng.integers(0, spec.all_cg_mask + 1, n).astype(np.int32)
    pr = rng.integers(0, 3000, n).astype(np.int32)
    kk = rng.integers(0, 6, n).astype(np.int32)
    ok = (rng.random(n) < 0.5).astype(np.int32)
    req = TopoRequest(2, 2, 1, alpha=0.5)
    base = topo_score_argmax_pallas(
        jnp.asarray(cg), jnp.asarray(cc), jnp.asarray(pr), jnp.asarray(kk),
        spec, req)
    masked = topo_score_argmax_pallas(
        jnp.asarray(cg), jnp.asarray(cc), jnp.asarray(pr), jnp.asarray(kk),
        spec, req, ok=jnp.asarray(ok))
    tier_b, tier_m = np.asarray(base[0]), np.asarray(masked[0])
    score_m = np.asarray(masked[1])
    off = ok == 0
    assert np.all(tier_m[off] == 3) and np.all(np.isneginf(score_m[off]))
    assert np.array_equal(tier_m[~off], tier_b[~off])
    # the per-tile argmax only ever picks unmasked lanes
    kmin, bidx = np.asarray(masked[2]), np.asarray(masked[5])
    for t in range(len(kmin)):
        if kmin[t] != K_INFEASIBLE:
            assert ok[bidx[t]] == 1


def test_pallas_engine_parity_with_mixed_eligibility():
    """A node mixing eligible and ineligible victims must still match the
    exact python engine (the eligible set is a prefix slice; the kernel's
    filtering mask guards the lanes)."""
    lo = WorkloadSpec("lo", priority=100, gpus_per_instance=1,
                      cores_per_instance=8, preemptible=True)
    hi = WorkloadSpec("hi", priority=2000, gpus_per_instance=1,
                      cores_per_instance=8, preemptible=True)
    mid = WorkloadSpec("mid", priority=900, gpus_per_instance=2,
                       cores_per_instance=16, preemptible=False)

    def build():
        cluster = Cluster(RTX4090_SERVER, 1)
        for i in range(4):
            cluster.bind(lo if i % 2 else hi, 0,
                         Placement(1 << i, 1 << i, 0))
        cluster.bind(mid, 0, Placement(0b11 << 4, 0b11 << 4, 0))
        return cluster

    want = _decision_key(TopoScheduler(build(), engine="imp")
                         .plan(mid, allow_normal=False).decision)
    got = _decision_key(TopoScheduler(build(), engine="imp_pallas")
                        .plan(mid, allow_normal=False).decision)
    assert got == want


def test_pallas_interpret_env_flag(monkeypatch):
    from repro.kernels import topo_score

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert topo_score._interpret_default() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert topo_score._interpret_default() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "auto")
    import jax

    assert topo_score._interpret_default() is (jax.default_backend() != "tpu")
