"""GPipe pipeline: forward equivalence + gradient match vs the plain stack
(subprocess with a 4-device "stage" mesh)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.pipeline import make_pipelined_fn

        S, M, mb, d = 4, 8, 2, 16
        mesh = jax.make_mesh((S,), ("stage",))
        rng = np.random.default_rng(0)
        # each stage: one dense layer + tanh
        ws = jnp.asarray(rng.standard_normal((S, d, d)) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        def sequential(ws, xs):
            def per_mb(x):
                for i in range(S):
                    x = stage_fn(ws[i], x)
                return x
            return jax.vmap(per_mb)(xs)

        pipe = make_pipelined_fn(stage_fn, mesh, S)
        with mesh:
            got = jax.jit(pipe)(ws, xs)
        want = sequential(ws, xs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

        # gradients flow through the schedule (ppermute is differentiable)
        def loss_pipe(ws):
            with mesh:
                return jnp.sum(jax.jit(pipe)(ws, xs) ** 2)
        def loss_seq(ws):
            return jnp.sum(sequential(ws, xs) ** 2)
        g1 = jax.grad(loss_pipe)(ws)
        g2 = jax.grad(loss_seq)(ws)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4, rtol=1e-4)
        print("gpipe fwd+bwd equivalence ok")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ok" in out.stdout
