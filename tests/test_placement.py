"""Placement tiers, bundle locality, hit predicate (+ hypothesis invariants)."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.placement import (INFEASIBLE, achieved_tier, best_tier,
                                  bundle_locality_ok, is_topology_hit,
                                  min_tier_for, place, place_blind)
from repro.core.topology import A100_SERVER, RTX4090_SERVER

S4090 = RTX4090_SERVER
FULL_G = S4090.all_gpu_mask
FULL_C = S4090.all_cg_mask


def test_min_tier():
    assert min_tier_for(S4090, 1) == 0
    assert min_tier_for(S4090, 2) == 1      # 1 GPU per NUMA on 4090
    assert min_tier_for(S4090, 4) == 1
    assert min_tier_for(S4090, 8) == 2
    assert min_tier_for(A100_SERVER, 4) == 0  # 4 GPUs per NUMA on A100


def test_tiers_on_empty_node():
    # empty 4090: 1 GPU -> NUMA tier; 4 GPUs -> socket; 8 -> cross
    assert best_tier(S4090, FULL_G, FULL_C, 1, 1) == 0
    assert best_tier(S4090, FULL_G, FULL_C, 4, 4) == 1
    assert best_tier(S4090, FULL_G, FULL_C, 8, 8) == 2
    assert best_tier(S4090, 0, 0, 1, 1) == INFEASIBLE


def test_bundle_locality_blocks_mismatched_free():
    # free: GPU on NUMA 0, CoreGroup on NUMA 1 — counts fit, bundles don't
    free_g = 0b1            # gpu 0 (numa 0)
    free_c = 0b10           # cg 1 (numa 1)
    assert best_tier(S4090, free_g, free_c, 1, 1, bundle_locality=True) \
        == INFEASIBLE
    assert best_tier(S4090, free_g, free_c, 1, 1, bundle_locality=False) == 1


def test_place_commits_best_tier():
    p = place(S4090, FULL_G, FULL_C, 2, 2)
    assert p is not None and p.tier == 1
    assert achieved_tier(S4090, p.gpu_mask) == 1
    assert bundle_locality_ok(S4090, p.gpu_mask, p.cg_mask, 1)
    assert is_topology_hit(S4090, p.gpu_mask, p.cg_mask, 2, 2)


def test_blind_placement_can_miss():
    # free GPUs 3 and 4 are on different sockets; blind takes lowest indices
    free_g = 0b00011000
    free_c = 0b00011000
    p = place_blind(S4090, free_g, free_c, 2, 2)
    assert p.tier == 2
    assert not is_topology_hit(S4090, p.gpu_mask, p.cg_mask, 2, 2)


@settings(max_examples=200, deadline=None)
@given(free_g=st.integers(0, FULL_G), free_c=st.integers(0, FULL_C),
       g=st.integers(1, 8))
def test_place_matches_best_tier(free_g, free_c, g):
    """place() commits exactly the tier best_tier promises, with valid masks."""
    t = best_tier(S4090, free_g, free_c, g, g)
    p = place(S4090, free_g, free_c, g, g)
    if t == INFEASIBLE:
        assert p is None
    else:
        assert p is not None
        assert p.tier == t
        # allocated resources were actually free and of the right count
        assert p.gpu_mask & ~free_g == 0 and p.cg_mask & ~free_c == 0
        assert p.gpu_mask.bit_count() == g and p.cg_mask.bit_count() == g
        assert bundle_locality_ok(S4090, p.gpu_mask, p.cg_mask, 1)
        assert achieved_tier(S4090, p.gpu_mask) <= t


@settings(max_examples=200, deadline=None)
@given(free_g=st.integers(0, A100_SERVER.all_gpu_mask),
       free_c=st.integers(0, A100_SERVER.all_cg_mask),
       g=st.integers(1, 8), extra_c=st.integers(0, 2))
def test_place_matches_best_tier_a100(free_g, free_c, g, extra_c):
    c = min(g + extra_c, A100_SERVER.num_coregroups)
    t = best_tier(A100_SERVER, free_g, free_c, g, c)
    p = place(A100_SERVER, free_g, free_c, g, c)
    if t == INFEASIBLE:
        assert p is None
    else:
        assert p is not None and p.tier == t
        assert p.gpu_mask.bit_count() == g and p.cg_mask.bit_count() == c
