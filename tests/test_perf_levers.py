"""§Perf levers stay correct: chunked attention, MoE dispatch modes,
quick-failure pruning, and the loop-scaled HLO walker."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


def _loss(cfg, params, batch):
    api = build_model(cfg)
    return float(jax.jit(api.loss)(params, batch)[0])


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b",
                                  "paligemma-3b"])
def test_chunked_attention_matches_baseline(arch):
    """q-chunked attention (H4) is bit-identical across causal/SWA/prefix-LM."""
    cfg = get_config(arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 64
    batch = {}
    if cfg.frontend == "patch":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.d_model)),
            cfg.compute_dtype)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S - cfg.frontend_len)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)
    base = _loss(cfg, params, batch)
    chunked = _loss(dataclasses.replace(cfg, attn_chunk_q=16), params, batch)
    assert base == pytest.approx(chunked, abs=1e-6)


def test_moe_dispatch_modes_agree():
    """per_sequence dispatch ~= global (capacity grouping noise only)."""
    cfg = get_config("mixtral-8x7b", smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    g = _loss(cfg, params, batch)
    ps = _loss(dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="per_sequence")),
        params, batch)
    assert g == pytest.approx(ps, abs=0.3)


def test_quick_failure_pruning_sound():
    """min_feasible_k never exceeds the true brute-force minimal size."""
    from repro.core import preemption
    from repro.core.simulator import SimConfig, build_saturated_cluster
    from repro.core.workload import table3_workloads

    # node counts must keep the scaled Table-3 instance mix exact (multiples
    # of 10 do; e.g. 8 nodes overflows by rounding)
    cluster = build_saturated_cluster(SimConfig(num_nodes=10, seed=2))
    wls = {w.name: w for w in table3_workloads()}
    for name in ("A", "B", "C"):
        wl = wls[name]
        for node in range(cluster.num_nodes):
            victims = cluster.victims_on(node, wl.priority)
            k_min = preemption.min_feasible_k(cluster, wl, node, victims)
            brute = preemption.brute_force_min_k(cluster, wl, node)
            if brute is not None:
                assert k_min <= brute[0], (name, node)


def test_hlo_walker_scales_scan_bodies():
    """The roofline FLOPs source: scan bodies multiplied by trip count."""
    from repro.launch import hlo

    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    stats = hlo.walk_stats(compiled.as_text())
    assert stats["flops_scaled"] == 5 * 2 * 64 ** 3
    # raw cost_analysis counts the body once — the reason the walker exists
    assert hlo.cost_dict(compiled)["flops"] < stats["flops_scaled"]


def test_collective_parser_on_sharded_module():
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ, PYTHONPATH=src,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo
        mesh = jax.make_mesh((4,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        f = jax.jit(lambda x: x.sum(), in_shardings=(sh,))
        c = f.lower(jax.ShapeDtypeStruct((64, 8), jnp.float32)).compile()
        s = hlo.summarize(c.as_text())
        assert s["collective_counts"]["all-reduce"] >= 1, s
        print("ok")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
