"""ServerSpec invariants + the paper's Fig. 2 cost matrix."""
import numpy as np
import pytest

from repro.core.topology import A100_SERVER, RTX4090_SERVER, SPECS, ServerSpec


def test_fig2_4090_costs():
    s = RTX4090_SERVER
    assert s.comm_cost(0, 0) == 10          # intra-NUMA
    assert s.comm_cost(0, 1) == 12          # cross-NUMA same socket (1.2x)
    assert s.comm_cost(0, 4) == 32          # cross-socket (3.2x)


def test_fig2_a100_costs():
    s = A100_SERVER
    assert s.comm_cost(0, 0) == 10
    assert s.comm_cost(0, 1) == 20          # 2x — one NUMA per socket


def test_4090_layout():
    s = RTX4090_SERVER
    assert s.num_coregroups == 8
    assert [s.numa_of_gpu(g) for g in range(8)] == list(range(8))
    assert [s.socket_of_gpu(g) for g in range(8)] == [0] * 4 + [1] * 4
    # paper §2.2: cores 24-31 are NUMA 3, nearest GPU 3
    assert s.numa_of_core(24) == 3 and s.numa_of_core(31) == 3


def test_a100_layout():
    s = A100_SERVER
    assert s.num_coregroups == 16
    assert [s.numa_of_gpu(g) for g in range(8)] == [0] * 4 + [1] * 4


@pytest.mark.parametrize("spec", list(SPECS.values()), ids=lambda s: s.name)
def test_masks_partition(spec: ServerSpec):
    # NUMA masks partition the full GPU/CG masks exactly
    assert int(np.bitwise_or.reduce(spec.numa_gpu_masks)) == spec.all_gpu_mask
    assert int(np.bitwise_or.reduce(spec.numa_cg_masks)) == spec.all_cg_mask
    for u in range(spec.num_numa):
        for w in range(u + 1, spec.num_numa):
            assert int(spec.numa_gpu_masks[u]) & int(spec.numa_gpu_masks[w]) == 0
            assert int(spec.numa_cg_masks[u]) & int(spec.numa_cg_masks[w]) == 0
    # socket masks aggregate their NUMA masks
    for s in range(spec.num_sockets):
        agg = 0
        for u in range(spec.num_numa):
            if spec.socket_of_numa(u) == s:
                agg |= int(spec.numa_gpu_masks[u])
        assert agg == int(spec.socket_gpu_masks[s])


def test_invalid_specs_rejected():
    with pytest.raises(ValueError):
        ServerSpec("bad", 2, 3, 64, 8, 8)   # 3 NUMA across 2 sockets
    with pytest.raises(ValueError):
        ServerSpec("bad", 2, 8, 63, 8, 8)   # cores not divisible
