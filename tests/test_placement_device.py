"""Device-side placement scorer: randomized host-vs-device parity, the
fused normal cycle, persistent batch sessions, and the `_lowest_bits`
feasibility fix.

Seeded-random loops, no hypothesis dependency (the fused placement path is
the default ``imp_batched`` engine and must be testable in minimal
environments).
"""
import random

import numpy as np
import pytest

from repro.core import (Cluster, RTX4090_SERVER, TopoScheduler,
                        table3_workloads)
from repro.core.placement import (INFEASIBLE, _lowest_bits, best_tier, place,
                                  place_blind)
from repro.core.placement_jax import (device_best_tier, device_place,
                                      device_place_blind)
from repro.core.topology import SPECS
from repro.core.workload import TABLE3_INITIAL_INSTANCES, WorkloadSpec

WL3 = {w.name: w for w in table3_workloads()}


def _partial_cluster(seed: int, nodes: int = 6, fill: float = 0.6) -> Cluster:
    """A partially-drained cluster: some nodes keep normal-cycle room."""
    from repro.core.simulator import SimConfig, build_saturated_cluster

    counts = {k: max(0, round(v * nodes / 100.0 * fill))
              for k, v in TABLE3_INITIAL_INSTANCES.items()}
    return build_saturated_cluster(SimConfig(num_nodes=nodes, seed=seed),
                                   counts=counts)


def _decision_key(dec):
    return (dec.kind, dec.node, dec.victims, dec.hit,
            None if dec.placement is None else
            (dec.placement.gpu_mask, dec.placement.cg_mask,
             dec.placement.tier))


# ---------------------------------------------------------------------------------
# Randomized host-vs-device place()/best_tier/place_blind equivalence
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_place_and_tier_parity_all_skus(spec_name, seed):
    """Across every ServerSpec SKU: random partially-drained free masks and
    random (gpus, cgs, bundle) asks must tier AND place bitwise-identically
    on host and device (masks included, CPU-only and no-bundle covered)."""
    spec = SPECS[spec_name]
    rng = random.Random(seed)
    for _ in range(150):
        fg = rng.randrange(0, spec.all_gpu_mask + 1)
        fc = rng.randrange(0, spec.all_cg_mask + 1)
        ng = rng.randrange(0, spec.num_gpus + 1)
        nc = rng.randrange(0, spec.num_coregroups + 1)
        bundle = rng.random() < 0.7
        args = (spec, fg, fc, ng, nc, bundle)
        assert best_tier(*args) == device_best_tier(*args), args
        assert place(*args) == device_place(*args), args
        assert (place_blind(spec, fg, fc, ng, nc)
                == device_place_blind(spec, fg, fc, ng, nc)), args


def test_device_place_commits_best_tier_masks():
    spec = RTX4090_SERVER
    p = device_place(spec, spec.all_gpu_mask, spec.all_cg_mask, 2, 2)
    assert p is not None and p.tier == 1
    assert p == place(spec, spec.all_gpu_mask, spec.all_cg_mask, 2, 2)
    assert device_place(spec, 0, 0, 1, 1) is None
    assert device_best_tier(spec, 0, 0, 1, 1) == INFEASIBLE


# ---------------------------------------------------------------------------------
# Normal-cycle decision parity: host imp vs the fused chained dispatch
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11, 42])
def test_normal_cycle_parity_imp_vs_fused(seed):
    """On a cluster with free room, plan() must resolve in the normal cycle
    with the SAME node, masks, tier and hit for the host loop and the
    single chained dispatch."""
    for name in ("A", "B", "C", "D"):
        decs = {}
        for engine in ("imp", "imp_batched"):
            sched = TopoScheduler(_partial_cluster(seed), engine=engine)
            decs[engine] = _decision_key(sched.plan(WL3[name]).decision)
        assert decs["imp"] == decs["imp_batched"], (seed, name, decs)
        if name in ("C", "D"):    # small asks always fit at 60% fill
            assert decs["imp"][0] == "placed"


@pytest.mark.parametrize("seed", [2, 9])
def test_normal_cycle_parity_under_commit_rollback(seed):
    """Commit/rollback sequences: the resident state must track every
    mutation and the chained dispatch must keep agreeing with imp."""
    seqs = {}
    for engine in ("imp", "imp_batched"):
        sched = TopoScheduler(_partial_cluster(seed, nodes=5), engine=engine)
        seq = []
        pending = []
        for step, name in enumerate(("C", "B", "D", "C", "B", "D", "C")):
            txn = sched.plan(WL3[name])
            txn.commit()
            pending.append(txn)
            seq.append(_decision_key(txn.decision))
            if step % 3 == 2:            # roll the last two back
                pending.pop().rollback()
                pending.pop().rollback()
        seqs[engine] = seq
    assert seqs["imp"] == seqs["imp_batched"], seqs


def test_fused_plan_falls_through_to_preemption_with_masks():
    """Saturated cluster: the chained dispatch must take the preemptive
    branch and return the same victims AND placement masks as imp."""
    from tests.test_fused_sourcing import random_cluster

    kinds = set()
    for seed in (0, 7, 42):
        decs = {}
        for engine in ("imp", "imp_batched"):
            sched = TopoScheduler(random_cluster(seed), engine=engine)
            decs[engine] = _decision_key(sched.plan(WL3["B"]).decision)
        assert decs["imp"] == decs["imp_batched"], (seed, decs)
        kinds.add(decs["imp"][0])
    assert "preempted" in kinds   # the chained cond took the preempt branch


def test_schedule_only_uses_normal_dispatch():
    """allow_preempt=False on the fused engine: placed on free clusters,
    rejected (never preempted) on saturated ones — identically to imp."""
    for seed in (1, 5):
        for build, want in ((_partial_cluster, "placed"),):
            decs = {}
            for engine in ("imp", "imp_batched"):
                sched = TopoScheduler(build(seed), engine=engine)
                decs[engine] = _decision_key(
                    sched.plan(WL3["B"], allow_preempt=False).decision)
            assert decs["imp"] == decs["imp_batched"]
            assert decs["imp"][0] == want
    from tests.test_fused_sourcing import random_cluster

    dec = TopoScheduler(random_cluster(3), engine="imp_batched").plan(
        WL3["B"], allow_preempt=False).decision
    assert dec.rejected


def test_blind_ablation_keeps_host_placement_path():
    """topology_aware_placement=False must not consume device placements
    (the device scorer is the topology-aware allocator)."""
    decs = {}
    for engine in ("imp", "imp_batched"):
        sched = TopoScheduler(_partial_cluster(4), engine=engine,
                              topology_aware_placement=False)
        assert not sched._fused_place
        decs[engine] = _decision_key(sched.plan(WL3["C"]).decision)
    assert decs["imp"] == decs["imp_batched"]


def test_device_state_exposes_numa_socket_slices():
    """`DeviceClusterState.slices` hands out the per-SKU slice layout the
    placement scorer consumes (cached: same object as spec_slices)."""
    from repro.core.placement_jax import spec_slices

    cluster = _partial_cluster(0, nodes=2)
    spec = cluster.spec
    sl = cluster.device_state().slices
    assert sl is spec_slices(spec)
    assert sl.scope_mask.shape == (spec.num_numa + spec.num_sockets + 1,
                                   spec.num_numa)
    assert sl.g_bits.shape == (spec.num_gpus,)
    assert int(sl.scope_tier[-1]) == 2    # the global (cross-socket) scope


# ---------------------------------------------------------------------------------
# Persistent BatchSourcingSession
# ---------------------------------------------------------------------------------

def test_persistent_session_reused_across_plan_batch_calls():
    from repro.core.preemption_jax import persistent_batch_session

    from tests.test_fused_sourcing import random_cluster

    cluster = random_cluster(13)
    s1 = persistent_batch_session(cluster, (WL3["B"], WL3["C"]), 0.5)
    s2 = persistent_batch_session(cluster, (WL3["B"], WL3["C"]), 0.5)
    assert s1 is s2, "clean state + same request classes must reuse"
    # different request mix or alpha -> fresh session
    s3 = persistent_batch_session(cluster, (WL3["C"], WL3["B"]), 0.5)
    assert s3 is not s2
    s4 = persistent_batch_session(cluster, (WL3["C"], WL3["B"]), 0.3)
    assert s4 is not s3


def test_persistent_session_invalidated_by_mutation():
    from repro.core.preemption_jax import persistent_batch_session

    from tests.test_fused_sourcing import random_cluster

    cluster = random_cluster(17)
    s1 = persistent_batch_session(cluster, (WL3["B"], WL3["B"]), 0.5)
    sched = TopoScheduler(cluster, engine="imp_batched")
    sched.plan(WL3["B"], allow_normal=False).commit()   # mutates the cluster
    s2 = persistent_batch_session(cluster, (WL3["B"], WL3["B"]), 0.5)
    assert s2 is not s1, "any invalidate_node must void the cached session"


def test_persistent_session_parity_across_repeated_plan_batch():
    """Repeated identical plan_batch bursts (pure reads, session reused)
    must stay decision-identical to the legacy engine every round."""
    from tests.test_fused_sourcing import random_cluster

    batch = [WL3["B"], WL3["C"], WL3["B"]]
    want = None
    legacy = TopoScheduler(random_cluster(23), engine="imp_batched_legacy")
    want = [_decision_key(t.decision) for t in legacy.plan_batch(batch)]
    sched = TopoScheduler(random_cluster(23), engine="imp_batched")
    for _ in range(3):
        got = [_decision_key(t.decision) for t in sched.plan_batch(batch)]
        assert got == want


def test_persistent_session_parity_across_commit_bursts():
    """Bursts separated by commits: the session rebuilds after each commit
    and the whole sequence matches per-request planning on imp."""
    seqs = {}
    for engine in ("imp", "imp_batched"):
        sched = TopoScheduler(_partial_cluster(8, nodes=4, fill=0.9),
                              engine=engine)
        seq = []
        for _ in range(3):
            txns = sched.plan_batch([WL3["B"], WL3["C"], WL3["B"]])
            for t in txns:
                t.commit()
            seq.extend(_decision_key(t.decision) for t in txns)
        seqs[engine] = seq
    assert seqs["imp"] == seqs["imp_batched"], seqs


# ---------------------------------------------------------------------------------
# _lowest_bits feasibility semantics (race hardening)
# ---------------------------------------------------------------------------------

def test_lowest_bits_returns_none_instead_of_raising():
    assert _lowest_bits(0b101, 2, 8) == 0b101
    assert _lowest_bits(0b101, 3, 8) is None      # was: bare ValueError
    assert _lowest_bits(0, 1, 8) is None
    assert _lowest_bits(0b1111, 2, 8) == 0b11


def test_place_survives_short_masks():
    """place()/place_blind() on raced (inconsistent) masks degrade to None
    rather than crashing the planner."""
    spec = RTX4090_SERVER
    assert place_blind(spec, 0b1, 0b1, 2, 2) is None
    assert place(spec, 0b1, 0b1, 2, 2) is None


# ---------------------------------------------------------------------------------
# Pallas mirror of the placement tier scorer
# ---------------------------------------------------------------------------------

def test_placement_tier_pallas_matches_host_best_tier():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.topo_score import TopoRequest, placement_tier_pallas

    spec = RTX4090_SERVER
    rng = np.random.default_rng(3)
    n = 1200   # > one (8, 128) tile, not a tile multiple
    fg = rng.integers(0, spec.all_gpu_mask + 1, n).astype(np.int32)
    fc = rng.integers(0, spec.all_cg_mask + 1, n).astype(np.int32)
    for ng, nc, cpb, bundle in ((2, 2, 1, True), (4, 4, 1, True),
                                (0, 3, 0, True), (2, 4, 0, False)):
        req = TopoRequest(ng, nc, cpb)
        tier = np.asarray(placement_tier_pallas(
            jnp.asarray(fg), jnp.asarray(fc), spec, req))
        for i in range(0, n, 97):
            assert tier[i] == best_tier(spec, int(fg[i]), int(fc[i]),
                                        ng, nc, bundle), i


def test_blocker_workload_normal_parity_with_degraded_admission():
    """A node whose counts fit but whose topology is infeasible must admit
    DEGRADED via the blind allocator identically on host and device (the
    kubelet best-effort branch of the normal cycle)."""
    v = WorkloadSpec("frag", priority=100, gpus_per_instance=1,
                     cores_per_instance=8, preemptible=True)
    ask = WorkloadSpec("ask", priority=1000, gpus_per_instance=2,
                       cores_per_instance=16, preemptible=False)

    def build():
        from repro.core.placement import Placement

        cluster = Cluster(RTX4090_SERVER, 1)
        # leave GPUs 0 and 4 free (cross-socket), CGs 1..3 and 5..7 busy
        for g in (1, 2, 3, 5, 6, 7):
            cluster.bind(v, 0, Placement(1 << g, 1 << g, 0))
        return cluster

    decs = {}
    for engine in ("imp", "imp_batched"):
        sched = TopoScheduler(build(), engine=engine)
        decs[engine] = _decision_key(
            sched.plan(ask, allow_preempt=False).decision)
    assert decs["imp"] == decs["imp_batched"], decs
    assert decs["imp"][0] == "placed" and not decs["imp"][3]  # a miss
