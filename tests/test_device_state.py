"""Device-resident cluster state: coherence under randomized mutation
sequences, single-row scatter updates, drain masks, warm-up, and the
``undo()`` deprecation shim.

The coherence tests are hypothesis-style seed loops (no hypothesis
dependency — these must run in minimal environments): random
commit/rollback/plan_batch sequences drive the incremental
``invalidate_node`` → ``sync()`` path, and after EVERY mutation the device
arrays must equal a from-scratch host rebuild.
"""
import random

import numpy as np
import pytest

from repro.core import (Cluster, RTX4090_SERVER, TopoScheduler,
                        table3_workloads)
from repro.core.cluster import encode_row, pack_rows
from repro.core.placement import Placement
from repro.core.workload import WorkloadSpec

WL3 = {w.name: w for w in table3_workloads()}


def random_cluster(seed: int, nodes: int = 5) -> Cluster:
    rng = random.Random(seed)
    cluster = Cluster(RTX4090_SERVER, nodes)
    for node in range(nodes):
        free = list(range(8))
        rng.shuffle(free)
        while free:
            if len(free) >= 2 and rng.random() < 0.4:
                g = [free.pop(), free.pop()]
                wl = WL3["C"]
            else:
                g = [free.pop()]
                wl = WL3["D"]
            if rng.random() < 0.2:
                continue
            mask = sum(1 << x for x in g)
            cluster.bind(wl, node, Placement(mask, mask, 0))
    return cluster


def rebuilt_arrays(cluster: Cluster):
    """From-scratch host rebuild of the device layout (no incremental path)."""
    cap = cluster.sourcing_context().cap
    rows = [encode_row(cluster, n, cap) for n in range(cluster.num_nodes)]
    return pack_rows(rows, list(range(cluster.num_nodes)), cap)


def assert_coherent(dcs):
    dcs.sync()
    ns, v, dr = rebuilt_arrays(dcs.cluster)
    assert np.array_equal(np.asarray(dcs.nodestate), ns), "nodestate diverged"
    assert np.array_equal(np.asarray(dcs.victims), v), "victim rows diverged"
    assert np.array_equal(np.asarray(dcs.drain), dr), "drain masks diverged"


@pytest.mark.parametrize("seed", [0, 1, 2, 5, 8, 13])
def test_device_state_coherent_after_random_sequences(seed):
    """Randomized commit / rollback / plan_batch / dropped-plan sequences:
    the resident arrays must equal a from-scratch rebuild after EVERY
    mutation (single-row scatters only — the cluster is never majority
    dirty after the initial upload)."""
    rng = random.Random(1000 + seed)
    cluster = random_cluster(seed)
    sched = TopoScheduler(cluster, engine="imp_batched")
    dcs = cluster.device_state()
    assert_coherent(dcs)
    committed = []
    names = ["B", "C", "D"]
    for _ in range(10):
        op = rng.choice(["plan_commit", "rollback", "plan_batch",
                         "plan_drop"])
        if op == "plan_commit":
            txn = sched.plan(WL3[rng.choice(names)],
                             allow_normal=rng.random() < 0.5)
            txn.commit()
            if txn.decision:
                committed.append(txn)
        elif op == "rollback" and committed:
            # LIFO: only the most recent commit is guaranteed reversible
            # (an older txn's instance may since have been preempted)
            committed.pop().rollback()
        elif op == "plan_batch":
            txns = sched.plan_batch(
                [WL3[rng.choice(names)] for _ in range(rng.randint(2, 4))])
            for t in txns:
                t.commit()
                if t.decision:
                    committed.append(t)
        else:  # plan_drop: a pure read must not dirty anything for real
            sched.plan(WL3[rng.choice(names)])
        assert_coherent(dcs)


def test_single_mutation_uses_row_scatter_not_full_rebuild():
    cluster = random_cluster(3, nodes=6)
    dcs = cluster.device_state()
    dcs.sync()                       # initial full upload
    before = dcs.nodestate
    victims = cluster.victims_on(2, WL3["B"].priority)
    assert victims
    cluster.evict(victims[0].uid)    # dirties exactly one row
    assert dcs._dirty == {2}
    assert_coherent(dcs)
    # other rows were scattered in place, not re-uploaded wholesale
    assert np.array_equal(np.asarray(before)[:, :2],
                          np.asarray(dcs.nodestate)[:, :2])


def test_drain_masks_are_free_union_victims():
    """Independent check of the drain field against the live instances."""
    cluster = random_cluster(7, nodes=4)
    dcs = cluster.device_state().sync()
    dr = np.asarray(dcs.drain)
    for node in range(cluster.num_nodes):
        fg, fc = cluster.free_masks(node)
        for inst in cluster.instances_on(node):
            if inst.preemptible:
                fg |= inst.gpu_mask
                fc |= inst.cg_mask
        assert dr[0, node] == fg and dr[1, node] == fc


def test_view_deltas_never_touch_resident_arrays():
    """plan() against a delta'd view overlays patches in-dispatch; the
    resident arrays must stay byte-identical to the base cluster."""
    from repro.core.cluster import ClusterView

    cluster = random_cluster(11, nodes=4)
    sched = TopoScheduler(cluster, engine="imp_batched")
    dcs = cluster.device_state()
    view = ClusterView(cluster)
    for wl in (WL3["B"], WL3["C"], WL3["B"]):
        sched.plan(wl, view=view, allow_normal=False)
    assert view.delta_nodes()        # the plans really did stack deltas
    assert_coherent(dcs)             # ... without dirtying the base state


def test_warmup_precompiles_and_plans_identically():
    cold = TopoScheduler(random_cluster(5), engine="imp_batched")
    warm = TopoScheduler(random_cluster(5), engine="imp_batched",
                         warmup=True)
    d0 = cold.plan(WL3["B"], allow_normal=False).decision
    d1 = warm.plan(WL3["B"], allow_normal=False).decision
    assert (d0.kind, d0.node, d0.victims) == (d1.kind, d1.node, d1.victims)
    # warmup is a no-op for engines without jit buckets
    TopoScheduler(random_cluster(5), engine="imp", warmup=True)


def test_undo_shim_warns_deprecation():
    cluster = random_cluster(9)
    sched = TopoScheduler(cluster, engine="imp_batched")
    dec = sched.preempt(WL3["B"])
    assert dec.preempted
    with pytest.warns(DeprecationWarning, match="Transaction.rollback") as rec:
        sched.undo(dec)
    # stacklevel=2: the warning must blame THIS file (the caller), not the
    # shim's own frame inside scheduler.py
    assert rec[0].filename == __file__


def test_undo_shim_not_reexported():
    """The deprecated shim is a method-level compat hook only: nothing in
    the package re-exports an ``undo`` symbol."""
    import repro
    import repro.core as core

    assert "undo" not in getattr(core, "__all__", ())
    assert not hasattr(core, "undo")
    assert not hasattr(repro, "undo")


@pytest.mark.parametrize("seed", [0, 2, 4, 6])
def test_journal_replay_matches_full_encode(seed):
    """The mirror's vectorized op-journal replay — including evict→restore
    cancellation inside one refresh window — reproduces a from-scratch
    context bitwise across all eleven dense arrays."""
    from repro.core.cluster import SourcingContext

    rng = random.Random(2000 + seed)
    cluster = random_cluster(seed, nodes=6)
    ctx = cluster.sourcing_context()
    ctx.refresh()                        # baseline build, journal drained
    evicted = []
    for _ in range(14):                  # one burst = one replay window
        op = rng.random()
        if op < 0.45 and cluster.instances:
            inst = cluster.evict(rng.choice(sorted(cluster.instances)))
            evicted.append(inst)
        elif op < 0.70 and evicted:
            inst = evicted.pop(rng.randrange(len(evicted)))
            fg, fc = cluster.free_masks(inst.node)
            if (fg & inst.gpu_mask) == inst.gpu_mask and \
                    (fc & inst.cg_mask) == inst.cg_mask:
                cluster.restore(inst)    # slots still free: reversible
        else:
            node = rng.randrange(cluster.num_nodes)
            fg, fc = cluster.free_masks(node)
            if fg & fc:
                g = (fg & fc) & -(fg & fc)
                cluster.bind(WL3["D"], node, Placement(g, g, 0))
    ctx.refresh()                        # incremental journal replay
    fresh = SourcingContext(cluster)
    fresh.refresh()                      # all-dirty: full re-encode
    for name in ("free_gpu", "free_cg", "vg", "vc", "vp", "vu", "rank",
                 "stored", "count", "overflow", "next_prio"):
        assert np.array_equal(getattr(ctx, name), getattr(fresh, name)), name


@pytest.mark.parametrize("seed", [0, 4, 9, 12])
def test_view_delta_device_rows_match_host_encode(seed):
    """Dense `ViewDelta` rows rebuilt by the DEVICE delta encoder equal the
    host ``encode_row`` packing of the same view — the per-plan patch path
    no longer round-trips rows through python."""
    from repro.core.cluster import ClusterView, ViewDelta, flatten_rows

    cluster = random_cluster(seed, nodes=6)
    sched = TopoScheduler(cluster, engine="imp_batched")
    dcs = cluster.device_state().sync()
    view = ClusterView(cluster)
    for wl in (WL3["B"], WL3["C"], WL3["B"]):
        sched.plan(wl, view=view, allow_normal=False)
    assert view.delta_nodes()
    vd = ViewDelta(view, dcs.mirror, dcs.pending)
    got = vd.device_rows(dcs)
    assert got is not None, "expected dense rows for this seed"
    didx, buf = got
    d = len(vd.dense)
    cap = dcs.cap
    nodes = [int(n) for n in didx[:d]]
    want = flatten_rows(*pack_rows(
        [encode_row(view, n, cap) for n in nodes], nodes, cap))
    assert np.array_equal(np.asarray(buf)[:d], want)
