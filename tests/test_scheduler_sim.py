"""End-to-end scheduler behaviour: Fig. 3 scenario, Table 4 hit rates,
agent event semantics, autoscaler co-location."""
import pytest

from repro.core import (Cluster, RTX4090_SERVER, SchedulingDecision,
                        TopoScheduler, table1_workloads)
from repro.core.agent import AgentFleet
from repro.core.autoscale import AutoscalePolicy, Autoscaler, diurnal_traffic
from repro.core.simulator import (SimConfig, build_saturated_cluster,
                                  run_hit_rate_experiment, run_timeline)
from repro.core.workload import table3_workloads

WL1 = {w.name: w for w in table1_workloads()}
WL3 = {w.name: w for w in table3_workloads()}


def fig3_cluster():
    """Paper Fig. 3: 3 nodes, 1×A + 6×B + 8×C, fully allocated."""
    cluster = Cluster(RTX4090_SERVER, 3)
    sched = TopoScheduler(cluster, engine="imp")
    sched.schedule(WL1["A"])
    for _ in range(6):
        sched.schedule(WL1["B"])
    for _ in range(8):
        sched.schedule(WL1["C"])
    return cluster, sched


def test_fig3_saturated():
    cluster, _ = fig3_cluster()
    assert cluster.count_by_workload() == {"A": 1, "B": 6, "C": 8}
    for n in range(3):
        fg, fc = cluster.free_masks(n)
        assert fg == 0 and fc == 0


def test_fig3_a_scaleup_preempts_topology_aware():
    """Scaling A (32c/4G) must evict 4 C victims from ONE socket (machine 3
    holds all C instances) — the paper's central example."""
    cluster, sched = fig3_cluster()
    res = sched.preempt(WL1["A"])
    assert isinstance(res, SchedulingDecision) and res.preempted
    assert len(res.victims) == 4
    assert res.hit
    assert res.placement.tier <= 1           # same socket
    evicted_nodes = {v.node for v in res.evicted}
    assert evicted_nodes == {res.node}


def test_fig3_b_scaleup():
    cluster, sched = fig3_cluster()
    res = sched.preempt(WL1["B"])
    assert res.preempted
    assert len(res.victims) == 2
    assert res.hit and res.placement.tier <= 1


def test_hit_rates_table4_small():
    """FlexTopo-IMP reaches 100% topology-affinity hit; Gödel-standard does
    not (paper Table 4: 44.5% vs 100%)."""
    cfg = SimConfig(num_nodes=20, seed=3)
    godel = run_hit_rate_experiment(cfg, "godel", cycles=2,
                                    scaleups_per_cycle=10)
    imp = run_hit_rate_experiment(cfg, "imp", cycles=2, scaleups_per_cycle=10)
    assert imp.preemptions > 0
    assert imp.hit_rate == 1.0
    assert godel.hit_rate < 0.9


def test_saturation_is_full():
    cluster = build_saturated_cluster(SimConfig(num_nodes=10, seed=0))
    for n in range(10):
        fg, fc = cluster.free_masks(n)
        assert fg == 0
    counts = cluster.count_by_workload()
    assert counts == {"A": 2, "B": 4, "C": 20, "D": 8}


def test_timeline_preemption_shifts_instances():
    """Fig. 9: scaling B/A up removes offline C/D instances."""
    tl = run_timeline(SimConfig(num_nodes=10, seed=1), engine="imp",
                      events=[("B", 3), ("A", 1)])
    first, last = tl[0], tl[-1]
    assert last["B"] == first["B"] + 3
    assert last["A"] == first["A"] + 1
    assert last.get("C", 0) + last.get("D", 0) < first["C"] + first["D"]


def test_agent_event_driven_updates():
    """§3.3: agents PATCH only on actual allocation change."""
    cluster = Cluster(RTX4090_SERVER, 2)
    fleet = AgentFleet(cluster)
    base = fleet.store.patch_count          # initial sync
    assert base == 2
    sched = TopoScheduler(cluster, engine="imp")
    res = sched.schedule(WL1["C"])
    assert fleet.notify(res.node) is True   # change -> patch
    assert fleet.notify(res.node) is False  # no change -> NO patch
    assert fleet.store.patch_count == base + 1
    crd = fleet.store.get(f"node-{res.node}")
    used = [g for g in crd["status"]["gpus"] if g["usedBy"]]
    assert len(used) == 1


def test_agent_periodic_scan_detects_gpu_failure():
    cluster = Cluster(RTX4090_SERVER, 1)
    fleet = AgentFleet(cluster)
    assert fleet.scan_all() == 0            # stable hardware: no reports
    fleet.inject_gpu_failure(0, gpu=2)
    assert fleet.scan_all() == 1            # discrepancy -> patch
    crd = fleet.store.get("node-0")
    assert crd["status"]["gpus"][2]["status"] == "failed"
    # scheduler no longer places onto the failed GPU
    sched = TopoScheduler(cluster, engine="imp")
    for _ in range(7):
        res = sched.schedule(WL1["C"])
        assert res.placed
        assert not res.placement.gpu_mask >> 2 & 1
    assert sched.schedule(WL1["C"]).rejected  # only the failed GPU remains


def test_autoscaler_diurnal_colocation():
    cluster = Cluster(RTX4090_SERVER, 8)
    sched = TopoScheduler(cluster, engine="imp")
    online = WL3["B"]
    offline = WL3["D"]
    # start at trough: min replicas + backfill
    auto = Autoscaler(cluster, sched,
                      [AutoscalePolicy(online, min_replicas=2,
                                       max_replicas=12)],
                      backfill=offline)
    auto.step(hour=2.0)     # valley
    valley = cluster.count_by_workload()
    auto.step(hour=14.0)    # peak -> scale up, preempting D
    peak = cluster.count_by_workload()
    assert peak["B"] > valley["B"]
    assert peak.get("D", 0) < valley.get("D", 0)
    assert diurnal_traffic(14.0) > diurnal_traffic(2.0)


def test_hit_rate_jax_batched_engine_matches_python():
    cfg = SimConfig(num_nodes=10, seed=5)
    py = run_hit_rate_experiment(cfg, "imp", cycles=1, scaleups_per_cycle=8)
    bat = run_hit_rate_experiment(cfg, "imp_batched", cycles=1,
                                  scaleups_per_cycle=8)
    assert py.preemptions == bat.preemptions
    assert py.hits == bat.hits
