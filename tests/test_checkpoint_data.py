"""Checkpoint store semantics + data pipeline determinism/resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.checkpoint.store import latest_step
from repro.data import DataConfig, SyntheticTokenPipeline


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32), "step": jnp.int32(5)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, str(tmp_path), 7, extra={"note": "x"})
    template = jax.eval_shape(lambda: t)
    restored, meta = restore_pytree(template, str(tmp_path))
    assert meta["step"] == 7 and meta["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_ignores_tmp(tmp_path):
    t = tree()
    save_pytree(t, str(tmp_path), 1)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_00000009.tmp-999-123")
    assert latest_step(str(tmp_path)) == 1
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(t, 2)
    assert not any(".tmp" in d for d in os.listdir(tmp_path))  # GC'd


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save(t, s)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_shape_mismatch_rejected(tmp_path):
    save_pytree(tree(), str(tmp_path), 1)
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,), jnp.int32),
                                         "step": jnp.int32(0)}}
    with pytest.raises(ValueError):
        restore_pytree(jax.eval_shape(lambda: bad), str(tmp_path))


# ---------------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------------

def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=3)
    p1 = SyntheticTokenPipeline(cfg)
    p2 = SyntheticTokenPipeline(cfg, start_step=0)
    b1 = p1.batch_at(5)
    b2 = p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resume from state dict
    p2.load_state_dict({"step": 17})
    assert p2.step == 17


def test_data_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=1)
    whole = SyntheticTokenPipeline(cfg).batch_at(3)["tokens"]
    shards = [SyntheticTokenPipeline(cfg, shard=s, num_shards=4).batch_at(3)
              ["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), whole)
    # elastic: different shard count, same global batch
    shards2 = [SyntheticTokenPipeline(cfg, shard=s, num_shards=2).batch_at(3)
               ["tokens"] for s in range(2)]
    np.testing.assert_array_equal(np.concatenate(shards2), whole)


def test_data_tokens_in_vocab():
    cfg = DataConfig(vocab=100, seq_len=64, global_batch=2, seed=0)
    toks = SyntheticTokenPipeline(cfg).batch_at(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < 100
