"""Per-arch smoke tests (assignment requirement): reduced config, one
forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cache_capacity, get_config
from repro.models import build_model, count_params


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), cfg.compute_dtype)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.frontend == "patch":
        P = cfg.frontend_len
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)), cfg.compute_dtype)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S - P)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert count_params(params) > 10_000
    batch = make_batch(cfg)

    loss, aux = jax.jit(api.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    # near-uniform prediction at init
    assert float(loss) < np.log(cfg.vocab) + 2.0

    grads = jax.jit(jax.grad(lambda p, b: api.loss(p, b)[0]))(params, batch)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    cap = cache_capacity(cfg, S)
    logits, caches = jax.jit(lambda p, b: api.prefill(p, b, cap))(params,
                                                                  batch)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = jax.jit(api.decode_step)(params, caches, tok,
                                                jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits2))
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    rows = {
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, H, K, f, V) in rows.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
                cfg.vocab) == (L, d, H, K, f, V), arch
    sm = get_config("seamless-m4t-medium")
    assert (sm.enc_layers, sm.dec_layers, sm.d_model, sm.n_heads,
            sm.d_ff) == (12, 12, 1024, 16, 4096)
    assert sm.vocab == 256_256  # 256206 padded for 16-way vocab sharding
    # feature flags
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("qwen3-8b").qk_norm
    assert get_config("mixtral-8x7b").swa_window == 4096
    assert get_config("mixtral-8x7b").moe.num_experts == 8
    assert get_config("olmoe-1b-7b").moe.top_k == 8
    assert get_config("recurrentgemma-9b").local_window == 2048
    assert get_config("paligemma-3b").prefix_lm
