"""Event-driven co-location day cycle: victim requeue lifecycle, metric
determinism, imp-vs-fused report parity, worst-tier scale-downs, and the
chunked plan_batch backfill."""
import dataclasses

import pytest

from repro.core import Cluster, RTX4090_SERVER, TopoScheduler
from repro.core.autoscale import AutoscalePolicy, Autoscaler
from repro.core.colocation import (ColocationConfig, ColocationSim,
                                   default_policies, run_day_cycle)
from repro.core.placement import achieved_tier
from repro.core.simulator import (SimConfig, run_plan_batch_latency,
                                  run_timeline)
from repro.core.workload import table3_workloads

WL3 = {w.name: w for w in table3_workloads()}


def day(engine: str, num_nodes: int = 12, horizon: float = 24.0,
        seed: int = 0):
    cfg = ColocationConfig(num_nodes=num_nodes, seed=seed, engine=engine,
                           horizon_hours=horizon)
    sim = ColocationSim(cfg, policies=default_policies(cfg))
    return sim, sim.run()


# ---- victim requeue lifecycle ------------------------------------------------------

def test_requeue_round_trip_preserves_identity_and_uids():
    """preempt -> requeue -> replan keeps the job's workload identity and
    NEVER resurrects an evicted instance uid."""
    sim, rep = day("imp")
    assert rep.preemptions > 0, "scenario must exercise preemption"
    assert rep.requeued > 0
    assert rep.requeue_replanned > 0, "reopened capacity must replan victims"
    requeued = [j for j in sim.jobs if j.requeues > 0]
    assert requeued
    for job in requeued:
        # one uid per (re)placement, all distinct: nothing was resurrected
        assert len(job.uids) == len(set(job.uids))
        assert len(job.uids) >= 1
        if len(job.uids) > 1:
            # replanned after preemption: a strictly NEWER uid each time
            assert list(job.uids) == sorted(job.uids)
        # the workload spec rode along unchanged
        assert job.workload.name in ("C", "D")
        assert job.workload == WL3[job.workload.name]
    # a replanned victim that is still running is registered under its
    # LAST uid only
    for job in requeued:
        if job.uid is not None:
            assert job.uid == job.uids[-1]
            assert sim.cluster.instances[job.uid].workload == job.workload
            for stale in job.uids[:-1]:
                assert stale not in sim.cluster.instances


def test_requeue_preserves_remaining_work():
    sim, rep = day("imp")
    for job in sim.jobs:
        if job.requeues and job.completed_at is not None:
            # a preempted-then-completed job took LONGER wall-clock than its
            # nominal duration (requeue delay + queue wait)
            assert job.completed_at - job.submitted_at > job.duration_hours


def test_requeue_disabled_drops_victims():
    cfg = ColocationConfig(num_nodes=12, seed=0, engine="imp", requeue=False)
    sim = ColocationSim(cfg, policies=default_policies(cfg))
    rep = sim.run()
    assert rep.requeued > 0          # victims are still counted...
    assert rep.requeue_replanned == 0  # ...but never come back


# ---- determinism and parity --------------------------------------------------------

def test_day_cycle_metrics_deterministic():
    _, a = day("imp", num_nodes=8, horizon=12.0)
    _, b = day("imp", num_nodes=8, horizon=12.0)
    assert a.key_metrics() == b.key_metrics()


def test_day_cycle_seed_changes_day():
    _, a = day("imp", num_nodes=8, horizon=12.0, seed=0)
    _, b = day("imp", num_nodes=8, horizon=12.0, seed=7)
    assert a.key_metrics() != b.key_metrics()


def test_imp_vs_fused_report_parity():
    """The fused device engine must produce the SAME ColocationReport as the
    host IMP engine over a short horizon (wall-clock fields excluded)."""
    _, host = day("imp", num_nodes=8, horizon=8.0)
    _, fused = day("imp_batched", num_nodes=8, horizon=8.0)
    hk, fk = host.key_metrics(), fused.key_metrics()
    hk.pop("engine"), fk.pop("engine")
    assert hk == fk


# ---- scheduled-performance accounting ----------------------------------------------

def test_scheduled_perf_positive_and_bounded():
    sim, rep = day("imp", num_nodes=8, horizon=12.0)
    assert rep.scheduled_perf > 0
    # the integral can never exceed the cluster's raw GPU-hours
    assert rep.scheduled_perf <= 8 * sim.cluster.spec.num_gpus * 12.0
    assert rep.offline_goodput > 0
    for row in rep.hours:
        assert set(row.served) <= {"A", "B", "C", "D"}
        assert row.scheduled_perf == pytest.approx(sum(
            v for k, v in row.served.items() if k in ("A", "B")))


def test_report_plan_latency_excluded_from_key_metrics():
    _, rep = day("imp", num_nodes=8, horizon=6.0)
    row = rep.hours[0]
    assert "plan_p50_us" not in row.key_metrics()
    assert "plan_p50_us" in dataclasses.asdict(row)
    # the CompileWatch tag rides the row but, being machine-dependent,
    # stays out of the deterministic metric set too
    assert "compiled_n" not in row.key_metrics()
    assert "compiled_n" in dataclasses.asdict(row)


# ---- the O(delta) event loop -------------------------------------------------------

def _day_metrics(legacy: bool, elastic: bool = False, **kw):
    cfg = ColocationConfig(num_nodes=10, seed=0, engine="imp",
                           horizon_hours=10.0, legacy_loop=legacy,
                           elastic=elastic, **kw)
    sim = ColocationSim(cfg, policies=default_policies(cfg))
    return sim, sim.run().key_metrics()


def test_legacy_loop_parity():
    """The O(delta) loop (rate accumulator, same-instant coalescing,
    count-gated dispatch, maintained indexes) must be BIT-exact vs the
    legacy full-scan-per-event loop."""
    sim_new, new = _day_metrics(legacy=False)
    sim_old, old = _day_metrics(legacy=True)
    assert new == old
    # and it was a real day, not a vacuous one
    assert new["preemptions"] > 0 and new["completed_jobs"] > 0
    # both loops pop the same event stream
    assert sim_new.events_processed == sim_old.events_processed > 0


def test_legacy_loop_parity_elastic():
    """Same bit-exactness through the two-level request+instance ladder
    (O(changed) pool reconcile, demotion index, dead-online tracking)."""
    _, new = _day_metrics(legacy=False, elastic=True)
    _, old = _day_metrics(legacy=True, elastic=True)
    assert new == old
    assert new["elastic_admitted"] > 0


def test_event_order_invariance():
    """Day metrics must be invariant to the ORDER same-timestamp events
    were pushed in: the heap's per-kind sort key (jid/uid) canonicalizes
    pop order, so enqueue order — an engine/generation artifact — cannot
    leak into the metrics.  Pins the tie-break the coalescing path relies
    on."""
    from repro.core.colocation import _SUBMIT

    class ReorderedSim(ColocationSim):
        def _generate_offline_arrivals(self):
            buffered = []
            orig_push = self._push

            def buffering_push(t, kind, payload):
                if kind == _SUBMIT:
                    buffered.append((t, payload))
                else:
                    orig_push(t, kind, payload)

            self._push = buffering_push
            try:
                super()._generate_offline_arrivals()
            finally:
                del self._push
            for t, payload in reversed(buffered):
                self._push(t, _SUBMIT, payload)

    cfg = ColocationConfig(num_nodes=10, seed=0, engine="imp",
                           horizon_hours=10.0)
    straight = ColocationSim(cfg, policies=default_policies(cfg)).run()
    shuffled = ReorderedSim(cfg, policies=default_policies(cfg)).run()
    assert straight.key_metrics() == shuffled.key_metrics()


def test_autoscaler_index_matches_cluster_scan():
    """The listener-maintained replica/tier/GPU index stays consistent
    with a fresh full scan after a whole simulated day of binds, evicts,
    and restores."""
    sim, _ = day("imp", num_nodes=8, horizon=8.0)
    cluster, auto = sim.cluster, sim.auto
    assert auto.used_gpus == sum(i.workload.gpus_per_instance
                                 for i in cluster.instances.values())
    by_class = {}
    for uid, inst in cluster.instances.items():
        by_class.setdefault(inst.workload.name, []).append(uid)
    for name, uids in by_class.items():
        assert [i.uid for i in auto.replicas(name)] == sorted(uids)
    for uid, inst in cluster.instances.items():
        assert auto._tier[uid] == achieved_tier(cluster.spec, inst.gpu_mask)


# ---- autoscaler satellites ---------------------------------------------------------

def test_scale_down_evicts_worst_tier_first():
    cluster = Cluster(RTX4090_SERVER, 2)
    sched = TopoScheduler(cluster, engine="imp")
    # 3 B replicas; force one onto a degraded (cross-socket) placement by
    # pre-fragmenting node 1 with D instances on alternating GPUs
    d = WL3["D"]
    b = WL3["B"]
    for _ in range(2):
        assert sched.schedule(b).placed
    blockers = []
    for _ in range(4):
        dec = sched.schedule(d)
        assert dec.placed
        blockers.append(dec)
    degraded = sched.schedule(b)
    assert degraded.placed
    spec = cluster.spec
    tiers = {uid: achieved_tier(spec, inst.gpu_mask)
             for uid, inst in cluster.instances.items()
             if inst.workload.name == "B"}
    worst = max(tiers.values())
    auto = Autoscaler(cluster, sched, [])
    ev = auto.scale_to(AutoscalePolicy(b, 0, 3), want=2)
    assert ev.action == "scale_down"
    # the released replica was one of the worst-tier ones, and the reclaimed
    # tier distribution says so
    assert ev.reclaimed_tiers == {worst: 1}
    remaining = [achieved_tier(spec, i.gpu_mask)
                 for i in cluster.instances.values()
                 if i.workload.name == "B"]
    assert all(t <= worst for t in remaining)


def test_backfill_chunked_admission_fills_and_stops():
    cluster = Cluster(RTX4090_SERVER, 2)
    sched = TopoScheduler(cluster, engine="imp")
    auto = Autoscaler(cluster, sched, [], backfill=WL3["D"], backfill_chunk=4)
    admitted, rejected = auto.backfill_valleys()
    assert admitted == 2 * cluster.spec.num_gpus     # D is 1 GPU / instance
    assert rejected > 0                              # final round stopped it
    # idempotent on a full cluster: one round, nothing placed, no spin
    again, rejected = auto.backfill_valleys()
    assert again == 0 and rejected == 4


def test_autoscale_event_counts_normal_placements():
    cluster = Cluster(RTX4090_SERVER, 4)
    sched = TopoScheduler(cluster, engine="imp")
    auto = Autoscaler(cluster, sched, [])
    ev = auto.scale_to(AutoscalePolicy(WL3["B"], 0, 4), want=3)
    assert ev.action == "scale_up"
    assert ev.placements == 3 and ev.preemptions == 0 and ev.failures == 0


# ---- simulator satellites ----------------------------------------------------------

def test_plan_batch_latency_counts_placed_outcomes():
    cfg = SimConfig(num_nodes=6, seed=2)
    rep = run_plan_batch_latency(cfg, "imp", "D", batch=4, rounds=2)
    # a saturated cluster admits 1-GPU D requests only via preemption or not
    # at all, but every outcome must now be accounted for
    assert rep.placements + rep.preemptions + rep.failures == rep.decisions
    assert rep.decisions == 4 * 2


def test_timeline_view_rides_event_loop():
    tl = run_timeline(SimConfig(num_nodes=10, seed=1), engine="imp",
                      events=[("B", 2)])
    assert [r["step"] for r in tl] == [0, 1, 2]
    assert tl[-1]["B"] == tl[0]["B"] + 2
