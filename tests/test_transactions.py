"""Transactional scheduling API: plan/commit/rollback round-trips.

The paper's Table 4 "independent preemptions" protocol depends on evaluation
leaving the cluster untouched; these tests assert bitwise-exact state
round-trips (free masks, instance uids, per-victim placements) across every
registered engine, for plan-only reads, commit+rollback, and the legacy
``undo`` shim.
"""
import pytest

from repro.core import (Cluster, RTX4090_SERVER, SchedulingDecision,
                        TopoScheduler, TransactionError, registered_engines,
                        table1_workloads)
from repro.core.agent import AgentFleet
from repro.core.decisions import COMMITTED, ROLLED_BACK

WL1 = {w.name: w for w in table1_workloads()}
ENGINES = registered_engines()


def fig3_cluster(engine="imp"):
    cluster = Cluster(RTX4090_SERVER, 3)
    sched = TopoScheduler(cluster, engine=engine)
    sched.schedule(WL1["A"])
    for _ in range(6):
        sched.schedule(WL1["B"])
    for _ in range(8):
        sched.schedule(WL1["C"])
    return cluster, sched


def snapshot(cluster):
    """Free masks + full instance registry, bitwise."""
    return (
        tuple(cluster.free_masks(n) for n in range(cluster.num_nodes)),
        tuple(sorted((uid, i.node, i.gpu_mask, i.cg_mask, i.workload.name)
                     for uid, i in cluster.instances.items())),
    )


@pytest.mark.parametrize("engine", ENGINES)
def test_plan_is_a_pure_read(engine):
    cluster, sched = fig3_cluster(engine)
    before = snapshot(cluster)
    txn = sched.plan(WL1["A"])
    assert txn.decision.preempted
    assert snapshot(cluster) == before
    txn.rollback()          # rolling back a planned txn is a no-op
    assert txn.state == ROLLED_BACK
    assert snapshot(cluster) == before


@pytest.mark.parametrize("engine", ENGINES)
def test_commit_rollback_roundtrip_is_bitwise_exact(engine):
    cluster, sched = fig3_cluster(engine)
    before = snapshot(cluster)
    txn = sched.plan(WL1["A"])
    dec = txn.commit()
    assert txn.state == COMMITTED
    assert dec.instance is not None and dec.instance.uid in cluster.instances
    assert snapshot(cluster) != before
    txn.rollback()
    # free masks, instance uids, AND per-victim placements all restored
    assert snapshot(cluster) == before


@pytest.mark.parametrize("engine", ENGINES)
def test_legacy_undo_delegates_to_rollback(engine):
    cluster, sched = fig3_cluster(engine)
    before = snapshot(cluster)
    dec = sched.schedule_or_preempt(WL1["A"])
    assert dec.preempted
    sched.undo(dec)
    assert snapshot(cluster) == before
    # victims were restored with their ORIGINAL uids, not rebound as new
    assert dec.txn.state == ROLLED_BACK


def test_victim_restore_preserves_tier_fidelity():
    """The old undo() rebound victims with tier=0 placements and fresh uids;
    restore() must keep the exact masks so achieved tiers are unchanged."""
    from repro.core.placement import achieved_tier

    cluster, sched = fig3_cluster()
    spec = cluster.spec
    tiers_before = {uid: achieved_tier(spec, i.gpu_mask)
                    for uid, i in cluster.instances.items()}
    txn = sched.plan(WL1["A"])
    txn.commit()
    txn.rollback()
    tiers_after = {uid: achieved_tier(spec, i.gpu_mask)
                   for uid, i in cluster.instances.items()}
    assert tiers_after == tiers_before


def test_commit_twice_and_stale_plan_rejected():
    cluster, sched = fig3_cluster()
    txn = sched.plan(WL1["A"])
    txn.commit()
    with pytest.raises(TransactionError):
        txn.commit()
    # a second plan made before the first commit goes stale if its victims
    # were taken by a conflicting commit
    cluster2, sched2 = fig3_cluster()
    t1 = sched2.plan(WL1["A"])
    t2 = sched2.plan(WL1["A"])
    t1.commit()
    if set(t1.decision.victims) & set(t2.decision.victims):
        with pytest.raises(TransactionError):
            t2.commit()


def test_rejected_decision_is_falsy_and_commits_as_noop():
    cluster = Cluster(RTX4090_SERVER, 1)
    sched = TopoScheduler(cluster, engine="imp")
    while sched.schedule(WL1["B"]):
        pass
    before = snapshot(cluster)
    dec = sched.schedule_or_preempt(WL1["B"])   # nothing preemptible below B
    assert isinstance(dec, SchedulingDecision)
    assert dec.rejected and not dec
    assert snapshot(cluster) == before


def test_plan_batch_composes_against_one_snapshot():
    cluster, sched = fig3_cluster()
    before = snapshot(cluster)
    txns = sched.plan_batch([WL1["B"], WL1["B"], WL1["A"]])
    assert [t.decision.kind for t in txns] == ["preempted"] * 3
    assert snapshot(cluster) == before          # planning mutated nothing
    # later plans saw earlier planned evictions: no victim is claimed twice
    all_victims = [uid for t in txns for uid in t.decision.victims]
    assert len(all_victims) == len(set(all_victims))
    for t in txns:
        t.commit()                              # the batch commits cleanly
    counts = cluster.count_by_workload()
    assert counts["A"] == 2 and counts["B"] == 8


def test_plan_batch_later_plan_preempts_earlier_planned_bind():
    """A later plan in the batch may pick an earlier plan's (still virtual)
    bind as a victim; commit must resolve the virtual uid to the real one."""
    from repro.core import table3_workloads

    wl3 = {w.name: w for w in table3_workloads()}
    cluster = Cluster(RTX4090_SERVER, 1)
    sched = TopoScheduler(cluster, engine="imp")
    for _ in range(6):                      # 6 GPUs of preemptible D work
        assert sched.schedule(wl3["D"])
    # batch: C (2 GPUs, fills the node) then A (needs all 8 -> must evict
    # every D AND the C planned one line above)
    txns = sched.plan_batch([wl3["C"], wl3["A"]])
    kinds = [t.decision.kind for t in txns]
    assert kinds == ["placed", "preempted"]
    assert any(uid < 0 for uid in txns[1].decision.victims)  # virtual ref
    for t in txns:
        dec = t.commit()                    # must not raise TransactionError
        assert dec
    assert all(uid >= 0 for uid in txns[1].decision.victims)
    assert cluster.count_by_workload() == {"A": 1}


def test_plan_batch_matches_sequential_commits():
    seq_cluster, seq_sched = fig3_cluster()
    seq = [seq_sched.schedule_or_preempt(WL1["B"]) for _ in range(2)]
    bat_cluster, bat_sched = fig3_cluster()
    bat = [t.commit() for t in bat_sched.plan_batch([WL1["B"]] * 2)]
    assert [(d.kind, d.node, d.victims) for d in seq] == \
        [(d.kind, d.node, d.victims) for d in bat]
    assert snapshot(seq_cluster) == snapshot(bat_cluster)


def test_agent_fleet_watches_transactions():
    """Commits/rollbacks drive event-driven CRD patches on touched nodes."""
    cluster, sched = fig3_cluster()
    fleet = AgentFleet(cluster)
    fleet.watch(sched)
    fleet.scan_all()                 # settle initial state
    base = fleet.store.patch_count
    txn = sched.plan(WL1["A"])       # planning alone patches nothing
    assert fleet.store.patch_count == base
    dec = txn.commit()
    assert fleet.store.patch_count > base
    crd = fleet.store.get(f"node-{dec.node}")
    users = {g["usedBy"] for g in crd["status"]["gpus"] if g["usedBy"]}
    assert dec.instance.name in users
    after_commit = fleet.store.patch_count
    txn.rollback()
    assert fleet.store.patch_count > after_commit
