"""Request-level elastic co-location: ReplicaSlots slot/KV accounting under
tier degradation, SLOMonitor hysteresis, the two-level ladder's
eject-before-preempt ordering at peak ramps, instance demotion ahead of a
ramp scale-up, and the two-level day cycle's determinism + A/B direction."""
import itertools

from repro.core.colocation import (ColocationConfig, ColocationSim,
                                   compare_two_level, default_policies,
                                   run_day_cycle)
from repro.core.perfmodel import TIER_PERF
from repro.serving.elastic import (ElasticConfig, ElasticPool, ReplicaSlots,
                                   SLOMonitor, max_offline_share,
                                   predicted_tpot_ms, predicted_ttft_ms)

WORST = TIER_PERF[2] / TIER_PERF[0]          # Fig. 2 cross-socket, 0.3125


def two_level_config(**kw) -> ColocationConfig:
    base = dict(num_nodes=8, seed=0, engine="imp", horizon_hours=12.0,
                elastic=True, elastic_cfg=ElasticConfig())
    base.update(kw)
    return ColocationConfig(**base)


# ---- ReplicaSlots accounting -------------------------------------------------------

def test_replica_slots_kv_binds_before_slot_headroom():
    cfg = ElasticConfig()                    # offline_ctx_factor=2.0
    rs = ReplicaSlots(1, "A", 8, 1.0, cfg)
    assert rs.total_slots == cfg.slots_per_gpu * 8
    rs.set_load(0.5)
    assert rs.online_slots == rs.total_slots // 2
    # full SLO share: slot headroom would allow total/2, but each offline
    # slot carries 2x the KV footprint, so the KV budget halves it
    spare = rs.spare_slots(1.0)
    assert spare == rs.total_slots // 4
    assert spare < rs.total_slots - rs.online_slots
    # grants consume both accounts
    rs.jobs[7] = spare
    assert rs.spare_slots(1.0) == 0
    assert rs.kv_headroom_slots() == 0
    assert rs.overflow_slots(1.0) == 0
    # load rise pushes the same grant into overflow
    rs.set_load(1.0)
    assert rs.overflow_slots(1.0) == spare


def test_tier_degradation_shrinks_share_and_rate():
    cfg = ElasticConfig()
    # NUMA-local replica affords full share at mid load...
    assert max_offline_share(cfg, 1.0, 0.5) == 1.0
    # ...the worst Fig. 2 tier affords none (guard * slo * 0.3125 < 1)
    assert max_offline_share(cfg, WORST, 0.5) == 0.0
    full = ReplicaSlots(1, "B", 4, 1.0, cfg)
    degraded = ReplicaSlots(2, "B", 4, WORST, cfg)
    assert degraded.rate(8, 2) == full.rate(8, 2) * WORST
    # predictions scale the same way (shared interference model)
    assert (predicted_tpot_ms(cfg, WORST, 0.5)
            == predicted_tpot_ms(cfg, 1.0, 0.5) / WORST)
    assert (predicted_ttft_ms(cfg, WORST, 0.5, 0.5)
            == predicted_ttft_ms(cfg, 1.0, 0.5, 0.5) / WORST)


def test_pool_ejects_youngest_grant_first():
    cfg = ElasticConfig()
    pool = ElasticPool(cfg, SLOMonitor(cfg))
    pool.register(1, "A", 8, 1.0)
    assert pool.admit(101, 1) is not None
    assert pool.admit(102, 1) is not None
    ejected = pool.set_load(1.0)             # peak: online reclaims all slots
    assert ejected == [102, 101]             # youngest (highest jid) first
    assert pool.hosted() == 0


# ---- SLOMonitor hysteresis ---------------------------------------------------------

def test_slo_monitor_trips_after_breach_ticks_and_recovers_after_window():
    cfg = ElasticConfig()                    # breach_ticks=2, window=6
    mon = SLOMonitor(cfg)
    bad = cfg.tpot_target_ms * 2
    ok = cfg.base_tpot_ms
    uid = 5
    assert not mon.observe("A", uid, ok, bad)
    assert not mon.violated(uid), "one breach must not trip"
    assert mon.allowed_share(uid, 1.0, 0.2) > 0
    mon.observe("A", uid, ok, bad)
    assert mon.violated(uid), "breach_ticks consecutive breaches trip"
    assert mon.allowed_share(uid, 1.0, 0.2) == 0.0
    # hysteresis: a tripped replica stays drained through window-1 cleans
    for _ in range(cfg.window - 1):
        mon.observe("A", uid, ok, ok)
        assert mon.violated(uid)
    mon.observe("A", uid, ok, ok)
    assert not mon.violated(uid), "full clean window recovers"
    counts = mon.drain_counts()["A"]
    assert counts["violations"] == 2
    assert counts["total"] == 2 + cfg.window
    assert counts["ok"] == cfg.window
    assert mon.drain_counts() == {}, "drain resets the row"


def test_breach_interrupted_by_clean_sample_does_not_trip():
    cfg = ElasticConfig()
    mon = SLOMonitor(cfg)
    bad, ok = cfg.tpot_target_ms * 2, cfg.base_tpot_ms
    mon.observe("A", 1, ok, bad)
    mon.observe("A", 1, ok, ok)              # resets the breach run
    mon.observe("A", 1, ok, bad)
    assert not mon.violated(1)


# ---- the two-level ladder in the day cycle -----------------------------------------

def test_peak_ramp_ejects_requests_before_preempting_instances():
    """Reversed ladder: within every tick, request-level ejection
    (`pool.set_load`) runs before the scale executor can preempt."""
    cfg = two_level_config()
    sim = ColocationSim(cfg, policies=default_policies(cfg))
    order: list[tuple[float, str]] = []
    pool_set_load, scale_to = sim.pool.set_load, sim.auto.scale_to

    def spy_set_load(load):
        order.append((sim._now, "a_eject"))
        return pool_set_load(load)

    def spy_scale_to(pol, want, hour=0.0):
        order.append((sim._now, "b_scale"))
        return scale_to(pol, want, hour)

    sim.pool.set_load = spy_set_load
    sim.auto.scale_to = spy_scale_to
    rep = sim.run()
    assert rep.elastic_admitted > 0, "scenario must exercise the pool"
    ticks = 0
    for _, group in itertools.groupby(order, key=lambda e: e[0]):
        kinds = [k for _, k in group]
        if "a_eject" in kinds and "b_scale" in kinds:
            ticks += 1
            assert kinds == sorted(kinds), \
                "ejection must precede the scale executor in a tick"
    assert ticks > 0


def test_ramp_demotes_instances_instead_of_preempting():
    """The same seeded day: instance-only preempts at the ramp, the
    two-level ladder demotes offline instances into request slots and the
    preemption never happens."""
    ab = compare_two_level(ColocationConfig(num_nodes=8, seed=0, engine="imp",
                                            horizon_hours=12.0))
    io, tl = ab["reports"]["instance_only"], ab["reports"]["two_level"]
    assert io.preemptions > 0, "baseline must exercise preemption"
    assert tl.preemptions < io.preemptions
    assert tl.elastic_demoted > 0
    assert tl.requeued < io.requeued
    # demoted jobs keep running: goodput strictly rises, SLO no worse
    assert ab["goodput_uplift"] > 0
    assert tl.slo_attainment >= io.slo_attainment
    assert tl.elastic_admitted > 0 and tl.elastic_completed > 0


def test_two_level_day_metrics_deterministic():
    a = run_day_cycle(two_level_config())
    b = run_day_cycle(two_level_config())
    assert a.key_metrics() == b.key_metrics()
    assert a.elastic_admitted > 0 and a.elastic_completed > 0


def test_monitored_instance_only_run_schedules_identically():
    """elastic_cfg WITHOUT elastic=True is the monitored baseline: the SLO
    monitor observes but the ladder must not change a single decision."""
    plain = run_day_cycle(ColocationConfig(num_nodes=8, seed=0, engine="imp",
                                           horizon_hours=12.0))
    monitored = run_day_cycle(ColocationConfig(
        num_nodes=8, seed=0, engine="imp", horizon_hours=12.0,
        elastic_cfg=ElasticConfig()))
    for metric in ("scheduled_perf", "offline_goodput", "preemptions",
                   "placements", "requeued", "requeue_replanned"):
        assert getattr(monitored, metric) == getattr(plain, metric)
    assert monitored.elastic_admitted == 0
