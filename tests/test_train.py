"""Training substrate: learning happens, accumulation is exact,
checkpoint restart is bit-faithful."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models import build_model
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step
from repro.train.optim import global_norm, lr_at


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-1b", smoke=True)
    api = build_model(cfg)
    data = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                             global_batch=8, seed=0))
    return cfg, api, data


def test_loss_decreases(setup):
    cfg, api, data = setup
    tcfg = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=5,
                                     total_steps=60))
    step = jax.jit(make_train_step(api, tcfg), donate_argnums=(0,))
    state = init_train_state(api, jax.random.PRNGKey(0))
    losses = []
    for i in range(35):
        batch = {"tokens": jnp.asarray(data.batch_at(i)["tokens"])}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses


def test_grad_accum_matches_full_batch(setup):
    """Accumulated microbatch gradients equal the full-batch gradient.

    Compared at the GRADIENT level: post-Adam params are ill-conditioned for
    this (where a grad is ~0, m/sqrt(v) amplifies fp reassociation noise to
    O(1), so updates may differ by ~lr on isolated elements regardless of
    how exact the accumulation is)."""
    from repro.train.step import _split_microbatches

    cfg, api, data = setup
    state = init_train_state(api, jax.random.PRNGKey(1))
    batch = {"tokens": jnp.asarray(data.batch_at(0)["tokens"])}
    grad_fn = jax.jit(jax.grad(lambda p, b: api.loss(p, b)[0]))
    full = grad_fn(state["params"], batch)
    for accum in (2, 4):
        mbs = _split_microbatches(batch, accum)
        acc = jax.tree.map(jnp.zeros_like, full)
        for i in range(accum):
            mb = jax.tree.map(lambda x: x[i], mbs)
            g = grad_fn(state["params"], mb)
            acc = jax.tree.map(jnp.add, acc, g)
        acc = jax.tree.map(lambda g: g / accum, acc)
        # bf16 forward: reassociating the batch slices perturbs O(1)-magnitude
        # grad elements by up to ~2*eps_bf16 (|delta| <= 0.02 observed)
        for a, b in zip(jax.tree.leaves(acc), jax.tree.leaves(full)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-2, rtol=5e-2)
    # and the train_step losses agree across accumulation settings
    losses = {}
    for accum in (1, 2, 4):
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1),
                           accum_steps=accum)
        step = jax.jit(make_train_step(api, tcfg))
        _, m = step(state, batch)
        losses[accum] = float(m["loss"])
    assert losses[1] == pytest.approx(losses[2], rel=2e-3)
    assert losses[1] == pytest.approx(losses[4], rel=2e-3)


def test_lr_schedule():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                   min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.int32(0))) < 2e-4
    assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_clip_by_global_norm():
    from repro.train.optim import clip_by_global_norm

    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(700.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_restart_is_bit_faithful(tmp_path, setup):
    """Crash/restart equivalence: train 6 steps straight == train 3, save,
    restore, train 3 more (same data stream)."""
    cfg, api, _ = setup
    from repro.checkpoint import CheckpointManager

    data = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                             global_batch=8, seed=9))
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2))
    step = jax.jit(make_train_step(api, tcfg))

    state = init_train_state(api, jax.random.PRNGKey(7))
    for i in range(6):
        state, _ = step(state, {"tokens": jnp.asarray(
            data.batch_at(i)["tokens"])})
    straight = jax.tree.leaves(state["params"])[0]

    state = init_train_state(api, jax.random.PRNGKey(7))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for i in range(3):
        state, _ = step(state, {"tokens": jnp.asarray(
            data.batch_at(i)["tokens"])})
    mgr.save(state, 3, extra={"data": {"step": 3}})
    template = jax.eval_shape(lambda: state)
    restored, meta = mgr.restore_latest(template)
    assert meta["step"] == 3
    for i in range(3, 6):
        restored, _ = step(restored, {"tokens": jnp.asarray(
            data.batch_at(i)["tokens"])})
    np.testing.assert_array_equal(np.asarray(straight),
                                  np.asarray(
                                      jax.tree.leaves(restored["params"])[0]))
