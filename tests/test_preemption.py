"""IMP (Algorithm 2) correctness: minimality, engine equivalence, scoring."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import preemption, preemption_jax
from repro.core.cluster import Cluster
from repro.core.placement import Placement
from repro.core.scoring import Candidate, score, select_best
from repro.core.simulator import SimConfig, build_saturated_cluster
from repro.core.topology import RTX4090_SERVER
from repro.core.workload import WorkloadSpec, table3_workloads

WLS = {w.name: w for w in table3_workloads()}


def random_cluster(seed: int, nodes: int = 4) -> Cluster:
    import random

    rng = random.Random(seed)
    cluster = Cluster(RTX4090_SERVER, nodes)
    d = WLS["D"]
    c = WLS["C"]
    for node in range(nodes):
        free = list(range(8))
        rng.shuffle(free)
        while free:
            if len(free) >= 2 and rng.random() < 0.4:
                g = [free.pop(), free.pop()]
                wl = c
            else:
                g = [free.pop()]
                wl = d
            if rng.random() < 0.2:
                continue  # leave a hole
            mask = sum(1 << x for x in g)
            cluster.bind(wl, node, Placement(mask, mask, 0))
    return cluster


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), wl_name=st.sampled_from(["A", "B", "C"]))
def test_imp_matches_bruteforce_min_k(seed, wl_name):
    """Algorithm 2 early-stop returns exactly the brute-force minimal size,
    and the same feasible set of candidates at that size."""
    cluster = random_cluster(seed)
    wl = WLS[wl_name]
    for node in range(cluster.num_nodes):
        brute = preemption.brute_force_min_k(cluster, wl, node)
        imp = preemption.flextopo_imp(cluster, wl, node)
        if brute is None:
            assert imp == []
        else:
            k, cands = brute
            assert {c.victims for c in imp} == {c.victims for c in cands}
            assert all(len(c.victims) == k for c in imp)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), wl_name=st.sampled_from(["A", "B"]))
def test_engines_agree(seed, wl_name):
    """python IMP == vectorized == batched == pallas engines."""
    from repro.kernels.topo_score import flextopo_imp_pallas

    cluster = random_cluster(seed, nodes=3)
    wl = WLS[wl_name]
    nodes = list(range(cluster.num_nodes))
    key = lambda cs: sorted((c.node, c.victims, c.tier, c.priority_sum)
                            for c in cs)
    py = key(c for n in nodes for c in preemption.flextopo_imp(cluster, wl, n))
    vec = key(c for n in nodes
              for c in preemption_jax.flextopo_imp_vectorized(cluster, wl, n))
    bat = key(preemption_jax.source_candidates_batched(cluster, wl, nodes))
    pls = key(c for n in nodes for c in flextopo_imp_pallas(cluster, wl, n))
    assert py == vec == bat == pls


def test_imp_subset_of_exhaustive():
    cluster = random_cluster(123)
    wl = WLS["B"]
    for node in range(cluster.num_nodes):
        imp = {c.victims for c in preemption.flextopo_imp(cluster, wl, node)}
        exh = {c.victims
               for c in preemption.flextopo_exhaustive(cluster, wl, node)}
        assert imp <= exh
        if exh:
            assert min(len(v) for v in exh) == min(len(v) for v in imp)


def test_godel_ignores_topology():
    cluster = random_cluster(7)
    wl = WLS["B"]
    for node in range(cluster.num_nodes):
        c = preemption.godel_standard(cluster, wl, node)
        if c is None:
            continue
        # victims are the lowest-priority ones, greedily
        victims = cluster.victims_on(node, wl.priority)
        chosen = [v for v in victims if v.uid in c.victims]
        others = [v for v in victims if v.uid not in c.victims]
        if chosen and others:
            assert max(v.priority for v in chosen) <= min(
                v.priority for v in others)


def test_eq1_alpha_extremes():
    low_prio_bad_topo = Candidate(0, (1,), tier=2, priority_sum=200)
    high_prio_good_topo = Candidate(0, (2,), tier=0, priority_sum=1000)
    # alpha=1: priority only -> prefers evicting low priority
    assert select_best([low_prio_bad_topo, high_prio_good_topo],
                       alpha=1.0) == low_prio_bad_topo
    # alpha=0: topology only -> prefers NUMA-aligned candidate
    assert select_best([low_prio_bad_topo, high_prio_good_topo],
                       alpha=0.0) == high_prio_good_topo
    assert score(low_prio_bad_topo, 1.0) == pytest.approx(1 / 200)
    assert score(high_prio_good_topo, 0.0) == pytest.approx(1.0)
