import os
import sys

# Tests run on the single real CPU device; ONLY the dry-run uses 512
# placeholder devices (launch/dryrun.py sets XLA_FLAGS itself, in a
# subprocess).  Keep this file free of XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
