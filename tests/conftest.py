import importlib.util
import os
import sys

# Tests run on the single real CPU device; ONLY the dry-run uses 512
# placeholder devices (launch/dryrun.py sets XLA_FLAGS itself, in a
# subprocess).  Keep this file free of XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property-based tests need hypothesis; skip those modules (instead of
# erroring at collection) in minimal environments without it.
collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore = ["test_kernels.py", "test_placement.py",
                      "test_preemption.py"]
