"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.topology import A100_SERVER, RTX4090_SERVER
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_ref, topo_score_ref
from repro.kernels.topo_score import TopoRequest, topo_score_pallas


# ---------------------------------------------------------------------------------
# topo_score
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [RTX4090_SERVER, A100_SERVER],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("need", [(1, 1), (2, 2), (4, 4), (8, 8)])
def test_topo_score_matches_ref(spec, need):
    g, c = need
    rng = np.random.default_rng(g * 7 + spec.num_numa)
    n = 700  # deliberately not a tile multiple (padding path)
    cg = jnp.asarray(rng.integers(0, spec.all_gpu_mask + 1, n), jnp.int32)
    cc = jnp.asarray(rng.integers(0, spec.all_cg_mask + 1, n), jnp.int32)
    pr = jnp.asarray(rng.integers(0, 3000, n), jnp.int32)
    req = TopoRequest(g, c, c // g, alpha=0.5)
    t_k, s_k = topo_score_pallas(cg, cc, pr, spec, req)
    t_r, s_r = topo_score_ref(cg, cc, pr, spec, g, c, c // g, 0.5)
    assert np.array_equal(np.asarray(t_k), np.asarray(t_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(masks=st.lists(st.tuples(st.integers(0, 255), st.integers(0, 255),
                                st.integers(0, 4000)),
                      min_size=1, max_size=40),
       g=st.sampled_from([1, 2, 4]), alpha=st.sampled_from([0.0, 0.5, 1.0]))
def test_topo_score_property(masks, g, alpha):
    spec = RTX4090_SERVER
    arr = np.array(masks, np.int32)
    req = TopoRequest(g, g, 1, alpha=alpha)
    t_k, s_k = topo_score_pallas(jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
                                 jnp.asarray(arr[:, 2]), spec, req)
    t_r, s_r = topo_score_ref(jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1]),
                              jnp.asarray(arr[:, 2]), spec, g, g, 1, alpha)
    assert np.array_equal(np.asarray(t_k), np.asarray(t_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


# ---------------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------------

SHAPES = [
    # B, H, K, Sq, Sk, d, causal, window
    (2, 4, 2, 128, 128, 32, True, None),
    (1, 4, 1, 200, 200, 16, True, None),      # MQA + padding path
    (2, 2, 2, 96, 96, 64, True, 32),          # sliding window
    (1, 8, 4, 64, 256, 32, False, None),      # bidirectional, Sq != Sk
    (1, 2, 2, 257, 257, 16, True, 100),       # odd lengths + window
]


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s[:6]) for s in SHAPES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_flash_attention_matches_ref(shape, dtype):
    B, H, K, Sq, Sk, d, causal, window = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, Sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((B, K, Sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((B, K, Sk, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    ref = mha_ref(q, k, v, causal=causal, window=window)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_block_shape_invariance():
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    outs = [np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bk))
            for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's XLA attention path (einsum+softmax)."""
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = get_config("llama3.2-1b", smoke=True)
    rng = np.random.default_rng(2)
    B, S = 2, 64
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                    cfg.compute_dtype)
    p = A.attn_init(jax.random.PRNGKey(0), cfg)
    q, k, v = A._project_qkv(p, cfg, x)
    # compare the two implementations in f32 (bf16 softmax noise amplifies
    # through near-tied scores; semantic agreement is what's under test)
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    mask = A.make_mask(S, S, causal=True)
    xla = A._gqa_attend(p, cfg, q, k, v, mask)
    tr = lambda t: jnp.moveaxis(t, 1, 2)     # [B,S,H,d] -> [B,H,S,d]
    flash = flash_attention(tr(q), tr(k), tr(v), causal=True,
                            block_q=32, block_k=32)
    flash_out = jnp.einsum("BSHd,HdD->BSD", jnp.moveaxis(flash, 1, 2),
                           p["wo"].astype(cfg.compute_dtype))
    np.testing.assert_allclose(np.asarray(flash_out, np.float32),
                               np.asarray(xla, np.float32), atol=3e-2,
                               rtol=3e-2)
