"""The paper's Fig. 3 walkthrough: three 4090 servers, workloads A/B/C
co-located at saturation, then a topology-aware scale-up of A.

Shows the exact failure mode of priority-only preemption (victims freed on
the wrong socket) and how FlexTopo+IMP fixes it — plus the transactional
scheduler API:

* ``sched.plan(wl)`` evaluates Filtering → Sorting → Bind against a
  copy-on-write view and returns a ``Transaction``; the cluster is untouched
  until ``txn.commit()``, and ``txn.rollback()`` restores the exact prior
  state (original victim uids and placements) after a commit.
* ``sched.plan_batch([wl, ...])`` plans several scale-ups against ONE
  snapshot so the decisions compose before anything is committed.
* ``@register_engine("name")`` plugs a custom victim-sourcing engine into
  the registry, making it a valid ``TopoScheduler(engine="name")`` choice.

  PYTHONPATH=src python examples/preemption_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core import (Cluster, RTX4090_SERVER, TopoScheduler,
                        register_engine, registered_engines, table1_workloads)
from repro.core.preemption import flextopo_imp


def gpu_map(cluster, node):
    topo = cluster.topos[node]
    cells = []
    for g in range(8):
        owner = topo.graph.nodes[("gpu", g)]["used_by"]
        cells.append((owner or "....")[:6].ljust(6))
    return " ".join(cells[:4]) + " | " + " ".join(cells[4:])


def show(cluster, title):
    print(f"\n== {title} ==")
    print("          socket 0                    | socket 1")
    for n in range(cluster.num_nodes):
        print(f"machine {n + 1}: {gpu_map(cluster, n)}")


def saturated(engine):
    wls = {w.name: w for w in table1_workloads()}
    cluster = Cluster(RTX4090_SERVER, 3)
    sched = TopoScheduler(cluster, engine=engine)
    sched.schedule(wls["A"])
    for _ in range(6):
        sched.schedule(wls["B"])
    for _ in range(8):
        sched.schedule(wls["C"])
    return cluster, sched, wls


# A custom engine is one decorated sourcing function: here, plain IMP
# restricted to even node INDICES — machines 1 and 3, say a maintenance
# policy that fences off the rest.
@register_engine("imp_even_nodes")
def imp_even_nodes(cluster, workload, node):
    return flextopo_imp(cluster, workload, node) if node % 2 == 0 else []


def main() -> None:
    for engine in ("godel", "imp"):
        cluster, sched, wls = saturated(engine)
        show(cluster, f"saturated cluster (engine={engine})")

        # two-phase: plan (pure read) ... then commit
        txn = sched.plan(wls["A"])
        dec = txn.decision
        print(f"\nscale-up A with engine={engine}: planned "
              f"{dec.kind} on machine {dec.node + 1}, victims={dec.victims}")
        txn.commit()
        print(f"  committed: evicted {[v.name for v in dec.evicted]}")
        print(f"  placement tier={dec.placement.tier} "
              f"({['NUMA', 'socket', 'cross-socket'][dec.placement.tier]}) "
              f"topology hit={dec.hit}")
        show(cluster, f"after preemption (engine={engine})")

        # rollback restores the exact pre-commit state (same victim uids)
        txn.rollback()
        show(cluster, f"after rollback (engine={engine})")
        print("-" * 70)

    # batched admission: plan 3 scale-ups against one snapshot, commit together
    cluster, sched, wls = saturated("imp")
    txns = sched.plan_batch([wls["B"], wls["B"], wls["A"]])
    print("\nplan_batch against one snapshot:",
          [(t.decision.kind, t.decision.node + 1) for t in txns])
    for t in txns:
        t.commit()
    show(cluster, "after committing the batch")

    # the registry knows every engine, including custom ones
    print("\nregistered engines:", ", ".join(registered_engines()))
    cluster, sched, wls = saturated("imp_even_nodes")
    dec = sched.preempt(wls["B"])
    print(f"custom engine chose machine {dec.node + 1} "
          f"(even node indices only), hit={dec.hit}")


if __name__ == "__main__":
    main()
