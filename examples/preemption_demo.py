"""The paper's Fig. 3 walkthrough: three 4090 servers, workloads A/B/C
co-located at saturation, then a topology-aware scale-up of A.

Shows the exact failure mode of priority-only preemption (victims freed on
the wrong socket) and how FlexTopo+IMP fixes it.

  PYTHONPATH=src python examples/preemption_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core import Cluster, RTX4090_SERVER, TopoScheduler, table1_workloads


def gpu_map(cluster, node):
    topo = cluster.topos[node]
    cells = []
    for g in range(8):
        owner = topo.graph.nodes[("gpu", g)]["used_by"]
        cells.append((owner or "....")[:6].ljust(6))
    return " ".join(cells[:4]) + " | " + " ".join(cells[4:])


def show(cluster, title):
    print(f"\n== {title} ==")
    print("          socket 0                    | socket 1")
    for n in range(cluster.num_nodes):
        print(f"machine {n + 1}: {gpu_map(cluster, n)}")


def main() -> None:
    wls = {w.name: w for w in table1_workloads()}

    for engine in ("godel", "imp"):
        cluster = Cluster(RTX4090_SERVER, 3)
        sched = TopoScheduler(cluster, engine=engine)
        sched.schedule(wls["A"])
        for _ in range(6):
            sched.schedule(wls["B"])
        for _ in range(8):
            sched.schedule(wls["C"])
        show(cluster, f"saturated cluster (engine={engine})")

        res = sched.preempt(wls["A"])
        print(f"\nscale-up A with engine={engine}:")
        print(f"  chose machine {res.node + 1}, evicted "
              f"{[v.name for v in res.evicted]}")
        print(f"  placement tier={res.placement.tier} "
              f"({['NUMA', 'socket', 'cross-socket'][res.placement.tier]}) "
              f"topology hit={res.hit}")
        show(cluster, f"after preemption (engine={engine})")
        print("-" * 70)


if __name__ == "__main__":
    main()
