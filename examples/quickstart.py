"""Quickstart: train a tiny LM end to end on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models import build_model, count_params
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def main() -> None:
    cfg = get_config("llama3.2-1b", smoke=True)
    api = build_model(cfg)
    state = init_train_state(api, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params: {count_params(state['params']):,}")

    tcfg = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=10,
                                     total_steps=100))
    step = jax.jit(make_train_step(api, tcfg), donate_argnums=(0,))
    data = SyntheticTokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                             global_batch=8, seed=0))
    first = None
    for i in range(60):
        batch = {"tokens": jnp.asarray(data.batch_at(i)["tokens"])}
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")
    final = float(m["loss"])
    print(f"loss {first:.3f} -> {final:.3f} "
          f"({'LEARNING' if final < first - 0.2 else 'check config'})")


if __name__ == "__main__":
    main()
