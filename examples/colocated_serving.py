"""End-to-end driver (the paper's kind: SERVING): co-located LLM serving
through the event-driven day cycle.

A small cluster runs the paper's §1/§2.3 scenario on the co-location event
loop (`repro.core.colocation`): diurnal online traffic scales a chat
service up and down through `AutoscalePolicy` event sources, offline batch
jobs pad the valleys via chunked ``plan_batch`` admission, the morning ramp
preempts offline victims (which re-enter the pending queue and are
replanned when capacity reopens), and every committed decision streams
through the scheduler listeners into a per-hour `ColocationReport`.

On the committed 24-node benchmark day (``BENCH_colocation.json``) the
topology-aware engine beats the topology-unaware baseline by ~9% on the
whole-day scheduled-performance integral and by ~50% on the
preemption-scheduled slice — the same direction and order as the paper's
headline 55% claim.

The same day then runs through the two-level backfill ladder
(`repro.serving.elastic` + ``ColocationConfig(elastic=True)``): valley
ticks pack pending offline jobs into online replicas' spare
continuous-batching slots under the SLO-guarded admission controller
before spinning whole offline instances, and peak ramps reverse the
ladder — eject request-level grants, then demote whole offline instances
into request slots, and only preempt what neither step absorbs.  On the
committed day (``BENCH_elastic.json``) that strictly raises offline
goodput at equal online SLO attainment with strictly fewer instance
preemptions.

After the simulated day, the best- and worst-placed online instances from
the run serve REAL batched requests through the JAX serving engine, and
the Fig. 2 factor converts measured decode throughput into scheduled
performance.

  PYTHONPATH=src python examples/colocated_serving.py
"""
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.core.colocation import (ColocationConfig, compare_day_cycle,
                                   compare_two_level, default_policies)
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine, TIER_PERF


def main() -> None:
    # ---- the simulated day: topology-aware vs topology-unaware A/B -------
    cfg = ColocationConfig(num_nodes=12, seed=0, horizon_hours=24.0)
    print(f"simulating a {cfg.horizon_hours:.0f}h day on {cfg.num_nodes} "
          f"nodes (Table 3 mix, policies: "
          f"{[p.workload.name for p in default_policies(cfg)]}) ...")
    ab = compare_day_cycle(cfg, engines=("imp", "godel"))
    for name, rep in ab["reports"].items():
        print(f"  {name:6} scheduled-perf {rep.scheduled_perf:7.1f} "
              f"GPU-h | hit rate {rep.hit_rate:.0%} over "
              f"{rep.preemptions} preemptions | requeue "
              f"{rep.requeue_replanned}/{rep.requeued} replanned | "
              f"offline goodput {rep.offline_goodput:.0f} GPU-h")
    print(f"  scheduled-performance uplift: {ab['uplift'] * 100:+.1f}% "
          f"(preemptor slice {ab['preemptor_uplift'] * 100:+.1f}%; the "
          f"paper reports +55%)")

    # ---- the two-level backfill ladder on the same seeded day -------------
    bench = Path(__file__).parent.parent / "BENCH_elastic.json"
    if bench.exists():
        b = json.loads(bench.read_text())
        io_b, tl_b = b["modes"]["instance_only"], b["modes"]["two_level"]
        print(f"\ncommitted two-level A/B ({b['num_nodes']} nodes, "
              f"BENCH_elastic.json): offline goodput "
              f"{b['goodput_uplift'] * 100:+.1f}%, SLO attainment "
              f"{tl_b['slo_attainment']:.3f} vs {io_b['slo_attainment']:.3f}, "
              f"preemptions {io_b['preemptions']} -> {tl_b['preemptions']}")
    print("two-level request+instance ladder on this day:")
    two = compare_two_level(cfg)
    for name, rep in two["reports"].items():
        extra = (f" | request-level adm {rep.elastic_admitted} "
                 f"demote {rep.elastic_demoted} "
                 f"done {rep.elastic_completed}"
                 if name == "two_level" else "")
        print(f"  {name:13} offline goodput {rep.offline_goodput:7.1f} "
              f"GPU-h | SLO attainment {rep.slo_attainment:.3f} | "
              f"{rep.preemptions} preemptions, {rep.requeued} victims"
              f"{extra}")
    print(f"  ramps absorbed at request granularity: "
          f"{two['preemption_delta']:+d} preemptions, every victim requeue "
          f"avoided (goodput {two['goodput_uplift'] * 100:+.1f}% on this "
          f"small unsaturated day; the committed saturated protocol above "
          f"is the gated number)")

    # ---- serve real tokens at the day's achieved placement tiers ----------
    aware = ab["reports"]["imp"]
    ramp = max(aware.hours, key=lambda r: r.preemptions)
    print(f"\nbusiest ramp hour {ramp.hour:.0f}: {ramp.preemptions} "
          f"preemptions, {ramp.requeued} victims requeued, "
          f"mean decision factor {ramp.decision_factor_mean:.2f}")

    cfg_m = get_config("llama3.2-1b", smoke=True)
    api = build_model(cfg_m)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def batch():
        return [Request(rid=i,
                        prompt=rng.integers(1, cfg_m.vocab, 12,
                                            dtype=np.int32),
                        max_new_tokens=8) for i in range(4)]

    engine = ServeEngine(api, params, batch_size=2, seq_len=32)
    engine.run(batch())                     # jit warm-up, excluded
    t0 = time.perf_counter()
    engine.run(batch())
    dt = time.perf_counter() - t0
    raw_tps = engine.stats["tokens"] / 2 / dt   # stats span both runs
    print("decode throughput x Fig. 2 factor per placement tier:")
    for tier in sorted(TIER_PERF):
        factor = TIER_PERF[tier]
        print(f"  tier {tier}: {raw_tps:6.1f} tok/s raw x {factor:.2f} = "
              f"{raw_tps * factor:6.1f} tok/s scheduled")


if __name__ == "__main__":
    main()
