"""End-to-end driver (the paper's kind: SERVING): co-located LLM serving
under the topology-aware scheduler.

A small cluster hosts two workloads: a high-priority online chat service
(llama-class instances) and a low-priority offline batch-inference job
(qwen-class instances), at saturation.  Diurnal traffic rises; the
autoscaler scales the online service up, the FlexTopo+IMP scheduler evicts
offline victims whose freed resources satisfy the online instances' topology
affinity, and the newly placed instances serve REAL batched requests through
the JAX serving engine.  The paper's Fig. 2 cost matrix converts each
placement tier into a 'scheduled performance' factor applied to measured
decode throughput.

  PYTHONPATH=src python examples/colocated_serving.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.core import Cluster, RTX4090_SERVER, TopoScheduler
from repro.core.workload import TopoPolicy, WorkloadSpec
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine, scheduled_factor


def main() -> None:
    online = WorkloadSpec("chat", priority=1000, gpus_per_instance=2,
                          cores_per_instance=16, preemptible=False,
                          arch="llama3.2-1b")
    offline = WorkloadSpec("batch", priority=200, gpus_per_instance=1,
                           cores_per_instance=8, preemptible=True,
                           numa_policy=TopoPolicy.NONE,
                           socket_policy=TopoPolicy.NONE, critical=False,
                           kind="offline", arch="qwen1.5-0.5b")

    cluster = Cluster(RTX4090_SERVER, 4)
    sched = TopoScheduler(cluster, engine="imp")

    # saturation allocation: 2 chat instances + offline fills the rest
    for _ in range(2):
        sched.schedule(online)
    while sched.schedule(offline):
        pass
    print("saturated:", cluster.count_by_workload())

    # build the online model ONCE (instances share weights)
    cfg = get_config(online.arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    # traffic spike: plan the +2 chat scale-up as one batch against a single
    # snapshot (HyGen-style batched admission), then commit both decisions
    decisions = []
    for txn in sched.plan_batch([online, online]):
        dec = txn.commit()
        assert not dec.rejected
        print(f"scale-up: {dec.kind} on node {dec.node} tier="
              f"{dec.placement.tier} hit={dec.hit} victims={dec.victims}")
        decisions.append(dec)

    # each placed instance serves a batch of requests
    rng = np.random.default_rng(0)
    total_tps = 0.0
    for dec in decisions:
        engine = ServeEngine(api, params, batch_size=2, seq_len=32)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab, 12, dtype=np.int32),
                        max_new_tokens=8) for i in range(4)]
        t0 = time.perf_counter()
        engine.run(reqs)
        dt = time.perf_counter() - t0
        raw_tps = engine.stats["tokens"] / dt
        factor = scheduled_factor(dec)
        total_tps += raw_tps * factor
        print(f"instance on node {dec.node}: {raw_tps:6.1f} tok/s raw x "
              f"{factor:.2f} (tier {dec.placement.tier}) = "
              f"{raw_tps * factor:6.1f} tok/s scheduled")
    print(f"\nscheduled throughput of the scale-up: {total_tps:.1f} tok/s")
    print("final cluster:", cluster.count_by_workload())


if __name__ == "__main__":
    main()
