"""Fault-tolerant training demo: checkpoint -> injected failure -> supervised
restart resumes the exact data stream and matches the uninterrupted run.

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).parent.parent


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt:
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "llama3.2-1b", "--steps", "16", "--batch", "4",
               "--seq", "64", "--ckpt-every", "5", "--log-every", "4",
               "--ckpt-dir", ckpt, "--supervise", "--fail-at", "8"]
        env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
        print("running with an injected failure at step 8 + supervisor...")
        out = subprocess.run(cmd, capture_output=True, text=True, env=env)
        print(out.stdout)
        assert "injected failure" in out.stdout or out.returncode == 0
        assert "resumed from step" in out.stdout, "supervisor did not resume!"
        print("supervisor resumed from checkpoint and finished: OK")


if __name__ == "__main__":
    main()
