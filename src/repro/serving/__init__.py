from .engine import (TIER_PERF, BatchQueue, Request, ServeEngine,
                     scheduled_factor)

__all__ = ["TIER_PERF", "BatchQueue", "Request", "ServeEngine",
           "scheduled_factor"]
