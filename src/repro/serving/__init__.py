from .engine import (TIER_PERF, BatchQueue, Request, ServeEngine,
                     relative_scheduled_factor, scheduled_factor)

__all__ = ["TIER_PERF", "BatchQueue", "Request", "ServeEngine",
           "relative_scheduled_factor", "scheduled_factor"]
