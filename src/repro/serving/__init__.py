from .engine import BatchQueue, Request, ServeEngine

__all__ = ["BatchQueue", "Request", "ServeEngine"]
