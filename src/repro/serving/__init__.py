from .elastic import (ElasticConfig, ElasticPool, ReplicaSlots, SLOMonitor,
                      max_offline_share, predicted_tpot_ms, predicted_ttft_ms)
from .engine import (TIER_PERF, BatchQueue, Request, RequestQueue,
                     ServeEngine, relative_scheduled_factor, scheduled_factor)

__all__ = ["TIER_PERF", "BatchQueue", "Request", "RequestQueue",
           "ServeEngine", "relative_scheduled_factor", "scheduled_factor",
           "ElasticConfig", "ElasticPool", "ReplicaSlots", "SLOMonitor",
           "max_offline_share", "predicted_tpot_ms", "predicted_ttft_ms"]
