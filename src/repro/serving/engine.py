"""Serving engine: request batching + prefill/decode loop.

One ServeEngine corresponds to one scheduler *instance* from the paper's
co-location model: the topology-aware scheduler places/preempts instances,
and each instance runs this engine.  The continuous-batching queue pads
requests to a fixed batch and runs jit'd prefill + decode steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import cache_capacity
from repro.core.placement import min_tier_for
from repro.models.api import ModelApi

# Paper Fig. 2: relative communication cost per placement tier converted to a
# scheduled-performance multiplier (NUMA-local = 1.0, same-socket, cross-socket).
TIER_PERF = {0: 1.0, 1: 10 / 12, 2: 10 / 32}


def scheduled_factor(decision) -> float:
    """Fig. 2 performance multiplier for a committed `SchedulingDecision`.

    Raw engine throughput times this factor gives the paper's "scheduled
    performance" of the instance at its placement tier.  Rejected decisions
    (no placement) score 0.
    """
    if decision.placement is None:
        return 0.0
    return TIER_PERF[decision.placement.tier]


def relative_scheduled_factor(spec, tier: int, need_gpus: int) -> float:
    """Fig. 2 factor normalized by the best tier ``need_gpus`` can
    physically achieve on the SKU.

    A full-node instance necessarily spans sockets and serves at 1.0 when
    it does, while a small instance misplaced across sockets is charged the
    full cross-socket/NUMA-local cost ratio — so degradation measures
    scheduling quality, not instance size.  This is the per-instance rate
    the co-location day cycle (`repro.core.colocation`) integrates into its
    scheduled-performance metric.
    """
    return TIER_PERF.get(tier, 0.0) / TIER_PERF[min_tier_for(spec, need_gpus)]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchQueue:
    """Pads pending requests into fixed [B, S] prompt batches."""

    def __init__(self, batch_size: int, seq_len: int) -> None:
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pending: list[Request] = []

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def next_batch(self) -> list[Request] | None:
        if not self.pending:
            return None
        batch = self.pending[:self.batch_size]
        self.pending = self.pending[self.batch_size:]
        return batch

    def pad_prompts(self, batch: list[Request]) -> np.ndarray:
        out = np.zeros((self.batch_size, self.seq_len), np.int32)
        for i, r in enumerate(batch):
            s = min(len(r.prompt), self.seq_len)
            out[i, -s:] = r.prompt[:s]        # left-pad (decode continues right)
        return out


class ServeEngine:
    def __init__(self, api: ModelApi, params: Any, batch_size: int,
                 seq_len: int, donate_cache: bool = True) -> None:
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.batch_size = batch_size
        self.seq_len = seq_len
        # the cache must hold the modality prefix in addition to the text
        prefix = self.cfg.frontend_len if self.cfg.frontend == "patch" else 0
        self.capacity = cache_capacity(self.cfg, prefix + seq_len)
        cap = self.capacity
        self._prefill = jax.jit(lambda p, b: api.prefill(p, b, cap))
        self._decode = jax.jit(
            api.decode_step,
            donate_argnums=(1,) if donate_cache else (),
        )
        self.queue = BatchQueue(batch_size, seq_len)
        self.stats = {"prefill_s": [], "decode_s": [], "tokens": 0}

    def _make_batch(self, prompts: np.ndarray) -> dict:
        batch: dict[str, Any] = {"tokens": jnp.asarray(prompts)}
        B = prompts.shape[0]
        if self.cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (B, self.seq_len, self.cfg.d_model), self.cfg.compute_dtype)
        elif self.cfg.frontend == "patch":
            batch["prefix_embeds"] = jnp.zeros(
                (B, self.cfg.frontend_len, self.cfg.d_model),
                self.cfg.compute_dtype)
        return batch

    def run(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        for r in requests:
            self.queue.submit(r)
        while True:
            group = self.queue.next_batch()
            if group is None:
                break
            prompts = self.queue.pad_prompts(group)
            t0 = time.perf_counter()
            logits, caches = jax.block_until_ready(
                self._prefill(self.params, self._make_batch(prompts)))
            self.stats["prefill_s"].append(time.perf_counter() - t0)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            steps = max(r.max_new_tokens for r in group)
            prefix = (self.cfg.frontend_len if self.cfg.frontend == "patch"
                      else 0)
            pos = prefix + prompts.shape[1]
            for t in range(steps):
                for i, r in enumerate(group):
                    if t < r.max_new_tokens:
                        r.output.append(int(tok[i]))
                t0 = time.perf_counter()
                logits, caches = jax.block_until_ready(
                    self._decode(self.params, caches, tok,
                                 jnp.int32(pos + t)))
                self.stats["decode_s"].append(time.perf_counter() - t0)
                self.stats["tokens"] += len(group)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r in group:
                r.done = True
        return requests
