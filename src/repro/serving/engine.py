"""Serving engine: request batching + prefill/decode loop.

One ServeEngine corresponds to one scheduler *instance* from the paper's
co-location model: the topology-aware scheduler places/preempts instances,
and each instance runs this engine.  The continuous-batching queue pads
requests to a fixed batch and runs jit'd prefill + decode steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import cache_capacity
# Fig. 2 tier-performance model: single source of truth is
# repro.core.perfmodel (the day-cycle integral and the elastic layer's SLO
# monitor consume the same constants); these are compat re-exports.
from repro.core.perfmodel import (TIER_PERF, relative_scheduled_factor,
                                  scheduled_factor)
from repro.models.api import ModelApi

__all__ = ["TIER_PERF", "scheduled_factor", "relative_scheduled_factor",
           "Request", "RequestQueue", "BatchQueue", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class RequestQueue:
    """Pads pending requests into fixed [B, S] prompt batches.

    With ``flush_after > 0`` the queue holds a partial batch back and waits
    for a full ``batch_size`` (full batches amortize the jit'd prefill), but
    only up to the age threshold: once the HEAD request has waited
    ``flush_after`` seconds, the partial batch is released padded.  This
    fixes the head-of-line stall where a sub-``batch_size`` tail could wait
    forever behind an empty arrival stream — the elastic co-location layer
    (`repro.serving.elastic`) relies on it to drain ejected offline
    requests that will never be topped up to a full batch.  ``flush=True``
    forces the partial batch out regardless of age (the synchronous
    ``ServeEngine.run`` drain).  ``flush_after=0`` keeps the legacy eager
    behavior: partial batches are served immediately.
    """

    def __init__(self, batch_size: int, seq_len: int,
                 flush_after: float = 0.0, clock=time.monotonic) -> None:
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.flush_after = flush_after
        self.clock = clock
        self.pending: list[Request] = []
        self._arrived: list[float] = []     # aligned with ``pending``

    def __len__(self) -> int:
        return len(self.pending)

    def submit(self, req: Request) -> None:
        self.pending.append(req)
        self._arrived.append(self.clock())

    def head_age(self) -> float:
        """Seconds the oldest pending request has waited (0 if empty)."""
        return self.clock() - self._arrived[0] if self.pending else 0.0

    def next_batch(self, flush: bool = False) -> list[Request] | None:
        if not self.pending:
            return None
        if (len(self.pending) < self.batch_size and not flush
                and self.flush_after > 0
                and self.head_age() < self.flush_after):
            return None                     # wait for a full batch, bounded
        batch = self.pending[:self.batch_size]
        self.pending = self.pending[self.batch_size:]
        self._arrived = self._arrived[self.batch_size:]
        return batch

    def pad_prompts(self, batch: list[Request]) -> np.ndarray:
        out = np.zeros((self.batch_size, self.seq_len), np.int32)
        for i, r in enumerate(batch):
            s = min(len(r.prompt), self.seq_len)
            out[i, -s:] = r.prompt[:s]        # left-pad (decode continues right)
        return out


#: compat alias — the eager (flush_after=0) behavior is the old BatchQueue
BatchQueue = RequestQueue


class ServeEngine:
    def __init__(self, api: ModelApi, params: Any, batch_size: int,
                 seq_len: int, donate_cache: bool = True) -> None:
        self.api = api
        self.cfg = api.cfg
        self.params = params
        self.batch_size = batch_size
        self.seq_len = seq_len
        # the cache must hold the modality prefix in addition to the text
        prefix = self.cfg.frontend_len if self.cfg.frontend == "patch" else 0
        self.capacity = cache_capacity(self.cfg, prefix + seq_len)
        cap = self.capacity
        self._prefill = jax.jit(lambda p, b: api.prefill(p, b, cap))
        self._decode = jax.jit(
            api.decode_step,
            donate_argnums=(1,) if donate_cache else (),
        )
        self.queue = RequestQueue(batch_size, seq_len)
        self.stats = {"prefill_s": [], "decode_s": [], "tokens": 0}

    def _make_batch(self, prompts: np.ndarray) -> dict:
        batch: dict[str, Any] = {"tokens": jnp.asarray(prompts)}
        B = prompts.shape[0]
        if self.cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (B, self.seq_len, self.cfg.d_model), self.cfg.compute_dtype)
        elif self.cfg.frontend == "patch":
            batch["prefix_embeds"] = jnp.zeros(
                (B, self.cfg.frontend_len, self.cfg.d_model),
                self.cfg.compute_dtype)
        return batch

    def run(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        for r in requests:
            self.queue.submit(r)
        while True:
            # synchronous drain: flush partial tails instead of waiting for
            # arrivals that will never come (RequestQueue HOL-stall fix)
            group = self.queue.next_batch(flush=True)
            if group is None:
                break
            prompts = self.queue.pad_prompts(group)
            t0 = time.perf_counter()
            logits, caches = jax.block_until_ready(
                self._prefill(self.params, self._make_batch(prompts)))
            self.stats["prefill_s"].append(time.perf_counter() - t0)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            steps = max(r.max_new_tokens for r in group)
            prefix = (self.cfg.frontend_len if self.cfg.frontend == "patch"
                      else 0)
            pos = prefix + prompts.shape[1]
            for t in range(steps):
                for i, r in enumerate(group):
                    if t < r.max_new_tokens:
                        r.output.append(int(tok[i]))
                t0 = time.perf_counter()
                logits, caches = jax.block_until_ready(
                    self._decode(self.params, caches, tok,
                                 jnp.int32(pos + t)))
                self.stats["decode_s"].append(time.perf_counter() - t0)
                self.stats["tokens"] += len(group)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r in group:
                r.done = True
        return requests
