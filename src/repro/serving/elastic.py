"""Request-level elastic co-location (HyGen lineage): the level-1 layer of
the two-level scheduler.

The paper's preemption is *instance*-granular: a traffic valley is filled by
spinning up whole offline instances and a peak reclaims them by killing
instances, so valley capacity smaller than one instance is wasted and every
ramp pays full preemption + requeue cost.  This module fills valleys at
*request* granularity instead: offline requests are interleaved into online
replicas' spare continuous-batching slots under a latency-SLO interference
bound, and are drained/ejected — degrade-before-kill — the moment the bound
is predicted to break.

Three pieces, sitting between the day cycle (`repro.core.colocation`) and
the per-instance `ServeEngine`:

* `ReplicaSlots`   — per-online-replica accounting of continuous-batching
  slots and KV-cache headroom (`configs.shapes.cache_capacity` over the
  replica's slot budget; offline requests carry a larger KV footprint) at
  the replica's ACHIEVED placement tier.
* `SLOMonitor`     — sliding-window per-class TTFT/TPOT targets with
  tier-aware service rates (`repro.core.perfmodel.relative_scheduled_factor`
  feeds the same Fig. 2 factor the day-cycle integral uses).  Violation
  detection trips after ``breach_ticks`` consecutive breaches and recovers
  with hysteresis only after a full clean window, so a replica flapping on
  the SLO boundary is drained and *stays* drained.
* `ElasticPool`    — the admission controller: injects offline requests
  into spare slots only while the monitor predicts the interference stays
  inside the bound, and ejects them (youngest first, whole requests) when
  online load reclaims slots, KV headroom shrinks, or the monitor trips.

The level-2 ladder lives in `repro.core.colocation`: each valley tick first
packs pending offline work into request slots through this pool and only
spins up whole offline instances for the residual; peak ramps reverse the
ladder (eject request-level work before preempting instances).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.configs.shapes import cache_capacity
from repro.core.perfmodel import TIER_PERF


@dataclasses.dataclass(frozen=True)
class _KVShape:
    """The slice of ModelConfig that `cache_capacity` reads."""
    swa_window: int | None = None


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Knobs of the request-level elastic layer (frozen so A/B runs share
    it via ``dataclasses.replace``)."""

    #: continuous-batching slots one GPU of an online replica contributes
    slots_per_gpu: int = 4
    #: per-slot context budget the KV cache is sized for
    seq_len: int = 4096
    #: sliding-window attention bound (None = full cache), fed to
    #: `configs.shapes.cache_capacity`
    swa_window: int | None = None
    #: KV footprint of an offline request relative to an online slot
    #: (offline batch work carries longer contexts), so KV headroom binds
    #: before slot headroom does
    offline_ctx_factor: float = 2.0
    #: an offline request is admitted only if it gets at least this fraction
    #: of its full slot demand (running a 2-GPU job on one slot is waste)
    min_slot_fraction: float = 0.25
    #: spare-slot GPU-equivalence discount (interleaved offline tokens ride
    #: leftover batch capacity, not dedicated GPUs)
    efficiency: float = 0.85
    #: interference-free NUMA-local service times
    base_tpot_ms: float = 50.0
    base_ttft_ms: float = 400.0
    #: per-class targets as multiples of the base times; defaults clear the
    #: worst Fig. 2 tier (1/0.3125 = 3.2x) so tier degradation alone does
    #: not violate — interference beyond the admission guard does
    tpot_slo: float = 3.5
    ttft_slo: float = 8.0
    #: relative TPOT/TTFT inflation at offline share 1.0
    interference: float = 0.35
    #: admit only while the prediction stays below guard * target
    guard: float = 0.9
    #: sliding SLO window length (ticks) — a tripped replica re-admits only
    #: after a full window of clean samples (hysteresis)
    window: int = 6
    #: consecutive breaches before the monitor trips a replica
    breach_ticks: int = 2

    @property
    def tpot_target_ms(self) -> float:
        return self.base_tpot_ms * self.tpot_slo

    @property
    def ttft_target_ms(self) -> float:
        return self.base_ttft_ms * self.ttft_slo

    @property
    def offline_kv_per_slot(self) -> int:
        return math.ceil(self.seq_len * self.offline_ctx_factor)


# ---- the interference model (shared by prediction AND sampling, so the
# ---- admission guard can never disagree with what the monitor observes) ----

def predicted_tpot_ms(cfg: ElasticConfig, tier_factor: float,
                      offline_share: float) -> float:
    """Decode-step time under tier degradation + offline interference."""
    return cfg.base_tpot_ms / tier_factor * (
        1.0 + cfg.interference * offline_share)


def predicted_ttft_ms(cfg: ElasticConfig, tier_factor: float, load: float,
                      offline_share: float) -> float:
    """First-token time: tier + interference, queueing with online load."""
    return cfg.base_ttft_ms / tier_factor * (
        1.0 + cfg.interference * offline_share) * (1.0 + load)


def max_offline_share(cfg: ElasticConfig, tier_factor: float,
                      load: float) -> float:
    """Largest offline slot share keeping BOTH predictions under
    ``guard * target`` — tier-aware: a degraded replica affords less
    interference headroom, a cross-socket one often none at all."""
    if tier_factor <= 0:
        return 0.0
    s_tpot = (cfg.guard * cfg.tpot_slo * tier_factor - 1.0) / cfg.interference
    s_ttft = ((cfg.guard * cfg.ttft_slo * tier_factor / (1.0 + load) - 1.0)
              / cfg.interference)
    return max(0.0, min(1.0, s_tpot, s_ttft))


class SLOMonitor:
    """Sliding-window per-class TTFT/TPOT monitor with hysteresis.

    ``observe`` feeds one (ttft, tpot) sample per replica per tick;
    ``allowed_share`` is the admission bound the pool enforces.  A replica
    breaches when either metric exceeds its target; ``breach_ticks``
    consecutive breaches trip it (allowed share -> 0, the pool drains it),
    and it recovers only after ``window`` consecutive clean samples — the
    hysteresis that stops a boundary replica from flapping between admit
    and eject every tick.
    """

    def __init__(self, cfg: ElasticConfig) -> None:
        self.cfg = cfg
        self._window: dict[int, deque] = {}      # uid -> recent ok-flags
        self._breach: dict[int, int] = {}        # uid -> consecutive breaches
        self._clean: dict[int, int] = {}         # uid -> consecutive oks
        self._tripped: set[int] = set()
        #: per-class counts since the last ``drain_counts`` (one report row)
        self._counts: dict[str, dict[str, int]] = {}

    def _cls(self, name: str) -> dict[str, int]:
        return self._counts.setdefault(name,
                                       {"ok": 0, "total": 0, "violations": 0})

    def observe(self, cls_name: str, uid: int, ttft_ms: float,
                tpot_ms: float) -> bool:
        cfg = self.cfg
        ok = (tpot_ms <= cfg.tpot_target_ms and ttft_ms <= cfg.ttft_target_ms)
        win = self._window.setdefault(uid, deque(maxlen=cfg.window))
        win.append(ok)
        row = self._cls(cls_name)
        row["total"] += 1
        if ok:
            row["ok"] += 1
            self._breach[uid] = 0
            self._clean[uid] = self._clean.get(uid, 0) + 1
            if uid in self._tripped and self._clean[uid] >= cfg.window:
                self._tripped.discard(uid)
        else:
            row["violations"] += 1
            self._clean[uid] = 0
            self._breach[uid] = self._breach.get(uid, 0) + 1
            if self._breach[uid] >= cfg.breach_ticks:
                self._tripped.add(uid)
        return ok

    def violated(self, uid: int) -> bool:
        """Is the replica currently tripped (being drained)?"""
        return uid in self._tripped

    def allowed_share(self, uid: int, tier_factor: float,
                      load: float) -> float:
        if uid in self._tripped:
            return 0.0                   # drain until a clean window passes
        return max_offline_share(self.cfg, tier_factor, load)

    def forget(self, uid: int) -> None:
        """Drop per-replica state (the replica was scaled down/evicted)."""
        self._window.pop(uid, None)
        self._breach.pop(uid, None)
        self._clean.pop(uid, None)
        self._tripped.discard(uid)

    def drain_counts(self) -> dict[str, dict]:
        """Per-class {ok, total, violations, attainment} since the last
        call — one `ColocationReport` hour row — and reset."""
        out = {}
        for name in sorted(self._counts):
            c = self._counts[name]
            out[name] = dict(c, attainment=(c["ok"] / c["total"]
                                            if c["total"] else 1.0))
        self._counts = {}
        return out


class ReplicaSlots:
    """Slot + KV-cache accounting for ONE online replica.

    ``total_slots = slots_per_gpu * gpus`` continuous-batching slots; the
    KV budget is `configs.shapes.cache_capacity` per slot.  Online traffic
    at load L claims ``ceil(total * L)`` slots; offline requests take whole
    slot grants out of the remainder, each slot carrying the larger
    ``offline_kv_per_slot`` footprint, so KV headroom binds before slot
    headroom.  The achieved-tier factor discounts every service rate.
    """

    def __init__(self, uid: int, cls_name: str, gpus: int,
                 tier_factor: float, cfg: ElasticConfig) -> None:
        self.uid = uid
        self.cls_name = cls_name
        self.gpus = gpus
        self.tier_factor = tier_factor
        self.cfg = cfg
        self.total_slots = cfg.slots_per_gpu * gpus
        self.kv_budget = (cache_capacity(_KVShape(cfg.swa_window), cfg.seq_len)
                         * self.total_slots)
        self.online_slots = 0
        self._synced_load: float | None = None
        self.jobs: dict[int, int] = {}       # jid -> granted slots

    @property
    def offline_slots(self) -> int:
        return sum(self.jobs.values())

    def offline_share(self) -> float:
        return self.offline_slots / self.total_slots if self.total_slots else 0.0

    def set_load(self, load: float) -> None:
        self._synced_load = load
        self.online_slots = min(self.total_slots,
                                math.ceil(self.total_slots * load))

    def sync_load(self, load: float) -> None:
        """Lazily apply the pool's current load.  ``online_slots`` is a
        pure function of (total_slots, load), so a replica untouched since
        the last load change recomputes it on first access instead of the
        pool eagerly updating every replica per tick."""
        if self._synced_load != load:
            self.set_load(load)

    def kv_headroom_slots(self) -> int:
        """Offline slot grants the remaining KV budget can still hold."""
        used = (self.online_slots * self.cfg.seq_len
                + self.offline_slots * self.cfg.offline_kv_per_slot)
        return max(0, (self.kv_budget - used) // self.cfg.offline_kv_per_slot)

    def _permitted_offline(self, allowed_share: float) -> int:
        """Offline slots this replica may hold in total right now."""
        by_kv = max(0, (self.kv_budget - self.online_slots * self.cfg.seq_len)
                    // self.cfg.offline_kv_per_slot)
        return max(0, min(self.total_slots - self.online_slots,
                          math.floor(allowed_share * self.total_slots),
                          by_kv))

    def spare_slots(self, allowed_share: float) -> int:
        """Slots an admission could still grant under the SLO bound."""
        return max(0, self._permitted_offline(allowed_share)
                   - self.offline_slots)

    def overflow_slots(self, allowed_share: float) -> int:
        """Offline slots that must be ejected to get back under the bound."""
        return max(0, self.offline_slots
                   - self._permitted_offline(allowed_share))

    def rate(self, slots: int, job_gpus: int) -> float:
        """Progress rate (fraction of a dedicated full-rate instance) of an
        offline job granted ``slots`` here: slot share of its full demand,
        discounted by spare-slot efficiency and the achieved tier."""
        full = self.cfg.slots_per_gpu * job_gpus
        return min(1.0, slots / full) * self.cfg.efficiency * self.tier_factor


class ElasticPool:
    """Level-1 admission controller over all online replicas' spare slots.

    Deterministic throughout: replicas are scanned in uid order, ejections
    evict the youngest grants first (highest jid — the most recently
    admitted request has made the least progress), and every decision is a
    pure function of (replica state, monitor state, load).
    """

    def __init__(self, cfg: ElasticConfig, monitor: SLOMonitor) -> None:
        self.cfg = cfg
        self.monitor = monitor
        self.replicas: dict[int, ReplicaSlots] = {}
        self._host: dict[int, int] = {}          # jid -> replica uid
        self.load = 0.0

    # ---- replica lifecycle ----------------------------------------------------------
    def register(self, uid: int, cls_name: str, gpus: int,
                 tier_factor: float) -> ReplicaSlots:
        rs = ReplicaSlots(uid, cls_name, gpus, tier_factor, self.cfg)
        rs.set_load(self.load)
        self.replicas[uid] = rs
        return rs

    def unregister(self, uid: int) -> list[int]:
        """Drop a replica (scaled down / evicted); returns the hosted jids
        the caller must eject back to its pending queue."""
        rs = self.replicas.pop(uid, None)
        if rs is None:
            return []
        self.monitor.forget(uid)
        out = sorted(rs.jobs, reverse=True)
        for jid in out:
            del self._host[jid]
        rs.jobs.clear()
        return out

    # ---- load / SLO reclaim (degrade-before-kill, step 1) ---------------------------
    def set_load(self, load: float) -> list[int]:
        """Online traffic reclaims its slots: record the new load (every
        replica picks it up lazily via ``sync_load`` on next access) and
        eject offline grants that no longer fit under the slot / KV / SLO
        bounds.  Only replicas actually HOSTING grants are walked — a
        replica without jobs has nothing to eject, so the reclaim pass is
        O(changed replicas), not O(fleet).  Returns ejected jids in the
        same deterministic order the full scan produced."""
        self.load = load
        ejected: list[int] = []
        for uid in sorted(set(self._host.values())):
            rs = self.replicas[uid]
            rs.sync_load(load)
            allowed = self.monitor.allowed_share(uid, rs.tier_factor, load)
            while rs.overflow_slots(allowed) > 0 and rs.jobs:
                jid = max(rs.jobs)           # youngest grant first
                del rs.jobs[jid]
                del self._host[jid]
                ejected.append(jid)
        return ejected

    # ---- admission ------------------------------------------------------------------
    def admit(self, jid: int, job_gpus: int) -> tuple[int, int, float] | None:
        """Try to place one offline request: pick the replica with the most
        spare slots under its SLO bound (tie: lowest uid) and grant up to
        the request's full slot demand.  Returns ``(replica uid, slots,
        rate)`` or None if no replica clears ``min_slot_fraction``."""
        need = self.cfg.slots_per_gpu * job_gpus
        min_slots = max(1, math.ceil(need * self.cfg.min_slot_fraction))
        best: ReplicaSlots | None = None
        best_spare = 0
        for uid in sorted(self.replicas):
            rs = self.replicas[uid]
            rs.sync_load(self.load)
            spare = rs.spare_slots(
                self.monitor.allowed_share(uid, rs.tier_factor, self.load))
            if spare > best_spare:
                best, best_spare = rs, spare
        if best is None or best_spare < min_slots:
            return None
        slots = min(best_spare, need)
        best.jobs[jid] = slots
        self._host[jid] = best.uid
        return best.uid, slots, best.rate(slots, job_gpus)

    def release(self, jid: int) -> None:
        """An elastic request finished; free its grant (tolerates a replica
        that was already unregistered)."""
        uid = self._host.pop(jid, None)
        if uid is not None and uid in self.replicas:
            self.replicas[uid].jobs.pop(jid, None)

    def host_of(self, jid: int) -> int | None:
        return self._host.get(jid)

    def hosted(self) -> int:
        return len(self._host)

    def spare_total(self) -> int:
        for rs in self.replicas.values():
            rs.sync_load(self.load)
        return sum(
            rs.spare_slots(self.monitor.allowed_share(uid, rs.tier_factor,
                                                      self.load))
            for uid, rs in sorted(self.replicas.items()))

    # ---- observation ----------------------------------------------------------------
    def sample(self, load: float) -> None:
        """Push one deterministic SLO sample per replica through the
        monitor — the SAME interference model the admission guard predicts
        with, so a grant the guard allowed can only breach through tier
        degradation or an external load jump, never by construction."""
        for uid in sorted(self.replicas):
            rs = self.replicas[uid]
            share = rs.offline_share()
            self.monitor.observe(
                rs.cls_name, uid,
                predicted_ttft_ms(self.cfg, rs.tier_factor, load, share),
                predicted_tpot_ms(self.cfg, rs.tier_factor, share))
