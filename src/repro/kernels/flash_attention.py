"""Blocked (flash) causal GQA attention — Pallas TPU kernel.

The train/prefill compute hot spot.  Tiling per DESIGN.md §3: the grid is
(batch, q_head, q_block); each step streams K/V blocks of the matching KV
head through VMEM with running-max/denominator softmax in fp32, so the
[Sq, Sk] score matrix never materializes in HBM.  Causal + sliding-window
masking prunes K blocks entirely outside the window (the loop bound is
computed per q_block, not masked per-element).

MXU alignment: block_q × head_dim and block_k × head_dim tiles with
block_q = block_k = 128 by default (multiples of the 128-lane MXU).
Validated against ``ref.mha_ref`` in interpret mode (CPU container); set
``interpret=False`` on real TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  sk: int, causal: bool, window: int | None, scale: float):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale          # [block_q, d]

    q_start = qi * block_q
    # K-block range actually needed by this q block
    if causal:
        hi = jnp.minimum(sk, q_start + block_q)
    else:
        hi = sk
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, q_start - (window - 1))
        lo = (lo // block_k) * block_k

    m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q, 1), jnp.float32)
    acc = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)

    def body(kb, carry):
        m, l, acc = carry
        k_start = kb * block_k
        k = pl.load(k_ref, (pl.dslice(k_start, block_k), slice(None))
                    ).astype(jnp.float32)               # [block_k, d]
        v = pl.load(v_ref, (pl.dslice(k_start, block_k), slice(None))
                    ).astype(jnp.float32)
        s = q @ k.T                                     # [block_q, block_k]
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = cols < sk
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v
        return m_new, l_new, acc_new

    n_blocks = pl.cdiv(hi - lo, block_k)
    m, l, acc = jax.lax.fori_loop(
        lo // block_k, lo // block_k + n_blocks, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,              # [B, H, Sq, d]
    k: jnp.ndarray,              # [B, K, Sk, d]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, d = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    G = H // K
    assert H % K == 0

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    sq_pad = -(-Sq // block_q) * block_q
    sk_pad = -(-Sk // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))

    grid = (B, H, sq_pad // block_q)
    kernel = partial(_flash_kernel, block_q=block_q, block_k=block_k, sk=Sk,
                     causal=causal, window=window, scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, sk_pad, d),
                         lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((None, None, sk_pad, d),
                         lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, d), q.dtype),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
