"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topology import ServerSpec


# ---------------------------------------------------------------------------------
# topo_score oracle
# ---------------------------------------------------------------------------------

def topo_score_ref(
    combo_gpu: jnp.ndarray,      # int32[n] — freed GPU mask per subset
    combo_cg: jnp.ndarray,       # int32[n]
    prio: jnp.ndarray,           # int32[n] — sum of victim priorities
    spec: ServerSpec,
    need_gpus: int,
    need_cgs: int,
    cgs_per_bundle: int,
    alpha: float,
    tier_values: tuple[float, ...] = (1.0, 0.5, 0.1),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (tier int32[n] with 3 = infeasible, score f32[n])."""
    U = spec.num_numa
    cnt_gpu = jnp.stack([
        jax.lax.population_count(combo_gpu & int(spec.numa_gpu_masks[u]))
        for u in range(U)], axis=-1)
    cnt_cg = jnp.stack([
        jax.lax.population_count(combo_cg & int(spec.numa_cg_masks[u]))
        for u in range(U)], axis=-1)
    if cgs_per_bundle > 0:
        units = jnp.minimum(cnt_gpu, cnt_cg // cgs_per_bundle)
    else:
        units = cnt_gpu
    numa_ok = jnp.any((units >= need_gpus) & (cnt_cg >= need_cgs), axis=-1)
    sock_units = jnp.stack([
        sum(units[..., u] for u in range(U) if spec.socket_of_numa(u) == s)
        for s in range(spec.num_sockets)], axis=-1)
    sock_cg = jnp.stack([
        sum(cnt_cg[..., u] for u in range(U) if spec.socket_of_numa(u) == s)
        for s in range(spec.num_sockets)], axis=-1)
    sock_ok = jnp.any((sock_units >= need_gpus) & (sock_cg >= need_cgs),
                      axis=-1)
    glob_ok = (jnp.sum(units, axis=-1) >= need_gpus) & (
        jnp.sum(cnt_cg, axis=-1) >= need_cgs)
    tier = jnp.where(numa_ok, 0,
                     jnp.where(sock_ok, 1,
                               jnp.where(glob_ok, 2, 3))).astype(jnp.int32)
    tv = jnp.asarray(tier_values + (0.0,), jnp.float32)
    prio_term = jnp.where(prio > 0,
                          1.0 / jnp.maximum(prio, 1).astype(jnp.float32), 1.0)
    score = alpha * prio_term + (1.0 - alpha) * tv[tier]
    score = jnp.where(tier < 3, score, -jnp.inf)
    return tier, score


# ---------------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------------

def mha_ref(
    q: jnp.ndarray,              # [B, H, Sq, d]
    k: jnp.ndarray,              # [B, K, Sk, d]
    v: jnp.ndarray,              # [B, K, Sk, d]
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    B, H, Sq, d = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, Sq, d)
    scores = jnp.einsum("BKGSd,BKTd->BKGST", qg, k).astype(jnp.float32)
    scores = scores * (d ** -0.5)
    if causal:
        rows = jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[2]), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[2]), 1)
        mask = cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("BKGST,BKTd->BKGSd", probs.astype(v.dtype), v)
    return out.reshape(B, H, Sq, d)
