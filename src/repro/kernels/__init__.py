"""Pallas TPU kernels (validated in interpret mode on CPU).

topo_score       — the paper's candidate-sourcing hot loop as bitmask lane math
flash_attention  — blocked causal/SWA GQA attention (train/prefill hot spot)
"""
from . import flash_attention, ops, ref, topo_score

__all__ = ["flash_attention", "ops", "ref", "topo_score"]
