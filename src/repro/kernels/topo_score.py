"""Pallas TPU kernel for batched victim-subset scoring (paper §3.4 hot loop).

TPU adaptation (DESIGN.md §3): the paper's candidate sourcing walks victim
subsets with branchy CPU code (Table 5: 180-417ms P90 at scale).  Here a
subset is one int32 lane: its freed-GPU/CoreGroup bitmasks.  Per-NUMA
availability is ``popcount(mask & numa_mask)`` — numa masks are compile-time
constants baked into the kernel — and the Eq. 1 score is pure VPU math.  One
grid step scores a (8, 128) tile of subsets from VMEM; a 100k-subset sourcing
wave is a handful of grid steps.

Three kernels share the tier/score math:

* ``topo_score_pallas``        — tier + Eq. 1 score per subset (dense out).
* ``placement_tier_pallas``    — per-NODE placement tier over free masks:
  the VPU mirror of the device placement scorer (`placement_jax`) that the
  fused dispatch chains in front of sourcing (§3.4 Sorting / normal cycle).
* ``topo_score_argmax_pallas`` — same tier math, plus a *per-tile running
  argmax*:
  each grid step also reduces its tile to (smallest feasible subset size,
  best tier, best score, flat index of that winner), so the ``imp_pallas``
  engine evaluates every subset size in ONE dispatch and only scans the
  dense outputs at the winning size.  It also takes a per-lane *filtering
  mask* (``ok``): the scheduler's Guaranteed-Filtering / victim-eligibility
  constraints become VPU lane masking instead of host pre-filtering —
  masked lanes report tier 3 / -inf score and never win the argmax.

Layout: subsets are padded to (rows, 128) int32.  Outputs: tier (0/1/2,
3 = infeasible) and the Eq. 1 score (-inf where infeasible).

``interpret`` resolution: the Mosaic interpreter is required off-TPU.  Pass
``interpret=None`` (default) to auto-detect (interpret unless the JAX
backend is TPU), or force it with the ``REPRO_PALLAS_INTERPRET`` env var
("1"/"0"/"auto").
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.engines import register_engine
from repro.core.topology import ServerSpec

TIER_VALUES = (1.0, 0.5, 0.1)
ROWS_PER_TILE = 8
LANES = 128
#: k fill value for padding lanes in the argmax kernel (also the "no
#: feasible subset in this tile" sentinel of the per-tile k-min output).
K_INFEASIBLE = np.int32(2**30)


def _interpret_default() -> bool:
    """Resolve the Mosaic-interpreter flag: env override, else backend."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "auto").lower()
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        return False
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class TopoRequest:
    need_gpus: int
    need_cgs: int
    cgs_per_bundle: int
    alpha: float = 0.5


def _tier_score(g_mask, c_mask, prio, *, spec: ServerSpec, req: TopoRequest):
    """Shared VPU math: (tier int32, Eq. 1 score f32) for one tile."""
    U = spec.num_numa
    S = spec.num_sockets
    shape = g_mask.shape
    zero = jnp.zeros(shape, jnp.int32)
    sock_units = [zero] * S
    sock_cg = [zero] * S
    glob_units = zero
    glob_cg = zero
    numa_ok = jnp.zeros(shape, jnp.bool_)
    for u in range(U):                       # static unroll over NUMA nodes
        ugm = int(spec.numa_gpu_masks[u])    # compile-time constants
        ucm = int(spec.numa_cg_masks[u])
        cnt_gpu = jax.lax.population_count(g_mask & ugm)
        cnt_cg = jax.lax.population_count(c_mask & ucm)
        if req.cgs_per_bundle > 0:
            units = jnp.minimum(cnt_gpu, cnt_cg // req.cgs_per_bundle)
        else:
            units = cnt_gpu
        numa_ok |= (units >= req.need_gpus) & (cnt_cg >= req.need_cgs)
        s = spec.socket_of_numa(u)
        sock_units[s] = sock_units[s] + units
        sock_cg[s] = sock_cg[s] + cnt_cg
        glob_units = glob_units + units
        glob_cg = glob_cg + cnt_cg
    sock_ok = jnp.zeros(shape, jnp.bool_)
    for s in range(S):
        sock_ok |= (sock_units[s] >= req.need_gpus) & (
            sock_cg[s] >= req.need_cgs)
    glob_ok = (glob_units >= req.need_gpus) & (glob_cg >= req.need_cgs)

    tier = jnp.where(numa_ok, 0, jnp.where(sock_ok, 1,
                                           jnp.where(glob_ok, 2, 3)))
    tier = tier.astype(jnp.int32)

    tv = TIER_VALUES + (0.0,)
    topo = jnp.where(tier == 0, tv[0],
                     jnp.where(tier == 1, tv[1],
                               jnp.where(tier == 2, tv[2], tv[3])))
    prio_term = jnp.where(prio > 0,
                          1.0 / jnp.maximum(prio, 1).astype(jnp.float32), 1.0)
    score = req.alpha * prio_term + (1.0 - req.alpha) * topo
    score = jnp.where(tier < 3, score, -jnp.inf).astype(jnp.float32)
    return tier, score


def _kernel(combo_gpu_ref, combo_cg_ref, prio_ref, tier_ref, score_ref, *,
            spec: ServerSpec, req: TopoRequest):
    tier, score = _tier_score(combo_gpu_ref[...], combo_cg_ref[...],
                              prio_ref[...], spec=spec, req=req)
    tier_ref[...] = tier
    score_ref[...] = score


def _argmax_kernel(combo_gpu_ref, combo_cg_ref, prio_ref, k_ref, ok_ref,
                   tier_ref, score_ref, kmin_ref, btier_ref, bscore_ref,
                   bidx_ref, *, spec: ServerSpec, req: TopoRequest):
    """Tier/score tile + filtering mask + per-tile running argmax.

    ``ok`` is the fused filtering input: lanes whose subset violates the
    scheduler's constraints (ineligible victims, filtered-out node) are
    masked to tier 3 / -inf score ON DEVICE, so callers never pre-filter
    subsets host-side.  The reduction implements the IMP selection order
    inside one tile: smallest feasible subset size k first, then
    tier-then-score (lowest tier, highest Eq. 1 score), then lowest flat
    subset index.  Host-side merging of the ``[n_tiles]`` outputs is
    O(tiles) on scalars, so the engine dispatches exactly once per node
    regardless of victim count.
    """
    tier, score = _tier_score(combo_gpu_ref[...], combo_cg_ref[...],
                              prio_ref[...], spec=spec, req=req)
    ok = ok_ref[...] != 0
    tier = jnp.where(ok, tier, 3).astype(jnp.int32)
    score = jnp.where(ok, score, -jnp.inf).astype(jnp.float32)
    tier_ref[...] = tier
    score_ref[...] = score

    k = k_ref[...]
    feas = tier < 3
    big = jnp.int32(K_INFEASIBLE)
    kmin = jnp.min(jnp.where(feas, k, big))
    kmin_ref[0] = kmin
    sel = feas & (k == kmin)
    tmin = jnp.min(jnp.where(sel, tier, 3))
    btier_ref[0] = tmin
    sel &= tier == tmin
    smax = jnp.max(jnp.where(sel, score, -jnp.inf))
    bscore_ref[0] = smax
    sel &= score == smax
    rows, lanes = k.shape
    flat = (jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0) * lanes
            + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1))
    local = jnp.min(jnp.where(sel, flat, big))
    bidx_ref[0] = pl.program_id(0) * (rows * lanes) + local


def _tiled(x, fill, n_pad, tile):
    return jnp.pad(x, [(0, n_pad - x.shape[0])],
                   constant_values=fill).reshape(
        n_pad // tile, ROWS_PER_TILE, LANES)


def topo_score_pallas(
    combo_gpu: jnp.ndarray,      # int32[n] freed-GPU mask per subset
    combo_cg: jnp.ndarray,
    prio: jnp.ndarray,
    spec: ServerSpec,
    req: TopoRequest,
    interpret: bool | None = None,   # None: auto (env/backend detection)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (tier int32[n], score f32[n])."""
    if interpret is None:
        interpret = _interpret_default()
    n = combo_gpu.shape[0]
    tile = ROWS_PER_TILE * LANES
    n_pad = -(-n // tile) * tile

    cg2 = _tiled(combo_gpu, 0, n_pad, tile)
    cc2 = _tiled(combo_cg, 0, n_pad, tile)
    pr2 = _tiled(prio, 0, n_pad, tile)

    grid = (n_pad // tile,)
    blk = pl.BlockSpec((None, ROWS_PER_TILE, LANES), lambda i: (i, 0, 0))
    kernel = partial(_kernel, spec=spec, req=req)
    tier, score = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(cg2.shape, jnp.int32),
            jax.ShapeDtypeStruct(cg2.shape, jnp.float32),
        ],
        interpret=interpret,
    )(cg2, cc2, pr2)
    return tier.reshape(-1)[:n], score.reshape(-1)[:n]


def topo_score_argmax_pallas(
    combo_gpu: jnp.ndarray,      # int32[n] freed-GPU mask per subset
    combo_cg: jnp.ndarray,
    prio: jnp.ndarray,
    k: jnp.ndarray,              # int32[n] subset size per lane
    spec: ServerSpec,
    req: TopoRequest,
    interpret: bool | None = None,
    ok: jnp.ndarray | None = None,   # filtering mask per lane (None = all ok)
):
    """Single-dispatch scoring of subsets of EVERY size plus the per-tile
    running argmax.

    ``ok`` is the fused filtering-mask input: lanes with ``ok == 0`` (e.g.
    subsets touching victims the preemptor may not evict, or subsets of a
    node Guaranteed Filtering rejected) are masked infeasible inside the
    kernel instead of being pre-filtered on the host.

    Returns (tier int32[n], score f32[n], kmin int32[T], btier int32[T],
    bscore f32[T], bidx int32[T]) with T = number of (8, 128) grid tiles;
    ``kmin[t] == K_INFEASIBLE`` marks a tile with no feasible subset, and
    ``bidx`` is the *global* flat index of tile t's winner under the
    (k, tier-then-score, index) order.
    """
    if interpret is None:
        interpret = _interpret_default()
    n = combo_gpu.shape[0]
    tile = ROWS_PER_TILE * LANES
    n_pad = -(-n // tile) * tile

    cg2 = _tiled(combo_gpu, 0, n_pad, tile)
    cc2 = _tiled(combo_cg, 0, n_pad, tile)
    pr2 = _tiled(prio, 0, n_pad, tile)
    kk2 = _tiled(k, K_INFEASIBLE, n_pad, tile)
    if ok is None:
        ok = jnp.ones(n, jnp.int32)
    ok2 = _tiled(ok.astype(jnp.int32), 0, n_pad, tile)

    n_tiles = n_pad // tile
    blk = pl.BlockSpec((None, ROWS_PER_TILE, LANES), lambda i: (i, 0, 0))
    scl = pl.BlockSpec((1,), lambda i: (i,))
    kernel = partial(_argmax_kernel, spec=spec, req=req)
    tier, score, kmin, btier, bscore, bidx = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[blk, blk, blk, blk, blk],
        out_specs=[blk, blk, scl, scl, scl, scl],
        out_shape=[
            jax.ShapeDtypeStruct(cg2.shape, jnp.int32),
            jax.ShapeDtypeStruct(cg2.shape, jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        interpret=interpret,
    )(cg2, cc2, pr2, kk2, ok2)
    return tier.reshape(-1)[:n], score.reshape(-1)[:n], kmin, btier, bscore, bidx


def _place_tier_kernel(free_gpu_ref, free_cg_ref, tier_ref, *,
                       spec: ServerSpec, req: TopoRequest):
    """Placement-tier tile: each lane is one NODE's free masks (not a
    victim subset) — the VPU mirror of the normal-cycle / §3.4 tier
    scorer (`repro.core.placement_jax.best_tier_counts`)."""
    tier, _ = _tier_score(free_gpu_ref[...], free_cg_ref[...],
                          jnp.zeros_like(free_gpu_ref[...]),
                          spec=spec, req=req)
    tier_ref[...] = tier


def placement_tier_pallas(
    free_gpu: jnp.ndarray,       # int32[n] free-GPU mask per node
    free_cg: jnp.ndarray,        # int32[n] free-CoreGroup mask per node
    spec: ServerSpec,
    req: TopoRequest,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-node placement tier (0/1/2, 3 = infeasible) on the TPU VPU.

    Mirrors the device placement scorer that the fused dispatch chains in
    front of sourcing: popcounts of the per-NUMA free-mask slices with the
    numa masks baked in as compile-time constants.  Bitwise-matching
    ``placement.best_tier`` for the request's ``(need_gpus, need_cgs,
    cgs_per_bundle)`` encoding; the normal-cycle argmin over ``(tier,
    leftover, node)`` is host/XLA reduction work on the dense output.
    """
    if interpret is None:
        interpret = _interpret_default()
    n = free_gpu.shape[0]
    tile = ROWS_PER_TILE * LANES
    n_pad = -(-n // tile) * tile
    fg2 = _tiled(free_gpu, 0, n_pad, tile)
    fc2 = _tiled(free_cg, 0, n_pad, tile)
    blk = pl.BlockSpec((None, ROWS_PER_TILE, LANES), lambda i: (i, 0, 0))
    kernel = partial(_place_tier_kernel, spec=spec, req=req)
    tier = pl.pallas_call(
        kernel,
        grid=(n_pad // tile,),
        in_specs=[blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(fg2.shape, jnp.int32),
        interpret=interpret,
    )(fg2, fc2)
    return tier.reshape(-1)[:n]


# ---------------------------------------------------------------------------------
# IMP engine backed by the kernel (scheduler engine "imp_pallas")
# ---------------------------------------------------------------------------------

def _all_size_combos(free_gpu: int, free_cg: int, vg, vc, vp):
    """Every victim subset as its slot-bitmask id: freed masks, priority sum
    and subset size for ids 0..2^m-1 (id 0 = evict nothing)."""
    m = len(vg)
    ids = np.arange(1 << m, dtype=np.int64)
    cg = np.full(ids.shape, free_gpu, np.int64)
    cc = np.full(ids.shape, free_cg, np.int64)
    pr = np.zeros(ids.shape, np.int64)
    kk = np.zeros(ids.shape, np.int64)
    for j in range(m):
        b = (ids >> j) & 1
        cg |= b * int(vg[j])
        cc |= b * int(vc[j])
        pr += b * int(vp[j])
        kk += b
    return ids, cg, cc, pr, kk


@register_engine("imp_pallas")
def flextopo_imp_pallas(cluster, workload, node):
    """Drop-in engine: same semantics as preemption.flextopo_imp, but every
    subset size is evaluated in ONE kernel dispatch — the per-tile running
    argmax locates the smallest feasible size, then candidates are read off
    the dense tier output at that size only.

    Eligible victims are a prefix of the (priority, uid) order, so the
    preemptor-priority filter is a host-side SLICE (never a subset
    enumeration blow-up); the kernel's filtering-mask input (``ok``)
    additionally zeroes any lane whose subset escapes that eligibility —
    the belt-and-braces in-kernel expression of Guaranteed Filtering that
    fused callers with ragged eligibility rely on."""
    from repro.core.cluster import MAX_DENSE_VICTIMS
    from repro.core.scoring import Candidate
    from repro.core.workload import TopoPolicy

    spec = cluster.spec
    victims = cluster.victims_on(node, workload.priority)
    if len(victims) > MAX_DENSE_VICTIMS:
        # 2^m lanes would blow up; the per-node python engine is exact
        from repro.core.preemption import flextopo_imp

        return flextopo_imp(cluster, workload, node)
    free_gpu, free_cg = cluster.free_masks(node)
    need_gpus = workload.gpus_per_instance
    need_cgs = workload.coregroups_per_instance(spec.coregroup_size)
    bundle = workload.numa_policy == TopoPolicy.GUARANTEED
    req = TopoRequest(
        need_gpus=need_gpus, need_cgs=need_cgs,
        cgs_per_bundle=(need_cgs // need_gpus if (bundle and need_gpus) else 0))
    vg = [v.gpu_mask for v in victims]
    vc = [v.cg_mask for v in victims]
    vp = [v.priority for v in victims]
    ids, cg, cc, pr, kk = _all_size_combos(free_gpu, free_cg, vg, vc, vp)
    elig_bits = sum(1 << j for j, v in enumerate(victims)
                    if v.priority < workload.priority)
    ok = (ids & ~np.int64(elig_bits)) == 0
    tier, _, kmin, _, _, _ = topo_score_argmax_pallas(
        jnp.asarray(cg, jnp.int32), jnp.asarray(cc, jnp.int32),
        jnp.asarray(pr, jnp.int32), jnp.asarray(kk, jnp.int32), spec, req,
        ok=jnp.asarray(ok, jnp.int32))
    k_star = int(np.min(np.asarray(kmin)))
    if k_star >= int(K_INFEASIBLE):
        return []
    tier = np.asarray(tier)
    at_min = np.nonzero((tier < 3) & (kk == k_star))[0]
    return [
        Candidate(
            node=node,
            victims=tuple(sorted(
                victims[j].uid for j in range(len(victims))
                if (int(ids[i]) >> j) & 1)),
            tier=int(tier[i]),
            priority_sum=int(pr[i]),
        )
        for i in at_min
    ]
