"""Pallas TPU kernel for batched victim-subset scoring (paper §3.4 hot loop).

TPU adaptation (DESIGN.md §3): the paper's candidate sourcing walks victim
subsets with branchy CPU code (Table 5: 180-417ms P90 at scale).  Here a
subset is one int32 lane: its freed-GPU/CoreGroup bitmasks.  Per-NUMA
availability is ``popcount(mask & numa_mask)`` — numa masks are compile-time
constants baked into the kernel — and the Eq. 1 score is pure VPU math.  One
grid step scores a (8, 128) tile of subsets from VMEM; a 100k-subset sourcing
wave is a handful of grid steps.

Layout: subsets are padded to (rows, 128) int32.  Outputs: tier (0/1/2,
3 = infeasible) and the Eq. 1 score (-inf where infeasible).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.engines import register_engine
from repro.core.topology import ServerSpec

TIER_VALUES = (1.0, 0.5, 0.1)
ROWS_PER_TILE = 8
LANES = 128


@dataclasses.dataclass(frozen=True)
class TopoRequest:
    need_gpus: int
    need_cgs: int
    cgs_per_bundle: int
    alpha: float = 0.5


def _kernel(combo_gpu_ref, combo_cg_ref, prio_ref, tier_ref, score_ref, *,
            spec: ServerSpec, req: TopoRequest):
    g_mask = combo_gpu_ref[...]
    c_mask = combo_cg_ref[...]
    prio = prio_ref[...]

    U = spec.num_numa
    S = spec.num_sockets
    shape = g_mask.shape
    zero = jnp.zeros(shape, jnp.int32)
    sock_units = [zero] * S
    sock_cg = [zero] * S
    glob_units = zero
    glob_cg = zero
    numa_ok = jnp.zeros(shape, jnp.bool_)
    for u in range(U):                       # static unroll over NUMA nodes
        ugm = int(spec.numa_gpu_masks[u])    # compile-time constants
        ucm = int(spec.numa_cg_masks[u])
        cnt_gpu = jax.lax.population_count(g_mask & ugm)
        cnt_cg = jax.lax.population_count(c_mask & ucm)
        if req.cgs_per_bundle > 0:
            units = jnp.minimum(cnt_gpu, cnt_cg // req.cgs_per_bundle)
        else:
            units = cnt_gpu
        numa_ok |= (units >= req.need_gpus) & (cnt_cg >= req.need_cgs)
        s = spec.socket_of_numa(u)
        sock_units[s] = sock_units[s] + units
        sock_cg[s] = sock_cg[s] + cnt_cg
        glob_units = glob_units + units
        glob_cg = glob_cg + cnt_cg
    sock_ok = jnp.zeros(shape, jnp.bool_)
    for s in range(S):
        sock_ok |= (sock_units[s] >= req.need_gpus) & (
            sock_cg[s] >= req.need_cgs)
    glob_ok = (glob_units >= req.need_gpus) & (glob_cg >= req.need_cgs)

    tier = jnp.where(numa_ok, 0, jnp.where(sock_ok, 1,
                                           jnp.where(glob_ok, 2, 3)))
    tier = tier.astype(jnp.int32)
    tier_ref[...] = tier

    tv = TIER_VALUES + (0.0,)
    topo = jnp.where(tier == 0, tv[0],
                     jnp.where(tier == 1, tv[1],
                               jnp.where(tier == 2, tv[2], tv[3])))
    prio_term = jnp.where(prio > 0,
                          1.0 / jnp.maximum(prio, 1).astype(jnp.float32), 1.0)
    score = req.alpha * prio_term + (1.0 - req.alpha) * topo
    score_ref[...] = jnp.where(tier < 3, score, -jnp.inf).astype(jnp.float32)


def topo_score_pallas(
    combo_gpu: jnp.ndarray,      # int32[n] freed-GPU mask per subset
    combo_cg: jnp.ndarray,
    prio: jnp.ndarray,
    spec: ServerSpec,
    req: TopoRequest,
    interpret: bool = True,      # CPU container: interpret; False on real TPU
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (tier int32[n], score f32[n])."""
    n = combo_gpu.shape[0]
    tile = ROWS_PER_TILE * LANES
    n_pad = -(-n // tile) * tile
    pad = [(0, n_pad - n)]

    def prep(x, fill):
        return jnp.pad(x, pad, constant_values=fill).reshape(
            n_pad // tile, ROWS_PER_TILE, LANES)

    cg2 = prep(combo_gpu, 0)
    cc2 = prep(combo_cg, 0)
    pr2 = prep(prio, 0)

    grid = (n_pad // tile,)
    blk = pl.BlockSpec((None, ROWS_PER_TILE, LANES), lambda i: (i, 0, 0))
    kernel = partial(_kernel, spec=spec, req=req)
    tier, score = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(cg2.shape, jnp.int32),
            jax.ShapeDtypeStruct(cg2.shape, jnp.float32),
        ],
        interpret=interpret,
    )(cg2, cc2, pr2)
    return tier.reshape(-1)[:n], score.reshape(-1)[:n]


# ---------------------------------------------------------------------------------
# IMP engine backed by the kernel (scheduler engine "imp_pallas")
# ---------------------------------------------------------------------------------

@register_engine("imp_pallas")
def flextopo_imp_pallas(cluster, workload, node):
    """Drop-in engine: same semantics as preemption.flextopo_imp."""
    from repro.core.preemption_jax import combo_table
    from repro.core.scoring import Candidate
    from repro.core.workload import TopoPolicy

    spec = cluster.spec
    victims = cluster.victims_on(node, workload.priority)
    free_gpu, free_cg = cluster.free_masks(node)
    need_gpus = workload.gpus_per_instance
    need_cgs = workload.coregroups_per_instance(spec.coregroup_size)
    bundle = workload.numa_policy == TopoPolicy.GUARANTEED
    req = TopoRequest(
        need_gpus=need_gpus, need_cgs=need_cgs,
        cgs_per_bundle=(need_cgs // need_gpus if (bundle and need_gpus) else 0))
    m = len(victims)
    vg = np.array([v.gpu_mask for v in victims], dtype=np.int64)
    vc = np.array([v.cg_mask for v in victims], dtype=np.int64)
    vp = np.array([v.priority for v in victims], dtype=np.int64)
    for k in range(0, m + 1):
        table = combo_table(max(m, 1), k) if m else np.zeros((1, 0), np.int32)
        if k == 0:
            cg = np.array([free_gpu], dtype=np.int64)
            cc = np.array([free_cg], dtype=np.int64)
            pr = np.zeros(1, np.int64)
        else:
            cg = free_gpu | np.bitwise_or.reduce(vg[table], axis=1)
            cc = free_cg | np.bitwise_or.reduce(vc[table], axis=1)
            pr = vp[table].sum(axis=1)
        tier, _ = topo_score_pallas(
            jnp.asarray(cg, jnp.int32), jnp.asarray(cc, jnp.int32),
            jnp.asarray(pr, jnp.int32), spec, req)
        tier = np.asarray(tier)
        feasible = np.nonzero(tier < 3)[0]
        if feasible.size:
            return [
                Candidate(
                    node=node,
                    victims=tuple(sorted(victims[j].uid for j in table[i])),
                    tier=int(tier[i]),
                    priority_sum=int(pr[i]),
                )
                for i in feasible
            ]
        if m == 0:
            break
    return []
