"""jit'd public wrappers for the Pallas kernels.

Interpret mode resolves through ``topo_score._interpret_default``:
``REPRO_PALLAS_INTERPRET=1|0|auto`` (auto = interpret unless the backend is
TPU).  The legacy ``REPRO_PALLAS_COMPILED=1`` switch still forces compiled
mode for back-compat; the ``interpret`` kwarg overrides everything.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import topo_score as _ts


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILED", "") == "1":
        return False
    return _ts._interpret_default()


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = _interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def topo_score(combo_gpu, combo_cg, prio, spec, req, interpret=None):
    if interpret is None:
        interpret = _interpret()
    return _ts.topo_score_pallas(combo_gpu, combo_cg, prio, spec, req,
                                 interpret=interpret)
