"""jit'd public wrappers for the Pallas kernels.

``interpret=True`` everywhere in this container (CPU); flip to compiled mode
on real TPU via the ``REPRO_PALLAS_COMPILED`` env var or the interpret kwarg.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import topo_score as _ts

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILED", "") != "1"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=_INTERPRET):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def topo_score(combo_gpu, combo_cg, prio, spec, req, interpret=_INTERPRET):
    return _ts.topo_score_pallas(combo_gpu, combo_cg, prio, spec, req,
                                 interpret=interpret)
