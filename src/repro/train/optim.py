"""AdamW optimizer + LR schedule + global-norm clipping (no external deps).

Optimizer state mirrors the parameter pytree, so the same partition rules
shard it (the FSDP/ZeRO property: each data-shard owns its slice of m/v).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: OptConfig, params: Any, grads: Any, opt_state: dict
                 ) -> tuple[Any, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
