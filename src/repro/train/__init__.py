from .optim import OptConfig, adamw_update, init_opt_state, lr_at
from .step import TrainConfig, init_train_state, make_train_step, train_state_specs

__all__ = ["OptConfig", "adamw_update", "init_opt_state", "lr_at",
           "TrainConfig", "init_train_state", "make_train_step",
           "train_state_specs"]
