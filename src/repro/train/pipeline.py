"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``pipeline_apply`` runs a stage function over microbatches with the classic
GPipe schedule: at step t, stage s processes microbatch (t - s); activations
move stage→stage via ``ppermute``.  The whole schedule is a ``lax.scan`` so
reverse-mode autodiff yields the standard 1F1B-equivalent backward wave for
free (grad of ppermute is the reversed ppermute).

Bubble fraction is the usual (S-1)/(M+S-1); stages compute during bubbles on
zero inputs and the outputs are masked, which keeps the schedule branch-free
(TPU-friendly) at the cost of the bubble FLOPs.

Used via shard_map over a ("stage", ...) mesh; see tests/test_pipeline.py
for the executable 4-stage example (forward equivalence + gradient match
against the unpipelined stack).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, microbatches, *, n_stages: int,
                   axis: str = "stage"):
    """Run inside shard_map(..., axis_names={axis}).

    stage_fn: (stage_params, x) -> y       (one stage's layer stack)
    stage_params: THIS stage's parameter shard (leading stage axis stripped)
    microbatches: [M, mb, ...] — identical on every stage; only stage 0
        consumes it (others ignore their copy).
    Returns [M, mb, ...]: the last stage's outputs per microbatch (valid on
    the last stage; other stages return zeros — combine with psum or slice
    outside).
    """
    M = microbatches.shape[0]
    s = jax.lax.axis_index(axis)
    T = M + n_stages - 1
    x_shape = microbatches.shape[1:]
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def step(carry, t):
        buf = carry                                    # [mb, ...] held input
        mb_idx = t - s                                 # microbatch this stage
        active = (mb_idx >= 0) & (mb_idx < M)
        y = stage_fn(stage_params, buf)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # ship to the next stage; stage 0 picks up the next microbatch
        shipped = jax.lax.ppermute(y, axis, fwd_perm)
        nxt = jnp.clip(t + 1, 0, M - 1)
        from_feed = microbatches[nxt]
        buf_next = jnp.where(s == 0, from_feed, shipped)
        # last stage emits y for microbatch (t - (S-1)) when valid
        out_idx = t - (n_stages - 1)
        emit = jnp.where((s == n_stages - 1) & (out_idx >= 0), 1.0, 0.0)
        return buf_next, y * emit.astype(y.dtype)

    buf0 = jnp.where(s == 0, microbatches[0],
                     jnp.zeros(x_shape, microbatches.dtype))
    _, ys = jax.lax.scan(step, buf0, jnp.arange(T))
    # ys: [T, mb, ...]; last stage's valid outputs are at t = S-1 .. S-1+M
    return ys[n_stages - 1:]


def make_pipelined_fn(stage_fn, mesh: Mesh, n_stages: int,
                      axis: str = "stage"):
    """shard_map wrapper: stage-stacked params [S, ...] + microbatches in,
    last-stage outputs [M, mb, ...] out (replicated via psum)."""

    def inner(params_stacked, microbatches):
        my_params = jax.tree.map(lambda p: p[0], params_stacked)
        outs = pipeline_apply(stage_fn, my_params, microbatches,
                              n_stages=n_stages, axis=axis)
        # only the last stage holds real outputs; make them global
        return jax.lax.psum(outs, axis)

    from repro.models.common import shard_map_compat

    return shard_map_compat(
        inner, mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis},
    )
