"""Distributed train step: grad accumulation, AdamW, donated state.

``make_train_step`` builds the (state, batch) -> (state, metrics) function the
launcher jits with in/out shardings from ``repro.sharding``.  Microbatch
accumulation runs under ``lax.scan`` so the peak activation footprint is one
microbatch regardless of global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import ModelApi

from .optim import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum_steps: int = 1          # microbatch gradient accumulation


def init_train_state(api: ModelApi, key) -> dict:
    params = api.init(key)
    return {"params": params, "opt": init_opt_state(params)}


def train_state_specs(api: ModelApi, key=None) -> Any:
    """Abstract TrainState (ShapeDtypeStructs) without allocating anything."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: init_train_state(api, k), key)


def _split_microbatches(batch: dict, accum: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(api: ModelApi, tcfg: TrainConfig):
    def loss_fn(params, mb):
        loss, aux = api.loss(params, mb)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if tcfg.accum_steps == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, tcfg.accum_steps)

            def body(acc, mb):
                (l, a), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), a

            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params), jnp.float32(0))
            (grads, loss), aux = jax.lax.scan(body, zero, mbs)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, grads)
            loss = loss / tcfg.accum_steps
            aux = jax.tree.map(lambda x: jnp.mean(x, axis=0), aux)

        new_params, new_opt, om = adamw_update(tcfg.opt, params, grads,
                                               state["opt"])
        metrics = {"loss": loss, **om}
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()})
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
