"""Cross-pod gradient/delta synchronization with int8 compression.

Distributed-optimization trick for the multi-pod mesh (DESIGN.md §6): the
"pod" axis crosses DCN, which is ~10-50x slower than ICI.  Instead of letting
every step's gradient all-reduce cross DCN at fp32, pods run local steps and
periodically all-reduce a *parameter delta* quantized to int8 with per-tensor
scales and error-feedback residuals (the quantization error is carried into
the next sync, so the compression is unbiased over time).

8x less DCN traffic per sync × sync every K steps => up to 8K× DCN reduction.
Validated numerically in tests on a multi-device host mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _compressed_mean_one(x, err, axis_name: str):
    """Quantize (x + error feedback), all-reduce mean over the pod axis."""
    xf = x.astype(jnp.float32) + err
    q, scale = quantize_int8(xf)
    deq = dequantize_int8(q, scale)
    new_err = xf - deq                       # residual carried to next sync
    # int8 payload crosses DCN; the psum itself runs on the dequantized value
    # of each pod's int8 message (sum of 8-bit messages == sum of deq values).
    mean = jax.lax.pmean(deq, axis_name)
    return mean.astype(x.dtype), new_err


def make_pod_sync(mesh: Mesh, pod_axis: str = "pod"):
    """Returns sync(params, anchor, err) -> (synced params, new err).

    ``anchor`` is the last-synced parameter snapshot; the delta
    (params - anchor) is what gets compressed and averaged — equivalent to
    DiLoCo-style local steps with compressed outer sync.
    """
    if pod_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no '{pod_axis}' axis")
    other = tuple(a for a in mesh.axis_names if a != pod_axis)

    def _sync(params, anchor, err):
        def leaf(p, a, e):
            delta = p.astype(jnp.float32) - a.astype(jnp.float32)
            mean_delta, new_e = _compressed_mean_one(delta, e, pod_axis)
            return (a.astype(jnp.float32) + mean_delta).astype(p.dtype), new_e

        flat_p, tdef = jax.tree.flatten(params)
        flat_a = jax.tree.leaves(anchor)
        flat_e = jax.tree.leaves(err)
        pairs = [leaf(p, a, e) for p, a, e in zip(flat_p, flat_a, flat_e)]
        new_params = jax.tree.unflatten(tdef, [t[0] for t in pairs])
        new_err = jax.tree.unflatten(tdef, [t[1] for t in pairs])
        return new_params, new_err

    # shard_map over the pod axis; params keep their in-pod sharding via the
    # remaining axes (specs supplied by the caller through jit shardings).
    def sync(params, anchor, err, param_specs):
        in_specs = jax.tree.map(lambda s: s.spec if hasattr(s, "spec") else s,
                                param_specs)
        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            _sync, mesh=mesh,
            in_specs=(in_specs, in_specs, in_specs),
            out_specs=(in_specs, in_specs),
        )
        return fn(params, anchor, err)

    return sync


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
