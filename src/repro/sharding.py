"""Partition rules: param/batch/cache pytrees -> NamedSharding.

Scheme (DESIGN.md §6): batch over ("pod","data"); weights FSDP-sharded over
"data" and tensor-parallel over "model" (Megatron split: heads / d_ff /
vocab); experts over "model" when the expert count divides it (true EP),
expert-TP otherwise.  Dims that do not divide their mesh axis are REPLICATED
by default — visible as redundant compute in the roofline — and re-sharded in
hillclimb configs (e.g. qwen2 head padding), keeping the baseline honest.

Rules are name-based on pytree paths, so they cover params, optimizer states
(mirror params), and serving caches uniformly.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

BATCH_AXES = ("pod", "data")


def _axes(mesh: Mesh) -> tuple[tuple[str, ...], str, str]:
    names = tuple(mesh.axis_names)
    batch = tuple(a for a in BATCH_AXES if a in names)
    return batch, ("data" if "data" in names else names[0]), "model"


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % int(mesh.shape[axis]) == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------------

def param_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape: tuple[int, ...]
               ) -> P:
    """PartitionSpec for one parameter leaf (leading dim may be the layer
    stack; rules key on the trailing structure + leaf name)."""
    _, fsdp, tp = _axes(mesh)
    name = path.rsplit("/", 1)[-1]
    stacked = ("blocks/" in path or "tail/" in path or "encoder/" in path
               or "decoder/" in path)
    L = (None,) if stacked else ()
    # ZeRO-1: parameter leaves drop the FSDP axis (optimizer-state mirrors
    # under opt/m, opt/v keep it — they never enter the fwd/bwd graph)
    zero1_leaf = cfg.zero1 and path.startswith("params")

    def maybe(axis: str, n: int):
        if axis == fsdp and zero1_leaf:
            return None
        return axis if _div(n, mesh, axis) else None

    d = len(shape) - len(L)

    if name == "embed":
        return P(maybe(tp, shape[0]), maybe(fsdp, shape[1]))
    if name == "head":
        return P(maybe(fsdp, shape[0]), maybe(tp, shape[1]))

    # attention projections
    if name == "wq" and d == 3:
        return P(*L, maybe(fsdp, shape[-3]), maybe(tp, shape[-2]), None)
    if name in ("wk", "wv") and d == 3 and "attn" in path:
        return P(*L, maybe(fsdp, shape[-3]), maybe(tp, shape[-2]), None)
    if name == "wo" and d == 3:
        return P(*L, maybe(tp, shape[-3]), None, maybe(fsdp, shape[-1]))
    if name in ("bq", "bk", "bv"):
        return P(*L, maybe(tp, shape[-2]), None)
    if name == "u":  # rwkv bonus [H, hd]
        return P(*L, maybe(tp, shape[-2]), None)

    # MLP / MoE
    if name in ("w_gate", "w_up", "w_in") and d == 2:
        return P(*L, maybe(fsdp, shape[-2]), maybe(tp, shape[-1]))
    if name in ("w_down", "w_out") and d == 2:
        return P(*L, maybe(tp, shape[-2]), maybe(fsdp, shape[-1]))
    if name in ("w_gate", "w_up") and d == 3:        # moe [E, D, F]
        if _div(shape[-3], mesh, tp):                # true expert parallelism
            return P(*L, tp, maybe(fsdp, shape[-2]), None)
        if cfg.moe_zero1 and path.startswith("params"):
            # ZeRO-1: parameters replicated over data; optimizer states (the
            # opt/m, opt/v mirrors) keep the data-sharded layout below
            return P(*L, None, None, maybe(tp, shape[-1]))
        return P(*L, None, maybe(fsdp, shape[-2]), maybe(tp, shape[-1]))
    if name == "w_down" and d == 3:                  # moe [E, F, D]
        if _div(shape[-3], mesh, tp):
            return P(*L, tp, None, maybe(fsdp, shape[-1]))
        if cfg.moe_zero1 and path.startswith("params"):
            return P(*L, None, maybe(tp, shape[-2]), None)
        return P(*L, None, maybe(tp, shape[-2]), maybe(fsdp, shape[-1]))
    if name == "router":
        if cfg.moe_zero1 and path.startswith("params"):
            return P(*L, None, None)     # replicated for the shard_map island
        return P(*L, maybe(fsdp, shape[-2]), None)

    # rwkv dense [D, D] / lora
    if name in ("wr", "wk", "wv", "wg") and d == 2:
        return P(*L, maybe(fsdp, shape[-2]), maybe(tp, shape[-1]))
    if name == "wo" and d == 2:
        return P(*L, maybe(tp, shape[-2]), maybe(fsdp, shape[-1]))
    if name in ("maa_w1", "wd1") and d == 2:
        return P(*L, maybe(fsdp, shape[-2]), None)
    if name in ("wd2",) and d == 2:
        return P(*L, None, maybe(fsdp, shape[-1]))

    # rg-lru block
    if name in ("w_y", "w_x") and d == 2:
        return P(*L, maybe(fsdp, shape[-2]), maybe(tp, shape[-1]))
    if name in ("w_r", "w_i") and d == 2:
        return P(*L, None, maybe(tp, shape[-1]))
    if name == "conv_w":
        return P(*L, None, maybe(tp, shape[-1]))
    if name in ("conv_b", "lam", "b_r", "b_i"):
        return P(*L, maybe(tp, shape[-1]))

    # everything else (norms, mus, small vectors): replicated (layer-stacked)
    return P(*L, *([None] * d))


def make_param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Any) -> Any:
    def rule(path, leaf):
        return NamedSharding(mesh, param_spec(cfg, mesh, _path_str(path),
                                              leaf.shape))
    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------------

def _batch_axes_for(mesh: Mesh, b: int) -> tuple[str, ...]:
    """Largest prefix of the batch axes that divides the batch size."""
    batch, _, _ = _axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in batch])) if batch else 1
    if batch and b % n == 0:
        return batch
    if "data" in batch and b % int(mesh.shape["data"]) == 0:
        return ("data",)
    return ()


def batch_sharding(mesh: Mesh, batch_shape: Any) -> Any:
    def rule(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        axes = _batch_axes_for(mesh, leaf.shape[0])
        extra = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(axes if axes else None, *extra))

    return jax.tree.map(rule, batch_shape)


def cache_spec_for(cfg: ModelConfig, mesh: Mesh, path: str,
                   shape: tuple[int, ...]) -> P:
    """Serving caches: [L, B, ...] — batch over ("pod","data"), heads/width
    over "model" where divisible."""
    _, fsdp, tp = _axes(mesh)
    name = path.rsplit("/", 1)[-1]
    b = shape[1] if len(shape) >= 2 else 1
    batch = _batch_axes_for(mesh, b) or None

    def maybe(axis, n):
        return axis if _div(n, mesh, axis) else None

    kv_div = _div(cfg.n_kv, mesh, tp)
    if name in ("k", "v", "cross_k", "cross_v"):   # [L, B, W|Sm, K, hd]
        if kv_div:
            return P(None, batch, None, tp, None)
        # kv heads don't divide the model axis: shard the KV sequence instead
        # (flash-decode style — softmax over the sharded axis psums)
        return P(None, batch, maybe(tp, shape[-3]), None, None)
    if name == "abs":                      # [L, W] — must mirror the k/v choice
        return P(None, None if kv_div else maybe(tp, shape[-1]))
    if name == "S":                        # rwkv state [L, B, H, hd, hd]
        return P(None, batch, maybe(tp, shape[-3]), None, None)
    if name in ("x_prev_tm", "x_prev_cm"):  # [L, B, D]
        return P(None, batch, None)
    if name == "h":                        # rg-lru [L, B, R]
        return P(None, batch, maybe(tp, shape[-1]))
    if name == "conv":                     # [L, B, K-1, R]
        return P(None, batch, None, maybe(tp, shape[-1]))
    # fallback: shard the second axis as batch if it exists
    extra = (None,) * max(len(shape) - 2, 0)
    if len(shape) >= 2:
        return P(None, batch, *extra)
    return P(*((None,) * len(shape)))


def make_cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape: Any) -> Any:
    def rule(path, leaf):
        return NamedSharding(mesh, cache_spec_for(cfg, mesh, _path_str(path),
                                                  leaf.shape))
    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
