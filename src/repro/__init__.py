"""repro — topology-aware preemptive scheduling for co-located LLM workloads.

A production-grade JAX framework reproducing and extending
"Topology-aware Preemptive Scheduling for Co-located LLM Workloads"
(Zhang et al., Baichuan-Inc, 2024).
"""

__version__ = "1.0.0"
