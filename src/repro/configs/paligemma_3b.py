"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1, head_dim 256) d_ff=16384 vocab=257216.
The SigLIP patch frontend is a stub: input_specs provides precomputed patch
embeddings [B, 256, d]; the gemma text stack uses prefix-LM masking over the
patch prefix, GeGLU MLP, tied + sqrt(d)-scaled embeddings.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    d_ff=16384,
    vocab=257_216,
    head_dim=256,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    frontend="patch",
    frontend_len=256,
    prefix_lm=True,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=1,
    d_ff=128,
    vocab=512,
    head_dim=16,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    frontend="patch",
    frontend_len=8,
    prefix_lm=True,
)
