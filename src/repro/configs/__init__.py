"""Architecture registry: ``--arch <id>`` selectable configs + shapes."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

from .shapes import (SHAPES, ShapeSpec, batch_specs, cache_capacity,
                     decode_specs, shape_applicable, supports_long_context)

_MODULES = {
    "paligemma-3b": "paligemma_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-8b": "qwen3_8b",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "get_config", "all_configs", "SHAPES", "ShapeSpec",
    "batch_specs", "cache_capacity", "decode_specs", "shape_applicable",
    "supports_long_context",
]
