"""rwkv6-7b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 (attention-free, 64 heads × head_dim 64) d_ff=14336
vocab=65536.  Matrix-state recurrence: O(1) state in sequence length, so
long_500k runs.  The paper's attention-sharding concerns are inapplicable —
the scheduler treats instances identically (DESIGN.md §5).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # d_model / 64 rwkv head size
    n_kv=64,
    d_ff=14336,
    vocab=65_536,
    head_dim=64,
    attn_pattern="rwkv",
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,          # 2 rwkv heads of 64
    n_heads=2,
    n_kv=2,
    d_ff=256,
    vocab=512,
    head_dim=64,
    attn_pattern="rwkv",
)
