"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8, head_dim 64) d_ff=8192 vocab=128256,
tied embeddings, rope theta 500k.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv=8,
    d_ff=8192,
    vocab=128_256,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=512,
    head_dim=16,
    tie_embeddings=True,
)
