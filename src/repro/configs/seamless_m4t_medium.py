"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L (encoder) + 12L (decoder) d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 (padded to 256256 for 16-way vocab sharding).  The audio frame
frontend is a stub: input_specs provides precomputed frame embeddings
[B, S, d] for the encoder.  Non-gated ReLU FFN per the NLLB/M4T family.
"""
from repro.models.common import ModelConfig

VOCAB_RAW = 256_206         # paper value; padded so vocab % 16 == 0
VOCAB_PADDED = 256_256

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=VOCAB_PADDED,
    head_dim=64,
    act="relu",
    frontend="frames",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    enc_layers=2,
    dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=512,
    head_dim=16,
    act="relu",
    frontend="frames",
)
