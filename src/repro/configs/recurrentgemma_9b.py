"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427;
unverified].

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000.
Period-3 pattern [recurrent, recurrent, local-attn] (Griffin 1:2 ratio):
12 scanned super-blocks + 2 tail recurrent layers = 38.  Local window 2048
bounds attention; RG-LRU state is O(1) in sequence, so long_500k runs.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256_000,
    head_dim=256,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    attn_pattern="griffin_1_2",
    local_window=2048,
    rnn_width=4096,
    conv_kernel=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=5,           # 1 super-block + 2 tail layers (exercises the tail)
    d_model=64,
    n_heads=4,
    n_kv=1,
    d_ff=128,
    vocab=512,
    head_dim=16,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    attn_pattern="griffin_1_2",
    local_window=16,
    rnn_width=64,
    conv_kernel=4,
)
