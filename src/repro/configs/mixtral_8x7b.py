"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
sliding-window attention (window 4096) -> bounded KV cache, so long_500k runs.
"""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32_000,
    head_dim=128,
    swa_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=96,
    vocab=512,
    head_dim=16,
    swa_window=16,
    moe=MoEConfig(num_experts=4, top_k=2),
)
