"""qwen2-7b [dense] — GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4, head_dim 128) d_ff=18944 vocab=152064.
28 query heads do NOT divide the 16-way model axis — XLA pads; this is the
documented hillclimb target for uneven-sharding waste (EXPERIMENTS.md §Perf).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv=4,
    d_ff=18944,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=56,
    n_heads=7,
    n_kv=1,
    d_ff=128,
    vocab=512,
    head_dim=8,
    qkv_bias=True,
)
