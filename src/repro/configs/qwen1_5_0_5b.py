"""qwen1.5-0.5b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936, QKV bias, tied embeds.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=2816,
    vocab=151_936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=512,
    head_dim=16,
    qkv_bias=True,
    tie_embeddings=True,
)
