"""Assigned input shapes and ShapeDtypeStruct input specs per (arch × shape).

LM transformer shapes are seq_len × global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and only runs
for SSM / hybrid / SWA-bounded archs (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """KV-cache slots needed for a context of seq_len under this arch."""
    if cfg.swa_window is not None:
        return min(seq_len, cfg.swa_window)
    return seq_len


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic context: SSM state, Griffin local-attn, or SWA window."""
    return (cfg.attn_pattern in ("rwkv", "griffin_1_2")
            or cfg.swa_window is not None)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not supports_long_context(cfg):
        return False, ("full-attention arch: 500k dense KV decode is "
                       "unbounded/quadratic — skipped per assignment")
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.int32) -> dict:
    """ShapeDtypeStructs for the *data* inputs of train/prefill steps.

    For decode shapes this is the (token, pos) pair; the cache specs are
    derived with jax.eval_shape over prefill (launch/dryrun.py).
    """
    B, S = shape.global_batch, shape.seq_len
    cd = cfg.compute_dtype
    if cfg.is_encdec:
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cd),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.frontend == "patch":
        P = cfg.frontend_len
        return {
            "prefix_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), cd),
            "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_specs(shape: ShapeSpec) -> tuple:
    """(token, pos) specs for a decode step."""
    return (
        jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
