"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8,
qk-norm (OLMoE uses QK-Norm).  64 experts divide the 16-way model axis ->
true expert parallelism (4 experts/shard).
"""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,
    vocab=50_304,
    head_dim=128,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8),
)

SMOKE = ModelConfig(
    name="olmoe-1b-7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=64,
    vocab=512,
    head_dim=16,
    qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=4),
)
