"""Serving driver: batched requests through prefill + decode.

This is one *instance* in the paper's co-location model — see
examples/colocated_serving.py for the full scheduler-driven deployment.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(api, params, batch_size=args.batch, seq_len=args.seq)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, rng.integers(8, args.seq),
                                    dtype=np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = engine.stats["tokens"]
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    dec = engine.stats["decode_s"]
    if dec:
        print(f"decode p50 {1e3 * np.percentile(dec, 50):.1f}ms "
              f"p90 {1e3 * np.percentile(dec, 90):.1f}ms")
    for r in reqs[:2]:
        print(f"req {r.rid}: {r.output[:8]}...")


if __name__ == "__main__":
    main()
