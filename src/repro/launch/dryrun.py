import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real train/prefill/decode step with the
production shardings onto the single-pod (16,16) and multi-pod (2,16,16)
meshes, compiles it, and records memory analysis, cost analysis, and the
collective schedule (parsed from the post-SPMD HLO) into
results/dryrun/<cell>.json (+ gzipped HLO for offline analysis).

Resumable: existing result files are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import (ARCH_IDS, SHAPES, batch_specs, cache_capacity,
                           decode_specs, get_config, shape_applicable)
from repro.launch import hlo as hlo_util
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train import TrainConfig, make_train_step, train_state_specs

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _out_dir() -> str:
    d = os.path.abspath(os.environ.get("DRYRUN_DIR", RESULTS_DIR))
    os.makedirs(os.path.join(d, "hlo"), exist_ok=True)
    return d


def _cell_name(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}"


def _memory_dict(ma) -> dict:
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    return {f: int(getattr(ma, f, 0)) for f in fields}


def lower_cell(arch: str, shape_name: str, mesh, overrides=None):
    """Build and lower the step function for one cell.  Returns lowered."""
    import dataclasses as dc

    from repro.models.common import set_batch_axes

    cfg = get_config(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    api = build_model(cfg)
    repl = NamedSharding(mesh, P())
    set_batch_axes(shd._batch_axes_for(mesh, shape.global_batch), mesh=mesh)
    try:
        with mesh:
            if shape.kind == "train":
                state_shape = train_state_specs(api)
                state_sh = shd.make_param_shardings(cfg, mesh, state_shape)
                bspec = batch_specs(cfg, shape)
                b_sh = shd.batch_sharding(mesh, bspec)
                # 4-way microbatch accumulation keeps the per-device scan-saved
                # residuals (L x B_loc x S x d) within v5e HBM for the 7-9B
                # archs (see EXPERIMENTS.md §Dry-run memory notes).
                accum = int(os.environ.get("DRYRUN_ACCUM", "4"))
                step = make_train_step(api, TrainConfig(accum_steps=accum))
                fn = jax.jit(step, in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, repl),
                             donate_argnums=(0,))
                return fn.lower(state_shape, bspec)

            params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            p_sh = shd.make_param_shardings(cfg, mesh, params_shape)
            cap = cache_capacity(cfg, shape.seq_len)
            bspec = batch_specs(cfg, shape)

            axes = shd._batch_axes_for(mesh, shape.global_batch)
            logits_sh = NamedSharding(
                mesh, P(axes if axes else None,
                        "model" if cfg.vocab % int(mesh.shape["model"]) == 0
                        else None))

            if shape.kind == "prefill":
                b_sh = shd.batch_sharding(mesh, bspec)
                cache_shape = jax.eval_shape(
                    lambda p, b: api.prefill(p, b, cap), params_shape,
                    bspec)[1]
                cache_sh = shd.make_cache_shardings(cfg, mesh, cache_shape)
                fn = jax.jit(lambda p, b: api.prefill(p, b, cap),
                             in_shardings=(p_sh, b_sh),
                             out_shardings=(logits_sh, cache_sh))
                return fn.lower(params_shape, bspec)

            # decode: cache specs from an abstract prefill
            cache_shape = jax.eval_shape(
                lambda p, b: api.prefill(p, b, cap), params_shape, bspec)[1]
            cache_sh = shd.make_cache_shardings(cfg, mesh, cache_shape)
            tok_spec, pos_spec = decode_specs(shape)
            tok_sh = NamedSharding(mesh, P(axes) if axes else P())
            fn = jax.jit(api.decode_step,
                         in_shardings=(p_sh, cache_sh, tok_sh, repl),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(1,))
            return fn.lower(params_shape, cache_shape, tok_spec, pos_spec)
    finally:
        set_batch_axes(None)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, save_hlo: bool = True) -> dict:
    cell = _cell_name(arch, shape_name, mesh_kind)
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "cell": cell, "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(path, rec)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.time()
        lowered = lower_cell(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ca = hlo_util.cost_dict(compiled)
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            devices=int(jnp.prod(jnp.array(list(mesh.shape.values())))),
            memory=_memory_dict(ma),
            cost={k: float(ca[k]) for k in ("flops", "bytes accessed",
                                            "optimal_seconds") if k in ca},
            hlo=hlo_util.summarize(txt),
        )
        if save_hlo:
            with gzip.open(os.path.join(out_dir, "hlo", cell + ".txt.gz"),
                           "wt") as f:
                f.write(txt)
        print(compiled.memory_analysis())
        print({k: rec["cost"].get(k) for k in ("flops", "bytes accessed")})
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1)
    os.replace(tmp, path)


def run_scheduler_cell(mesh_kind: str, out_dir: str, force: bool = False) -> dict:
    """Dry-run the distributed candidate sourcing (cluster_parallel) itself.

    Lowers the per-size legacy sweep, the fused single-dispatch evaluator
    (all subset sizes + on-device Eq. 2 argmax + winner placement), and the
    sharded normal-cycle placement scorer over the mesh.
    """
    from repro.core.cluster_parallel import (lower_distributed_fused_source,
                                             lower_distributed_normal_cycle,
                                             lower_distributed_source)
    from repro.core.topology import RTX4090_SERVER

    cell = _cell_name("scheduler-sourcing", "cluster64k", mesh_kind)
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    rec = {"cell": cell, "arch": "scheduler-sourcing", "shape": "cluster64k",
           "mesh": mesh_kind, "kind": "scheduler"}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.time()
        lowered = lower_distributed_source(mesh, RTX4090_SERVER)
        compiled = lowered.compile()
        rec.update(status="ok", compile_s=round(time.time() - t0, 2),
                   memory=_memory_dict(compiled.memory_analysis()),
                   cost={k: float(v) for k, v in
                         hlo_util.cost_dict(compiled).items()
                         if k in ("flops", "bytes accessed")},
                   hlo=hlo_util.summarize(compiled.as_text()))
        t0 = time.time()
        fused = lower_distributed_fused_source(mesh, RTX4090_SERVER).compile()
        rec["fused"] = {"compile_s": round(time.time() - t0, 2),
                        "memory": _memory_dict(fused.memory_analysis()),
                        "hlo": hlo_util.summarize(fused.as_text())}
        t0 = time.time()
        normal = lower_distributed_normal_cycle(mesh,
                                                RTX4090_SERVER).compile()
        rec["normal_cycle"] = {
            "compile_s": round(time.time() - t0, 2),
            "memory": _memory_dict(normal.memory_analysis()),
            "hlo": hlo_util.summarize(normal.as_text())}
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--scheduler", action="store_true",
                    help="also dry-run the distributed scheduler sourcing")
    args = ap.parse_args()

    out_dir = _out_dir()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    total = ok = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind, out_dir,
                               force=args.force)
                total += 1
                ok += rec["status"] in ("ok", "skipped")
                print(f"[{rec['status']:>7}] {rec['cell']:58s} "
                      f"({time.time() - t0:6.1f}s)", flush=True)
        if args.scheduler or args.all:
            rec = run_scheduler_cell(mesh_kind, out_dir, force=args.force)
            total += 1
            ok += rec["status"] == "ok"
            print(f"[{rec['status']:>7}] {rec['cell']}", flush=True)
    print(f"dry-run: {ok}/{total} cells ok")
    if ok < total:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
