"""Production mesh definitions.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many (host) devices exist — for tests/examples."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
