"""Training driver: data pipeline -> jit'd train step -> checkpoints.

Fault tolerance: atomic checkpoints every --ckpt-every steps, crash-safe
resume (--resume picks the latest commit), and a --supervise mode that
restarts the run after failures (simulate one with --fail-at).  The data
pipeline is keyed by global step, so a restarted run consumes the exact
batches the crashed run would have.

XLA collective/compute overlap on real TPU is enabled via
--xla_tpu_enable_async_collective_fusion and the latency-hiding scheduler
(--xla_latency_hiding_scheduler); they are no-ops on CPU so we only document
them here.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.common import set_batch_axes
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step
from repro.train.step import train_state_specs


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    api = build_model(cfg)
    mesh = make_host_mesh(model=args.tp)
    set_batch_axes(shd._batch_axes_for(mesh, args.batch), mesh=mesh)
    tcfg = TrainConfig(opt=OptConfig(lr=args.lr, warmup_steps=args.warmup,
                                     total_steps=args.steps),
                       accum_steps=args.accum)
    step_fn = make_train_step(api, tcfg)
    state_shape = train_state_specs(api)
    state_sh = shd.make_param_shardings(cfg, mesh, state_shape)
    repl = NamedSharding(mesh, P())
    with mesh:
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, repl),
                           donate_argnums=(0,))
    return cfg, api, mesh, jit_step, state_sh


def run(args) -> dict:
    cfg, api, mesh, jit_step, state_sh = build(args)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    data = SyntheticTokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                   seed=args.seed))

    start = 0
    if args.resume:
        try:
            template = train_state_specs(api)
            state, meta = ckpt.restore_latest(template, state_sh)
            start = int(meta["step"])
            data.load_state_dict(meta["extra"].get("data", {"step": start}))
            print(f"resumed from step {start}")
        except FileNotFoundError:
            state = init_train_state(api, jax.random.PRNGKey(args.seed))
    else:
        state = init_train_state(api, jax.random.PRNGKey(args.seed))

    losses = []
    for step in range(start, args.steps):
        batch = data.batch_at(step)
        feed = {"tokens": jnp.asarray(batch["tokens"])}
        if cfg.is_encdec:
            feed["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                       cfg.compute_dtype)
        elif cfg.frontend == "patch":
            feed["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), cfg.compute_dtype)
        t0 = time.perf_counter()
        with mesh:  # constraint anchors need the mesh context at trace time
            state, metrics = jit_step(state, feed)
        loss = float(metrics["loss"])
        losses.append(loss)
        if args.fail_at is not None and step == args.fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.perf_counter() - t0:5.2f}s)", flush=True)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, step + 1, extra={"data": data.state_dict(),
                                              "arch": cfg.name})
    if args.ckpt_every:
        ckpt.save(state, args.steps, extra={"data": data.state_dict(),
                                            "arch": cfg.name})
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--supervise", action="store_true",
                    help="auto-restart from the latest checkpoint on failure")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    if not args.supervise:
        out = run(args)
        print(out)
        return
    restarts = 0
    while True:
        try:
            out = run(args)
            print(out)
            return
        except RuntimeError as e:  # node failure — restart from checkpoint
            restarts += 1
            print(f"[supervisor] failure: {e}; restart {restarts}")
            if restarts > args.max_restarts:
                raise
            args.resume = True
            args.fail_at = None


if __name__ == "__main__":
    main()
