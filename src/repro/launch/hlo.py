"""Post-SPMD HLO inspection: collective bytes + roofline terms.

``cost_analysis`` does not expose collective traffic, so we parse the
compiled HLO text and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.  XLA's cost analysis
also under-counts while-loop (lax.scan) bodies on some backends, so we
independently count per-iteration FLOPs inside while bodies and scale by the
trip count parsed from the loop condition.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in the HLO module text."""
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        if "fusion" in stripped.split("(")[0]:
            continue
        for kind in _COLLECTIVES:
            # match "= <ty> kind(" — an op definition, not a reference
            marker = f" {kind}("
            if marker not in stripped:
                continue
            if f" {kind}-start(" in stripped and marker not in stripped:
                continue
            head, _, args = stripped.partition(marker)
            if "=" not in head:
                continue
            # operand shapes are inside the argument list
            arg_str = args.split(")")[0]
            total = 0
            for dtype, dims in _SHAPE_RE.findall(arg_str):
                total += _shape_bytes(dtype, dims)
            if total == 0:
                # some printers omit operand types: fall back to result shape
                m = _SHAPE_RE.search(head)
                if m:
                    total = _shape_bytes(*m.groups())
            bytes_by[kind] += total
            count_by[kind] += 1
            break
    return CollectiveStats(bytes_by, count_by)


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort trip counts of while loops (scan) from known-trip-count
    annotations or constant comparisons in loop conditions."""
    counts = []
    for m in re.finditer(r'known_trip_count=\{?"?n"?[:=](\d+)', hlo_text):
        counts.append(int(m.group(1)))
    if counts:
        return counts
    # fallback: "%constant.N = s32[] constant(K)" referenced by compare in cond
    return counts


def summarize(hlo_text: str) -> dict:
    stats = collective_bytes(hlo_text)
    return {
        "collective_bytes": stats.total_bytes,
        "collective_bytes_by_kind": stats.bytes_by_kind,
        "collective_counts": stats.count_by_kind,
        "while_trip_counts": while_trip_counts(hlo_text),
    }


def cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jaxlib versions: newer
    jaxlibs return the properties dict directly, older ones a one-element
    list of dicts (one per partition)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


# ---------------------------------------------------------------------------------
# Full module walk: loop-trip-scaled FLOPs and collective bytes.
#
# XLA's cost_analysis counts while (lax.scan) bodies ONCE (verified
# empirically — see EXPERIMENTS.md §Roofline methodology).  Here we parse the
# module per-computation, attribute dot FLOPs / collective operand bytes to
# their computation, wire up the call graph (fusion/call/while/conditional),
# and evaluate from ENTRY with while bodies multiplied by their trip count
# (read from the loop-condition constant).
# ---------------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{")
_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s+=\s+([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"(%?[\w\.\-]+(?:,\s*%?[\w\.\-]+)*)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _prod(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _args_of(stripped: str, op: str) -> list[str]:
    """Operand %names of `... op(...)` (first level of parens)."""
    args = stripped.split(f" {op}(", 1)[1]
    depth = 1
    out = []
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out = args[:i]
                break
    return _OPERAND_RE.findall(out)


def parse_computations(hlo_text: str) -> dict:
    """comp name -> {"flops", "coll_bytes", "whiles": [(body, cond)],
    "calls": [names], "max_const": int, "entry": bool}.

    Two passes: (1) collect every op's result shape so untyped operand
    references can be resolved; (2) attribute dot FLOPs, collective operand
    bytes, and call-graph edges per computation.
    """
    lines = hlo_text.splitlines()
    shapes: dict[str, tuple[str, str]] = {}
    for raw in lines:
        m = _DEF_RE.match(raw.strip())
        if m:
            name, dtype, dims = m.groups()
            shapes[name] = (dtype, dims)

    def op_bytes(name: str) -> int:
        if name in shapes:
            return _shape_bytes(*shapes[name])
        return 0

    comps: dict[str, dict] = {}
    cur = None
    for raw in lines:
        line = raw.rstrip()
        if not line.startswith(" ") and line.endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m and ("(" in line or "ENTRY" in line):
                cur = m.group(1)
                comps[cur] = {"flops": 0, "coll_bytes": 0, "whiles": [],
                              "calls": [], "max_const": 0,
                              "entry": line.startswith("ENTRY")}
                continue
        if cur is None:
            continue
        stripped = line.strip()
        if stripped == "}":
            continue
        # dot flops: 2 * prod(result) * prod(lhs contracting dims)
        if " dot(" in stripped and "=" in stripped.split(" dot(")[0]:
            dm = _DEF_RE.match(stripped)
            ops = _args_of(stripped, "dot")
            if dm and ops and ops[0] in shapes:
                _, _, result_dims = dm.groups()
                lhs_dims_s = shapes[ops[0]][1]
                lhs = ([int(x) for x in lhs_dims_s.split(",")]
                       if lhs_dims_s else [])
                cm = _LHS_CONTRACT_RE.search(stripped)
                contract = 1
                if cm and cm.group(1):
                    for d in cm.group(1).split(","):
                        di = int(d)
                        if di < len(lhs):
                            contract *= lhs[di]
                comps[cur]["flops"] += 2 * _prod(result_dims) * contract
        # collectives: sum operand bytes (resolved via the shape table)
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped and "=" in stripped.split(
                    f" {kind}(")[0]:
                total = sum(op_bytes(n) for n in _args_of(stripped, kind))
                if total == 0:  # fallback: result shape
                    dm = _DEF_RE.match(stripped)
                    if dm:
                        total = _shape_bytes(dm.group(2), dm.group(3))
                comps[cur]["coll_bytes"] += total
                break
        # call graph
        if " while(" in stripped:
            body = re.search(r"body=%?([\w\.\-]+)", stripped)
            cond = re.search(r"condition=%?([\w\.\-]+)", stripped)
            if body and cond:
                comps[cur]["whiles"].append((body.group(1), cond.group(1)))
        else:
            for cm_ in _CALL_RE.finditer(stripped):
                for name in cm_.group(1).split(","):
                    comps[cur]["calls"].append(name.strip().lstrip("%"))
        for c in _CONST_RE.findall(stripped):
            comps[cur]["max_const"] = max(comps[cur]["max_const"], int(c))
    return comps


def walk_stats(hlo_text: str) -> dict:
    """Loop-trip-scaled (flops, collective_bytes) for the whole module."""
    comps = parse_computations(hlo_text)
    memo: dict[str, tuple[int, int]] = {}

    def trip_count(cond: str) -> int:
        c = comps.get(cond)
        return max(1, c["max_const"]) if c else 1

    def eval_comp(name: str, seen: frozenset) -> tuple[int, int]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or name in seen:
            return (0, 0)
        seen = seen | {name}
        fl, by = c["flops"], c["coll_bytes"]
        for callee in c["calls"]:
            f2, b2 = eval_comp(callee, seen)
            fl += f2
            by += b2
        for body, cond in c["whiles"]:
            t = trip_count(cond)
            f2, b2 = eval_comp(body, seen)
            fl += t * f2
            by += t * b2
        memo[name] = (fl, by)
        return memo[name]

    entries = [n for n, c in comps.items() if c["entry"]]
    if not entries:
        entries = list(comps)[:1]
    fl, by = eval_comp(entries[-1], frozenset())
    return {"flops_scaled": fl, "collective_bytes_scaled": by,
            "n_computations": len(comps)}
