"""Model substrate: all assigned architectures in pure JAX."""
from .api import ModelApi, build_model
from .common import ModelConfig, MoEConfig, count_params

__all__ = ["ModelApi", "build_model", "ModelConfig", "MoEConfig",
           "count_params"]
