"""Feed-forward layers: gated MLP (SwiGLU/GeGLU), plain MLP, and top-k MoE.

The MoE uses sort-based capacity dispatch (TPU-friendly: batched per-expert
matmuls on dense [E, C, d] buffers, no ragged ops): tokens are argsorted by
expert id, placed into per-expert capacity slots, processed with one batched
einsum per projection, and combined with their router gates.  Tokens beyond
an expert's capacity are dropped (standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (ACTIVATIONS, ModelConfig, constrain_spec, dense_init,
                     split_keys)


# ---------------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.act == "relu":  # non-gated (seamless / classic transformer)
        return {
            "w_in": dense_init(ks[0], (d, f)),
            "w_out": dense_init(ks[1], (f, d)),
        }
    return {
        "w_gate": dense_init(ks[0], (d, f)),
        "w_up": dense_init(ks[1], (d, f)),
        "w_down": dense_init(ks[2], (f, d)),
    }


def mlp_apply(p, cfg: ModelConfig, x):
    cd = cfg.compute_dtype
    act = ACTIVATIONS[cfg.act]
    if "w_in" in p:
        h = act(jnp.einsum("BSD,DF->BSF", x, p["w_in"].astype(cd)))
        return jnp.einsum("BSF,FD->BSD", h, p["w_out"].astype(cd))
    g = act(jnp.einsum("BSD,DF->BSF", x, p["w_gate"].astype(cd)))
    u = jnp.einsum("BSD,DF->BSF", x, p["w_up"].astype(cd))
    return jnp.einsum("BSF,FD->BSD", g * u, p["w_down"].astype(cd))


# ---------------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, E)),
        "w_gate": dense_init(ks[1], (E, d, f)),
        "w_up": dense_init(ks[2], (E, d, f)),
        "w_down": dense_init(ks[3], (E, f, d)),
    }


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    moe = cfg.moe
    c = int(num_tokens * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8 for TPU lanes


def moe_apply(p, cfg: ModelConfig, x):
    """x: [B, S, d] -> [B, S, d].  Returns (out, aux) with load-balance stats."""
    if cfg.moe.dispatch == "per_sequence" and x.shape[0] > 1:
        # data-local dispatch: sort/capacity buffers never cross the batch
        # sharding (tokens of one sequence live on one data shard)
        out, aux = jax.vmap(lambda xb: _moe_tokens(p, cfg, xb[None]))(x)
        return out[:, 0], jax.tree.map(jnp.mean, aux)
    if cfg.moe.dispatch == "shard_map":
        from jax.sharding import PartitionSpec as P

        from .common import get_batch_axes, get_mesh

        axes = get_batch_axes()
        if axes and x.shape[0] > 1:
            # manual island over the batch axes: dispatch/sort/capacity math
            # never crosses data shards; the model axis stays auto (GSPMD
            # shards the expert einsums by d_ff as usual).  Requires expert
            # weights replicated over data (cfg.moe_zero1).
            def body(p_, xb):
                out, aux = _moe_tokens(p_, cfg, xb)
                aux = jax.tree.map(lambda a: jax.lax.pmean(a, axes), aux)
                return out, aux

            from .common import shard_map_compat

            fn = shard_map_compat(
                body,
                get_mesh(),
                in_specs=(jax.tree.map(lambda _: P(), p),
                          P(axes, None, None)),
                out_specs=(P(axes, None, None), P()),
                axis_names=set(axes),
            )
            return fn(p, x)
    return _moe_tokens(p, cfg, x)


def _moe_tokens(p, cfg: ModelConfig, x):
    moe = cfg.moe
    cd = cfg.compute_dtype
    B, S, d = x.shape
    T = B * S
    E, k = moe.num_experts, moe.top_k
    C = moe_capacity(cfg, T)
    xf = x.reshape(T, d)

    logits = jnp.einsum("TD,DE->TE", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_e = expert_idx.reshape(-1)                            # [T*k]
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)   # token of each slot
    order = jnp.argsort(flat_e)                                # stable in jax
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]

    counts = jnp.bincount(flat_e, length=E)                    # [E]
    excl = jnp.cumsum(counts) - counts                         # exclusive prefix
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - excl[sorted_e]
    keep = pos_in_e < C
    e_safe = jnp.where(keep, sorted_e, E)                      # dropped -> dummy row
    pos_safe = jnp.where(keep, pos_in_e, C - 1)

    buf = jnp.zeros((E + 1, C, d), cd)
    buf = buf.at[e_safe, pos_safe].set(xf[sorted_tok].astype(cd))

    act = ACTIVATIONS[cfg.act]
    constrain = (moe.constrain_ffn and cfg.moe.dispatch == "global")
    buf_c = constrain_spec(buf, (None, None, None)) if constrain else buf
    g = act(jnp.einsum("ECD,EDF->ECF", buf_c[:E], p["w_gate"].astype(cd)))
    u = jnp.einsum("ECD,EDF->ECF", buf_c[:E], p["w_up"].astype(cd))
    if constrain:
        # Megatron pattern: intermediates live sharded on the model axis,
        # the psum happens once on the (d-sized) down-projection output
        g = constrain_spec(g, (None, None, "model"))
        u = constrain_spec(u, (None, None, "model"))
    out_buf = jnp.einsum("ECF,EFD->ECD", g * u, p["w_down"].astype(cd))
    if constrain:
        out_buf = constrain_spec(out_buf, (None, None, None))
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, C, d), cd)], axis=0)

    gathered = out_buf[e_safe, pos_safe] * keep[:, None].astype(cd)
    inv = jnp.argsort(order)
    y_flat = gathered[inv]                                     # back to [T*k, d]
    y = (y_flat.reshape(T, k, d)
         * gate_vals.reshape(T, k, 1).astype(cd)).sum(axis=1)

    # aux: load-balancing loss terms (Switch-style) + drop fraction
    me = jnp.mean(probs, axis=0)                               # mean router prob
    ce = counts.astype(jnp.float32) / (T * k)                  # token fraction
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "dropped_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y.reshape(B, S, d), aux
