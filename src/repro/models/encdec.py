"""Encoder-decoder backbone (seamless-m4t-medium class).

The modality frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_enc, d] for the encoder.  The decoder is a
standard causal stack with cross-attention; serving uses a self-attention KV
cache plus per-layer precomputed cross-attention K/V from the encoder memory.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn
from .common import (ModelConfig, Params, constrain_batch, embed_init,
                     maybe_remat, rmsnorm, rmsnorm_init, split_keys,
                     stack_layers)
from .lm import chunked_xent, last_token_logits


def _enc_block_init(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model),
        "mlp": ffn.mlp_init(ks[1], cfg),
    }


def _dec_block_init(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "self_attn": attn.attn_init(ks[0], cfg),
        "ln_x": rmsnorm_init(cfg.d_model),
        "cross_attn": attn.attn_init(ks[1], cfg),
        "ln2": rmsnorm_init(cfg.d_model),
        "mlp": ffn.mlp_init(ks[2], cfg),
    }


def encdec_init(key, cfg: ModelConfig) -> Params:
    ks = split_keys(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "encoder": stack_layers(partial(_enc_block_init, cfg=cfg), ks[1],
                                cfg.enc_layers),
        "decoder": stack_layers(partial(_dec_block_init, cfg=cfg), ks[2],
                                cfg.dec_layers),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "head": embed_init(ks[3], cfg.vocab, cfg.d_model).T,
    }


def encode(params, cfg: ModelConfig, frames) -> jnp.ndarray:
    """frames: [B, S_enc, d] stub embeddings -> encoder memory [B, S_enc, d]."""
    x = frames.astype(cfg.compute_dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    mask = attn.make_mask(S, S, causal=False)

    def body(carry, bp):
        carry = constrain_batch(carry)
        h, _ = attn.attn_forward(bp["attn"], cfg,
                                 rmsnorm(bp["ln1"], carry, cfg.rms_eps),
                                 positions=positions, mask=mask)
        y = carry + h
        y = y + ffn.mlp_apply(bp["mlp"], cfg, rmsnorm(bp["ln2"], y, cfg.rms_eps))
        return y, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.rms_eps)


def _decoder_blocks(params, cfg: ModelConfig, x, memory, *, collect_cache=False,
                    capacity=None):
    S = x.shape[1]
    Sm = memory.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    mem_positions = jnp.arange(Sm, dtype=jnp.int32)[None]
    self_mask = attn.make_mask(S, S, causal=True)
    cross_mask = attn.make_mask(S, Sm, causal=False)

    def body(carry, bp):
        y = constrain_batch(carry)
        h, (k, v) = attn.attn_forward(bp["self_attn"], cfg,
                                      rmsnorm(bp["ln1"], y, cfg.rms_eps),
                                      positions=positions, mask=self_mask)
        y = y + h
        h, (ck, cv) = attn.attn_forward(
            bp["cross_attn"], cfg, rmsnorm(bp["ln_x"], y, cfg.rms_eps),
            positions=positions, mask=cross_mask, kv_x=memory,
            kv_positions=mem_positions, use_rope=False)
        y = y + h
        y = y + ffn.mlp_apply(bp["mlp"], cfg, rmsnorm(bp["ln2"], y, cfg.rms_eps))
        cache = None
        if collect_cache:
            cache = {
                "self": attn.fill_cache(
                    attn.init_cache(cfg, y.shape[0], capacity), k, v,
                    positions[0]),
                "cross_k": ck, "cross_v": cv,
            }
        return y, cache

    x, caches = jax.lax.scan(maybe_remat(body, cfg), x, params["decoder"])
    return x, caches


def encdec_loss(params, cfg: ModelConfig, batch):
    """batch: {"frames": [B,S_enc,d], "tokens": [B,S_dec]}."""
    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    hidden, _ = _decoder_blocks(params, cfg, x, memory)
    hidden = rmsnorm(params["final_norm"], hidden, cfg.rms_eps)
    labels = tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    loss, n = chunked_xent(params, cfg, hidden[:, :-1], labels, mask)
    return loss / jnp.maximum(n, 1.0), {}


def encdec_prefill(params, cfg: ModelConfig, batch, capacity: int):
    """Returns (last-token logits, caches incl. cross-attn K/V)."""
    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    hidden, caches = _decoder_blocks(params, cfg, x, memory,
                                     collect_cache=True, capacity=capacity)
    hidden = rmsnorm(params["final_norm"], hidden, cfg.rms_eps)
    return last_token_logits(params, cfg, hidden[:, -1]), caches


def encdec_decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One decoder token against cached self-KV + cross-KV."""
    x1 = params["embed"].astype(cfg.compute_dtype)[token[:, None]]

    def body(carry, xs):
        bp, cache = xs
        y = constrain_batch(carry)
        h, self_cache = attn.attn_decode(
            bp["self_attn"], cfg, rmsnorm(bp["ln1"], y, cfg.rms_eps), cache["self"],
            pos)
        y = y + h
        # cross-attention against static memory K/V (no rope, no cache update)
        q, _, _ = attn._project_qkv(bp["cross_attn"], cfg,
                                    rmsnorm(bp["ln_x"], y, cfg.rms_eps))
        Sm = cache["cross_k"].shape[1]
        mask = jnp.ones((1, 1, 1, 1, Sm), bool)
        h = attn._gqa_attend(bp["cross_attn"], cfg, q, cache["cross_k"],
                             cache["cross_v"], mask)
        y = y + h
        y = y + ffn.mlp_apply(bp["mlp"], cfg, rmsnorm(bp["ln2"], y, cfg.rms_eps))
        return y, {"self": self_cache, "cross_k": cache["cross_k"],
                   "cross_v": cache["cross_v"]}

    x1, caches = jax.lax.scan(maybe_remat(body, cfg), x1, (params["decoder"], caches))
    x1 = rmsnorm(params["final_norm"], x1, cfg.rms_eps)
    return last_token_logits(params, cfg, x1[:, 0]), caches