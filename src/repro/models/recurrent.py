"""Recurrent sequence mixers: RWKV-6 ("Finch") time/channel mix and the
RG-LRU block of RecurrentGemma/Griffin.

TPU adaptation notes (DESIGN.md §3):
  * RG-LRU is a *diagonal* linear recurrence, so training uses
    ``lax.associative_scan`` (log-depth, VPU-friendly) instead of a sequential
    loop.
  * RWKV-6 carries a matrix state (hd×hd per head) with data-dependent
    per-channel decay; the exact sequential ``lax.scan`` is the reference
    path (used for decode and correctness); a chunked MXU formulation is the
    hillclimb lever for the train cell (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, split_keys

LORA_R = 32       # rwkv6 ddlerp lora rank
DECAY_R = 64      # rwkv6 decay lora rank
RG_C = 8.0        # rg-lru temperature constant


# ===================================================================================
# RWKV-6
# ===================================================================================

def rwkv_heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = 64
    return cfg.d_model // hd, hd


def rwkv_timemix_init(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H, hd = rwkv_heads(cfg)
    ks = split_keys(key, 10)
    return {
        "mu_x": jnp.zeros((D,), jnp.float32),
        "mu": jnp.zeros((5, D), jnp.float32),              # r,k,v,w,g base mixes
        "maa_w1": dense_init(ks[0], (D, 5 * LORA_R), scale=0.01),
        "maa_w2": dense_init(ks[1], (5, LORA_R, D), scale=0.01),
        "wr": dense_init(ks[2], (D, D)),
        "wk": dense_init(ks[3], (D, D)),
        "wv": dense_init(ks[4], (D, D)),
        "wg": dense_init(ks[5], (D, D)),
        "wo": dense_init(ks[6], (D, D)),
        "w0": jnp.full((D,), -3.0, jnp.float32),           # decay bias
        "wd1": dense_init(ks[7], (D, DECAY_R), scale=0.01),
        "wd2": dense_init(ks[8], (DECAY_R, D), scale=0.01),
        "u": dense_init(ks[9], (H, hd), scale=0.5),        # bonus (time_faaaa)
        "gn_scale": jnp.ones((D,), jnp.float32),           # per-head group norm
    }


def _ddlerp(p, x, xx):
    """Data-dependent token-shift interpolation -> 5 mixed streams [...,5,D]."""
    base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("...D,DR->...R", base,
                               p["maa_w1"].astype(x.dtype)))
    B5 = lora.shape[-1] // 5
    lora = lora.reshape(*lora.shape[:-1], 5, B5)
    delta = jnp.einsum("...FR,FRD->...FD", lora, p["maa_w2"].astype(x.dtype))
    mix = p["mu"].astype(x.dtype) + delta                  # [...,5,D]
    return x[..., None, :] + xx[..., None, :] * mix


def _rwkv_projections(p, cfg: ModelConfig, x, x_prev):
    """Common to train and decode: compute r,k,v,w,g from x and shifted x."""
    H, hd = rwkv_heads(cfg)
    xx = x_prev - x
    mixed = _ddlerp(p, x, xx)                              # [...,5,D]
    xr, xk, xv, xw, xg = (mixed[..., i, :] for i in range(5))
    cd = x.dtype
    r = jnp.einsum("...D,DE->...E", xr, p["wr"].astype(cd))
    k = jnp.einsum("...D,DE->...E", xk, p["wk"].astype(cd))
    v = jnp.einsum("...D,DE->...E", xv, p["wv"].astype(cd))
    g = jax.nn.silu(jnp.einsum("...D,DE->...E", xg, p["wg"].astype(cd)))
    dec = jnp.tanh(jnp.einsum("...D,DR->...R", xw, p["wd1"].astype(cd)))
    logw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "...R,RD->...D", dec.astype(jnp.float32), p["wd2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(jnp.clip(logw, -10.0, 6.0)))      # data-dependent decay
    split = lambda t: t.reshape(*t.shape[:-1], H, hd)
    return split(r), split(k), split(v), split(w.astype(jnp.float32)), g


def _groupnorm_heads(scale, y, H, hd, eps=1e-5):
    """Per-head normalization of the wkv output (rwkv's GroupNorm(H))."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(*y.shape[:-2], H * hd) * scale.astype(jnp.float32)
    return yn


def rwkv_timemix_forward(p, cfg: ModelConfig, x, state=None):
    """Full-sequence forward. x: [B,S,D].  Returns (out, new_state).

    state = {"S": [B,H,hd,hd] f32, "x_prev": [B,D]} (None -> zeros).
    """
    B, S, D = x.shape
    H, hd = rwkv_heads(cfg)
    if state is None:
        state = {
            "S": jnp.zeros((B, H, hd, hd), jnp.float32),
            "x_prev": jnp.zeros((B, D), x.dtype),
        }
    x_shift = jnp.concatenate([state["x_prev"][:, None], x[:, :-1]], axis=1)
    r, k, v, w, g = _rwkv_projections(p, cfg, x, x_shift)

    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                               # [B,H,hd] each
        rt = rt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        kv = kt[..., :, None] * vt[..., None, :]           # [B,H,hd,hd]
        yt = jnp.einsum("BHi,BHij->BHj", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, yt

    tm = lambda t: jnp.moveaxis(t, 1, 0)                   # time-major for scan
    S_fin, y = jax.lax.scan(step, state["S"], (tm(r), tm(k), tm(v), tm(w)))
    y = jnp.moveaxis(y, 0, 1)                              # [B,S,H,hd]
    y = _groupnorm_heads(p["gn_scale"], y, H, hd).astype(x.dtype)
    out = jnp.einsum("BSD,DE->BSE", y * g, p["wo"].astype(x.dtype))
    return out, {"S": S_fin, "x_prev": x[:, -1]}


def rwkv_timemix_decode(p, cfg: ModelConfig, x1, state):
    """Single-token step. x1: [B,D]."""
    H, hd = rwkv_heads(cfg)
    r, k, v, w, g = _rwkv_projections(p, cfg, x1, state["x_prev"])
    rt = r.astype(jnp.float32)
    kt = k.astype(jnp.float32)
    vt = v.astype(jnp.float32)
    u = p["u"].astype(jnp.float32)
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("BHi,BHij->BHj", rt, state["S"] + u[..., :, None] * kv)
    S = w[..., :, None] * state["S"] + kv
    y = _groupnorm_heads(p["gn_scale"], y[:, None], H, hd)[:, 0].astype(x1.dtype)
    out = jnp.einsum("BD,DE->BE", y * g, p["wo"].astype(x1.dtype))
    return out, {"S": S, "x_prev": x1}


def rwkv_channelmix_init(key, cfg: ModelConfig) -> dict:
    D, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "mu_k": jnp.zeros((D,), jnp.float32),
        "mu_r": jnp.zeros((D,), jnp.float32),
        "wk": dense_init(ks[0], (D, f)),
        "wv": dense_init(ks[1], (f, D)),
        "wr": dense_init(ks[2], (D, D)),
    }


def rwkv_channelmix(p, cfg: ModelConfig, x, x_prev):
    """x: [..., D]; x_prev: same shape (token-shifted)."""
    cd = x.dtype
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(cd)
    xr = x + xx * p["mu_r"].astype(cd)
    k = jnp.square(jax.nn.relu(jnp.einsum("...D,DF->...F", xk,
                                          p["wk"].astype(cd))))
    kv = jnp.einsum("...F,FD->...D", k, p["wv"].astype(cd))
    return jax.nn.sigmoid(jnp.einsum("...D,DE->...E", xr,
                                     p["wr"].astype(cd))) * kv


# ===================================================================================
# RG-LRU (RecurrentGemma / Griffin)
# ===================================================================================

def rglru_block_init(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    R = cfg.rnn_width or D
    K = cfg.conv_kernel
    ks = split_keys(key, 6)
    return {
        "w_y": dense_init(ks[0], (D, R)),
        "w_x": dense_init(ks[1], (D, R)),
        "conv_w": dense_init(ks[2], (K, R), scale=K ** -0.5),
        "conv_b": jnp.zeros((R,), jnp.float32),
        "w_r": dense_init(ks[3], (R, R), scale=0.01),
        "b_r": jnp.zeros((R,), jnp.float32),
        "w_i": dense_init(ks[4], (R, R), scale=0.01),
        "b_i": jnp.zeros((R,), jnp.float32),
        "lam": jnp.full((R,), 3.0, jnp.float32),   # sigma(3) ~ .95 slow decay
        "w_out": dense_init(ks[5], (R, D)),
    }


def _causal_conv(w, b, x, prev):
    """Depthwise causal conv1d.  x: [B,S,R]; prev: [B,K-1,R] carried state."""
    K = w.shape[0]
    full = jnp.concatenate([prev, x], axis=1)               # [B, S+K-1, R]
    S = x.shape[1]
    out = sum(full[:, i:i + S] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype), full[:, -(K - 1):]


def _rglru_gates(p, xc):
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -RG_C * r * jax.nn.softplus(p["lam"])           # <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) * (i * xf)
    return a, b


def rglru_block_forward(p, cfg: ModelConfig, x, state=None):
    """Full-sequence Griffin recurrent block.  x: [B,S,D]."""
    B, S, D = x.shape
    R = cfg.rnn_width or D
    K = cfg.conv_kernel
    cd = x.dtype
    if state is None:
        state = {
            "h": jnp.zeros((B, R), jnp.float32),
            "conv": jnp.zeros((B, K - 1, R), cd),
        }
    y = jax.nn.gelu(jnp.einsum("BSD,DR->BSR", x, p["w_y"].astype(cd)))
    xb = jnp.einsum("BSD,DR->BSR", x, p["w_x"].astype(cd))
    xc, conv_state = _causal_conv(p["conv_w"], p["conv_b"], xb, state["conv"])
    a, b = _rglru_gates(p, xc)                              # [B,S,R] f32
    # h_t = a_t h_{t-1} + b_t  — diagonal linear recurrence => associative scan
    b = b.at[:, 0].add(a[:, 0] * state["h"])                # fold in carry
    def comb(lhs, rhs):
        return (rhs[0] * lhs[0], rhs[0] * lhs[1] + rhs[1])
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    out = jnp.einsum("BSR,RD->BSD", (h.astype(cd) * y), p["w_out"].astype(cd))
    return out, {"h": h[:, -1], "conv": conv_state}


def rglru_block_decode(p, cfg: ModelConfig, x1, state):
    """Single-token step. x1: [B,D]."""
    cd = x1.dtype
    y = jax.nn.gelu(x1 @ p["w_y"].astype(cd))
    xb = x1 @ p["w_x"].astype(cd)
    K = p["conv_w"].shape[0]
    window = jnp.concatenate([state["conv"], xb[:, None]], axis=1)  # [B,K,R]
    xc = sum(window[:, i] * p["conv_w"][i].astype(cd) for i in range(K))
    xc = xc + p["conv_b"].astype(cd)
    a, b = _rglru_gates(p, xc)
    h = a * state["h"] + b
    out = (h.astype(cd) * y) @ p["w_out"].astype(cd)
    return out, {"h": h, "conv": window[:, 1:]}
