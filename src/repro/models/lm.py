"""Decoder-only language model covering all assigned families.

Families map to block kinds:
  dense / vlm        -> "attn"      (attn + gated MLP)
  moe                -> "moe"       (attn + top-k MoE; optional SWA)
  ssm (rwkv6)        -> "rwkv"      (time-mix + channel-mix)
  hybrid (rec.gemma) -> "griffin"   (period-3 super-block: rglru, rglru,
                                     local-attn — each followed by an MLP)

Repeated blocks are stacked on a leading layer axis and run under
``lax.scan``; caches/states are scanned in/out per layer.  Cross-entropy is
computed in sequence chunks so full [T, vocab] logits are never materialized.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn, recurrent as rec
from .common import (ModelConfig, Params, constrain_batch, constrain_hidden,
                     embed_init, maybe_remat, rmsnorm, rmsnorm_init,
                     split_keys, stack_layers)

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------------

def block_kind(cfg: ModelConfig) -> str:
    if cfg.attn_pattern == "rwkv":
        return "rwkv"
    if cfg.attn_pattern == "griffin_1_2":
        return "griffin"
    return "moe" if cfg.moe is not None else "attn"


def _attn_block_init(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 2)
    p = {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attn.attn_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = ffn.moe_init(ks[1], cfg)
    else:
        p["mlp"] = ffn.mlp_init(ks[1], cfg)
    return p


def _rwkv_block_init(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "tm": rec.rwkv_timemix_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model),
        "cm": rec.rwkv_channelmix_init(ks[1], cfg),
    }


def _griffin_sub_init(key, cfg: ModelConfig, temporal: str) -> dict:
    ks = split_keys(key, 2)
    mix = (rec.rglru_block_init(ks[0], cfg) if temporal == "rglru"
           else attn.attn_init(ks[0], cfg))
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "mix": mix,
        "ln2": rmsnorm_init(cfg.d_model),
        "mlp": ffn.mlp_init(ks[1], cfg),
    }


def _griffin_block_init(key, cfg: ModelConfig) -> dict:
    ks = split_keys(key, 3)
    return {
        "sub0": _griffin_sub_init(ks[0], cfg, "rglru"),
        "sub1": _griffin_sub_init(ks[1], cfg, "rglru"),
        "sub2": _griffin_sub_init(ks[2], cfg, "attn"),
    }


def _n_scanned(cfg: ModelConfig) -> tuple[int, int]:
    """(#scanned blocks, #tail rglru layers) — tail only for griffin depth%3."""
    if block_kind(cfg) == "griffin":
        return cfg.n_layers // 3, cfg.n_layers % 3
    return cfg.n_layers, 0


def lm_init(key, cfg: ModelConfig) -> Params:
    kind = block_kind(cfg)
    ks = split_keys(key, 4)
    init_one = {
        "attn": _attn_block_init, "moe": _attn_block_init,
        "rwkv": _rwkv_block_init, "griffin": _griffin_block_init,
    }[kind]
    n_blocks, n_tail = _n_scanned(cfg)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "blocks": stack_layers(partial(init_one, cfg=cfg), ks[1], n_blocks),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if n_tail:
        params["tail"] = stack_layers(
            partial(_griffin_sub_init, cfg=cfg, temporal="rglru"), ks[2], n_tail)
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[3], cfg.vocab, cfg.d_model).T
    return params


# ---------------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------------

def _apply_attn_block(bp, cfg: ModelConfig, x, positions, mask, window,
                      capacity=None, mask_args=None):
    h, (k, v) = attn.attn_forward(bp["attn"], cfg, rmsnorm(bp["ln1"], x,
                                                           cfg.rms_eps),
                                  positions=positions, mask=mask,
                                  mask_args=mask_args)
    x = x + h
    if "moe" in bp:
        h, aux = ffn.moe_apply(bp["moe"], cfg, rmsnorm(bp["ln2"], x,
                                                       cfg.rms_eps))
    else:
        h, aux = ffn.mlp_apply(bp["mlp"], cfg, rmsnorm(bp["ln2"], x,
                                                       cfg.rms_eps)), None
    x = x + h
    cache = None
    if capacity is not None:
        cache = attn.fill_cache(
            attn.init_cache(cfg, x.shape[0], capacity), k, v, positions[0])
    return x, cache, aux


def _apply_rwkv_block(bp, cfg: ModelConfig, x, collect):
    h, tm_state = rec.rwkv_timemix_forward(bp["tm"], cfg,
                                           rmsnorm(bp["ln1"], x, cfg.rms_eps))
    x = x + h
    xn = rmsnorm(bp["ln2"], x, cfg.rms_eps)
    xn_prev = jnp.concatenate([jnp.zeros_like(xn[:, :1]), xn[:, :-1]], axis=1)
    x = x + rec.rwkv_channelmix(bp["cm"], cfg, xn, xn_prev)
    cache = None
    if collect:
        cache = {"S": tm_state["S"], "x_prev_tm": tm_state["x_prev"],
                 "x_prev_cm": xn[:, -1]}
    return x, cache


def _apply_griffin_sub(bp, cfg: ModelConfig, x, positions, local_mask,
                       temporal, capacity=None):
    xn = rmsnorm(bp["ln1"], x, cfg.rms_eps)
    cache = None
    if temporal == "rglru":
        h, state = rec.rglru_block_forward(bp["mix"], cfg, xn)
        if capacity is not None:
            cache = state
    else:
        h, (k, v) = attn.attn_forward(bp["mix"], cfg, xn, positions=positions,
                                      mask=local_mask)
        if capacity is not None:
            cap = min(capacity, cfg.local_window or capacity)
            cache = attn.fill_cache(
                attn.init_cache(cfg, x.shape[0], cap), k, v, positions[0])
    x = x + h
    x = x + ffn.mlp_apply(bp["mlp"], cfg, rmsnorm(bp["ln2"], x, cfg.rms_eps))
    return x, cache


def _forward_blocks(params, cfg: ModelConfig, x, positions, *,
                    prefix_len=None, collect_cache=False, capacity=None):
    """Run all blocks.  Returns (hidden, caches, aux)."""
    kind = block_kind(cfg)
    S = x.shape[1]
    cap = capacity if collect_cache else None

    if kind in ("attn", "moe"):
        window = cfg.swa_window
        mask_args = dict(causal=True, window=window, prefix_len=prefix_len)
        mask = attn.make_mask(S, S, causal=True, window=window,
                              prefix_len=prefix_len)

        def body(carry, bp):
            carry = constrain_hidden(carry, cfg)
            y, cache, aux = _apply_attn_block(bp, cfg, carry, positions, mask,
                                              window, cap, mask_args)
            lb = aux["load_balance_loss"] if aux else jnp.float32(0)
            return y, (cache, lb)

        x, (caches, lb) = jax.lax.scan(maybe_remat(body, cfg), x, params["blocks"])
        return x, caches, {"load_balance_loss": jnp.mean(lb)}

    if kind == "rwkv":
        def body(carry, bp):
            carry = constrain_hidden(carry, cfg)
            y, cache = _apply_rwkv_block(bp, cfg, carry, collect_cache)
            return y, cache

        x, caches = jax.lax.scan(maybe_remat(body, cfg), x, params["blocks"])
        return x, caches, {}

    # griffin
    local_mask = attn.make_mask(S, S, causal=True, window=cfg.local_window)

    def body(carry, bp):
        y = constrain_hidden(carry, cfg)
        y, c0 = _apply_griffin_sub(bp["sub0"], cfg, y, positions, local_mask,
                                   "rglru", cap)
        y, c1 = _apply_griffin_sub(bp["sub1"], cfg, y, positions, local_mask,
                                   "rglru", cap)
        y, c2 = _apply_griffin_sub(bp["sub2"], cfg, y, positions, local_mask,
                                   "attn", cap)
        return y, {"sub0": c0, "sub1": c1, "sub2": c2}

    x, caches = jax.lax.scan(maybe_remat(body, cfg), x, params["blocks"])
    if "tail" in params:
        def tail_body(carry, bp):
            carry = constrain_batch(carry)
            y, c = _apply_griffin_sub(bp, cfg, carry, positions, local_mask,
                                      "rglru", cap)
            return y, c

        x, tail_caches = jax.lax.scan(maybe_remat(tail_body, cfg), x, params["tail"])
        caches = {"main": caches, "tail": tail_caches}
    elif collect_cache:
        caches = {"main": caches}
    return x, caches, {}


# ---------------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.compute_dtype), x],
                            axis=1)
    return constrain_batch(x)


def _head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T         # [D, V]
    return params["head"]


def chunked_xent(params, cfg: ModelConfig, hidden, labels, mask):
    """Cross-entropy over sequence chunks; never materializes [T, V] logits.

    hidden: [B, S, D]; labels/mask: [B, S].  Returns (loss, n_tokens).
    """
    w = _head_weight(params, cfg).astype(cfg.compute_dtype)
    B, S, D = hidden.shape
    n_chunks = max(S // LOSS_CHUNK, 1)
    csize = S // n_chunks
    hid = hidden[:, :n_chunks * csize].reshape(B, n_chunks, csize, D)
    lab = labels[:, :n_chunks * csize].reshape(B, n_chunks, csize)
    msk = mask[:, :n_chunks * csize].reshape(B, n_chunks, csize)

    def body(acc, xs):
        h, l, m = xs                               # [B,c,D], [B,c], [B,c]
        h = constrain_batch(h)
        logits = jnp.einsum("BCD,DV->BCV", h, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - ll) * m)
        return (acc[0] + loss, acc[1] + jnp.sum(m)), None

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    (loss, n), _ = jax.lax.scan(maybe_remat(body, cfg), (jnp.float32(0), jnp.float32(0)),
                                (tm(hid), tm(lab), tm(msk)))
    return loss, n


def last_token_logits(params, cfg: ModelConfig, hidden_last):
    """hidden_last: [B, D] -> [B, V] (f32)."""
    w = _head_weight(params, cfg).astype(cfg.compute_dtype)
    return jnp.einsum("BD,DV->BV", hidden_last, w).astype(jnp.float32)


# ---------------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch) -> tuple[jnp.ndarray, dict]:
    """batch: {"tokens": [B,S] int32, optional "prefix_embeds": [B,P,D]}."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    x = embed_tokens(params, cfg, tokens, prefix)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    prefix_len = cfg.frontend_len if (cfg.prefix_lm and prefix is not None) else None
    hidden, _, aux = _forward_blocks(params, cfg, x, positions,
                                     prefix_len=prefix_len)
    hidden = rmsnorm(params["final_norm"], hidden, cfg.rms_eps)
    P = prefix.shape[1] if prefix is not None else 0
    # next-token prediction on the text region
    hid = hidden[:, P:P + tokens.shape[1] - 1]
    labels = tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    loss, n = chunked_xent(params, cfg, hid, labels, mask)
    total = loss / jnp.maximum(n, 1.0)
    if "load_balance_loss" in aux:
        total = total + 0.01 * aux["load_balance_loss"]
    return total, {"xent": loss / jnp.maximum(n, 1.0), **aux}


def lm_prefill(params, cfg: ModelConfig, batch, capacity: int):
    """Prefill: returns (last-token logits [B,V], caches pytree)."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    x = embed_tokens(params, cfg, tokens, prefix)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    prefix_len = cfg.frontend_len if (cfg.prefix_lm and prefix is not None) else None
    hidden, caches, _ = _forward_blocks(params, cfg, x, positions,
                                        prefix_len=prefix_len,
                                        collect_cache=True, capacity=capacity)
    hidden = rmsnorm(params["final_norm"], hidden, cfg.rms_eps)
    return last_token_logits(params, cfg, hidden[:, -1]), caches


# ---------------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------------

def _decode_attn_block(bp, cfg: ModelConfig, x1, cache, pos, window):
    h, cache = attn.attn_decode(bp["attn"], cfg,
                                rmsnorm(bp["ln1"], x1, cfg.rms_eps)[:, None],
                                cache, pos, window=window)
    x1 = x1 + h[:, 0]
    xn = rmsnorm(bp["ln2"], x1, cfg.rms_eps)
    if "moe" in bp:
        h, _ = ffn.moe_apply(bp["moe"], cfg, xn[:, None])
        h = h[:, 0]
    else:
        h = ffn.mlp_apply(bp["mlp"], cfg, xn[:, None])[:, 0]
    return x1 + h, cache


def _decode_rwkv_block(bp, cfg: ModelConfig, x1, cache):
    h, tm_state = rec.rwkv_timemix_decode(
        bp["tm"], cfg, rmsnorm(bp["ln1"], x1, cfg.rms_eps),
        {"S": cache["S"], "x_prev": cache["x_prev_tm"]})
    x1 = x1 + h
    xn = rmsnorm(bp["ln2"], x1, cfg.rms_eps)
    x1 = x1 + rec.rwkv_channelmix(bp["cm"], cfg, xn, cache["x_prev_cm"])
    return x1, {"S": tm_state["S"], "x_prev_tm": tm_state["x_prev"],
                "x_prev_cm": xn}


def _decode_griffin_sub(bp, cfg: ModelConfig, x1, cache, pos, temporal):
    xn = rmsnorm(bp["ln1"], x1, cfg.rms_eps)
    if temporal == "rglru":
        h, cache = rec.rglru_block_decode(bp["mix"], cfg, xn, cache)
    else:
        h, cache = attn.attn_decode(bp["mix"], cfg, xn[:, None], cache, pos,
                                    window=cfg.local_window)
        h = h[:, 0]
    x1 = x1 + h
    x1 = x1 + ffn.mlp_apply(bp["mlp"], cfg,
                            rmsnorm(bp["ln2"], x1, cfg.rms_eps)[:, None])[:, 0]
    return x1, cache


def lm_decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One token for the whole batch.  token: [B] int32, pos: scalar int32.

    Returns (logits [B,V] f32, new caches).
    """
    kind = block_kind(cfg)
    x1 = embed_tokens(params, cfg, token[:, None])[:, 0]

    if kind in ("attn", "moe"):
        def body(carry, xs):
            bp, cache = xs
            carry = constrain_batch(carry)
            y, cache = _decode_attn_block(bp, cfg, carry, cache, pos,
                                          cfg.swa_window)
            return y, cache

        x1, caches = jax.lax.scan(maybe_remat(body, cfg), x1, (params["blocks"], caches))
    elif kind == "rwkv":
        def body(carry, xs):
            bp, cache = xs
            carry = constrain_batch(carry)
            y, cache = _decode_rwkv_block(bp, cfg, carry, cache)
            return y, cache

        x1, caches = jax.lax.scan(maybe_remat(body, cfg), x1, (params["blocks"], caches))
    else:  # griffin
        def body(carry, xs):
            bp, cache = xs
            y = constrain_batch(carry)
            y, c0 = _decode_griffin_sub(bp["sub0"], cfg, y, cache["sub0"], pos,
                                        "rglru")
            y, c1 = _decode_griffin_sub(bp["sub1"], cfg, y, cache["sub1"], pos,
                                        "rglru")
            y, c2 = _decode_griffin_sub(bp["sub2"], cfg, y, cache["sub2"], pos,
                                        "attn")
            return y, {"sub0": c0, "sub1": c1, "sub2": c2}

        x1, main = jax.lax.scan(maybe_remat(body, cfg), x1, (params["blocks"], caches["main"]))
        new_caches = {"main": main}
        if "tail" in params:
            def tail_body(carry, xs):
                bp, cache = xs
                y, c = _decode_griffin_sub(bp, cfg, carry, cache, pos, "rglru")
                return y, c

            x1, tail = jax.lax.scan(maybe_remat(tail_body, cfg), x1,
                                    (params["tail"], caches["tail"]))
            new_caches["tail"] = tail
        caches = new_caches

    x1 = rmsnorm(params["final_norm"], x1, cfg.rms_eps)
    return last_token_logits(params, cfg, x1), caches
