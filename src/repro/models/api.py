"""Uniform model API over every family (the launcher/serving entry point)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from . import encdec, lm
from .common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[Any], Any]                     # key -> params
    loss: Callable[[Any, dict], tuple]             # (params, batch) -> (loss, aux)
    prefill: Callable[[Any, dict, int], tuple]     # -> (logits, caches)
    decode_step: Callable[[Any, Any, Any, Any], tuple]  # -> (logits, caches)


def build_model(cfg: ModelConfig) -> ModelApi:
    cfg.validate()
    if cfg.is_encdec:
        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec.encdec_init(key, cfg),
            loss=lambda p, b: encdec.encdec_loss(p, cfg, b),
            prefill=lambda p, b, cap: encdec.encdec_prefill(p, cfg, b, cap),
            decode_step=lambda p, c, t, pos: encdec.encdec_decode_step(
                p, cfg, c, t, pos),
        )
    return ModelApi(
        cfg=cfg,
        init=lambda key: lm.lm_init(key, cfg),
        loss=lambda p, b: lm.lm_loss(p, cfg, b),
        prefill=lambda p, b, cap: lm.lm_prefill(p, cfg, b, cap),
        decode_step=lambda p, c, t, pos: lm.lm_decode_step(p, cfg, c, t, pos),
    )
