"""Shared model building blocks (pure JAX, functional param-dict style).

Every layer is a pair of functions: ``*_init(key, ...) -> params`` (fp32
pytree of jnp arrays) and an apply function taking (params, x, ...).  Repeated
transformer blocks are stacked along a leading layer axis and executed with
``lax.scan`` so the lowered HLO stays one-block-sized at any depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # "global": one dispatch over all tokens (baseline; the argsort and
    #   capacity buffers span data shards -> cross-shard collectives).
    # "per_sequence": dispatch within each sequence (vmapped over batch; the
    #   sort/buffers stay data-local — §Perf hillclimb for collective-bound
    #   MoE training).
    dispatch: str = "global"
    # Megatron-style anchors on the expert FFN intermediates (g/u sharded on
    # the model axis, psum deferred to the down-projection output) — §Perf
    # lever for GSPMD backward partitioning (global dispatch only).
    constrain_ffn: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    qkv_bias: bool = False               # qwen1.5 / qwen2
    qk_norm: bool = False                # qwen3
    swa_window: int | None = None        # mixtral sliding-window
    local_window: int | None = None      # recurrentgemma local attention
    moe: MoEConfig | None = None
    act: str = "silu"                    # silu (swiglu) | gelu (geglu) | relu
    tie_embeddings: bool = False
    scale_embed: bool = False            # gemma-style sqrt(d) embedding scale
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    # enc-dec split (seamless): n_layers is the per-stack depth
    enc_layers: int = 0
    dec_layers: int = 0
    # hybrid pattern (recurrentgemma): period-3 [rec, rec, attn]
    attn_pattern: str = "all"            # all | griffin_1_2 | rwkv
    rnn_width: int | None = None         # rg-lru recurrence width
    conv_kernel: int = 4
    # modality frontend stub (vlm: patch embeddings; audio: frame embeddings)
    frontend: str | None = None          # None | patch | frames
    frontend_len: int = 256              # prefix length supplied by the stub
    prefix_lm: bool = False              # paligemma: bidirectional prefix mask
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # activation rematerialization for the layer scan body:
    #   none | full (save nothing) | dots (save non-batch matmul outputs)
    remat: str = "full"
    # performance levers (see EXPERIMENTS.md §Perf):
    # q-chunked attention for long-sequence train/prefill (XLA-level flash —
    # scores never exceed [B, H, chunk, S_k]); None = unchunked baseline
    attn_chunk_q: int | None = None
    # sequence parallelism: residual stream sharded over the model axis
    # between blocks (all-reduce -> all-gather/reduce-scatter in bf16)
    seq_shard: bool = False
    # ZeRO-1 for expert weights: params replicated over the data axis (only
    # optimizer states stay data-sharded), removing per-layer weight gathers
    # and GSPMD's backward activation psums at the cost of replicated
    # expert params in HBM — §Perf lever for collective-bound MoE training
    moe_zero1: bool = False
    # ZeRO-1 for ALL weights (dense archs): same trade as moe_zero1 —
    # bf16 params replicated over data (TP-sharded only), optimizer states
    # stay fully sharded; per-layer FSDP all-gathers disappear
    zero1: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv, 1) == 0 or self.n_kv == 0


# ---------------------------------------------------------------------------------
# Initializers / primitive layers
# ---------------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (shape[..., in, out] semantics by caller)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    # fan-in scale: unit-RMS hidden states then produce O(1) logits; gemma-style
    # configs recover O(1) activations at the input via the sqrt(d) embed scale.
    return (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


ACTIVATIONS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin = jnp.sin(angles)[..., None, :]                  # [..., S, 1, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------------

def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD needs anchors to keep the batch axis sharded through the network
# (otherwise it may treat the FSDP axis as a contraction split and replicate
# activations).  The launcher declares the batch mesh axes before tracing;
# model code calls constrain_batch() at block boundaries.
# ---------------------------------------------------------------------------------

_BATCH_AXES: tuple[str, ...] | None = None
_MESH = None


def set_batch_axes(axes: tuple[str, ...] | None, mesh=None) -> None:
    global _BATCH_AXES, _MESH
    _BATCH_AXES = tuple(axes) if axes else None
    _MESH = mesh


def get_batch_axes() -> tuple[str, ...] | None:
    return _BATCH_AXES


def get_mesh():
    return _MESH


def shard_map_compat(f, mesh, *, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` landed as a top-level API only on newer jax; older
    jaxlibs expose ``jax.experimental.shard_map.shard_map``, which takes the
    complement ``auto=`` set instead of ``axis_names=`` (and needs
    ``check_rep=False`` when any axis stays automatic)."""
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map

    # check_rep's replication tracking predates scan-carry support (the
    # error message itself prescribes disabling it) — correctness is still
    # covered by the equivalence tests.
    kw = {"check_rep": False}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Anchor: dim 0 sharded over the declared batch axes, rest unconstrained."""
    if _BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(_BATCH_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_spec(x: jnp.ndarray, axes: tuple) -> jnp.ndarray:
    """Anchor with an explicit per-dim axis tuple ('batch' expands to the
    declared batch axes); no-op outside a sharded run."""
    if _BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(*[(_BATCH_AXES if a == "batch" else a) for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_hidden(x: jnp.ndarray, cfg: "ModelConfig") -> jnp.ndarray:
    """Residual-stream anchor between blocks: batch-sharded, plus
    sequence-sharded over the model axis when cfg.seq_shard (SP)."""
    if cfg.seq_shard and x.ndim >= 3 and x.shape[1] > 1:
        return constrain_spec(x, ("batch", "model") + (None,) * (x.ndim - 2))
    return constrain_batch(x)


def maybe_remat(fn: Callable, cfg: "ModelConfig") -> Callable:
    """Wrap a scan body with jax.checkpoint per the config's remat policy."""
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def stack_layers(init_fn: Callable, key, n: int) -> Params:
    """Initialize n identical blocks and stack each leaf along axis 0."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def count_params(params: Params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
