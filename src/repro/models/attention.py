"""GQA/MQA attention with RoPE, optional QKV-bias / qk-norm / sliding window.

One implementation covers every assigned arch's attention flavour:
  * llama3 / qwen / gemma GQA (n_kv < n_heads), MQA (n_kv=1)
  * qwen1.5/qwen2 QKV bias, qwen3 qk-RMSNorm
  * mixtral sliding-window (SWA), recurrentgemma local attention
  * seamless enc-dec: bidirectional self-attention + cross-attention
  * paligemma prefix-LM masking

Serving uses a unified cache: K is stored pre-rotated at absolute positions;
``abs`` tracks each slot's absolute position (-1 = empty), which makes full
and ring-buffer (windowed) caches the same code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rmsnorm, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd)),
        "wk": dense_init(ks[1], (d, K, hd)),
        "wv": dense_init(ks[2], (d, K, hd)),
        "wo": dense_init(ks[3], (H, hd, d), scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((K, hd), jnp.float32)
        p["bv"] = jnp.zeros((K, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    cd = cfg.compute_dtype
    q = jnp.einsum("BSD,DHd->BSHd", x, p["wq"].astype(cd))
    k = jnp.einsum("BSD,DKd->BSKd", kv_x, p["wk"].astype(cd))
    v = jnp.einsum("BSD,DKd->BSKd", kv_x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.rms_eps)
        k = rmsnorm(p["k_norm"], k, cfg.rms_eps)
    return q, k, v


def _gqa_attend(p, cfg: ModelConfig, q, k, v, mask):
    """q: [B,S,H,hd]  k,v: [B,T,K,hd]  mask: bool broadcastable [B,1,1,S,T]."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("BSKGd,BTKd->BKGST", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cfg.compute_dtype)
    out = jnp.einsum("BKGST,BTKd->BSKGd", probs, v)
    out = out.reshape(B, S, H, hd)
    return jnp.einsum("BSHd,HdD->BSD", out, p["wo"].astype(cfg.compute_dtype))


# ---------------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------------

def make_mask(
    s_q: int,
    s_k: int,
    *,
    causal: bool,
    window: int | None = None,
    prefix_len: int | None = None,
) -> jnp.ndarray:
    """bool[1,1,1,s_q,s_k] — True where attention is allowed."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (s_q, s_k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s_q, s_k), 1)
    if causal:
        m = cols <= rows
        if window is not None:
            m &= (rows - cols) < window
        if prefix_len is not None:
            # prefix-LM (paligemma): prefix tokens attend bidirectionally
            m |= (rows < prefix_len) & (cols < prefix_len)
    else:
        m = jnp.ones((s_q, s_k), bool)
    return m[None, None, None]


# ---------------------------------------------------------------------------------
# full-sequence forward (train / prefill / encoder / cross-attention)
# ---------------------------------------------------------------------------------

def attn_forward(
    p,
    cfg: ModelConfig,
    x,
    *,
    positions,
    mask,
    kv_x=None,
    kv_positions=None,
    use_rope: bool = True,
    mask_args: dict | None = None,
):
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kp = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kp, cfg.rope_theta)
    chunk = cfg.attn_chunk_q
    if (chunk and mask_args is not None and q.shape[1] > chunk
            and q.shape[1] % chunk == 0):
        out = _gqa_attend_chunked(p, cfg, q, k, v, chunk=chunk, **mask_args)
    else:
        out = _gqa_attend(p, cfg, q, k, v, mask)
    return out, (k, v)


def _gqa_attend_chunked(p, cfg: ModelConfig, q, k, v, *, chunk: int,
                        causal: bool = True, window: int | None = None,
                        prefix_len: int | None = None):
    """Query-chunked attention (XLA-level flash): scores never exceed
    [B, heads, chunk, S_k] — the S_q x S_k matrix is never materialized.

    Online softmax is unnecessary because each chunk sees the FULL key range;
    memory drops by S_q/chunk (e.g. 64x for 32k prefill at chunk=512).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    Sk = k.shape[1]
    nb = S // chunk
    qb = jnp.moveaxis(q.reshape(B, nb, chunk, H, hd), 1, 0)   # [nb,B,c,H,hd]

    def block(_, inp):
        idx, qc = inp
        off = idx * chunk
        rows = off + jax.lax.broadcasted_iota(jnp.int32, (chunk, Sk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, Sk), 1)
        if causal:
            m = cols <= rows
            if window is not None:
                m &= (rows - cols) < window
            if prefix_len is not None:
                m |= (rows < prefix_len) & (cols < prefix_len)
        else:
            m = jnp.ones((chunk, Sk), bool)
        qg = qc.reshape(B, chunk, K, G, hd)
        s = jnp.einsum("BSKGd,BTKd->BKGST", qg, k).astype(jnp.float32)
        s = s * (hd ** -0.5)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(cfg.compute_dtype)
        oc = jnp.einsum("BKGST,BTKd->BSKGd", pr, v).reshape(B, chunk, H, hd)
        return None, oc

    _, ob = jax.lax.scan(block, None,
                         (jnp.arange(nb, dtype=jnp.int32), qb))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, S, H, hd)
    return jnp.einsum("BSHd,HdD->BSD", out, p["wo"].astype(cfg.compute_dtype))


# ---------------------------------------------------------------------------------
# serving cache
# ---------------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv, cfg.hd), dtype),
        "abs": jnp.full((capacity,), -1, jnp.int32),
    }


def cache_spec(cfg: ModelConfig, batch: int, capacity: int, dtype) -> dict:
    """ShapeDtypeStruct version of init_cache (for the dry-run)."""
    return {
        "k": jax.ShapeDtypeStruct((batch, capacity, cfg.n_kv, cfg.hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, capacity, cfg.n_kv, cfg.hd), dtype),
        "abs": jax.ShapeDtypeStruct((capacity,), jnp.int32),
    }


def fill_cache(cache: dict, k, v, positions) -> dict:
    """Write a prefill's rotated K/V into the cache (assumes S <= capacity and
    positions are the trailing ones if the window wrapped)."""
    W = cache["k"].shape[1]
    S = k.shape[1]
    if S > W:  # windowed cache: keep only the last W tokens
        k, v = k[:, -W:], v[:, -W:]
        positions = positions[-W:]
        S = W
    idx = positions % W
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, idx].set(k)
    cache["v"] = cache["v"].at[:, idx].set(v)
    cache["abs"] = cache["abs"].at[idx].set(positions)
    return cache


def attn_decode(
    p,
    cfg: ModelConfig,
    x,            # [B, 1, d]
    cache: dict,
    pos,          # scalar int32 — absolute position of the new token
    *,
    window: int | None = None,
    use_rope: bool = True,
):
    """One decode step; returns (out [B,1,d], updated cache)."""
    q, k_new, v_new = _project_qkv(p, cfg, x)
    if use_rope:
        posv = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, posv[None, :], cfg.rope_theta)
        k_new = apply_rope(k_new, posv[None, :], cfg.rope_theta)
    W = cache["k"].shape[1]
    idx = pos % W
    k = cache["k"].at[:, idx].set(k_new[:, 0])
    v = cache["v"].at[:, idx].set(v_new[:, 0])
    abs_pos = cache["abs"].at[idx].set(pos)
    dist = pos - abs_pos                                   # [W]
    valid = (abs_pos >= 0) & (dist >= 0)
    if window is not None:
        valid &= dist < window
    mask = valid[None, None, None, None, :]                # [1,1,1,1,W]
    out = _gqa_attend(p, cfg, q, k, v, mask)
    return out, {"k": k, "v": v, "abs": abs_pos}
