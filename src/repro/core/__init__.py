"""Core: the paper's contribution — FlexTopo + topology-aware preemption."""
from .cluster import (MAX_DENSE_VICTIMS, Cluster, ClusterArrays, ClusterView,
                      DeviceClusterState, SourcingContext)
from .colocation import (ColocationConfig, ColocationReport, ColocationSim,
                         OfflineJob, compare_day_cycle, compare_two_level,
                         run_day_cycle)
from .decisions import SchedulingDecision, Transaction, TransactionError
from .engines import (EngineName, SourcingEngine, UnknownEngineError,
                      get_engine, register_engine, registered_engines)
from .flextopo import FlexTopo, FlexTopoMasks
from .perfmodel import (TIER_PERF, relative_scheduled_factor,
                        scheduled_factor)
from .placement import (INFEASIBLE, Placement, achieved_tier, best_tier,
                        is_topology_hit, min_tier_for, place, place_blind)
from .preemption_jax import ShortlistConfig
from .scheduler import AUTO_ENGINE_THRESHOLD, TopoScheduler
from .scoring import Candidate, score, select_best
from .topology import A100_SERVER, RTX4090_SERVER, SPECS, TPU_V5E_HOST, ServerSpec
from .workload import (Instance, TopoPolicy, WorkloadSpec, table1_workloads,
                       table3_workloads)

__all__ = [
    "Cluster", "ClusterArrays", "ClusterView", "DeviceClusterState",
    "SourcingContext", "MAX_DENSE_VICTIMS", "ColocationConfig",
    "ColocationReport", "ColocationSim", "OfflineJob", "compare_day_cycle",
    "compare_two_level", "run_day_cycle", "FlexTopo", "FlexTopoMasks",
    "TIER_PERF", "relative_scheduled_factor", "scheduled_factor",
    "INFEASIBLE", "Placement", "achieved_tier", "best_tier", "is_topology_hit",
    "min_tier_for", "place", "place_blind", "SchedulingDecision",
    "Transaction", "TransactionError", "EngineName", "SourcingEngine",
    "UnknownEngineError", "get_engine", "register_engine",
    "registered_engines", "AUTO_ENGINE_THRESHOLD", "ShortlistConfig",
    "TopoScheduler", "Candidate", "score", "select_best",
    "A100_SERVER", "RTX4090_SERVER", "SPECS", "TPU_V5E_HOST", "ServerSpec",
    "Instance", "TopoPolicy", "WorkloadSpec", "table1_workloads",
    "table3_workloads",
]
