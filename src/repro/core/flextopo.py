"""FlexTopo — the paper's unified resource-topology representation (§3.2).

Two coupled views of the same state:

* **Graph view** (`FlexTopo`): a networkx graph with Socket / CoreGroup /
  CPU-Core / NUMA / GPU nodes and `host` / `contain` / `localized` / `nearby`
  edges, each annotated per paper Table 2 (`Status`, `UsedBy`, GPU `Model` /
  `Memory Capacity`).  This is the CRD-shaped object the FlexTopo agent
  maintains and the scheduler reads; it serializes to a Kubernetes-CRD-like
  dict.

* **Array view** (`as_masks()` / `ClusterTopoArrays` in cluster.py): free-GPU
  and free-CoreGroup int32 bitmasks per server.  All hot-path scheduling math
  (placement tiers, IMP subset evaluation, the Pallas kernel) runs on this
  encoding; the graph is the source of truth and the masks are derived.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import networkx as nx

from .topology import ServerSpec

FREE = "free"
ALLOCATED = "allocated"
FAILED = "failed"


def _gpu(i: int) -> tuple[str, int]:
    return ("gpu", i)


def _cg(i: int) -> tuple[str, int]:
    return ("coregroup", i)


def _core(i: int) -> tuple[str, int]:
    return ("core", i)


def _numa(i: int) -> tuple[str, int]:
    return ("numa", i)


def _socket(i: int) -> tuple[str, int]:
    return ("socket", i)


@dataclasses.dataclass
class FlexTopoMasks:
    """Dense bitmask snapshot of one server's allocatable state."""

    free_gpu_mask: int
    free_cg_mask: int


class FlexTopo:
    """Real-time resource topology of a single server (graph view)."""

    def __init__(self, spec: ServerSpec, node_name: str = "node-0") -> None:
        self.spec = spec
        self.node_name = node_name
        self.graph = nx.Graph()
        g = self.graph
        for s in range(spec.num_sockets):
            g.add_node(_socket(s), socket_id=s)
        for u in range(spec.num_numa):
            g.add_node(_numa(u), numa_id=u)
        for c in range(spec.num_coregroups):
            g.add_node(_cg(c), coregroup_id=c, status=FREE, used_by=None)
            # Socket — CoreGroup : host
            g.add_edge(
                _socket(spec.socket_of_numa(spec.numa_of_coregroup(c))),
                _cg(c),
                kind="host",
            )
            # CoreGroup — NUMA : localized
            g.add_edge(_cg(c), _numa(spec.numa_of_coregroup(c)), kind="localized")
            for core in spec.cores_of_coregroup(c):
                g.add_node(_core(core), core_id=core, status=FREE)
                # CoreGroup — core : contain
                g.add_edge(_cg(c), _core(core), kind="contain")
        for dev in range(spec.num_gpus):
            g.add_node(
                _gpu(dev),
                uuid=f"{node_name}-gpu-{dev}",
                model=spec.gpu_model,
                memory_capacity_mb=spec.gpu_memory_mb,
                status=FREE,
                used_by=None,
            )
            # GPU — NUMA : nearby
            g.add_edge(_gpu(dev), _numa(spec.numa_of_gpu(dev)), kind="nearby")

    # ---- allocation state -------------------------------------------------------
    def allocate(self, instance: str, gpus: Iterable[int], coregroups: Iterable[int]) -> None:
        for dev in gpus:
            node = self.graph.nodes[_gpu(dev)]
            if node["status"] != FREE:
                raise ValueError(f"GPU {dev} on {self.node_name} is {node['status']}")
            node["status"] = ALLOCATED
            node["used_by"] = instance
        for c in coregroups:
            node = self.graph.nodes[_cg(c)]
            if node["status"] != FREE:
                raise ValueError(f"CoreGroup {c} on {self.node_name} is {node['status']}")
            node["status"] = ALLOCATED
            node["used_by"] = instance
            for core in self.spec.cores_of_coregroup(c):
                self.graph.nodes[_core(core)]["status"] = ALLOCATED

    def release(self, instance: str) -> None:
        for key, data in self.graph.nodes(data=True):
            if data.get("used_by") == instance:
                data["status"] = FREE
                data["used_by"] = None
                if key[0] == "coregroup":
                    for core in self.spec.cores_of_coregroup(key[1]):
                        self.graph.nodes[_core(core)]["status"] = FREE

    def fail_gpu(self, gpu: int) -> None:
        """Hardware-topology change (§3.3 scenario 2): GPU device failure."""
        self.graph.nodes[_gpu(gpu)]["status"] = FAILED
        self.graph.nodes[_gpu(gpu)]["used_by"] = None

    def repair_gpu(self, gpu: int) -> None:
        if self.graph.nodes[_gpu(gpu)]["status"] == FAILED:
            self.graph.nodes[_gpu(gpu)]["status"] = FREE

    # ---- queries ------------------------------------------------------------------
    def gpu_status(self, gpu: int) -> str:
        return self.graph.nodes[_gpu(gpu)]["status"]

    def cg_status(self, cg: int) -> str:
        return self.graph.nodes[_cg(cg)]["status"]

    def used_by(self) -> dict[str, list[tuple[str, int]]]:
        """instance name -> list of (component kind, id) it holds."""
        out: dict[str, list[tuple[str, int]]] = {}
        for key, data in self.graph.nodes(data=True):
            owner = data.get("used_by")
            if owner is not None:
                out.setdefault(owner, []).append(key)
        return out

    def as_masks(self) -> FlexTopoMasks:
        gpu_mask = 0
        for dev in range(self.spec.num_gpus):
            if self.gpu_status(dev) == FREE:
                gpu_mask |= 1 << dev
        cg_mask = 0
        for c in range(self.spec.num_coregroups):
            if self.cg_status(c) == FREE:
                cg_mask |= 1 << c
        return FlexTopoMasks(free_gpu_mask=gpu_mask, free_cg_mask=cg_mask)

    def instance_masks(self, instance: str) -> FlexTopoMasks:
        """Bitmasks of the resources held by one instance (victim encoding)."""
        gpu_mask = 0
        cg_mask = 0
        for key, data in self.graph.nodes(data=True):
            if data.get("used_by") == instance:
                if key[0] == "gpu":
                    gpu_mask |= 1 << key[1]
                elif key[0] == "coregroup":
                    cg_mask |= 1 << key[1]
        return FlexTopoMasks(free_gpu_mask=gpu_mask, free_cg_mask=cg_mask)

    # ---- CRD (de)serialization ------------------------------------------------------
    def to_crd(self) -> dict:
        """Kubernetes-CRD-shaped dict (the object the agent PATCHes)."""
        spec = self.spec
        return {
            "apiVersion": "scheduling.repro.io/v1alpha1",
            "kind": "FlexTopo",
            "metadata": {"name": self.node_name},
            "spec": {"serverSpec": spec.name},
            "status": {
                "sockets": [
                    {"socketID": s} for s in range(spec.num_sockets)
                ],
                "numaNodes": [
                    {"numaID": u, "socketID": spec.socket_of_numa(u)}
                    for u in range(spec.num_numa)
                ],
                "coreGroups": [
                    {
                        "coreGroupID": c,
                        "cores": list(spec.cores_of_coregroup(c)),
                        "numaID": spec.numa_of_coregroup(c),
                        "status": self.cg_status(c),
                        "usedBy": self.graph.nodes[_cg(c)]["used_by"],
                    }
                    for c in range(spec.num_coregroups)
                ],
                "gpus": [
                    {
                        "uuid": self.graph.nodes[_gpu(d)]["uuid"],
                        "model": spec.gpu_model,
                        "memoryCapacityMB": spec.gpu_memory_mb,
                        "numaID": spec.numa_of_gpu(d),
                        "status": self.gpu_status(d),
                        "usedBy": self.graph.nodes[_gpu(d)]["used_by"],
                    }
                    for d in range(spec.num_gpus)
                ],
            },
        }

    @classmethod
    def from_crd(cls, crd: dict, spec: ServerSpec) -> "FlexTopo":
        topo = cls(spec, node_name=crd["metadata"]["name"])
        for entry in crd["status"]["coreGroups"]:
            c = entry["coreGroupID"]
            node = topo.graph.nodes[_cg(c)]
            node["status"] = entry["status"]
            node["used_by"] = entry["usedBy"]
            if entry["status"] == ALLOCATED:
                for core in spec.cores_of_coregroup(c):
                    topo.graph.nodes[_core(core)]["status"] = ALLOCATED
        for dev, entry in enumerate(crd["status"]["gpus"]):
            node = topo.graph.nodes[_gpu(dev)]
            node["status"] = entry["status"]
            node["used_by"] = entry["usedBy"]
        return topo
