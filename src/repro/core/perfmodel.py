"""Fig. 2 tier-performance model — the single source of truth.

The paper's Fig. 2 maps a placement tier (NUMA-local / same-socket /
cross-socket) to a relative scheduled-performance multiplier.  These
constants used to live in `repro.serving.engine` while their heaviest
consumer was `repro.core.colocation`'s day-cycle integral; promoting them
here means the serving-side SLO monitor's tier-aware service rates and the
day cycle's scheduled-performance accounting can never drift apart.
`repro.serving` keeps compat re-exports.
"""
from __future__ import annotations

from .placement import min_tier_for

# Paper Fig. 2: relative communication cost per placement tier converted to a
# scheduled-performance multiplier (NUMA-local = 1.0, same-socket, cross-socket).
TIER_PERF = {0: 1.0, 1: 10 / 12, 2: 10 / 32}


def scheduled_factor(decision) -> float:
    """Fig. 2 performance multiplier for a committed `SchedulingDecision`.

    Raw engine throughput times this factor gives the paper's "scheduled
    performance" of the instance at its placement tier.  Rejected decisions
    (no placement) score 0.
    """
    if decision.placement is None:
        return 0.0
    return TIER_PERF[decision.placement.tier]


def relative_scheduled_factor(spec, tier: int, need_gpus: int) -> float:
    """Fig. 2 factor normalized by the best tier ``need_gpus`` can
    physically achieve on the SKU.

    A full-node instance necessarily spans sockets and serves at 1.0 when
    it does, while a small instance misplaced across sockets is charged the
    full cross-socket/NUMA-local cost ratio — so degradation measures
    scheduling quality, not instance size.  This is the per-instance rate
    the co-location day cycle (`repro.core.colocation`) integrates into its
    scheduled-performance metric and the rate the elastic layer's
    `SLOMonitor` (`repro.serving.elastic`) predicts interference against.
    """
    return TIER_PERF.get(tier, 0.0) / TIER_PERF[min_tier_for(spec, need_gpus)]
