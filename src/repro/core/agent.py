"""FlexTopo agent — per-node daemon maintaining the FlexTopo CRD (paper §3.3).

Faithful semantics with an in-process stand-in for the API server:

* **Event-driven allocation updates** — the agent subscribes to allocation
  events (bind/evict) and PATCHes the CRD store only when allocation state
  actually changes, avoiding control-plane strain ("instead of continuously
  polling ... reports updates only when changes are detected").
* **Periodic hardware scans** — an infrequent scan compares the live hardware
  state against the internally maintained one and repairs the CRD on
  discrepancies (server failure is left to node-health machinery; GPU-device
  failure is the case the agent handles, §3.3 scenario 2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .cluster import Cluster
from .flextopo import FAILED, FlexTopo


@dataclasses.dataclass
class CRDStore:
    """In-process stand-in for the API server's FlexTopo CRD collection."""

    objects: dict[str, dict] = dataclasses.field(default_factory=dict)
    patch_count: int = 0
    watchers: list[Callable[[str, dict], None]] = dataclasses.field(
        default_factory=list)

    def patch(self, name: str, crd: dict) -> None:
        self.objects[name] = crd
        self.patch_count += 1
        for w in self.watchers:
            w(name, crd)

    def get(self, name: str) -> dict | None:
        return self.objects.get(name)

    def watch(self, fn: Callable[[str, dict], None]) -> None:
        self.watchers.append(fn)


class FlexTopoAgent:
    """One agent per node (a DaemonSet member in the paper)."""

    def __init__(self, topo: FlexTopo, store: CRDStore) -> None:
        self.topo = topo
        self.store = store
        self._last_serialized: dict | None = None
        self._known_failed: set[int] = set()
        self.sync()  # initial report

    # -- event-driven path ---------------------------------------------------------
    def on_allocation_event(self) -> bool:
        """Called on bind/evict affecting this node.  Returns True if patched."""
        return self.sync()

    def sync(self) -> bool:
        crd = self.topo.to_crd()
        if crd == self._last_serialized:
            return False   # no change: do NOT strain the control plane
        self.store.patch(self.topo.node_name, crd)
        self._last_serialized = crd
        return True

    # -- periodic hardware scan ------------------------------------------------------
    def periodic_hardware_scan(self) -> bool:
        """Compare live hardware against internal state; patch on discrepancy."""
        failed = {
            g for g in range(self.topo.spec.num_gpus)
            if self.topo.gpu_status(g) == FAILED
        }
        changed = failed != self._known_failed
        self._known_failed = failed
        if changed:
            return self.sync()
        # hardware stable: nothing reported
        return False


class AgentFleet:
    """All agents of a cluster + the event wiring from cluster mutations."""

    def __init__(self, cluster: Cluster) -> None:
        self.store = CRDStore()
        self.agents = [FlexTopoAgent(t, self.store) for t in cluster.topos]
        self.cluster = cluster

    def notify(self, node: int) -> bool:
        return self.agents[node].on_allocation_event()

    def watch(self, scheduler) -> None:
        """Subscribe to a TopoScheduler's transaction commits/rollbacks."""
        scheduler.add_listener(self.on_decision)

    def watch_cluster(self) -> None:
        """Subscribe to the cluster's per-node invalidation events so
        NON-transactional mutations (autoscaler scale-downs, offline-job
        completions — plain ``Cluster.evict`` calls that never flow through
        a Transaction) also patch the CRDs, per the paper's §3.3
        event-driven allocation reporting.  Safe to combine with ``watch``:
        ``sync`` is change-deduplicated, so double notification never
        issues a second PATCH."""
        self.cluster.add_dirty_listener(self.notify)

    def on_decision(self, decision, event: str | None = None) -> int:
        """Allocation event from a committed (or rolled-back) transaction:
        sync every node the decision touched.  Returns #patches issued."""
        nodes = set()
        if decision.node >= 0:
            nodes.add(decision.node)
        nodes.update(v.node for v in decision.evicted)
        # on rollback, `evicted` has been cleared — the victims' nodes are
        # recoverable from the live registry via the victim uids
        for uid in decision.victims:
            inst = self.cluster.instances.get(uid)
            if inst is not None:
                nodes.add(inst.node)
        return sum(self.notify(n) for n in sorted(nodes))

    def scan_all(self) -> int:
        return sum(a.periodic_hardware_scan() for a in self.agents)

    def inject_gpu_failure(self, node: int, gpu: int) -> None:
        """Test/ops hook: fail a device, let the scan repair the CRD view."""
        self.cluster.topos[node].fail_gpu(gpu)
        self.cluster.invalidate_node(node)
