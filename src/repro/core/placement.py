"""Tiered topology-aware placement inside one server (paper §3.4 Sorting).

Placement semantics
-------------------
A request of (g GPUs, c CoreGroups) is decomposed into g *bundles*, each
pairing one GPU with ``c // g`` CoreGroups that are ``localized`` to the same
NUMA node the GPU is ``nearby`` (guaranteed CPU↔GPU locality, paper Table 1
"NUMA: Guaranteed").  The *topology tier* of a placement is the paper's
piecewise score:

    tier 0 (high)   — every bundle in one single NUMA node
    tier 1 (medium) — bundles span NUMA nodes but stay within one socket
    tier 2 (low)    — bundles cross sockets

``best_tier`` computes the best achievable tier for given free masks (used by
IMP feasibility); ``place`` additionally commits to concrete GPU/CoreGroup
bitmasks.  ``place_blind`` is the topology-UNaware baseline (lowest free index
first) that reproduces the default/Gödel-standard allocator behaviour.

This module is the HOST implementation and the parity oracle: the fused
scheduling path evaluates the same tier feasibility, scope choice, and
lowest-free-bit mask selection as vectorized int32 bit math inside the
sourcing dispatch (`repro.core.placement_jax` — ``device_best_tier`` /
``device_place`` / ``device_place_blind`` are the bitwise twins), so
``plan()`` never walks these loops for ``fused_place`` engines.
``tests/test_placement_device.py`` pins host-vs-device equivalence across
SKUs, seeds, and partially-drained masks.
"""
from __future__ import annotations

import dataclasses

from .topology import ServerSpec

INFEASIBLE = 3  # tier value used for "does not fit at all"


@dataclasses.dataclass(frozen=True)
class Placement:
    gpu_mask: int
    cg_mask: int
    tier: int  # 0 NUMA / 1 socket / 2 cross-socket


def _bits(mask: int, n: int) -> list[int]:
    return [i for i in range(n) if mask >> i & 1]


def _lowest_bits(mask: int, k: int, n: int) -> int | None:
    """Lowest ``k`` set bits of ``mask``, or ``None`` when fewer are set.

    ``None`` (not an exception) keeps the feasibility API uniform: a caller
    racing against a concurrent allocation sees an infeasible placement,
    not a crashed planner.
    """
    out = 0
    for i in range(n):
        if k == 0:
            break
        if mask >> i & 1:
            out |= 1 << i
            k -= 1
    if k:
        return None
    return out


def min_tier_for(spec: ServerSpec, need_gpus: int) -> int:
    """Best tier physically achievable for a g-GPU instance on this SKU."""
    if need_gpus <= spec.gpus_per_numa:
        return 0
    if need_gpus <= spec.gpus_per_numa * spec.numa_per_socket:
        return 1
    return 2


def _numa_capacity(
    spec: ServerSpec,
    free_gpu_mask: int,
    free_cg_mask: int,
    cgs_per_bundle: int,
) -> list[tuple[int, int, int]]:
    """Per NUMA node: (#free gpus, #free coregroups, #whole bundles)."""
    out = []
    for u in range(spec.num_numa):
        fg = (free_gpu_mask & int(spec.numa_gpu_masks[u])).bit_count()
        fc = (free_cg_mask & int(spec.numa_cg_masks[u])).bit_count()
        bundles = min(fg, fc // cgs_per_bundle) if cgs_per_bundle else fg
        out.append((fg, fc, bundles))
    return out


def best_tier(
    spec: ServerSpec,
    free_gpu_mask: int,
    free_cg_mask: int,
    need_gpus: int,
    need_cgs: int,
    bundle_locality: bool = True,
) -> int:
    """Best achievable topology tier for the request, or INFEASIBLE.

    With ``bundle_locality`` (numa_policy=Guaranteed) each GPU must come with
    its share of CoreGroups from its own NUMA node; without it, GPU and
    CoreGroup counts are checked independently (numa_policy=None workloads).
    """
    if need_gpus == 0:
        # CPU-only request: tier by CoreGroup spread.
        for u in range(spec.num_numa):
            if (free_cg_mask & int(spec.numa_cg_masks[u])).bit_count() >= need_cgs:
                return 0
        for s in range(spec.num_sockets):
            if (free_cg_mask & int(spec.socket_cg_masks[s])).bit_count() >= need_cgs:
                return 1
        return 2 if free_cg_mask.bit_count() >= need_cgs else INFEASIBLE

    cgs_per_bundle = need_cgs // need_gpus if bundle_locality else 0
    caps = _numa_capacity(spec, free_gpu_mask, free_cg_mask, cgs_per_bundle)
    if bundle_locality:
        def scope_ok(numas: list[int]) -> bool:
            # need whole bundles for every GPU plus enough CoreGroups overall
            # (leftover CoreGroups beyond whole bundles may come from anywhere
            # within the scope)
            bundles = sum(caps[u][2] for u in numas)
            free_cg = sum(caps[u][1] for u in numas)
            return bundles >= need_gpus and free_cg >= need_cgs

    else:
        def scope_ok(numas: list[int]) -> bool:
            return (
                sum(caps[u][0] for u in numas) >= need_gpus
                and sum(caps[u][1] for u in numas) >= need_cgs
            )

    for u in range(spec.num_numa):
        if scope_ok([u]):
            return 0
    for s in range(spec.num_sockets):
        numas = [u for u in range(spec.num_numa) if spec.socket_of_numa(u) == s]
        if scope_ok(numas):
            return 1
    if scope_ok(list(range(spec.num_numa))):
        return 2
    return INFEASIBLE


def place(
    spec: ServerSpec,
    free_gpu_mask: int,
    free_cg_mask: int,
    need_gpus: int,
    need_cgs: int,
    bundle_locality: bool = True,
) -> Placement | None:
    """Commit a concrete topology-aware placement at the best achievable tier."""
    tier = best_tier(spec, free_gpu_mask, free_cg_mask, need_gpus, need_cgs,
                     bundle_locality)
    if tier == INFEASIBLE:
        return None
    # choose the scope (list of NUMA ids) matching the tier, best-fit
    cgs_per_bundle = need_cgs // need_gpus if (bundle_locality and need_gpus) else 0
    caps = _numa_capacity(spec, free_gpu_mask, free_cg_mask, cgs_per_bundle)

    def scope_capacity(numas: list[int]) -> tuple[int, int]:
        if bundle_locality and need_gpus:
            return (sum(caps[u][2] for u in numas), sum(caps[u][1] for u in numas))
        return (sum(caps[u][0] for u in numas), sum(caps[u][1] for u in numas))

    if tier == 0:
        scopes = [[u] for u in range(spec.num_numa)]
    elif tier == 1:
        scopes = [
            [u for u in range(spec.num_numa) if spec.socket_of_numa(u) == s]
            for s in range(spec.num_sockets)
        ]
    else:
        scopes = [list(range(spec.num_numa))]

    # best-fit: pick the feasible scope with the least leftover bundle capacity
    feasible = []
    for numas in scopes:
        units, cg_avail = scope_capacity(numas)
        if units >= need_gpus and cg_avail >= need_cgs:
            feasible.append((units - need_gpus, numas))
    if not feasible:
        return None
    _, numas = min(feasible, key=lambda t: (t[0], t[1]))

    gpu_mask = 0
    cg_mask = 0
    remaining_gpus = need_gpus
    remaining_cgs = need_cgs
    for u in numas:
        if remaining_gpus == 0:
            break
        u_free_g = free_gpu_mask & int(spec.numa_gpu_masks[u])
        u_free_c = free_cg_mask & int(spec.numa_cg_masks[u])
        take = min(remaining_gpus, caps[u][2] if (bundle_locality and need_gpus) else caps[u][0])
        if take <= 0:
            continue
        g_sel = _lowest_bits(u_free_g, take, spec.num_gpus)
        if g_sel is None:  # raced against a concurrent allocation
            return None
        gpu_mask |= g_sel
        remaining_gpus -= take
        if bundle_locality and cgs_per_bundle:
            c_take = min(take * cgs_per_bundle, remaining_cgs)
            c_sel = _lowest_bits(u_free_c, c_take, spec.num_coregroups)
            if c_sel is None:
                return None
            cg_mask |= c_sel
            remaining_cgs -= c_take
    # remaining CoreGroups (non-bundle leftovers or locality-free) from scope order
    if remaining_cgs:
        for u in numas:
            u_free_c = free_cg_mask & int(spec.numa_cg_masks[u]) & ~cg_mask
            avail = u_free_c.bit_count()
            take = min(avail, remaining_cgs)
            if take:
                c_sel = _lowest_bits(u_free_c, take, spec.num_coregroups)
                if c_sel is None:
                    return None
                cg_mask |= c_sel
                remaining_cgs -= take
            if remaining_cgs == 0:
                break
    if remaining_gpus or remaining_cgs:
        return None  # defensive; best_tier said feasible
    return Placement(gpu_mask=gpu_mask, cg_mask=cg_mask, tier=tier)


def place_blind(
    spec: ServerSpec,
    free_gpu_mask: int,
    free_cg_mask: int,
    need_gpus: int,
    need_cgs: int,
) -> Placement | None:
    """Topology-blind baseline: lowest free indices first (default scheduler)."""
    if free_gpu_mask.bit_count() < need_gpus or free_cg_mask.bit_count() < need_cgs:
        return None
    gpu_mask = _lowest_bits(free_gpu_mask, need_gpus, spec.num_gpus) if need_gpus else 0
    cg_mask = _lowest_bits(free_cg_mask, need_cgs, spec.num_coregroups) if need_cgs else 0
    if gpu_mask is None or cg_mask is None:
        return None
    return Placement(gpu_mask=gpu_mask, cg_mask=cg_mask,
                     tier=achieved_tier(spec, gpu_mask))


def achieved_tier(spec: ServerSpec, gpu_mask: int) -> int:
    """Tier actually achieved by a committed GPU set (for hit accounting)."""
    if gpu_mask == 0:
        return 0
    numas = {spec.numa_of_gpu(g) for g in _bits(gpu_mask, spec.num_gpus)}
    if len(numas) == 1:
        return 0
    sockets = {spec.socket_of_numa(u) for u in numas}
    return 1 if len(sockets) == 1 else 2


def bundle_locality_ok(spec: ServerSpec, gpu_mask: int, cg_mask: int,
                       need_cgs_per_gpu: int) -> bool:
    """Check the guaranteed-NUMA bundle constraint on a committed placement."""
    cg_left = cg_mask
    for g in _bits(gpu_mask, spec.num_gpus):
        u = spec.numa_of_gpu(g)
        local = cg_left & int(spec.numa_cg_masks[u])
        if local.bit_count() < need_cgs_per_gpu:
            return False
        # consume the local CoreGroups so two GPUs on one NUMA don't double count
        take = need_cgs_per_gpu
        for c in range(spec.num_coregroups):
            if take == 0:
                break
            if local >> c & 1:
                cg_left &= ~(1 << c)
                take -= 1
    return True


def is_topology_hit(spec: ServerSpec, gpu_mask: int, cg_mask: int,
                    need_gpus: int, need_cgs: int,
                    bundle_locality: bool = True) -> bool:
    """Paper Table 4 hit predicate: guaranteed NUMA bundles + best socket tier."""
    if need_gpus == 0:
        return True
    if bundle_locality and not bundle_locality_ok(
            spec, gpu_mask, cg_mask, need_cgs // need_gpus):
        return False
    return achieved_tier(spec, gpu_mask) <= min_tier_for(spec, need_gpus)
