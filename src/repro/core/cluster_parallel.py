"""Cluster-scale candidate sourcing sharded over the device mesh.

Beyond the paper: at 10^4–10^5 nodes, even vectorized subset evaluation on one
host dominates scheduling latency.  Here the *node* axis of the batched
evaluator is sharded across all mesh devices (every device scores its local
slice of servers), and the Eq. 1/Eq. 2 argmax reduces globally — XLA lowers
the reduction to all-reduce collectives across pods.  ``lower_distributed_source``
is compiled by the multi-pod dry-run to prove the scheduler itself scales to
the production mesh.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .cluster import (DRAIN_FIELDS, NODE_FIELDS, NS_FREE_CG, NS_FREE_GPU,
                      NS_NODE_ID, VF_CG, VF_GPU, VICTIM_FIELDS)
from .placement_jax import normal_cycle_core, winner_place
from .preemption_jax import (Request, _evaluate_subsets_core,
                             _fused_argmax_core, _fused_class_core,
                             combo_table, spec_constants)
from .scoring import TIER_SCORES
from .topology import ServerSpec

_TIER_VALUES = tuple(TIER_SCORES) + (0.0,)


def _source_best(
    free_gpu, free_cg, vg, vc, vp, valid,
    table, numa_gpu_masks, numa_cg_masks, sock_onehot,
    *, request: Request, alpha: float,
):
    """Evaluate all (node × subset) candidates and reduce to the global best.

    Returns (best_score f32[], best_node i32[], best_combo i32[]) — the
    argmax of Eq. 2 over every candidate in the cluster at this subset size.
    """
    eval_fn = partial(_evaluate_subsets_core, request=request)
    tier, prio, _ = jax.vmap(
        eval_fn, in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None)
    )(free_gpu, free_cg, vg, vc, vp, valid,
      table, numa_gpu_masks, numa_cg_masks, sock_onehot)
    # Eq. 1: S = alpha / sum_priority + (1 - alpha) * T(tier)
    tier_vals = jnp.asarray(_TIER_VALUES, jnp.float32)
    topo = tier_vals[tier]
    prio_term = jnp.where(prio > 0, 1.0 / jnp.maximum(prio, 1).astype(jnp.float32),
                          1.0)
    s = alpha * prio_term + (1.0 - alpha) * topo
    s = jnp.where(tier < 3, s, -jnp.inf)
    flat = s.reshape(-1)
    best = jnp.argmax(flat)                      # global argmax => all-reduce
    n_comb = s.shape[1]
    return flat[best], (best // n_comb).astype(jnp.int32), (
        best % n_comb).astype(jnp.int32)


def make_distributed_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
    alpha: float = 0.5,
):
    """jit the cluster-wide sourcing with the node axis sharded over ALL mesh
    axes (data, model, and pod when present)."""
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(axes))        # shard node axis
    repl = NamedSharding(mesh, P())
    fn = partial(_source_best, request=request, alpha=alpha)
    return jax.jit(
        fn,
        in_shardings=(node_sharding,) * 2 + (node_sharding,) * 4 + (repl,) * 4,
        out_shardings=(repl, repl, repl),
    )


def distributed_source_inputs(
    spec: ServerSpec,
    num_nodes: int,
    max_victims: int,
    k: int,
    request: Request,
    rng: np.random.Generator | None = None,
):
    """Build (or synthesize) the dense inputs for the distributed sourcing."""
    rng = rng or np.random.default_rng(0)
    consts = spec_constants(spec)
    table = combo_table(max_victims, k)
    free_gpu = np.zeros(num_nodes, np.int32)
    free_cg = np.zeros(num_nodes, np.int32)
    vg = rng.integers(0, spec.all_gpu_mask + 1, (num_nodes, max_victims),
                      dtype=np.int32)
    vc = rng.integers(0, spec.all_cg_mask + 1, (num_nodes, max_victims),
                      dtype=np.int32)
    vp = rng.integers(100, 600, (num_nodes, max_victims), dtype=np.int32)
    valid = np.ones((num_nodes, max_victims), bool)
    return (free_gpu, free_cg, vg, vc, vp, valid, np.asarray(table),
            np.asarray(consts["numa_gpu_masks"]),
            np.asarray(consts["numa_cg_masks"]),
            np.asarray(consts["sock_onehot"]))


def lower_distributed_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
    max_victims: int = 8,
    k: int = 2,
    alpha: float = 0.5,
):
    """Lower (without executing) the sharded sourcing for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_source(mesh, spec, request, alpha)
    args = distributed_source_inputs(spec, num_nodes, max_victims, k, request)
    shapes = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    return fn.lower(*shapes)


# ---------------------------------------------------------------------------------
# Fused single-dispatch sourcing, sharded
# ---------------------------------------------------------------------------------

def make_distributed_fused_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
    alpha: float = 0.5,
    m: int = 8,
):
    """jit the fused Filtering+Sorting evaluator (``imp_batched`` semantics:
    drain-mask Guaranteed Filtering, per-node smallest-k, global Eq. 2
    argmax in one program) over the DEVICE-RESIDENT layout
    (`DeviceClusterState`: nodestate/victims/drain tensors) with the node
    axis sharded over every mesh axis.

    The per-node filtering popcounts, subset evaluation and class
    reductions stay local to each device's node shard; only the final
    argmax chain over the ``[N, 3]`` class winners crosses shards (XLA
    all-reduce collectives) plus the one-row gather that feeds the winner
    through the SAME §3.4 placement scorer the local fused path uses
    (`placement_jax.winner_place`) — the device→host traffic is the
    ``int32[WIN_FIELDS]`` winner vector, concrete GPU/CoreGroup masks
    included, regardless of cluster size.
    """
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(None, axes))   # shard node axis 1
    victim_sharding = NamedSharding(mesh, P(None, axes, None))
    repl = NamedSharding(mesh, P())

    def fn(nodestate, victims, drain, thresh):
        ng = jnp.int32(request.need_gpus)
        nc = jnp.int32(request.need_cgs)
        cpb = jnp.int32(request.cgs_per_bundle)
        cls = _fused_class_core(
            nodestate, victims, drain, thresh, ng, nc, cpb,
            jnp.float32(alpha), spec=spec, m=m, narrow_gate=True)
        win = _fused_argmax_core(nodestate[NS_NODE_ID], cls,
                                 jnp.float32(alpha))
        return winner_place(win, nodestate[NS_FREE_GPU],
                            nodestate[NS_FREE_CG], victims[VF_GPU],
                            victims[VF_CG], ng, nc, cpb, spec=spec)

    return jax.jit(
        fn,
        in_shardings=(node_sharding, victim_sharding, node_sharding, repl),
        out_shardings=repl,
    )


def make_distributed_normal_cycle(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
):
    """jit the NORMAL scheduling cycle (`placement_jax.normal_cycle_core`)
    with the node axis sharded over every mesh axis.

    Per-node count screens, placement tiers and the blind degraded
    fallback stay shard-local; the ``(tier, leftover, node)`` argmin chain
    and the winner-row gather feeding the placement scorer reduce across
    shards — the same scorer the single-host fused dispatch chains in
    front of sourcing, so the no-preemption admission path scales to the
    dry-run mesh too.
    """
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(None, axes))
    repl = NamedSharding(mesh, P())

    def fn(nodestate):
        return normal_cycle_core(
            nodestate, jnp.int32(request.need_gpus),
            jnp.int32(request.need_cgs),
            jnp.int32(request.cgs_per_bundle), spec=spec)

    return jax.jit(fn, in_shardings=(node_sharding,), out_shardings=repl)


def lower_distributed_normal_cycle(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
):
    """Lower (without executing) the sharded normal cycle for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_normal_cycle(mesh, spec, request)
    shape = jax.ShapeDtypeStruct((NODE_FIELDS, num_nodes), np.int32)
    return fn.lower(shape)


def distributed_fused_inputs(
    spec: ServerSpec,
    num_nodes: int,
    m: int,
    rng: np.random.Generator | None = None,
):
    """Synthesize device-resident-layout inputs for the sharded sourcing.

    One GPU/CoreGroup per victim slot keeps the disjoint-mask invariant the
    fused fold relies on (real inputs are `DeviceClusterState` tensors).
    """
    rng = rng or np.random.default_rng(0)
    nodestate = np.zeros((NODE_FIELDS, num_nodes), np.int32)
    nodestate[NS_NODE_ID] = np.arange(num_nodes, dtype=np.int32)
    victims = np.zeros((VICTIM_FIELDS, num_nodes, m), np.int32)
    victims[0] = 1 << (np.arange(m, dtype=np.int32) % spec.num_gpus)
    victims[1] = 1 << (np.arange(m, dtype=np.int32) % spec.num_coregroups)
    victims[2] = rng.integers(100, 600, (num_nodes, m), dtype=np.int32)
    victims[3] = np.arange(m, dtype=np.int32)
    victims[4] = 1
    drain = np.zeros((DRAIN_FIELDS, num_nodes), np.int32)
    drain[0] = nodestate[0] | np.bitwise_or.reduce(victims[0], axis=1)
    drain[1] = nodestate[1] | np.bitwise_or.reduce(victims[1], axis=1)
    thresh = np.int32(1000)
    return (nodestate, victims, drain, thresh)


def lower_distributed_fused_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
    m: int = 8,
    alpha: float = 0.5,
):
    """Lower (without executing) the sharded fused sourcing for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_fused_source(mesh, spec, request, alpha, m)
    args = distributed_fused_inputs(spec, num_nodes, m)
    shapes = [jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
              for a in args]
    return fn.lower(*shapes)
