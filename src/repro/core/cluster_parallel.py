"""Cluster-scale candidate sourcing sharded over the device mesh.

Beyond the paper: at 10^4–10^5 nodes, even vectorized subset evaluation on one
host dominates scheduling latency.  Here the *node* axis of the batched
evaluator is sharded across all mesh devices (every device scores its local
slice of servers), and the Eq. 1/Eq. 2 argmax reduces globally — XLA lowers
the reduction to all-reduce collectives across pods.  ``lower_distributed_source``
is compiled by the multi-pod dry-run to prove the scheduler itself scales to
the production mesh.

The **``imp_sharded`` engine** goes beyond dry-run lowering: it installs a
`ShardedDeviceClusterState` (the resident nodestate/victims/drain tensors
`NamedSharding`-split on the node axis over a 1-D mesh of every local
device) and routes the full fused dispatch chain — `preemption_jax`'s
`plan_fused` / `plan_normal_fused` / `source_candidates_fused` / batch
sessions, UNCHANGED — through `sharded_evaluators`: jits of the very same
pipeline bodies with explicit sharding constraints.  Per-node filtering,
subset sweeps and class reductions stay shard-local; only the final Eq. 2
argmax chain (and the one-row winner gather) crosses shards.  Decisions are
bit-identical to ``imp_batched`` (see tests/test_distributed.py).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .cluster import (DRAIN_FIELDS, IDX_SENTINEL, NODE_FIELDS, NS_FREE_CG,
                      NS_FREE_GPU, NS_NODE_ID, VF_CG, VF_GPU, VICTIM_FIELDS,
                      DeviceClusterState, apply_rows, encode_delta_core)
from .engines import register_engine
from .placement_jax import normal_cycle_core, winner_place
from .preemption_jax import (Request, _evaluate_subsets_core,
                             _fused_argmax_core, _fused_class_core,
                             _sorting_winner, combo_table, spec_constants)
from . import preemption_jax as _pj
from .scoring import DEFAULT_ALPHA, TIER_SCORES
from .topology import ServerSpec

_TIER_VALUES = tuple(TIER_SCORES) + (0.0,)


def _source_best(
    free_gpu, free_cg, vg, vc, vp, valid,
    table, numa_gpu_masks, numa_cg_masks, sock_onehot,
    *, request: Request, alpha: float,
):
    """Evaluate all (node × subset) candidates and reduce to the global best.

    Returns (best_score f32[], best_node i32[], best_combo i32[]) — the
    argmax of Eq. 2 over every candidate in the cluster at this subset size.
    """
    eval_fn = partial(_evaluate_subsets_core, request=request)
    tier, prio, _ = jax.vmap(
        eval_fn, in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None)
    )(free_gpu, free_cg, vg, vc, vp, valid,
      table, numa_gpu_masks, numa_cg_masks, sock_onehot)
    # Eq. 1: S = alpha / sum_priority + (1 - alpha) * T(tier)
    tier_vals = jnp.asarray(_TIER_VALUES, jnp.float32)
    topo = tier_vals[tier]
    prio_term = jnp.where(prio > 0, 1.0 / jnp.maximum(prio, 1).astype(jnp.float32),
                          1.0)
    s = alpha * prio_term + (1.0 - alpha) * topo
    s = jnp.where(tier < 3, s, -jnp.inf)
    flat = s.reshape(-1)
    best = jnp.argmax(flat)                      # global argmax => all-reduce
    n_comb = s.shape[1]
    return flat[best], (best // n_comb).astype(jnp.int32), (
        best % n_comb).astype(jnp.int32)


def make_distributed_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
    alpha: float = 0.5,
):
    """jit the cluster-wide sourcing with the node axis sharded over ALL mesh
    axes (data, model, and pod when present)."""
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(axes))        # shard node axis
    repl = NamedSharding(mesh, P())
    fn = partial(_source_best, request=request, alpha=alpha)
    return jax.jit(
        fn,
        in_shardings=(node_sharding,) * 2 + (node_sharding,) * 4 + (repl,) * 4,
        out_shardings=(repl, repl, repl),
    )


def distributed_source_inputs(
    spec: ServerSpec,
    num_nodes: int,
    max_victims: int,
    k: int,
    request: Request,
    rng: np.random.Generator | None = None,
):
    """Build (or synthesize) the dense inputs for the distributed sourcing."""
    rng = rng or np.random.default_rng(0)
    consts = spec_constants(spec)
    table = combo_table(max_victims, k)
    free_gpu = np.zeros(num_nodes, np.int32)
    free_cg = np.zeros(num_nodes, np.int32)
    vg = rng.integers(0, spec.all_gpu_mask + 1, (num_nodes, max_victims),
                      dtype=np.int32)
    vc = rng.integers(0, spec.all_cg_mask + 1, (num_nodes, max_victims),
                      dtype=np.int32)
    vp = rng.integers(100, 600, (num_nodes, max_victims), dtype=np.int32)
    valid = np.ones((num_nodes, max_victims), bool)
    return (free_gpu, free_cg, vg, vc, vp, valid, np.asarray(table),
            np.asarray(consts["numa_gpu_masks"]),
            np.asarray(consts["numa_cg_masks"]),
            np.asarray(consts["sock_onehot"]))


def lower_distributed_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
    max_victims: int = 8,
    k: int = 2,
    alpha: float = 0.5,
):
    """Lower (without executing) the sharded sourcing for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_source(mesh, spec, request, alpha)
    args = distributed_source_inputs(spec, num_nodes, max_victims, k, request)
    shapes = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    return fn.lower(*shapes)


# ---------------------------------------------------------------------------------
# Fused single-dispatch sourcing, sharded
# ---------------------------------------------------------------------------------

def make_distributed_fused_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
    alpha: float = 0.5,
    m: int = 8,
):
    """jit the fused Filtering+Sorting evaluator (``imp_batched`` semantics:
    drain-mask Guaranteed Filtering, per-node smallest-k, global Eq. 2
    argmax in one program) over the DEVICE-RESIDENT layout
    (`DeviceClusterState`: nodestate/victims/drain tensors) with the node
    axis sharded over every mesh axis.

    The per-node filtering popcounts, subset evaluation and class
    reductions stay local to each device's node shard; only the final
    argmax chain over the ``[N, 3]`` class winners crosses shards (XLA
    all-reduce collectives) plus the one-row gather that feeds the winner
    through the SAME §3.4 placement scorer the local fused path uses
    (`placement_jax.winner_place`) — the device→host traffic is the
    ``int32[WIN_FIELDS]`` winner vector, concrete GPU/CoreGroup masks
    included, regardless of cluster size.
    """
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(None, axes))   # shard node axis 1
    victim_sharding = NamedSharding(mesh, P(None, axes, None))
    repl = NamedSharding(mesh, P())

    def fn(nodestate, victims, drain, thresh):
        # the SAME body the local fused engine dispatches (g=0: no
        # gathered mid-tier section) — `sharded_evaluators` jits the full
        # overlay/plan variants of it for the `imp_sharded` engine
        return _sorting_winner(
            nodestate, victims, drain, jnp.zeros(0, jnp.int32), thresh,
            jnp.int32(request.need_gpus), jnp.int32(request.need_cgs),
            jnp.int32(request.cgs_per_bundle), jnp.float32(alpha),
            spec=spec, m=m, g=0)

    return jax.jit(
        fn,
        in_shardings=(node_sharding, victim_sharding, node_sharding, repl),
        out_shardings=repl,
    )


def make_distributed_normal_cycle(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    request: Request,
):
    """jit the NORMAL scheduling cycle (`placement_jax.normal_cycle_core`)
    with the node axis sharded over every mesh axis.

    Per-node count screens, placement tiers and the blind degraded
    fallback stay shard-local; the ``(tier, leftover, node)`` argmin chain
    and the winner-row gather feeding the placement scorer reduce across
    shards — the same scorer the single-host fused dispatch chains in
    front of sourcing, so the no-preemption admission path scales to the
    dry-run mesh too.
    """
    axes = tuple(mesh.axis_names)
    node_sharding = NamedSharding(mesh, P(None, axes))
    repl = NamedSharding(mesh, P())

    def fn(nodestate):
        return normal_cycle_core(
            nodestate, jnp.int32(request.need_gpus),
            jnp.int32(request.need_cgs),
            jnp.int32(request.cgs_per_bundle), spec=spec)

    return jax.jit(fn, in_shardings=(node_sharding,), out_shardings=repl)


def lower_distributed_normal_cycle(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
):
    """Lower (without executing) the sharded normal cycle for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_normal_cycle(mesh, spec, request)
    shape = jax.ShapeDtypeStruct((NODE_FIELDS, num_nodes), np.int32)
    return fn.lower(shape)


def distributed_fused_inputs(
    spec: ServerSpec,
    num_nodes: int,
    m: int,
    rng: np.random.Generator | None = None,
):
    """Synthesize device-resident-layout inputs for the sharded sourcing.

    One GPU/CoreGroup per victim slot keeps the disjoint-mask invariant the
    fused fold relies on (real inputs are `DeviceClusterState` tensors).
    """
    rng = rng or np.random.default_rng(0)
    nodestate = np.zeros((NODE_FIELDS, num_nodes), np.int32)
    nodestate[NS_NODE_ID] = np.arange(num_nodes, dtype=np.int32)
    victims = np.zeros((VICTIM_FIELDS, num_nodes, m), np.int32)
    victims[0] = 1 << (np.arange(m, dtype=np.int32) % spec.num_gpus)
    victims[1] = 1 << (np.arange(m, dtype=np.int32) % spec.num_coregroups)
    victims[2] = rng.integers(100, 600, (num_nodes, m), dtype=np.int32)
    victims[3] = np.arange(m, dtype=np.int32)
    victims[4] = 1
    drain = np.zeros((DRAIN_FIELDS, num_nodes), np.int32)
    drain[0] = nodestate[0] | np.bitwise_or.reduce(victims[0], axis=1)
    drain[1] = nodestate[1] | np.bitwise_or.reduce(victims[1], axis=1)
    thresh = np.int32(1000)
    return (nodestate, victims, drain, thresh)


def lower_distributed_fused_source(
    mesh: jax.sharding.Mesh,
    spec: ServerSpec,
    num_nodes: int = 65536,
    m: int = 8,
    alpha: float = 0.5,
):
    """Lower (without executing) the sharded fused sourcing for the dry-run."""
    request = Request(need_gpus=4, need_cgs=4, bundle_locality=True)
    fn = make_distributed_fused_source(mesh, spec, request, alpha, m)
    args = distributed_fused_inputs(spec, num_nodes, m)
    shapes = [jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
              for a in args]
    return fn.lower(*shapes)


# ---------------------------------------------------------------------------------
# Mesh-sharded resident cluster state (the `imp_sharded` engine)
# ---------------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _cluster_mesh(devices) -> jax.sharding.Mesh:
    return jax.sharding.Mesh(np.asarray(devices), ("nodes",))


def cluster_mesh(devices=None) -> jax.sharding.Mesh:
    """1-D mesh over every local device (axis ``nodes``) — the default
    layout of `ShardedDeviceClusterState`.  Degrades to a one-device mesh
    when only one device exists, so the sharded paths stay parity-testable
    anywhere."""
    return _cluster_mesh(tuple(jax.devices()) if devices is None
                         else tuple(devices))


@lru_cache(maxsize=None)
def _mesh_shardings(mesh):
    """(node, victim, replicated) `NamedSharding`s of a mesh: node-axis
    tensors split their axis 1 over EVERY mesh axis."""
    axes = tuple(mesh.axis_names)
    return (NamedSharding(mesh, P(None, axes)),
            NamedSharding(mesh, P(None, axes, None)),
            NamedSharding(mesh, P()))


@lru_cache(maxsize=None)
def _sharded_scatter(mesh):
    """jit of the dirty-row scatter with the resident tensors held sharded
    on both sides (the update rows replicate — they are O(dirty), tiny)."""
    node_sh, victim_sh, repl = _mesh_shardings(mesh)
    return jax.jit(apply_rows,
                   in_shardings=(node_sh, victim_sh, node_sh, repl, repl),
                   out_shardings=(node_sh, victim_sh, node_sh))


@lru_cache(maxsize=None)
def _sharded_delta_encoder(mesh, cap: int, a: int):
    """jit of `cluster.encode_delta_core` against the SHARDED base tensors:
    the per-plan descriptor columns arrive replicated and the rebuilt patch
    rows come back replicated (they are O(delta), tiny), so the base-row
    gather is the only cross-shard traffic."""
    node_sh, victim_sh, repl = _mesh_shardings(mesh)
    return jax.jit(partial(encode_delta_core, cap=cap, a=a),
                   in_shardings=(node_sh, victim_sh) + (repl,) * 10,
                   out_shardings=repl)


class ShardedDeviceClusterState(DeviceClusterState):
    """`DeviceClusterState` with the node axis `NamedSharding`-split over a
    device mesh (install via ``cluster.device_state(sharded=True)``).

    The node axis is padded UP to a multiple of the device count; pad rows
    carry `IDX_SENTINEL` node ids and zero masks, which every fused core
    already excludes (``node_ids < 2**31-1`` screens), so evaluator
    results are bit-identical to the unsharded layout.  ``n_rows`` exposes
    the padded length — the fused paths use it as the row base of their
    gathered sections.  The full-rebuild upload, the dirty-row scatter and
    the view-delta encoder all pin their outputs sharded/replicated
    explicitly so the resident tensors never silently migrate."""

    def __init__(self, cluster, cap: int | None = None, mesh=None) -> None:
        self.mesh = cluster_mesh() if mesh is None else mesh
        self._node_sh, self._victim_sh, self._repl = _mesh_shardings(
            self.mesh)
        super().__init__(cluster, cap)

    @property
    def n_rows(self) -> int:
        d = int(self.mesh.size)
        return -(-max(self.cluster.num_nodes, 1) // d) * d

    def _upload_full(self, ns, v, dr):
        pad = self.n_rows - ns.shape[1]
        if pad:
            pns = np.zeros((NODE_FIELDS, pad), np.int32)
            pns[NS_NODE_ID] = IDX_SENTINEL
            ns = np.concatenate([ns, pns], axis=1)
            v = np.concatenate(
                [v, np.zeros((VICTIM_FIELDS, pad, v.shape[2]), np.int32)],
                axis=1)
            dr = np.concatenate(
                [dr, np.zeros((DRAIN_FIELDS, pad), np.int32)], axis=1)
        return (jax.device_put(np.ascontiguousarray(ns), self._node_sh),
                jax.device_put(np.ascontiguousarray(v), self._victim_sh),
                jax.device_put(np.ascontiguousarray(dr), self._node_sh))

    def _scatter(self, idx, buf):
        return _sharded_scatter(self.mesh)(
            self.nodestate, self.victims, self.drain,
            jnp.asarray(idx), jnp.asarray(buf))

    def delta_encode(self, a: int, didx, *descs):
        return _sharded_delta_encoder(self.mesh, self.cap, a)(
            self.nodestate, self.victims, didx, *descs)

    def _upload_rep(self, rep):
        """Pin the rep mask 1-D row-sharded so the shortlist prescreen
        stays shard-local up to its top-K collective."""
        return jax.device_put(
            np.ascontiguousarray(rep),
            NamedSharding(self.mesh, P(tuple(self.mesh.axis_names))))


# ---------------------------------------------------------------------------------
# Sharded twins of the fused evaluator factories
# ---------------------------------------------------------------------------------

class _ShardedEvaluators:
    """Drop-in namespace for `preemption_jax._evals`: the SAME pipeline
    bodies (`_plan_pipeline`, `_plan2_pipeline`, `_normal_pipeline`,
    `_gathered_pipeline`, the batch pipelines) jitted with explicit
    sharding constraints.  Node-axis tensors arrive sharded, every
    aux/patch upload and the request scalars replicate, and the
    int32[`WIN_FIELDS`]-sized winner vectors come back replicated — the
    per-node class math runs shard-local and only the final argmax chain
    (plus the winner-row gather) crosses shards as collectives."""

    def __init__(self, mesh) -> None:
        self.mesh = mesh
        self.node_sh, self.victim_sh, self.repl = _mesh_shardings(mesh)
        self._cache: dict = {}

    def _get(self, key, build):
        fn = self._cache.get(key)
        if fn is None:
            fn = self._cache[key] = build()
        return fn

    def resident_evaluator(self, spec, m, p, g, thresh, ng, nc, cpb,
                           alpha):
        def build():
            def f(nodestate, victims, drain, aux, pbuf):
                return _pj._plan_pipeline(
                    nodestate, victims, drain, aux, pbuf, thresh, ng, nc,
                    cpb, alpha, spec=spec, m=m, p=p, g=g)

            return jax.jit(f, in_shardings=(
                self.node_sh, self.victim_sh, self.node_sh, self.repl,
                self.repl), out_shardings=self.repl)

        return self._get(("res", spec, m, p, g, thresh, ng, nc, cpb,
                          alpha), build)

    def plan_evaluator(self, spec, m, p, g, thresh, ng, nc, cpb, alpha):
        def build():
            def f(nodestate, victims, drain, aux, pbuf):
                return _pj._plan2_pipeline(
                    nodestate, victims, drain, aux, pbuf, thresh, ng, nc,
                    cpb, alpha, spec=spec, m=m, p=p, g=g)

            return jax.jit(f, in_shardings=(
                self.node_sh, self.victim_sh, self.node_sh, self.repl,
                self.repl), out_shardings=self.repl)

        return self._get(("plan", spec, m, p, g, thresh, ng, nc, cpb,
                          alpha), build)

    def normal_evaluator(self, spec, p, ng, nc, cpb):
        def build():
            def f(nodestate, aux, pbuf):
                return _pj._normal_pipeline(nodestate, aux, pbuf, ng, nc,
                                            cpb, spec=spec, p=p)

            return jax.jit(f, in_shardings=(
                self.node_sh, self.repl, self.repl),
                out_shardings=self.repl)

        return self._get(("norm", spec, p, ng, nc, cpb), build)

    def gathered_evaluator(self, spec, m, p, thresh, ng, nc, cpb, alpha):
        def build():
            def f(nodestate, victims, drain, pidx, pbuf, gidx):
                return _pj._gathered_pipeline(
                    nodestate, victims, drain, pidx, pbuf, gidx, thresh,
                    ng, nc, cpb, alpha, spec=spec, m=m, p=p)

            return jax.jit(f, in_shardings=(
                self.node_sh, self.victim_sh, self.node_sh, self.repl,
                self.repl, self.repl), out_shardings=self.repl)

        return self._get(("gath", spec, m, p, thresh, ng, nc, cpb, alpha),
                         build)

    @property
    def _rep_sh(self):
        """1-D row sharding of the equivalence-class rep mask."""
        return NamedSharding(self.mesh, P(tuple(self.mesh.axis_names)))

    def shortlist_evaluator(self, spec, k, p, f, thresh, ng, nc, cpb,
                            alpha):
        def build():
            def fn(nodestate, victims, drain, rep, aux, pbuf):
                return _pj._shortlist_pipeline(
                    nodestate, victims, drain, rep, aux, pbuf, thresh, ng,
                    nc, cpb, alpha, spec=spec, k=k, p=p, f=f)

            return jax.jit(fn, in_shardings=(
                self.node_sh, self.victim_sh, self.node_sh, self._rep_sh,
                self.repl, self.repl), out_shardings=self.repl)

        return self._get(("sl", spec, k, p, f, thresh, ng, nc, cpb,
                          alpha), build)

    def shortlist_plan_evaluator(self, spec, k, p, f, thresh, ng, nc,
                                 cpb, alpha):
        def build():
            def fn(nodestate, victims, drain, rep, aux, pbuf):
                return _pj._shortlist_plan2_pipeline(
                    nodestate, victims, drain, rep, aux, pbuf, thresh, ng,
                    nc, cpb, alpha, spec=spec, k=k, p=p, f=f)

            return jax.jit(fn, in_shardings=(
                self.node_sh, self.victim_sh, self.node_sh, self._rep_sh,
                self.repl, self.repl), out_shardings=self.repl)

        return self._get(("slplan", spec, k, p, f, thresh, ng, nc, cpb,
                          alpha), build)

    def batch_class_evaluator(self, spec, m, alpha):
        def build():
            def f(nodestate, victims, drain, thresh, ng, nc, cpb):
                return _fused_class_core(
                    nodestate, victims, drain, thresh, ng, nc, cpb, alpha,
                    spec=spec, m=m, narrow_gate=True)

            cw3, cw2 = self.victim_sh, self.node_sh
            return jax.jit(
                jax.vmap(f, in_axes=(None, None, None, 0, 0, 0, 0)),
                in_shardings=(self.node_sh, self.victim_sh, self.node_sh)
                + (self.repl,) * 4,
                out_shardings=_pj.ClassWinners(cw3, cw3, cw3, cw3, cw2,
                                               cw2))

        return self._get(("bcls", spec, m, alpha), build)

    def batch_merge_evaluator(self, spec, m, dpad, g, thresh, ng, nc, cpb,
                              alpha):
        def build():
            def f(anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i,
                  aux, pbuf):
                return _pj._batch_merge_pipeline(
                    anyc, cb, pp, um, kn, cnt, nodestate, victims, drain,
                    i, aux, pbuf, thresh, ng, nc, cpb, alpha, spec=spec,
                    m=m, dpad=dpad, g=g)

            cw3, cw2 = self.victim_sh, self.node_sh
            return jax.jit(f, in_shardings=(
                cw3, cw3, cw3, cw3, cw2, cw2, self.node_sh, self.victim_sh,
                self.node_sh, self.repl, self.repl, self.repl),
                out_shardings=self.repl)

        return self._get(("bmerge", spec, m, dpad, g, thresh, ng, nc, cpb,
                          alpha), build)

    def batch_plan_evaluator(self, spec, m, dpad, g, p, thresh, ng, nc,
                             cpb, alpha):
        def build():
            def f(anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i,
                  aux, pbuf):
                return _pj._batch_plan_pipeline(
                    anyc, cb, pp, um, kn, cnt, nodestate, victims, drain,
                    i, aux, pbuf, thresh, ng, nc, cpb, alpha, spec=spec,
                    m=m, dpad=dpad, g=g, p=p)

            cw3, cw2 = self.victim_sh, self.node_sh
            return jax.jit(f, in_shardings=(
                cw3, cw3, cw3, cw3, cw2, cw2, self.node_sh, self.victim_sh,
                self.node_sh, self.repl, self.repl, self.repl),
                out_shardings=self.repl)

        return self._get(("bplan", spec, m, dpad, g, p, thresh, ng, nc,
                          cpb, alpha), build)


@lru_cache(maxsize=None)
def sharded_evaluators(mesh) -> _ShardedEvaluators:
    """The per-mesh sharded evaluator namespace (`preemption_jax._evals`
    routes here whenever the device state carries a mesh)."""
    return _ShardedEvaluators(mesh)


# ---------------------------------------------------------------------------------
# The `imp_sharded` engine: fused paths over the sharded resident state
# ---------------------------------------------------------------------------------

def _sharded_state(cluster) -> None:
    """Idempotently install the mesh-sharded device state on the base
    cluster: every fused path then routes through `sharded_evaluators`."""
    base = getattr(cluster, "base", cluster)
    base.device_state(sharded=True)


def plan_sharded(cluster, workload, alpha: float = DEFAULT_ALPHA,
                 allow_preempt: bool = True, shortlist=None):
    """`preemption_jax.plan_fused` over the sharded resident state."""
    _sharded_state(cluster)
    return _pj.plan_fused(cluster, workload, alpha, allow_preempt,
                          shortlist=shortlist)


def plan_normal_sharded(cluster, workload):
    """`preemption_jax.plan_normal_fused` over the sharded state."""
    _sharded_state(cluster)
    return _pj.plan_normal_fused(cluster, workload)


def batch_session_sharded(cluster, workloads, alpha: float):
    """`preemption_jax.persistent_batch_session` over the sharded state."""
    _sharded_state(cluster)
    return _pj.persistent_batch_session(cluster, workloads, alpha)


def warmup_sharded(cluster, alpha: float = DEFAULT_ALPHA, batch: int = 8,
                   workloads=None, shortlist=None) -> None:
    """`preemption_jax.warmup_fused` against the sharded jit variants."""
    _sharded_state(cluster)
    _pj.warmup_fused(cluster, alpha, batch, workloads, shortlist=shortlist)


@register_engine("imp_sharded", batched=True, needs_alpha=True,
                 fused_filter=True, fused_place=True, plan_fn=plan_sharded,
                 normal_fn=plan_normal_sharded,
                 batch_factory=batch_session_sharded,
                 warmup_fn=warmup_sharded, supports_shortlist=True)
def source_candidates_sharded(cluster, workload, nodes=None,
                              alpha: float = DEFAULT_ALPHA,
                              shortlist=None):
    """``imp_batched`` semantics, mesh-sharded state: same fused dispatch
    chain, node axis split across every local device.  The shortlist
    prescreen runs shard-local; only the top-K gather and the argmax
    chain cross shards."""
    _sharded_state(cluster)
    return _pj.source_candidates_fused(cluster, workload, nodes,
                                       alpha=alpha, shortlist=shortlist)


# full-sweep parity oracle (see ``imp_batched_full``)
register_engine("imp_sharded_full", batched=True, needs_alpha=True,
                fused_filter=True, fused_place=True, plan_fn=plan_sharded,
                normal_fn=plan_normal_sharded,
                batch_factory=batch_session_sharded,
                warmup_fn=warmup_sharded)(source_candidates_sharded)
