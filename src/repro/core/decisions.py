"""Unified scheduling decisions and two-phase transactions.

One `SchedulingDecision` describes the outcome of any scheduling attempt —
normal-cycle placement, preemption, or rejection — so no caller has to
isinstance-dispatch over separate result types.  Decisions are produced by
``TopoScheduler.plan`` wrapped in a `Transaction`:

* ``plan()`` evaluates the request against a copy-on-write `ClusterView`;
  the real cluster is untouched.  Reading the planned decision and dropping
  (or ``rollback()``-ing) the transaction is therefore free — the Table 4
  "independent preemptions" protocol is a pure read.
* ``commit()`` validates the plan against the live cluster and applies it:
  victims are evicted, the preemptor is bound, and the decision is completed
  with the live `Instance` objects.
* ``rollback()`` on a *committed* transaction restores the exact prior state:
  the bound instance is evicted and every victim is re-inserted via
  ``Cluster.restore`` with its original uid, node, and GPU/CoreGroup masks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal

from .cluster import Cluster
from .placement import Placement
from .workload import Instance, WorkloadSpec

DecisionKind = Literal["placed", "preempted", "rejected"]

PLANNED = "planned"
COMMITTED = "committed"
ROLLED_BACK = "rolled_back"


@dataclasses.dataclass
class SchedulingDecision:
    """Outcome of one scheduling attempt, uniform across all code paths.

    ``kind``:
      * ``"placed"``    — normal cycle succeeded, no victims.
      * ``"preempted"`` — victims evicted to make room.
      * ``"rejected"``  — no feasible placement even with preemption.

    ``victims`` holds victim instance uids as planned; ``instance`` and
    ``evicted`` are filled in at commit time with the live objects.
    """

    kind: DecisionKind
    workload: WorkloadSpec
    node: int = -1
    placement: Placement | None = None
    hit: bool = False
    victims: tuple[int, ...] = ()
    sourcing_us: float = 0.0
    num_candidates: int = 0
    #: how ``sourcing_us`` was produced: the resolved engine (and whether
    #: ``engine="auto"`` picked it, at which node-count threshold) plus the
    #: shortlist knobs in force.  Excluded from equality so decision-parity
    #: comparisons across engines stay meaningful.
    sourcing_provenance: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    instance: Instance | None = None
    evicted: list[Instance] = dataclasses.field(default_factory=list)
    txn: "Transaction | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def placed(self) -> bool:
        return self.kind == "placed"

    @property
    def preempted(self) -> bool:
        return self.kind == "preempted"

    @property
    def rejected(self) -> bool:
        return self.kind == "rejected"

    def __bool__(self) -> bool:
        """Truthy iff the request got a placement (placed or preempted)."""
        return self.kind != "rejected"


class TransactionError(RuntimeError):
    """Commit/rollback called in an invalid state, or the plan went stale."""


@dataclasses.dataclass
class Transaction:
    """Two-phase handle around one planned `SchedulingDecision`."""

    cluster: Cluster
    decision: SchedulingDecision
    state: str = PLANNED
    on_event: Callable[[SchedulingDecision, str], None] | None = dataclasses.field(
        default=None, repr=False)
    # the ClusterView the plan was made against and the virtual uid of its
    # planned bind: lets a batch of transactions sharing one view resolve
    # victims that reference earlier (still-virtual) binds at commit time
    view: object | None = dataclasses.field(default=None, repr=False)
    planned_uid: int | None = dataclasses.field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.decision.txn = self

    # -- phase 2: apply -----------------------------------------------------------
    def commit(self) -> SchedulingDecision:
        """Apply the planned decision to the live cluster and return it.

        Rejected decisions commit as no-ops.  A plan whose victims vanished
        or whose placement no longer fits (the cluster changed since
        ``plan()``) raises `TransactionError` and leaves the cluster
        untouched.
        """
        if self.state != PLANNED:
            raise TransactionError(f"cannot commit a {self.state} transaction")
        dec = self.decision
        if dec.rejected:
            self.state = COMMITTED
            return dec
        if self.view is not None:
            # victims planned against an earlier (virtual) bind in the same
            # batch resolve to the real uid that bind committed as
            dec.victims = tuple(self.view.resolve_uid(u) for u in dec.victims)
        missing = [uid for uid in dec.victims if uid not in self.cluster.instances]
        if missing:
            raise TransactionError(
                f"stale plan: victim uids {missing} no longer in the cluster")
        evicted = [self.cluster.evict(uid) for uid in dec.victims]
        free_gpu, free_cg = self.cluster.free_masks(dec.node)
        if (dec.placement.gpu_mask & ~free_gpu) or (dec.placement.cg_mask & ~free_cg):
            for v in evicted:  # put the world back before failing
                self.cluster.restore(v)
            raise TransactionError(
                f"stale plan: placement on node {dec.node} no longer fits")
        dec.evicted = evicted
        dec.instance = self.cluster.bind(dec.workload, dec.node, dec.placement)
        if self.view is not None and self.planned_uid is not None:
            self.view.committed_uids[self.planned_uid] = dec.instance.uid
        self.state = COMMITTED
        if self.on_event is not None:
            self.on_event(dec, COMMITTED)
        return dec

    # -- abandon / reverse --------------------------------------------------------
    def rollback(self) -> None:
        """Discard a planned transaction, or reverse a committed one exactly.

        After rolling back a commit, free masks, instance uids, and every
        victim's full placement are bitwise-identical to the pre-commit
        state (victims are restored with their original uid and masks, not
        rebound as new instances).
        """
        if self.state == ROLLED_BACK:
            return
        if self.state == PLANNED:
            self.state = ROLLED_BACK
            return
        dec = self.decision
        if not dec.rejected:
            self.cluster.evict(dec.instance.uid)
            dec.instance = None
            for victim in dec.evicted:
                self.cluster.restore(victim)
            dec.evicted = []
        self.state = ROLLED_BACK
        if self.on_event is not None:
            self.on_event(dec, ROLLED_BACK)
