"""Event-driven continuous-time co-location engine (paper §1/§2.3, Fig. 2).

The paper's headline scenario — long-running online chat services with
diurnal traffic, offline batch jobs padding the valleys between peaks, and
preemption waves at the ramps — is a *process over time*, not an episodic
experiment.  This module runs it end to end: a priority event queue of
traffic ticks, offline-job submissions/completions, and victim requeues is
driven entirely through the transactional plan/commit API (persistent batch
sessions, optional construction-time jit warm-up), and every committed
decision streams through the scheduler's listener chain into a per-hour
`ColocationReport`.

Event kinds (stable ordering at equal timestamps):

* ``tick``     — an `AutoscalePolicy` evaluation: the diurnal traffic level
  becomes desired replica counts and the delta is applied through the
  `Autoscaler` scale executor (batched ``plan_batch`` scale-ups that preempt
  offline victims at the ramps; worst-achieved-tier scale-downs that
  defragment on the way down).  Policies are *event sources*: they produce
  no state of their own between ticks.
* ``complete`` — a running offline job finished; its instance is released
  and the reopened capacity is immediately backfilled from the pending
  queue.
* ``requeue``  — a preempted offline victim re-enters the pending queue
  after a short delay and is replanned via chunked ``plan_batch`` admission
  when capacity allows.  The job keeps its workload identity and its
  remaining work; every instance uid it runs under is recorded and uids are
  never reused (`Cluster` uids are monotonic).
* ``submit``   — a new offline job arrives (seeded Poisson process, drawn
  entirely at construction so the arrival stream is identical across
  engines) and enters the pending queue.
* ``scale``    — an explicit one-shot scale-up request; the Fig. 8/9 views
  (`repro.core.simulator.run_timeline` / ``run_allocation_snapshot``) are
  day-cycle runs consisting only of these.
* ``ecomplete`` — an offline job hosted at REQUEST granularity inside an
  online replica's spare continuous-batching slots (the elastic layer,
  `repro.serving.elastic`) finished; its slot grant is released.

**The two-level backfill ladder** (``ColocationConfig.elastic=True``) sits
between the day cycle and the per-instance engines: each valley tick first
packs pending offline work into online replicas' spare request slots
through the `ElasticPool` admission controller (SLO-guarded, tier-aware)
and only spins up whole offline instances for the residual — holding back
the next tick's online GPU reserve so ramp scale-ups land in the normal
cycle instead of preempting instances created one tick earlier.  Peak
ramps reverse the ladder: online load reclaims request slots (ejecting
offline requests back to the pending queue — degrade-before-kill) BEFORE
the scale executor preempts whole instances, shrinking the Eq. 2 victim
set.  ``compare_two_level`` A/Bs instance-only vs two-level backfill on
the same seeded day.

**Scheduled performance** follows the paper's Fig. 2 accounting: each live
instance contributes ``gpus x TIER_PERF[achieved tier]`` per hour
(`repro.serving.scheduled_factor` is the same conversion applied to the
per-decision stream), and the headline metric is the integral of the ONLINE
classes' factor-weighted GPU-hours over the day — the quantity the paper
reports a 55% improvement on for topology-aware preemption.  Offline jobs
are credited separately as completed GPU-hours (goodput).

``compare_day_cycle`` runs the A/B: the same seeded day (identical arrival
stream, identical policies) under a topology-aware engine and a
topology-unaware baseline, reporting the scheduled-performance uplift.

**The O(delta) host loop.**  Per-event host work is independent of cluster
size: progress accrues through an aggregate piecewise-constant rate
accumulator (`_RateAcc` — maintained on every instance bind/evict/restore
via the cluster's inst-listener stream, materialized in a fixed summation
order so it is BIT-exact vs a full per-event scan), same-instant
requeue/submit waves coalesce into one chunked dispatch, backfill
dispatches are skipped by an exact count-feasibility gate when no pending
chunk job can place, and ramp/demotion/scale-down selection reads
maintained per-node and per-tier indexes instead of re-sorting the fleet
(`Autoscaler._index`, ``_offline_by_node``, free-count buckets).
``ColocationConfig.legacy_loop=True`` runs the pre-O(delta) loop — the
scale bench measures events/sec and bit-exact day-metric parity between
the two (`BENCH_colocation.json` ``scale`` block, sizes 24..10240 on
``engine="auto"``).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
import statistics
import time
from collections import deque

from .agent import AgentFleet
from .autoscale import AutoscalePolicy, Autoscaler, diurnal_traffic
from .cluster import Cluster
from .engines import EngineName
from .perfmodel import relative_scheduled_factor, scheduled_factor
from .placement import achieved_tier
from .scheduler import TopoScheduler
from .topology import RTX4090_SERVER, ServerSpec
from .workload import WorkloadSpec, table3_workloads

# event-kind priorities: stable processing order at equal timestamps
_TICK, _COMPLETE, _REQUEUE, _SUBMIT, _SCALE, _ECOMPLETE = range(6)


@dataclasses.dataclass(frozen=True)
class ColocationConfig:
    """One day-cycle scenario (frozen so A/B runs share it via ``replace``)."""

    num_nodes: int = 16
    spec: ServerSpec = RTX4090_SERVER
    seed: int = 0
    alpha: float = 0.5
    engine: EngineName = "imp_batched"
    warmup: bool = False
    horizon_hours: float = 24.0
    tick_hours: float = 1.0
    #: preempted victims re-enter the pending queue after this delay
    requeue_delay_hours: float = 0.1
    #: floor on a requeued job's remaining work (progress is checkpointed)
    min_requeue_hours: float = 0.05
    #: pending-queue admission rounds plan this many requests per dispatch
    backfill_chunk: int = 8
    #: offline arrivals per hour; None scales with the cluster (2.5 / node,
    #: deliberate oversupply so the allocation stays saturated through the
    #: night and the morning online ramp has to preempt — the paper's §2.3
    #: co-location regime; the surplus queues as backlog)
    offline_rate_per_hour: float | None = None
    mean_job_hours: float = 2.0
    #: day-0 burst that saturates the initial allocation; None -> 4 / node
    initial_offline_jobs: int | None = None
    #: False drops preempted victims instead of requeueing them (the legacy
    #: episodic semantics, kept for the Fig. 8/9 views)
    requeue: bool = True
    #: True enables the two-level backfill ladder: pending offline work is
    #: packed into online replicas' spare request slots (the elastic layer)
    #: before whole offline instances are spun up for the residual
    elastic: bool = False
    #: `repro.serving.elastic.ElasticConfig`; setting it WITHOUT
    #: ``elastic=True`` runs the instance-only ladder under the same SLO
    #: monitor — the A/B baseline that reports attainment without admitting
    #: request-level work.  None with ``elastic=True`` uses the defaults.
    elastic_cfg: object | None = None
    #: shortlist front-end knobs forwarded to `TopoScheduler` (engines that
    #: don't support shortlisting ignore them); ``shortlist_k=0`` disables
    shortlist_k: int = 128
    shortlist_mode: str = "guaranteed"
    #: True runs the pre-O(delta) host loop: a full instance scan per event
    #: in ``_advance``, one ``_drain`` dispatch per requeue/submit event
    #: (no same-timestamp coalescing), and no count-gated dispatch skip.
    #: Decisions and metrics are bit-exact either way — this is the A/B
    #: baseline the scale bench measures events/sec and parity against.
    legacy_loop: bool = False


@dataclasses.dataclass
class OfflineJob:
    """One offline batch job across its whole lifecycle (pending -> running
    -> preempted/requeued -> ... -> completed).  ``uids`` records every
    instance uid the job has run under; a replanned job always binds a NEW
    uid (cluster uids are monotonic), preserving workload identity without
    ever resurrecting an evicted instance."""

    jid: int
    workload: WorkloadSpec
    duration_hours: float
    submitted_at: float
    remaining_hours: float
    requeues: int = 0
    uids: tuple[int, ...] = ()
    uid: int | None = None          # live instance uid while running
    started_at: float = 0.0
    completed_at: float | None = None
    #: Fig. 2 progress rate of the CURRENT placement: a degraded tier runs
    #: the job slower, so it occupies its GPUs for proportionally longer
    rate: float = 1.0
    #: times this job was hosted at request granularity (elastic layer)
    elastic_hosts: int = 0
    #: times the job was ejected from request slots (degrade-before-kill)
    ejections: int = 0
    #: set when a preemption requeues the job; cleared (and counted as a
    #: successful replan) by its next start, instance-granular or elastic
    awaiting_replan: bool = False


@dataclasses.dataclass
class HourRow:
    """One reporting interval of a day-cycle run."""

    hour: float                     # interval start (simulation hours)
    load: float                     # diurnal traffic level at the last tick
    counts: dict[str, int]          # live instances by workload at interval end
    scheduled_perf: float           # ONLINE factor-weighted GPU-hours served
    preemptor_perf: float           # ...restricted to preemption-placed instances
    served: dict[str, float]        # per-class factor-weighted GPU-hours
    offline_goodput: float          # completed offline job GPU-hours
    placements: int                 # committed normal-cycle admissions
    preemptions: int
    hits: int                       # topology-affinity hits among preemptions
    failures: int                   # rejected online scale-up requests
    requeued: int                   # victims entering the requeue lifecycle
    requeue_replanned: int          # requeued jobs successfully replanned
    completed_jobs: int
    pending: int                    # offline queue depth at interval end
    crd_patches: int                # FlexTopo agent PATCHes (AgentFleet.watch)
    reclaimed_tiers: dict[int, int]  # scale-down tier distribution
    decision_factor_mean: float     # mean Fig. 2 factor of committed decisions
    #: P50 per-request plan wall time this interval, measured around every
    #: plan/plan_batch call the sim issues — the same metric for host and
    #: fused engines
    plan_p50_us: float
    #: XLA backend compiles that landed inside this interval
    #: (`simulator.CompileWatch`): a nonzero count means the interval's
    #: plan latencies paid cold-jit time, so the CI latency gate skips it
    compiled_n: int = 0
    # ---- request-level elastic co-location (two-level ladder) ----
    elastic_admitted: int = 0       # offline jobs packed into request slots
    elastic_ejected: int = 0        # request-level ejections (degrade path)
    elastic_completed: int = 0      # jobs finished inside request slots
    #: whole offline instances demoted into request slots ahead of a ramp
    #: scale-up (each one is an instance preemption that did NOT happen)
    elastic_demoted: int = 0
    elastic_goodput: float = 0.0    # ...their completed GPU-hours
    #: per-class SLO window counts {ok, total, violations, attainment}
    #: (goodput-vs-SLO-violation rows; empty without an SLO monitor)
    slo: dict = dataclasses.field(default_factory=dict)

    def key_metrics(self) -> dict:
        """Deterministic fields only (wall-clock latency and the
        machine-dependent compile tag excluded)."""
        out = dataclasses.asdict(self)
        out.pop("plan_p50_us")
        out.pop("compiled_n")
        return out


@dataclasses.dataclass
class ColocationReport:
    """Per-hour rows + day totals of one co-location day cycle."""

    engine: str
    seed: int
    num_nodes: int
    horizon_hours: float
    hours: list[HourRow] = dataclasses.field(default_factory=list)
    # fold-forward aggregate cache: day-total properties read from here
    # instead of rescanning every hour row on each access (`compare_*`
    # calls them repeatedly, and a 10k-node day has 24+ rows x ~20
    # properties).  Rows are append-only and never mutated after `_flush`,
    # so folding only the NEW rows — left to right, starting from 0 —
    # reproduces a fresh ``sum()`` over all rows bit-for-bit.
    _agg: dict = dataclasses.field(default_factory=dict, repr=False,
                                   compare=False)
    _agg_n: int = dataclasses.field(default=0, repr=False, compare=False)
    _km: dict | None = dataclasses.field(default=None, repr=False,
                                         compare=False)
    _km_n: int = dataclasses.field(default=-1, repr=False, compare=False)

    _SUM_FIELDS = ("scheduled_perf", "preemptor_perf", "offline_goodput",
                   "preemptions", "hits", "placements", "failures",
                   "requeued", "requeue_replanned", "completed_jobs",
                   "elastic_admitted", "elastic_ejected",
                   "elastic_completed", "elastic_demoted",
                   "elastic_goodput")

    def _fold(self) -> dict:
        agg = self._agg
        if not agg:
            agg.update({k: 0 for k in self._SUM_FIELDS},
                       slo_ok=0, slo_total=0, slo_violations=0)
        for row in self.hours[self._agg_n:]:
            for k in self._SUM_FIELDS:
                agg[k] += getattr(row, k)
            for c in row.slo.values():
                agg["slo_ok"] += c["ok"]
                agg["slo_total"] += c["total"]
                agg["slo_violations"] += c["violations"]
        self._agg_n = len(self.hours)
        return agg

    @property
    def scheduled_perf(self) -> float:
        return self._fold()["scheduled_perf"]

    @property
    def preemptor_perf(self) -> float:
        """Scheduled performance of preemption-placed instances only — the
        slice of the integral the paper's +55% claim is about."""
        return self._fold()["preemptor_perf"]

    @property
    def offline_goodput(self) -> float:
        return self._fold()["offline_goodput"]

    @property
    def preemptions(self) -> int:
        return self._fold()["preemptions"]

    @property
    def hits(self) -> int:
        return self._fold()["hits"]

    @property
    def hit_rate(self) -> float:
        return self.hits / self.preemptions if self.preemptions else 0.0

    @property
    def placements(self) -> int:
        return self._fold()["placements"]

    @property
    def failures(self) -> int:
        return self._fold()["failures"]

    @property
    def requeued(self) -> int:
        return self._fold()["requeued"]

    @property
    def requeue_replanned(self) -> int:
        return self._fold()["requeue_replanned"]

    @property
    def requeue_success_rate(self) -> float:
        return self.requeue_replanned / self.requeued if self.requeued else 0.0

    @property
    def completed_jobs(self) -> int:
        return self._fold()["completed_jobs"]

    @property
    def elastic_admitted(self) -> int:
        return self._fold()["elastic_admitted"]

    @property
    def elastic_ejected(self) -> int:
        return self._fold()["elastic_ejected"]

    @property
    def elastic_completed(self) -> int:
        return self._fold()["elastic_completed"]

    @property
    def elastic_demoted(self) -> int:
        return self._fold()["elastic_demoted"]

    @property
    def elastic_goodput(self) -> float:
        return self._fold()["elastic_goodput"]

    @property
    def slo_violations(self) -> int:
        return self._fold()["slo_violations"]

    @property
    def slo_attainment(self) -> float:
        """Fraction of online SLO window samples (all monitored classes)
        that met their TTFT/TPOT targets over the day; 1.0 when the run had
        no SLO monitor."""
        agg = self._fold()
        return agg["slo_ok"] / agg["slo_total"] if agg["slo_total"] else 1.0

    def slo_by_class(self) -> dict[str, dict]:
        """Whole-day goodput-vs-SLO rows per monitored class."""
        out: dict[str, dict] = {}
        for row in self.hours:
            for name, c in row.slo.items():
                agg = out.setdefault(name, {"ok": 0, "total": 0,
                                            "violations": 0})
                for k in ("ok", "total", "violations"):
                    agg[k] += c[k]
        for name, agg in out.items():
            agg["attainment"] = (agg["ok"] / agg["total"]
                                 if agg["total"] else 1.0)
        return out

    @property
    def plan_p50_us(self) -> float:
        vals = [r.plan_p50_us for r in self.hours if r.plan_p50_us > 0]
        return statistics.median(vals) if vals else 0.0

    def key_metrics(self) -> dict:
        """Everything deterministic under (seed, engine) — the parity and
        determinism tests compare these dicts whole.  Cached per row count
        (callers like ``compare_*`` and the regression gate call it
        repeatedly); treat the returned dict as read-only."""
        if self._km is not None and self._km_n == len(self.hours):
            return self._km
        self._km_n = len(self.hours)
        self._km = {
            "engine": self.engine,
            "seed": self.seed,
            "num_nodes": self.num_nodes,
            "scheduled_perf": self.scheduled_perf,
            "offline_goodput": self.offline_goodput,
            "preemptions": self.preemptions,
            "hits": self.hits,
            "placements": self.placements,
            "failures": self.failures,
            "requeued": self.requeued,
            "requeue_replanned": self.requeue_replanned,
            "completed_jobs": self.completed_jobs,
            "elastic_admitted": self.elastic_admitted,
            "elastic_ejected": self.elastic_ejected,
            "elastic_completed": self.elastic_completed,
            "elastic_demoted": self.elastic_demoted,
            "elastic_goodput": self.elastic_goodput,
            "slo_violations": self.slo_violations,
            "slo_attainment": self.slo_attainment,
            "hours": [r.key_metrics() for r in self.hours],
        }
        return self._km


def default_policies(cfg: ColocationConfig) -> list[AutoscalePolicy]:
    """Table 3 online mix scaled to the cluster: A and B ride the diurnal
    curve between ~25% of peak and the Table 3 per-100-node peak counts
    (the wide span is what produces the paper's preemption waves at the
    morning ramp and the defragmenting scale-downs at night)."""
    wl = {w.name: w for w in table3_workloads()}
    scale = cfg.num_nodes / 100.0
    a_max = max(1, round(20 * scale))
    b_max = max(2, round(40 * scale))
    return [
        AutoscalePolicy(wl["A"], max(1, round(a_max * 0.25)), a_max),
        AutoscalePolicy(wl["B"], max(1, round(b_max * 0.25)), b_max),
    ]


class _RateAcc:
    """Aggregate Fig. 2 progress-rate accumulator (piecewise-constant).

    One ``{contribution value -> live instance count}`` counter per
    workload class (value = GPUs x relative scheduled factor, so only a
    handful of distinct values exist per class) plus one counter for the
    preemptor slice.  A class rate materializes as ``sum(value * count)``
    in ascending value order — and because TIER_PERF holds non-dyadic
    rationals, THAT fixed summation order is what makes a counter
    maintained incrementally (the O(delta) loop) and a counter rebuilt by
    a full instance scan (the legacy loop) produce bit-identical floats:
    equal multisets sum identically.  ``_advance`` then accrues
    ``rate * dt`` per class instead of walking every live instance.
    """

    __slots__ = ("counts", "pre", "_rates", "_pre_rate")

    def __init__(self) -> None:
        self.counts: dict[str, dict[float, int]] = {}
        self.pre: dict[float, int] = {}
        self._rates: dict[str, float] | None = None
        self._pre_rate: float | None = None

    def add(self, name: str, value: float, delta: int) -> None:
        cnt = self.counts.setdefault(name, {})
        n = cnt.get(value, 0) + delta
        if n:
            cnt[value] = n
        else:
            del cnt[value]
            if not cnt:
                del self.counts[name]
        self._rates = None

    def add_pre(self, value: float, delta: int) -> None:
        n = self.pre.get(value, 0) + delta
        if n:
            self.pre[value] = n
        else:
            del self.pre[value]
        self._pre_rate = None

    @staticmethod
    def _materialize(counter: dict[float, int]) -> float:
        return sum(v * n for v, n in sorted(counter.items()))

    def rates(self) -> tuple[dict[str, float], float]:
        """(per-class rate, preemptor-slice rate), cached until mutated."""
        if self._rates is None:
            self._rates = {name: self._materialize(cnt)
                           for name, cnt in self.counts.items()}
        if self._pre_rate is None:
            self._pre_rate = self._materialize(self.pre)
        return self._rates, self._pre_rate


class ColocationSim:
    """The event loop.  Construct, then ``run()`` once."""

    def __init__(
        self,
        cfg: ColocationConfig,
        policies: list[AutoscalePolicy] | None = None,
        scale_events: list[tuple[float, WorkloadSpec]] | None = None,
        cluster: Cluster | None = None,
    ) -> None:
        self.cfg = cfg
        self.cluster = cluster if cluster is not None else Cluster(
            cfg.spec, cfg.num_nodes)
        self.sched = TopoScheduler(self.cluster, engine=cfg.engine,
                                   alpha=cfg.alpha, warmup=cfg.warmup,
                                   shortlist_k=cfg.shortlist_k,
                                   shortlist_mode=cfg.shortlist_mode)
        self.auto = Autoscaler(self.cluster, self.sched,
                               policies if policies is not None else [],
                               backfill_chunk=cfg.backfill_chunk)
        self.fleet = AgentFleet(self.cluster)
        self.fleet.watch(self.sched)
        # scale-downs and job completions evict WITHOUT a transaction;
        # the cluster-event subscription keeps the CRDs fresh for those
        self.fleet.watch_cluster()
        self.sched.add_listener(self._on_decision)
        # Fig. 2 factors: the single source of truth (repro.core.perfmodel;
        # repro.serving re-exports the same objects)
        self._rel_factor = relative_scheduled_factor
        self._scheduled_factor = scheduled_factor

        # request-level elastic layer: the pool + SLO monitor exist whenever
        # an ElasticConfig is in play; cfg.elastic additionally enables the
        # two-level ladder (admission + instance-spin-up reserve).  The
        # monitored-but-instance-only combination is the A/B baseline.
        ecfg = cfg.elastic_cfg
        if ecfg is None and cfg.elastic:
            from repro.serving.elastic import ElasticConfig
            ecfg = ElasticConfig()
        self._ecfg = ecfg
        if ecfg is not None:
            # lazy import keeps the serving stack out of core's import
            # graph until a scenario actually asks for the elastic layer
            from repro.serving.elastic import ElasticPool, SLOMonitor
            self.slo: SLOMonitor | None = SLOMonitor(ecfg)
            self.pool: ElasticPool | None = ElasticPool(ecfg, self.slo)
        else:
            self.slo = None
            self.pool = None
        self._elastic: dict[int, OfflineJob] = {}   # jid -> elastic-hosted
        self._egen: dict[int, int] = {}             # jid -> grant generation

        self.pending: deque[OfflineJob] = deque()
        self.jobs: list[OfflineJob] = []        # every job ever created
        self._running: dict[int, OfflineJob] = {}   # live uid -> job
        self._preemptor_uids: set[int] = set()  # instances placed by preemption
        self._factor_cache: dict[int, float] = {}   # uid -> Fig. 2 rate
        self.timeline: list[dict[str, int]] = []    # Fig. 9 view rows
        self._heap: list[tuple] = []
        self._seq = 0
        self._now = 0.0
        self._row_start = 0.0
        self._last_load = 0.0
        self._next_load = diurnal_traffic(cfg.tick_hours % 24.0)
        self._scale_step = 0
        self._ran = False
        self.report = ColocationReport(engine=cfg.engine, seed=cfg.seed,
                                       num_nodes=cfg.num_nodes,
                                       horizon_hours=cfg.horizon_hours)
        self._reset_acc()
        self._patch_base = self.fleet.store.patch_count
        self._plan_log_base = 0     # index into the autoscaler's plan_us log
        # per-hour compile tagging: rows record how many XLA backend
        # compiles landed inside their interval, so the latency gate can
        # exclude compile-polluted hours (simulator.CompileWatch; the lazy
        # import dodges the simulator <-> colocation module cycle)
        from .simulator import CompileWatch
        self._watch = CompileWatch.get()
        self._compile_mark = self._watch.mark()
        self._kind_cache: dict[str, str] = {}   # workload name -> kind
        self.events_processed = 0
        #: job-tracked offline instances per node (mirrors ``_running``) —
        #: `_demote_for_block` reads it instead of re-sorting the whole
        #: running set on every ramp
        self._offline_by_node: dict[int, set[int]] = {}
        # ---- O(delta) loop state (unused when cfg.legacy_loop) ----
        # aggregate progress rates + per-node free-GPU/CoreGroup counts +
        # the (gpus, coregroups) -> feasible-node-count gate, all kept
        # current through the cluster's instance-op stream; dead online
        # uids feed the O(changed) pool reconcile
        self._rates = _RateAcc()
        self._free_gpu = [0] * self.cluster.num_nodes
        self._free_cg = [0] * self.cluster.num_nodes
        self._feas: dict[tuple[int, int], int] = {}
        self._dead_online: set[int] = set()
        if not cfg.legacy_loop:
            for n in range(self.cluster.num_nodes):
                fg, fc = self.cluster.free_masks(n)
                self._free_gpu[n] = fg.bit_count()
                self._free_cg[n] = fc.bit_count()
            for inst in self.cluster.instances.values():
                self._rates.add(inst.workload.name,
                                inst.workload.gpus_per_instance
                                * self._instance_factor(inst), +1)
            self.cluster.add_inst_listener(self._on_inst)

        if policies:
            t = 0.0
            while t < cfg.horizon_hours:
                self._push(t, _TICK, None)
                t += cfg.tick_hours
            self._generate_offline_arrivals()
        for t, wl in (scale_events or []):
            self._push(t, _SCALE, wl)
        if scale_events:
            self.timeline.append(dict(self.cluster.count_by_workload(),
                                      step=0))

    # ---- event plumbing --------------------------------------------------------------
    @staticmethod
    def _sort_key(kind: int, payload):
        """Canonical tie-break WITHIN one (timestamp, kind) group: job
        events order by jid, completions by uid — intrinsic identities, so
        the day is invariant to the ORDER same-timestamp events were
        enqueued in (a requeue wave enqueues in victim order, which is an
        engine artifact).  Ticks and explicit scale events keep insertion
        order via the seq element.  Keys are only ever compared within one
        kind, so the per-kind types never mix."""
        if kind in (_REQUEUE, _SUBMIT):
            return payload.jid
        if kind == _COMPLETE:
            return payload          # instance uid
        if kind == _ECOMPLETE:
            return payload          # (jid, generation)
        return 0

    def _push(self, time: float, kind: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (time, kind,
                                    self._sort_key(kind, payload),
                                    self._seq, payload))

    def _generate_offline_arrivals(self) -> None:
        """Draw the WHOLE offline arrival stream (times, classes, durations)
        at construction from the seed, so every engine replays the same
        day."""
        cfg = self.cfg
        wl = {w.name: w for w in table3_workloads()}
        rng = random.Random(cfg.seed + 555)
        jid = 0

        def new_job(t: float) -> OfflineJob:
            nonlocal jid
            jid += 1
            # Table 3 offline mix: C (2-GPU) to D (1-GPU) roughly 0.7/0.3
            w = wl["C"] if rng.random() < 0.7 else wl["D"]
            dur = min(8.0, max(0.5, rng.expovariate(1.0 / cfg.mean_job_hours)))
            return OfflineJob(jid=jid, workload=w, duration_hours=dur,
                              submitted_at=t, remaining_hours=dur)

        initial = (cfg.initial_offline_jobs
                   if cfg.initial_offline_jobs is not None
                   else 4 * cfg.num_nodes)
        for _ in range(initial):
            self._push(0.0, _SUBMIT, new_job(0.0))
        rate = (cfg.offline_rate_per_hour
                if cfg.offline_rate_per_hour is not None
                else 2.5 * cfg.num_nodes)
        t = 0.0
        while rate > 0:
            t += rng.expovariate(rate)
            if t >= cfg.horizon_hours:
                break
            self._push(t, _SUBMIT, new_job(t))

    # ---- accounting ------------------------------------------------------------------
    def _reset_acc(self) -> None:
        self._acc = {
            "placements": 0, "preemptions": 0, "hits": 0, "failures": 0,
            "requeued": 0, "requeue_replanned": 0, "completed_jobs": 0,
            "offline_goodput": 0.0, "preemptor_perf": 0.0,
            "served": {}, "reclaimed": {}, "factors": [],
            "elastic_admitted": 0, "elastic_ejected": 0,
            "elastic_completed": 0, "elastic_demoted": 0,
            "elastic_goodput": 0.0,
        }

    def _instance_factor(self, inst) -> float:
        """Fig. 2 factor RELATIVE to the best tier this instance size can
        physically achieve on the SKU (`serving.relative_scheduled_factor`):
        degradation measures scheduling quality, not instance size.  Cached
        per uid — a placement is immutable for the instance's lifetime and
        uids are never reused, so ``_advance`` costs a dict hit per
        instance instead of a bit-scan per event."""
        factor = self._factor_cache.get(inst.uid)
        if factor is None:
            spec = self.cluster.spec
            factor = self._rel_factor(spec,
                                      achieved_tier(spec, inst.gpu_mask),
                                      inst.workload.gpus_per_instance)
            self._factor_cache[inst.uid] = factor
        return factor

    def _on_inst(self, delta: int, inst) -> None:
        """Cluster instance-op stream (bind/evict/restore, transactional or
        not): keep the aggregate rates, the per-node free counts, and the
        count-feasibility gate current in O(1) per mutation."""
        value = (inst.workload.gpus_per_instance
                 * self._instance_factor(inst))
        self._rates.add(inst.workload.name, value, delta)
        if inst.uid in self._preemptor_uids:
            self._rates.add_pre(value, delta)
        node = inst.node
        old_g, old_c = self._free_gpu[node], self._free_cg[node]
        new_g = old_g - delta * inst.gpu_mask.bit_count()
        new_c = old_c - delta * inst.cg_mask.bit_count()
        self._free_gpu[node], self._free_cg[node] = new_g, new_c
        for (ng, nc), cnt in self._feas.items():
            was = old_g >= ng and old_c >= nc
            now = new_g >= ng and new_c >= nc
            if was != now:
                self._feas[(ng, nc)] = cnt + (1 if now else -1)
        if self.pool is not None and inst.workload.kind == "online":
            if delta < 0:
                self._dead_online.add(inst.uid)
            else:
                self._dead_online.discard(inst.uid)

    def _count_feasible(self, workload: WorkloadSpec) -> bool:
        """Does ANY node have enough free GPU/CoreGroup *bits* for this
        workload?  Exactly the normal scheduling cycle's reject condition:
        `TopoScheduler._place_on` falls back to count-based blind placement
        (kubelet degraded admission) on every engine, host and fused alike,
        so count-infeasible everywhere <=> the plan would reject — which
        lets `_drain` skip whole dispatches against a saturated cluster."""
        need = (workload.gpus_per_instance,
                workload.coregroups_per_instance(
                    self.cluster.spec.coregroup_size))
        cnt = self._feas.get(need)
        if cnt is None:             # first query: seed from current counts
            ng, nc = need
            cnt = sum(1 for n in range(self.cluster.num_nodes)
                      if self._free_gpu[n] >= ng and self._free_cg[n] >= nc)
            self._feas[need] = cnt
        return cnt > 0

    def _advance(self, to_time: float) -> None:
        """Accumulate the factor-weighted GPU-hour integrals up to
        ``to_time`` (cluster state is piecewise-constant between events).

        Both loops accrue ``rate * dt`` per class from a `_RateAcc`; the
        O(delta) loop reads the incrementally-maintained one, the legacy
        loop rebuilds an identical counter by scanning every live instance
        — the multisets are equal, so the floats are too (bit-exact parity
        by construction)."""
        dt = to_time - self._now
        if dt > 0:
            if self.cfg.legacy_loop:
                acc = _RateAcc()
                for inst in self.cluster.instances.values():
                    value = (inst.workload.gpus_per_instance
                             * self._instance_factor(inst))
                    acc.add(inst.workload.name, value, +1)
                    if inst.uid in self._preemptor_uids:
                        acc.add_pre(value, +1)
                rates, pre_rate = acc.rates()
            else:
                rates, pre_rate = self._rates.rates()
            served = self._acc["served"]
            for name, rate in rates.items():
                served[name] = served.get(name, 0.0) + rate * dt
            if pre_rate:
                self._acc["preemptor_perf"] += pre_rate * dt
        self._now = to_time

    def _on_decision(self, dec, event: str) -> None:
        """The decision-listener stream: every committed transaction lands
        here (the `AgentFleet` is subscribed right next to us)."""
        if event != "committed" or dec.rejected:
            return
        acc = self._acc
        acc["factors"].append(self._scheduled_factor(dec))
        if dec.preempted:
            acc["preemptions"] += 1
            acc["hits"] += int(dec.hit)
            if dec.instance is not None:
                inst = dec.instance
                self._preemptor_uids.add(inst.uid)
                if not self.cfg.legacy_loop:
                    # the bind op fired BEFORE this listener (commit order:
                    # evict victims, bind, then decision listeners), so the
                    # class rate already counts this instance — only the
                    # preemptor slice starts here, where the uid is marked
                    self._rates.add_pre(inst.workload.gpus_per_instance
                                        * self._instance_factor(inst), +1)
        else:
            acc["placements"] += 1
        if (self.pool is not None and dec.instance is not None
                and dec.instance.workload.kind == "online"):
            inst = dec.instance
            self.pool.register(inst.uid, inst.workload.name,
                               inst.workload.gpus_per_instance,
                               self._instance_factor(inst))
        for victim in dec.evicted:
            job = self._running.pop(victim.uid, None)
            if job is None:
                continue        # not job-tracked (e.g. pre-saturated state)
            self._drop_offline_index(victim.node, victim.uid)
            ran = (self._now - job.started_at) * job.rate
            job.remaining_hours = max(self.cfg.min_requeue_hours,
                                      job.remaining_hours - ran)
            job.requeues += 1
            job.uid = None
            job.awaiting_replan = True
            acc["requeued"] += 1
            if self.cfg.requeue:
                self._push(self._now + self.cfg.requeue_delay_hours,
                           _REQUEUE, job)

    def _flush(self, end: float) -> None:
        acc = self._acc
        served = acc["served"]
        online = sum(v for k, v in served.items()
                     if self._kind_of(k) == "online")
        log = self.auto.plan_us[self._plan_log_base:]
        row = HourRow(
            hour=self._row_start,
            load=self._last_load,
            counts=dict(self.cluster.count_by_workload()),
            scheduled_perf=online,
            preemptor_perf=acc["preemptor_perf"],
            served=dict(served),
            offline_goodput=acc["offline_goodput"],
            placements=acc["placements"],
            preemptions=acc["preemptions"],
            hits=acc["hits"],
            failures=acc["failures"],
            requeued=acc["requeued"],
            requeue_replanned=acc["requeue_replanned"],
            completed_jobs=acc["completed_jobs"],
            pending=len(self.pending),
            crd_patches=self.fleet.store.patch_count - self._patch_base,
            reclaimed_tiers=dict(acc["reclaimed"]),
            decision_factor_mean=(statistics.fmean(acc["factors"])
                                  if acc["factors"] else 0.0),
            plan_p50_us=(statistics.median(log) if log else 0.0),
            compiled_n=self._watch.delta(self._compile_mark),
            elastic_admitted=acc["elastic_admitted"],
            elastic_ejected=acc["elastic_ejected"],
            elastic_completed=acc["elastic_completed"],
            elastic_demoted=acc["elastic_demoted"],
            elastic_goodput=acc["elastic_goodput"],
            slo=(self.slo.drain_counts() if self.slo is not None else {}),
        )
        self.report.hours.append(row)
        self._row_start = end
        self._patch_base = self.fleet.store.patch_count
        self._plan_log_base = len(self.auto.plan_us)
        self._compile_mark = self._watch.mark()
        self._reset_acc()

    def _kind_of(self, name: str) -> str:
        kind = self._kind_cache.get(name)
        if kind is None:            # memo: a class's kind never changes,
            kind = self._kind_of_uncached(name)     # and the fallback walks
            self._kind_cache[name] = kind           # every job ever created
        return kind

    def _kind_of_uncached(self, name: str) -> str:
        for w in self.auto.policies:
            if w.workload.name == name:
                return w.workload.kind
        for j in self.jobs:
            if j.workload.name == name:
                return j.workload.kind
        wl = {w.name: w for w in table3_workloads()}
        return wl[name].kind if name in wl else "online"

    # ---- handlers --------------------------------------------------------------------
    def _handle_tick(self, t: float) -> None:
        if t > self._row_start:
            self._flush(t)
        self._last_load = diurnal_traffic(t % 24.0)
        self._next_load = diurnal_traffic((t + self.cfg.tick_hours) % 24.0)
        if self.pool is not None:
            # the reversed ladder, step 1 (degrade before kill): online
            # load reclaims request slots — ejected offline requests land
            # back in the pending queue — BEFORE the scale executor below
            # preempts whole instances
            for jid in self.pool.set_load(self._last_load):
                self._eject_elastic(jid)
        if self.cfg.elastic and self.pool is not None:
            # reversed ladder, step 2: when the ramp's scale-up has no
            # node-contiguous free block (completions free SCATTERED 1-2
            # GPU fragments), demote whole offline instances into spare
            # request slots to assemble one — an instance preemption that
            # never happens
            self._harvest_for_ramp()
        for pol in self.auto.policies:
            ev = self.auto.scale_to(pol, pol.desired(self._last_load), t)
            self._acc["failures"] += ev.failures
            for tier, n in ev.reclaimed_tiers.items():
                self._acc["reclaimed"][tier] = (
                    self._acc["reclaimed"].get(tier, 0) + n)
        if self.pool is not None:
            self._reconcile_pool()
        self._drain()
        if self.pool is not None:
            self.pool.sample(self._last_load)

    def _handle_submit(self, job: OfflineJob) -> None:
        self.jobs.append(job)
        self.pending.append(job)
        self._drain()

    def _handle_requeue(self, job: OfflineJob) -> None:
        self.pending.append(job)
        self._drain()

    def _drop_offline_index(self, node: int, uid: int) -> None:
        uids = self._offline_by_node.get(node)
        if uids is not None:
            uids.discard(uid)
            if not uids:
                del self._offline_by_node[node]

    def _handle_complete(self, uid: int) -> None:
        job = self._running.get(uid)
        if job is None or job.uid != uid:
            return               # stale event: the job was preempted earlier
        del self._running[uid]
        inst = self.cluster.evict(uid)
        self._drop_offline_index(inst.node, uid)
        job.uid = None
        job.remaining_hours = 0.0
        job.completed_at = self._now
        self._acc["completed_jobs"] += 1
        self._acc["offline_goodput"] += (
            job.duration_hours * job.workload.gpus_per_instance)
        self._drain()

    def _handle_scale(self, workload: WorkloadSpec) -> None:
        """One explicit Algorithm 1 attempt (the Fig. 8/9 view events)."""
        t0 = time.perf_counter()
        txn = self.sched.plan(workload)
        self.auto.plan_us.append((time.perf_counter() - t0) * 1e6)
        dec = txn.commit()
        if dec.rejected:
            self._acc["failures"] += 1
        self._scale_step += 1
        self.timeline.append(dict(self.cluster.count_by_workload(),
                                  step=self._scale_step))

    def _drain(self) -> None:
        """The backfill ladder.  Two-level mode packs pending offline work
        into online replicas' spare request slots FIRST (`_elastic_pack`)
        and spins up whole offline instances only for the residual, capped
        by `_instance_gpu_budget` (free GPUs minus the next tick's online
        reserve).  Instance admission is chunked ``plan_batch`` (normal
        cycle only), FIFO, one pass per trigger; stops as soon as an entire
        chunk fails to place, so a full cluster costs one dispatch."""
        if self.cfg.elastic and self.pool is not None and self.pending:
            self._elastic_pack()
        if not self.pending:
            return
        budget = (self._instance_gpu_budget()
                  if self.cfg.elastic and self.pool is not None else None)
        queue, self.pending = self.pending, deque()
        while queue:
            chunk = []
            while queue and len(chunk) < self.cfg.backfill_chunk:
                need = queue[0].workload.gpus_per_instance
                if budget is not None and need > budget:
                    break       # FIFO head held by the online reserve
                chunk.append(queue.popleft())
                if budget is not None:
                    budget -= need
            if not chunk:
                self.pending.extend(queue)
                return
            any_placed = False
            if self.cfg.legacy_loop:
                txns = self.auto._timed_plan_batch(
                    [j.workload for j in chunk], allow_preempt=False)
                for job, txn in zip(chunk, txns):
                    if txn.decision.placed:
                        dec = txn.commit()
                        self._start_job(job, dec)
                        any_placed = True
                    else:
                        self.pending.append(job)
                        if budget is not None:
                            budget += job.workload.gpus_per_instance
            else:
                # count-gated per-job dispatch.  Normal-cycle placement
                # succeeds iff some node has enough free GPUs AND
                # coregroups (``_place_on`` always falls back to
                # ``place_blind``; the fused engines carry the same
                # degraded blind branch), so a job that fails the count
                # check would reject without mutating state — skip its
                # plan entirely.  Feasible jobs plan singly and commit
                # immediately; the inst-listener refreshes the free-count
                # index between jobs, which keeps the gate exact AND the
                # decisions bit-identical to the legacy shared-view batch
                # (the plan/commit interleave invariant,
                # ``TopoScheduler.plan_batch``).
                for job in chunk:
                    if not self._count_feasible(job.workload):
                        self.pending.append(job)
                        if budget is not None:
                            budget += job.workload.gpus_per_instance
                        continue
                    txn = self.auto._timed_plan_batch(
                        [job.workload], allow_preempt=False)[0]
                    if txn.decision.placed:
                        self._start_job(job, txn.commit())
                        any_placed = True
                    else:        # count gate is exact; defensive only
                        self.pending.append(job)
                        if budget is not None:
                            budget += job.workload.gpus_per_instance
            if not any_placed:
                self.pending.extend(queue)
                return

    def _start_job(self, job: OfflineJob, dec) -> None:
        uid = dec.instance.uid
        assert uid not in job.uids, "instance uid resurrected"
        job.uid = uid
        job.uids += (uid,)
        job.started_at = self._now
        # the placement tier sets the progress rate: a degraded offline
        # instance runs slower and holds its GPUs proportionally longer
        job.rate = self._instance_factor(dec.instance)
        self._running[uid] = job
        self._offline_by_node.setdefault(dec.instance.node, set()).add(uid)
        if job.awaiting_replan:
            job.awaiting_replan = False
            self._acc["requeue_replanned"] += 1
        self._push(self._now + job.remaining_hours / job.rate, _COMPLETE, uid)

    # ---- the request-level elastic layer (two-level ladder, level 1) -----------------
    def _elastic_pack(self) -> None:
        """Ladder step 1: FIFO-pack pending offline jobs into spare request
        slots through the pool's SLO-guarded admission controller.  Jobs no
        replica can host (no spare slots / KV headroom / SLO room) stay
        pending for the instance-granular residual path."""
        keep: deque[OfflineJob] = deque()
        while self.pending:
            job = self.pending.popleft()
            got = self.pool.admit(job.jid, job.workload.gpus_per_instance)
            if got is None:
                keep.append(job)
            else:
                _, slots, rate = got
                self._start_elastic(job, rate)
        self.pending = keep

    def _start_elastic(self, job: OfflineJob, rate: float) -> None:
        job.uid = None
        job.rate = rate
        job.started_at = self._now
        job.elastic_hosts += 1
        self._elastic[job.jid] = job
        gen = self._egen.get(job.jid, 0)
        self._egen[job.jid] = gen
        if job.awaiting_replan:
            # a preempted instance victim replanned INTO request slots
            job.awaiting_replan = False
            self._acc["requeue_replanned"] += 1
        self._acc["elastic_admitted"] += 1
        self._push(self._now + job.remaining_hours / rate, _ECOMPLETE,
                   (job.jid, gen))

    def _eject_elastic(self, jid: int) -> None:
        """Degrade-before-kill: a request-level grant was reclaimed (load
        rise, SLO trip, or host replica gone).  Checkpoint progress and put
        the job straight back in the pending queue — no requeue delay; the
        whole point of request granularity is that ejection is cheap."""
        job = self._elastic.pop(jid, None)
        if job is None:
            return
        ran = (self._now - job.started_at) * job.rate
        job.remaining_hours = max(self.cfg.min_requeue_hours,
                                  job.remaining_hours - ran)
        job.ejections += 1
        self._egen[jid] = self._egen.get(jid, 0) + 1    # void the ecomplete
        self._acc["elastic_ejected"] += 1
        self.pending.append(job)

    def _handle_ecomplete(self, payload: tuple[int, int]) -> None:
        jid, gen = payload
        job = self._elastic.get(jid)
        if job is None or self._egen.get(jid, 0) != gen:
            return               # stale event: the grant was ejected earlier
        del self._elastic[jid]
        self.pool.release(jid)
        job.remaining_hours = 0.0
        job.completed_at = self._now
        acc = self._acc
        acc["completed_jobs"] += 1
        acc["elastic_completed"] += 1
        good = job.duration_hours * job.workload.gpus_per_instance
        acc["offline_goodput"] += good
        acc["elastic_goodput"] += good
        self._drain()

    def _harvest_for_ramp(self) -> None:
        """Reversed ladder, step 2 (the scale executor is step 3).

        The `_instance_gpu_budget` reserve holds back the right GPU
        *count* for the next tick's scale-up, but offline completions free
        scattered 1-2 GPU fragments — an 8-GPU online replica still needs
        a node-contiguous block, and a count-only reserve cannot provide
        one.  Walk this tick's scale-up demand (policy order, the order the
        scale executor runs in) against the per-node free map; when no node
        can host a needed replica, demote whole offline instances into
        spare request slots (SLO-guarded `ElasticPool.admit`, so the jobs
        keep running at request granularity) until one node frees a block.
        Demotion stops the moment the pool cannot absorb a job — then the
        scale executor preempts exactly as before."""
        legacy = self.cfg.legacy_loop
        if legacy:
            free = [self.cluster.free_masks(n)[0].bit_count()
                    for n in range(self.cluster.num_nodes)]

            def take(gpn: int) -> int | None:
                # best-fit against the simulated free map: the tightest
                # node that already fits this replica absorbs it
                return min((n for n in range(len(free)) if free[n] >= gpn),
                           key=lambda n: (free[n], n), default=None)
        else:
            # listener-maintained free counts + lazy free-count buckets:
            # each bucket is a min-heap of node ids whose free count MAY be
            # that value (stale entries are popped on contact), so best-fit
            # is O(num_gpus + log N) per replica instead of an O(N) scan —
            # the heap head of the smallest feasible bucket is exactly the
            # legacy ``min((free[n], n))`` choice
            free = list(self._free_gpu)
            ngpu = self.cluster.spec.num_gpus
            buckets: list[list[int]] = [[] for _ in range(ngpu + 1)]
            for node, cnt in enumerate(free):
                if cnt > 0:
                    buckets[cnt].append(node)   # ascending ids: valid heaps

            def take(gpn: int) -> int | None:
                for cnt in range(gpn, ngpu + 1):
                    b = buckets[cnt]
                    while b and free[b[0]] != cnt:
                        heapq.heappop(b)        # stale since push
                    if b:
                        return heapq.heappop(b)
                return None

        for pol in self.auto.policies:
            have = len(self.auto.replicas(pol.workload.name))
            need_n = pol.desired(self._last_load) - have
            gpn = pol.workload.gpus_per_instance
            for _ in range(max(0, need_n)):
                fit = take(gpn)
                if fit is None:
                    fit = self._demote_for_block(gpn, free)
                    if fit is None:
                        return  # pool saturated: fall back to preemption
                free[fit] -= gpn
                if not legacy and free[fit] > 0:
                    heapq.heappush(buckets[free[fit]], fit)

    def _demote_for_block(self, need: int, free: list[int]) -> int | None:
        """Assemble one ``need``-GPU block by demoting offline instances on
        a single node into request slots.  Picks the node reaching the
        block with the fewest demotions (tie: lowest node index), demoting
        largest instances first.  Returns the node, or None if no node can
        reach the block or the pool rejects a job mid-assembly."""
        if self.cfg.legacy_loop:
            by_node: dict[int, list] = {}
            for uid in sorted(self._running):
                inst = self.cluster.instances.get(uid)
                if inst is not None:
                    by_node.setdefault(inst.node, []).append(inst)
            items = sorted(by_node.items())
        else:
            # per-node offline-instance index maintained at every
            # start/complete/preempt/demote — same node order and same
            # uid-sorted candidate lists as the legacy full-_running scan
            items = [(n, [self.cluster.instances[u]
                          for u in sorted(self._offline_by_node[n])])
                     for n in sorted(self._offline_by_node)]
        best = None             # (demotions, node, victims)
        for n, insts in items:
            insts = sorted(insts, key=lambda i: (
                -i.workload.gpus_per_instance, i.uid))
            got, take = free[n], []
            for inst in insts:
                if got >= need:
                    break
                take.append(inst)
                got += inst.workload.gpus_per_instance
            if got >= need and (best is None or (len(take), n) < best[:2]):
                best = (len(take), n, take)
        if best is None:
            return None
        _, node, take = best
        for inst in take:
            if not self._demote_instance(inst):
                # partial assembly still shrinks the Eq. 2 victim set
                return None
            free[node] += inst.workload.gpus_per_instance
        return node

    def _demote_instance(self, inst) -> bool:
        """Demote one running offline instance into request slots: admit
        through the SLO guard FIRST (no admission, no demotion), then
        checkpoint progress, release the instance's GPUs, and continue the
        job at the granted request-level rate."""
        job = self._running.get(inst.uid)
        if job is None:
            return False
        got = self.pool.admit(job.jid, job.workload.gpus_per_instance)
        if got is None:
            return False
        del self._running[inst.uid]
        self.cluster.evict(inst.uid)
        self._drop_offline_index(inst.node, inst.uid)
        ran = (self._now - job.started_at) * job.rate
        job.remaining_hours = max(self.cfg.min_requeue_hours,
                                  job.remaining_hours - ran)
        job.uid = None          # voids the instance's pending _COMPLETE
        self._acc["elastic_demoted"] += 1
        _, _, rate = got
        self._start_elastic(job, rate)
        return True

    def _reconcile_pool(self) -> None:
        """Scale-downs and completions evict online replicas WITHOUT a
        transaction; drop their ReplicaSlots and eject hosted requests."""
        if self.cfg.legacy_loop:
            live = {uid for uid, inst in self.cluster.instances.items()
                    if inst.workload.kind == "online"}
            dead = sorted(set(self.pool.replicas) - live)
        else:
            # O(changed): the inst-listener records every evicted online
            # uid; uids are never reused and replicas register only at
            # commit of live instances, so the intersection with the
            # registered set IS the legacy full-scan difference.
            dead = sorted(u for u in self._dead_online
                          if u in self.pool.replicas)
            self._dead_online.clear()
        for uid in dead:
            for jid in self.pool.unregister(uid):
                self._eject_elastic(jid)

    def _instance_gpu_budget(self) -> int:
        """Ladder step 2 cap: free GPUs minus the online reserve the next
        tick's scale-up will claim (`Autoscaler.online_reserve_gpus`), so
        ramps place online replicas in the normal cycle instead of
        preempting offline instances spun up one tick earlier."""
        if self.cfg.legacy_loop:
            used = sum(i.workload.gpus_per_instance
                       for i in self.cluster.instances.values())
        else:
            used = self.auto.used_gpus     # listener-maintained exact count
        free = self.cluster.spec.num_gpus * self.cluster.num_nodes - used
        return max(0, free - self.auto.online_reserve_gpus(self._next_load))

    # ---- the loop --------------------------------------------------------------------
    def run(self) -> ColocationReport:
        if self._ran:
            raise RuntimeError("ColocationSim.run() is one-shot")
        self._ran = True
        horizon = self.cfg.horizon_hours
        handlers = {
            _TICK: lambda t, p: self._handle_tick(t),
            _COMPLETE: lambda t, p: self._handle_complete(p),
            _REQUEUE: lambda t, p: self._handle_requeue(p),
            _SUBMIT: lambda t, p: self._handle_submit(p),
            _SCALE: lambda t, p: self._handle_scale(p),
            _ECOMPLETE: lambda t, p: self._handle_ecomplete(p),
        }
        heap = self._heap
        coalesce = not self.cfg.legacy_loop
        while heap and heap[0][0] <= horizon:
            t, kind, _, _, payload = heapq.heappop(heap)
            self._advance(t)
            self.events_processed += 1
            if (coalesce and kind in (_REQUEUE, _SUBMIT) and heap
                    and heap[0][0] == t and heap[0][1] == kind):
                # coalesce a same-instant wave (a preemption burst's
                # requeues, a submit cluster) into ONE drain: the sort_key
                # heap element fixes the pop order to jid order, identical
                # to the per-event appends, and deferring `_drain` to the
                # end of the wave plans the whole queue through chunked
                # ``plan_batch`` calls instead of one dispatch per event
                batch = [payload]
                while heap and heap[0][0] == t and heap[0][1] == kind:
                    batch.append(heapq.heappop(heap)[4])
                    self.events_processed += 1
                if kind == _SUBMIT:
                    self.jobs.extend(batch)
                self.pending.extend(batch)
                self._drain()
                continue
            handlers[kind](t, payload)
        self._advance(horizon)
        self._flush(horizon)
        # the run is one-shot: detach from the scheduler so a caller that
        # keeps using it does not stream decisions into a finished report
        self.sched.remove_listener(self._on_decision)
        return self.report


def run_day_cycle(cfg: ColocationConfig,
                  policies: list[AutoscalePolicy] | None = None,
                  ) -> ColocationReport:
    """One full simulated day on the Table 3 mix under ``cfg.engine``."""
    sim = ColocationSim(cfg, policies=policies or default_policies(cfg))
    return sim.run()


def compare_day_cycle(
    cfg: ColocationConfig,
    engines: tuple[str, str] = ("imp_batched", "godel"),
) -> dict:
    """The paper's A/B: the SAME seeded day under a topology-aware engine
    vs a topology-unaware baseline.  Returns the per-engine reports and the
    scheduled-performance uplift ``(aware - baseline) / baseline`` — the
    quantity the paper reports as +55%."""
    aware_name, baseline_name = engines
    reports = {
        name: run_day_cycle(dataclasses.replace(cfg, engine=name))
        for name in engines
    }

    def _uplift(metric: str) -> float:
        base = getattr(reports[baseline_name], metric)
        return ((getattr(reports[aware_name], metric) - base) / base
                if base else 0.0)

    return {
        "engines": engines,
        "reports": reports,
        "uplift": _uplift("scheduled_perf"),
        "preemptor_uplift": _uplift("preemptor_perf"),
        "goodput_uplift": _uplift("offline_goodput"),
    }


def compare_two_level(cfg: ColocationConfig) -> dict:
    """The HyGen-style A/B: the SAME seeded day (identical arrival stream,
    identical policies, identical engine) with the backfill ladder at
    instance granularity only vs the two-level request+instance ladder.

    Both runs carry the same `SLOMonitor`, so online SLO attainment is
    measured identically; the instance-only run simply never admits
    request-level work.  The expected direction: the two-level ladder
    strictly increases offline goodput (valley capacity smaller than one
    instance stops being wasted) at online SLO attainment no worse than the
    baseline, with strictly fewer instance preemptions (the reserve guard,
    request-granular ejection, and ramp-time instance demotion into request
    slots shrink the Eq. 2 victim set at the ramps)."""
    ecfg = cfg.elastic_cfg
    if ecfg is None:
        from repro.serving.elastic import ElasticConfig
        ecfg = ElasticConfig()
    base_cfg = dataclasses.replace(cfg, elastic=False, elastic_cfg=ecfg)
    two_cfg = dataclasses.replace(base_cfg, elastic=True)
    reports = {
        "instance_only": run_day_cycle(base_cfg),
        "two_level": run_day_cycle(two_cfg),
    }
    io, tl = reports["instance_only"], reports["two_level"]
    return {
        "reports": reports,
        "goodput_uplift": ((tl.offline_goodput - io.offline_goodput)
                           / io.offline_goodput if io.offline_goodput
                           else 0.0),
        "slo_attainment": {"instance_only": io.slo_attainment,
                           "two_level": tl.slo_attainment},
        "preemptions": {"instance_only": io.preemptions,
                        "two_level": tl.preemptions},
        "preemption_delta": tl.preemptions - io.preemptions,
        "requeued": {"instance_only": io.requeued, "two_level": tl.requeued},
    }
