"""Event-driven continuous-time co-location engine (paper §1/§2.3, Fig. 2).

The paper's headline scenario — long-running online chat services with
diurnal traffic, offline batch jobs padding the valleys between peaks, and
preemption waves at the ramps — is a *process over time*, not an episodic
experiment.  This module runs it end to end: a priority event queue of
traffic ticks, offline-job submissions/completions, and victim requeues is
driven entirely through the transactional plan/commit API (persistent batch
sessions, optional construction-time jit warm-up), and every committed
decision streams through the scheduler's listener chain into a per-hour
`ColocationReport`.

Event kinds (stable ordering at equal timestamps):

* ``tick``     — an `AutoscalePolicy` evaluation: the diurnal traffic level
  becomes desired replica counts and the delta is applied through the
  `Autoscaler` scale executor (batched ``plan_batch`` scale-ups that preempt
  offline victims at the ramps; worst-achieved-tier scale-downs that
  defragment on the way down).  Policies are *event sources*: they produce
  no state of their own between ticks.
* ``complete`` — a running offline job finished; its instance is released
  and the reopened capacity is immediately backfilled from the pending
  queue.
* ``requeue``  — a preempted offline victim re-enters the pending queue
  after a short delay and is replanned via chunked ``plan_batch`` admission
  when capacity allows.  The job keeps its workload identity and its
  remaining work; every instance uid it runs under is recorded and uids are
  never reused (`Cluster` uids are monotonic).
* ``submit``   — a new offline job arrives (seeded Poisson process, drawn
  entirely at construction so the arrival stream is identical across
  engines) and enters the pending queue.
* ``scale``    — an explicit one-shot scale-up request; the Fig. 8/9 views
  (`repro.core.simulator.run_timeline` / ``run_allocation_snapshot``) are
  day-cycle runs consisting only of these.

**Scheduled performance** follows the paper's Fig. 2 accounting: each live
instance contributes ``gpus x TIER_PERF[achieved tier]`` per hour
(`repro.serving.scheduled_factor` is the same conversion applied to the
per-decision stream), and the headline metric is the integral of the ONLINE
classes' factor-weighted GPU-hours over the day — the quantity the paper
reports a 55% improvement on for topology-aware preemption.  Offline jobs
are credited separately as completed GPU-hours (goodput).

``compare_day_cycle`` runs the A/B: the same seeded day (identical arrival
stream, identical policies) under a topology-aware engine and a
topology-unaware baseline, reporting the scheduled-performance uplift.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
import statistics
import time
from collections import deque

from .agent import AgentFleet
from .autoscale import AutoscalePolicy, Autoscaler, diurnal_traffic
from .cluster import Cluster
from .engines import EngineName
from .placement import achieved_tier
from .scheduler import TopoScheduler
from .topology import RTX4090_SERVER, ServerSpec
from .workload import WorkloadSpec, table3_workloads

# event-kind priorities: stable processing order at equal timestamps
_TICK, _COMPLETE, _REQUEUE, _SUBMIT, _SCALE = range(5)


@dataclasses.dataclass(frozen=True)
class ColocationConfig:
    """One day-cycle scenario (frozen so A/B runs share it via ``replace``)."""

    num_nodes: int = 16
    spec: ServerSpec = RTX4090_SERVER
    seed: int = 0
    alpha: float = 0.5
    engine: EngineName = "imp_batched"
    warmup: bool = False
    horizon_hours: float = 24.0
    tick_hours: float = 1.0
    #: preempted victims re-enter the pending queue after this delay
    requeue_delay_hours: float = 0.1
    #: floor on a requeued job's remaining work (progress is checkpointed)
    min_requeue_hours: float = 0.05
    #: pending-queue admission rounds plan this many requests per dispatch
    backfill_chunk: int = 8
    #: offline arrivals per hour; None scales with the cluster (2.5 / node,
    #: deliberate oversupply so the allocation stays saturated through the
    #: night and the morning online ramp has to preempt — the paper's §2.3
    #: co-location regime; the surplus queues as backlog)
    offline_rate_per_hour: float | None = None
    mean_job_hours: float = 2.0
    #: day-0 burst that saturates the initial allocation; None -> 4 / node
    initial_offline_jobs: int | None = None
    #: False drops preempted victims instead of requeueing them (the legacy
    #: episodic semantics, kept for the Fig. 8/9 views)
    requeue: bool = True


@dataclasses.dataclass
class OfflineJob:
    """One offline batch job across its whole lifecycle (pending -> running
    -> preempted/requeued -> ... -> completed).  ``uids`` records every
    instance uid the job has run under; a replanned job always binds a NEW
    uid (cluster uids are monotonic), preserving workload identity without
    ever resurrecting an evicted instance."""

    jid: int
    workload: WorkloadSpec
    duration_hours: float
    submitted_at: float
    remaining_hours: float
    requeues: int = 0
    uids: tuple[int, ...] = ()
    uid: int | None = None          # live instance uid while running
    started_at: float = 0.0
    completed_at: float | None = None
    #: Fig. 2 progress rate of the CURRENT placement: a degraded tier runs
    #: the job slower, so it occupies its GPUs for proportionally longer
    rate: float = 1.0


@dataclasses.dataclass
class HourRow:
    """One reporting interval of a day-cycle run."""

    hour: float                     # interval start (simulation hours)
    load: float                     # diurnal traffic level at the last tick
    counts: dict[str, int]          # live instances by workload at interval end
    scheduled_perf: float           # ONLINE factor-weighted GPU-hours served
    preemptor_perf: float           # ...restricted to preemption-placed instances
    served: dict[str, float]        # per-class factor-weighted GPU-hours
    offline_goodput: float          # completed offline job GPU-hours
    placements: int                 # committed normal-cycle admissions
    preemptions: int
    hits: int                       # topology-affinity hits among preemptions
    failures: int                   # rejected online scale-up requests
    requeued: int                   # victims entering the requeue lifecycle
    requeue_replanned: int          # requeued jobs successfully replanned
    completed_jobs: int
    pending: int                    # offline queue depth at interval end
    crd_patches: int                # FlexTopo agent PATCHes (AgentFleet.watch)
    reclaimed_tiers: dict[int, int]  # scale-down tier distribution
    decision_factor_mean: float     # mean Fig. 2 factor of committed decisions
    #: P50 per-request plan wall time this interval, measured around every
    #: plan/plan_batch call the sim issues — the same metric for host and
    #: fused engines
    plan_p50_us: float

    def key_metrics(self) -> dict:
        """Deterministic fields only (wall-clock latency excluded)."""
        out = dataclasses.asdict(self)
        out.pop("plan_p50_us")
        return out


@dataclasses.dataclass
class ColocationReport:
    """Per-hour rows + day totals of one co-location day cycle."""

    engine: str
    seed: int
    num_nodes: int
    horizon_hours: float
    hours: list[HourRow] = dataclasses.field(default_factory=list)

    @property
    def scheduled_perf(self) -> float:
        return sum(r.scheduled_perf for r in self.hours)

    @property
    def preemptor_perf(self) -> float:
        """Scheduled performance of preemption-placed instances only — the
        slice of the integral the paper's +55% claim is about."""
        return sum(r.preemptor_perf for r in self.hours)

    @property
    def offline_goodput(self) -> float:
        return sum(r.offline_goodput for r in self.hours)

    @property
    def preemptions(self) -> int:
        return sum(r.preemptions for r in self.hours)

    @property
    def hits(self) -> int:
        return sum(r.hits for r in self.hours)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.preemptions if self.preemptions else 0.0

    @property
    def placements(self) -> int:
        return sum(r.placements for r in self.hours)

    @property
    def failures(self) -> int:
        return sum(r.failures for r in self.hours)

    @property
    def requeued(self) -> int:
        return sum(r.requeued for r in self.hours)

    @property
    def requeue_replanned(self) -> int:
        return sum(r.requeue_replanned for r in self.hours)

    @property
    def requeue_success_rate(self) -> float:
        return self.requeue_replanned / self.requeued if self.requeued else 0.0

    @property
    def plan_p50_us(self) -> float:
        vals = [r.plan_p50_us for r in self.hours if r.plan_p50_us > 0]
        return statistics.median(vals) if vals else 0.0

    def key_metrics(self) -> dict:
        """Everything deterministic under (seed, engine) — the parity and
        determinism tests compare these dicts whole."""
        return {
            "engine": self.engine,
            "seed": self.seed,
            "num_nodes": self.num_nodes,
            "scheduled_perf": self.scheduled_perf,
            "offline_goodput": self.offline_goodput,
            "preemptions": self.preemptions,
            "hits": self.hits,
            "placements": self.placements,
            "failures": self.failures,
            "requeued": self.requeued,
            "requeue_replanned": self.requeue_replanned,
            "completed_jobs": sum(r.completed_jobs for r in self.hours),
            "hours": [r.key_metrics() for r in self.hours],
        }


def default_policies(cfg: ColocationConfig) -> list[AutoscalePolicy]:
    """Table 3 online mix scaled to the cluster: A and B ride the diurnal
    curve between ~25% of peak and the Table 3 per-100-node peak counts
    (the wide span is what produces the paper's preemption waves at the
    morning ramp and the defragmenting scale-downs at night)."""
    wl = {w.name: w for w in table3_workloads()}
    scale = cfg.num_nodes / 100.0
    a_max = max(1, round(20 * scale))
    b_max = max(2, round(40 * scale))
    return [
        AutoscalePolicy(wl["A"], max(1, round(a_max * 0.25)), a_max),
        AutoscalePolicy(wl["B"], max(1, round(b_max * 0.25)), b_max),
    ]


class ColocationSim:
    """The event loop.  Construct, then ``run()`` once."""

    def __init__(
        self,
        cfg: ColocationConfig,
        policies: list[AutoscalePolicy] | None = None,
        scale_events: list[tuple[float, WorkloadSpec]] | None = None,
        cluster: Cluster | None = None,
    ) -> None:
        self.cfg = cfg
        self.cluster = cluster if cluster is not None else Cluster(
            cfg.spec, cfg.num_nodes)
        self.sched = TopoScheduler(self.cluster, engine=cfg.engine,
                                   alpha=cfg.alpha, warmup=cfg.warmup)
        self.auto = Autoscaler(self.cluster, self.sched,
                               policies if policies is not None else [],
                               backfill_chunk=cfg.backfill_chunk)
        self.fleet = AgentFleet(self.cluster)
        self.fleet.watch(self.sched)
        # scale-downs and job completions evict WITHOUT a transaction;
        # the cluster-event subscription keeps the CRDs fresh for those
        self.fleet.watch_cluster()
        self.sched.add_listener(self._on_decision)
        # Fig. 2 factors come from the serving layer (lazy import keeps the
        # model/serving stack out of core's import graph until needed)
        from repro.serving import (relative_scheduled_factor,
                                   scheduled_factor)
        self._rel_factor = relative_scheduled_factor
        self._scheduled_factor = scheduled_factor

        self.pending: deque[OfflineJob] = deque()
        self.jobs: list[OfflineJob] = []        # every job ever created
        self._running: dict[int, OfflineJob] = {}   # live uid -> job
        self._preemptor_uids: set[int] = set()  # instances placed by preemption
        self._factor_cache: dict[int, float] = {}   # uid -> Fig. 2 rate
        self.timeline: list[dict[str, int]] = []    # Fig. 9 view rows
        self._heap: list[tuple] = []
        self._seq = 0
        self._now = 0.0
        self._row_start = 0.0
        self._last_load = 0.0
        self._scale_step = 0
        self._ran = False
        self.report = ColocationReport(engine=cfg.engine, seed=cfg.seed,
                                       num_nodes=cfg.num_nodes,
                                       horizon_hours=cfg.horizon_hours)
        self._reset_acc()
        self._patch_base = self.fleet.store.patch_count
        self._plan_log_base = 0     # index into the autoscaler's plan_us log

        if policies:
            t = 0.0
            while t < cfg.horizon_hours:
                self._push(t, _TICK, None)
                t += cfg.tick_hours
            self._generate_offline_arrivals()
        for t, wl in (scale_events or []):
            self._push(t, _SCALE, wl)
        if scale_events:
            self.timeline.append(dict(self.cluster.count_by_workload(),
                                      step=0))

    # ---- event plumbing --------------------------------------------------------------
    def _push(self, time: float, kind: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (time, kind, self._seq, payload))

    def _generate_offline_arrivals(self) -> None:
        """Draw the WHOLE offline arrival stream (times, classes, durations)
        at construction from the seed, so every engine replays the same
        day."""
        cfg = self.cfg
        wl = {w.name: w for w in table3_workloads()}
        rng = random.Random(cfg.seed + 555)
        jid = 0

        def new_job(t: float) -> OfflineJob:
            nonlocal jid
            jid += 1
            # Table 3 offline mix: C (2-GPU) to D (1-GPU) roughly 0.7/0.3
            w = wl["C"] if rng.random() < 0.7 else wl["D"]
            dur = min(8.0, max(0.5, rng.expovariate(1.0 / cfg.mean_job_hours)))
            return OfflineJob(jid=jid, workload=w, duration_hours=dur,
                              submitted_at=t, remaining_hours=dur)

        initial = (cfg.initial_offline_jobs
                   if cfg.initial_offline_jobs is not None
                   else 4 * cfg.num_nodes)
        for _ in range(initial):
            self._push(0.0, _SUBMIT, new_job(0.0))
        rate = (cfg.offline_rate_per_hour
                if cfg.offline_rate_per_hour is not None
                else 2.5 * cfg.num_nodes)
        t = 0.0
        while rate > 0:
            t += rng.expovariate(rate)
            if t >= cfg.horizon_hours:
                break
            self._push(t, _SUBMIT, new_job(t))

    # ---- accounting ------------------------------------------------------------------
    def _reset_acc(self) -> None:
        self._acc = {
            "placements": 0, "preemptions": 0, "hits": 0, "failures": 0,
            "requeued": 0, "requeue_replanned": 0, "completed_jobs": 0,
            "offline_goodput": 0.0, "preemptor_perf": 0.0,
            "served": {}, "reclaimed": {}, "factors": [],
        }

    def _instance_factor(self, inst) -> float:
        """Fig. 2 factor RELATIVE to the best tier this instance size can
        physically achieve on the SKU (`serving.relative_scheduled_factor`):
        degradation measures scheduling quality, not instance size.  Cached
        per uid — a placement is immutable for the instance's lifetime and
        uids are never reused, so ``_advance`` costs a dict hit per
        instance instead of a bit-scan per event."""
        factor = self._factor_cache.get(inst.uid)
        if factor is None:
            spec = self.cluster.spec
            factor = self._rel_factor(spec,
                                      achieved_tier(spec, inst.gpu_mask),
                                      inst.workload.gpus_per_instance)
            self._factor_cache[inst.uid] = factor
        return factor

    def _advance(self, to_time: float) -> None:
        """Accumulate the factor-weighted GPU-hour integrals up to
        ``to_time`` (cluster state is piecewise-constant between events)."""
        dt = to_time - self._now
        if dt > 0:
            served = self._acc["served"]
            for inst in self.cluster.instances.values():
                name = inst.workload.name
                contrib = (inst.workload.gpus_per_instance
                           * self._instance_factor(inst) * dt)
                served[name] = served.get(name, 0.0) + contrib
                if inst.uid in self._preemptor_uids:
                    self._acc["preemptor_perf"] += contrib
        self._now = to_time

    def _on_decision(self, dec, event: str) -> None:
        """The decision-listener stream: every committed transaction lands
        here (the `AgentFleet` is subscribed right next to us)."""
        if event != "committed" or dec.rejected:
            return
        acc = self._acc
        acc["factors"].append(self._scheduled_factor(dec))
        if dec.preempted:
            acc["preemptions"] += 1
            acc["hits"] += int(dec.hit)
            if dec.instance is not None:
                self._preemptor_uids.add(dec.instance.uid)
        else:
            acc["placements"] += 1
        for victim in dec.evicted:
            job = self._running.pop(victim.uid, None)
            if job is None:
                continue        # not job-tracked (e.g. pre-saturated state)
            ran = (self._now - job.started_at) * job.rate
            job.remaining_hours = max(self.cfg.min_requeue_hours,
                                      job.remaining_hours - ran)
            job.requeues += 1
            job.uid = None
            acc["requeued"] += 1
            if self.cfg.requeue:
                self._push(self._now + self.cfg.requeue_delay_hours,
                           _REQUEUE, job)

    def _flush(self, end: float) -> None:
        acc = self._acc
        served = acc["served"]
        online = sum(v for k, v in served.items()
                     if self._kind_of(k) == "online")
        log = self.auto.plan_us[self._plan_log_base:]
        row = HourRow(
            hour=self._row_start,
            load=self._last_load,
            counts=dict(self.cluster.count_by_workload()),
            scheduled_perf=online,
            preemptor_perf=acc["preemptor_perf"],
            served=dict(served),
            offline_goodput=acc["offline_goodput"],
            placements=acc["placements"],
            preemptions=acc["preemptions"],
            hits=acc["hits"],
            failures=acc["failures"],
            requeued=acc["requeued"],
            requeue_replanned=acc["requeue_replanned"],
            completed_jobs=acc["completed_jobs"],
            pending=len(self.pending),
            crd_patches=self.fleet.store.patch_count - self._patch_base,
            reclaimed_tiers=dict(acc["reclaimed"]),
            decision_factor_mean=(statistics.fmean(acc["factors"])
                                  if acc["factors"] else 0.0),
            plan_p50_us=(statistics.median(log) if log else 0.0),
        )
        self.report.hours.append(row)
        self._row_start = end
        self._patch_base = self.fleet.store.patch_count
        self._plan_log_base = len(self.auto.plan_us)
        self._reset_acc()

    def _kind_of(self, name: str) -> str:
        for w in self.auto.policies:
            if w.workload.name == name:
                return w.workload.kind
        for j in self.jobs:
            if j.workload.name == name:
                return j.workload.kind
        wl = {w.name: w for w in table3_workloads()}
        return wl[name].kind if name in wl else "online"

    # ---- handlers --------------------------------------------------------------------
    def _handle_tick(self, t: float) -> None:
        if t > self._row_start:
            self._flush(t)
        self._last_load = diurnal_traffic(t % 24.0)
        for pol in self.auto.policies:
            ev = self.auto.scale_to(pol, pol.desired(self._last_load), t)
            self._acc["failures"] += ev.failures
            for tier, n in ev.reclaimed_tiers.items():
                self._acc["reclaimed"][tier] = (
                    self._acc["reclaimed"].get(tier, 0) + n)
        self._drain()

    def _handle_submit(self, job: OfflineJob) -> None:
        self.jobs.append(job)
        self.pending.append(job)
        self._drain()

    def _handle_requeue(self, job: OfflineJob) -> None:
        self.pending.append(job)
        self._drain()

    def _handle_complete(self, uid: int) -> None:
        job = self._running.get(uid)
        if job is None or job.uid != uid:
            return               # stale event: the job was preempted earlier
        del self._running[uid]
        self.cluster.evict(uid)
        job.uid = None
        job.remaining_hours = 0.0
        job.completed_at = self._now
        self._acc["completed_jobs"] += 1
        self._acc["offline_goodput"] += (
            job.duration_hours * job.workload.gpus_per_instance)
        self._drain()

    def _handle_scale(self, workload: WorkloadSpec) -> None:
        """One explicit Algorithm 1 attempt (the Fig. 8/9 view events)."""
        t0 = time.perf_counter()
        txn = self.sched.plan(workload)
        self.auto.plan_us.append((time.perf_counter() - t0) * 1e6)
        dec = txn.commit()
        if dec.rejected:
            self._acc["failures"] += 1
        self._scale_step += 1
        self.timeline.append(dict(self.cluster.count_by_workload(),
                                  step=self._scale_step))

    def _drain(self) -> None:
        """Backfill the pending offline queue through chunked ``plan_batch``
        admission (normal cycle only).  One FIFO pass per trigger; stops as
        soon as an entire chunk fails to place, so a full cluster costs one
        dispatch."""
        if not self.pending:
            return
        queue, self.pending = self.pending, deque()
        while queue:
            chunk = [queue.popleft()
                     for _ in range(min(self.cfg.backfill_chunk, len(queue)))]
            txns = self.auto._timed_plan_batch([j.workload for j in chunk],
                                               allow_preempt=False)
            any_placed = False
            for job, txn in zip(chunk, txns):
                if txn.decision.placed:
                    dec = txn.commit()
                    self._start_job(job, dec)
                    any_placed = True
                else:
                    self.pending.append(job)
            if not any_placed:
                self.pending.extend(queue)
                return

    def _start_job(self, job: OfflineJob, dec) -> None:
        uid = dec.instance.uid
        assert uid not in job.uids, "instance uid resurrected"
        job.uid = uid
        job.uids += (uid,)
        job.started_at = self._now
        # the placement tier sets the progress rate: a degraded offline
        # instance runs slower and holds its GPUs proportionally longer
        job.rate = self._instance_factor(dec.instance)
        self._running[uid] = job
        if job.requeues:
            self._acc["requeue_replanned"] += 1
        self._push(self._now + job.remaining_hours / job.rate, _COMPLETE, uid)

    # ---- the loop --------------------------------------------------------------------
    def run(self) -> ColocationReport:
        if self._ran:
            raise RuntimeError("ColocationSim.run() is one-shot")
        self._ran = True
        horizon = self.cfg.horizon_hours
        handlers = {
            _TICK: lambda t, p: self._handle_tick(t),
            _COMPLETE: lambda t, p: self._handle_complete(p),
            _REQUEUE: lambda t, p: self._handle_requeue(p),
            _SUBMIT: lambda t, p: self._handle_submit(p),
            _SCALE: lambda t, p: self._handle_scale(p),
        }
        while self._heap and self._heap[0][0] <= horizon:
            t, kind, _, payload = heapq.heappop(self._heap)
            self._advance(t)
            handlers[kind](t, payload)
        self._advance(horizon)
        self._flush(horizon)
        # the run is one-shot: detach from the scheduler so a caller that
        # keeps using it does not stream decisions into a finished report
        self.sched.remove_listener(self._on_decision)
        return self.report


def run_day_cycle(cfg: ColocationConfig,
                  policies: list[AutoscalePolicy] | None = None,
                  ) -> ColocationReport:
    """One full simulated day on the Table 3 mix under ``cfg.engine``."""
    sim = ColocationSim(cfg, policies=policies or default_policies(cfg))
    return sim.run()


def compare_day_cycle(
    cfg: ColocationConfig,
    engines: tuple[str, str] = ("imp_batched", "godel"),
) -> dict:
    """The paper's A/B: the SAME seeded day under a topology-aware engine
    vs a topology-unaware baseline.  Returns the per-engine reports and the
    scheduled-performance uplift ``(aware - baseline) / baseline`` — the
    quantity the paper reports as +55%."""
    aware_name, baseline_name = engines
    reports = {
        name: run_day_cycle(dataclasses.replace(cfg, engine=name))
        for name in engines
    }

    def _uplift(metric: str) -> float:
        base = getattr(reports[baseline_name], metric)
        return ((getattr(reports[aware_name], metric) - base) / base
                if base else 0.0)

    return {
        "engines": engines,
        "reports": reports,
        "uplift": _uplift("scheduled_perf"),
        "preemptor_uplift": _uplift("preemptor_perf"),
        "goodput_uplift": _uplift("offline_goodput"),
    }
