"""KWOK-analogue cluster simulator (paper §5).

Reproduces the paper's experimental protocol without a Kubernetes control
plane: N simulated GPU servers, Table 3 workloads, *saturation allocation*
(§3.1) as the initial condition, then auto-scaling events that trigger
preemptive scheduling.

The initial saturation uses seeded random placement (largest-GPU-first so the
divisible instance sizes always pack) with random GPU/CoreGroup bit choice —
this mirrors the fragmented "before" state of the paper's Fig. 8 snapshot
produced by a topology-unaware default scheduler.
"""
from __future__ import annotations

import dataclasses
import random
import time

import numpy as np

from .cluster import Cluster
from .placement import Placement
from .scheduler import EngineName, TopoScheduler
from .topology import RTX4090_SERVER, ServerSpec
from .workload import (TABLE3_INITIAL_INSTANCES, WorkloadSpec,
                       table3_workloads)


@dataclasses.dataclass
class SimConfig:
    num_nodes: int = 100
    spec: ServerSpec = RTX4090_SERVER
    seed: int = 0
    alpha: float = 0.5


class CompileWatch:
    """Counts XLA backend compiles so timed samples that secretly pay
    compile time (a cold jit bucket hit mid-run) can be tagged instead of
    polluting the latency distribution.  Install once per process; ``mark``
    /``delta`` bracket a timed region."""

    _installed: "CompileWatch | None" = None

    def __init__(self) -> None:
        self.count = 0

        def _cb(event: str, duration: float, **kw) -> None:
            if event.endswith("backend_compile_duration"):
                self.count += 1

        try:
            from jax import monitoring

            monitoring.register_event_duration_secs_listener(_cb)
        except Exception:
            pass    # no jax (host-only engines): every delta reads 0

    @classmethod
    def get(cls) -> "CompileWatch":
        if cls._installed is None:
            cls._installed = cls()
        return cls._installed

    def mark(self) -> int:
        return self.count

    def delta(self, mark: int) -> int:
        return self.count - mark


@dataclasses.dataclass
class HitRateReport:
    engine: str
    preemptions: int = 0
    hits: int = 0
    failures: int = 0          # no feasible candidate found
    placements: int = 0        # normal-cycle (non-preemptive) outcomes
    sourcing_us: list[float] = dataclasses.field(default_factory=list)
    #: aligned with ``sourcing_us``: True where the timed region compiled
    #: at least one new XLA program (see `CompileWatch`)
    compiled: list[bool] = dataclasses.field(default_factory=list)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.preemptions if self.preemptions else 0.0

    @property
    def compiled_samples(self) -> int:
        return sum(self.compiled)

    @property
    def decisions(self) -> int:
        """Every evaluated outcome: placed + preempted + rejected."""
        return self.placements + self.preemptions + self.failures

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.sourcing_us, q)) if self.sourcing_us else 0.0


def _random_bits(rng: random.Random, mask: int, k: int, n: int) -> int:
    free = [i for i in range(n) if mask >> i & 1]
    picked = rng.sample(free, k)
    out = 0
    for i in picked:
        out |= 1 << i
    return out


def _aligned_random_placement(
    cluster: Cluster, node: int, wl: WorkloadSpec, rng: random.Random,
    sequential_prob: float = 0.5,
) -> Placement | None:
    """Kubelet-style placement: each GPU paired with a local CoreGroup
    (CPU↔GPU locality guaranteed at admission) but NUMA/socket choice random —
    reproduces the fragmented-yet-locally-aligned 'before' state of Fig. 8.

    ``sequential_prob`` is the probability this instance fills NUMA nodes in
    index order (real schedulers deploy replicas in bursts that pack
    sequentially) vs. fully shuffled — it calibrates the fragmentation entropy
    of the initial state, which the paper does not fully specify.
    """
    spec = cluster.spec
    need_gpus = wl.gpus_per_instance
    need_cgs = wl.coregroups_per_instance(spec.coregroup_size)
    cgs_per_bundle = need_cgs // need_gpus if need_gpus else 0
    free_gpu, free_cg = cluster.free_masks(node)
    gpu_mask = 0
    cg_mask = 0
    numas = list(range(spec.num_numa))
    if rng.random() >= sequential_prob:
        rng.shuffle(numas)
    remaining = need_gpus
    for u in numas * max(1, spec.gpus_per_numa):
        if remaining == 0:
            break
        ug = free_gpu & int(spec.numa_gpu_masks[u]) & ~gpu_mask
        uc = free_cg & int(spec.numa_cg_masks[u]) & ~cg_mask
        if ug and uc.bit_count() >= cgs_per_bundle:
            g = (ug & -ug).bit_length() - 1   # lowest free GPU in this NUMA
            gpu_mask |= 1 << g
            taken = 0
            for c in range(spec.num_coregroups):
                if taken == cgs_per_bundle:
                    break
                if uc >> c & 1:
                    cg_mask |= 1 << c
                    taken += 1
            remaining -= 1
    if remaining:
        return None
    # leftover CoreGroups beyond whole bundles from anywhere free
    extra = need_cgs - cg_mask.bit_count()
    if extra:
        avail = free_cg & ~cg_mask
        if avail.bit_count() < extra:
            return None
        cg_mask |= _random_bits(rng, avail, extra, spec.num_coregroups)
    return Placement(gpu_mask=gpu_mask, cg_mask=cg_mask, tier=0)


def saturate(
    cluster: Cluster,
    workloads: list[WorkloadSpec],
    counts: dict[str, int],
    rng: random.Random,
    aligned: bool = True,
) -> None:
    """Fill the cluster to 100% GPU allocation with fragmented placement.

    ``aligned=True`` (default, matches the paper's production baseline) keeps
    per-GPU CPU locality but randomizes NUMA/socket spread; ``aligned=False``
    is the fully blind ablation.
    """
    spec = cluster.spec
    for wl in sorted(workloads, key=lambda w: -w.gpus_per_instance):
        need_cgs = wl.coregroups_per_instance(spec.coregroup_size)
        for _ in range(counts.get(wl.name, 0)):
            feasible = []
            for node in range(cluster.num_nodes):
                fg, fc = cluster.free_masks(node)
                if (fg.bit_count() >= wl.gpus_per_instance
                        and fc.bit_count() >= need_cgs):
                    feasible.append(node)
            if not feasible:
                raise RuntimeError(
                    f"saturation failed: no node fits {wl.name} "
                    f"({wl.gpus_per_instance} GPUs)"
                )
            placement = None
            node = -1
            if aligned:
                for node in rng.sample(feasible, len(feasible)):
                    placement = _aligned_random_placement(cluster, node, wl, rng)
                    if placement is not None:
                        break
            if placement is None:
                node = rng.choice(feasible)
                fg, fc = cluster.free_masks(node)
                placement = Placement(
                    gpu_mask=_random_bits(rng, fg, wl.gpus_per_instance,
                                          spec.num_gpus),
                    cg_mask=_random_bits(rng, fc, need_cgs, spec.num_coregroups),
                    tier=0,
                )
            cluster.bind(wl, node, placement)


def build_saturated_cluster(cfg: SimConfig,
                            workloads: list[WorkloadSpec] | None = None,
                            counts: dict[str, int] | None = None) -> Cluster:
    workloads = workloads or table3_workloads()
    if counts is None:
        # scale Table 3's 100-node counts to cfg.num_nodes
        scale = cfg.num_nodes / 100.0
        counts = {k: max(0, round(v * scale))
                  for k, v in TABLE3_INITIAL_INSTANCES.items()}
        # rounding may oversubscribe GPUs on small clusters: trim the
        # lowest-priority workloads until the mix fits
        by_gpus = {w.name: w.gpus_per_instance for w in workloads}
        capacity = cfg.num_nodes * cfg.spec.num_gpus
        order = sorted(workloads, key=lambda w: w.priority)
        while sum(counts[k] * by_gpus[k] for k in counts) > capacity:
            for w in order:
                if counts.get(w.name, 0) > 0:
                    counts[w.name] -= 1
                    break
    cluster = Cluster(cfg.spec, cfg.num_nodes)
    saturate(cluster, workloads, counts, random.Random(cfg.seed))
    return cluster


# ---------------------------------------------------------------------------------
# Experiments
# ---------------------------------------------------------------------------------

def run_hit_rate_experiment(
    cfg: SimConfig,
    engine: EngineName,
    cycles: int = 100,
    scaleups_per_cycle: int = 50,
    preemptor_names: tuple[str, ...] = ("B", "C"),
    independent: bool = True,
) -> HitRateReport:
    """Paper Table 4: cycles × scale-ups, hit-rate of topology affinity.

    ``independent=True`` follows the paper's protocol ("for each instance
    scaled up, the candidate sourcing and victim selection processes are
    evaluated independently"): every scale-up is *planned* against the
    cycle's saturated state and never committed — a rollback-free read of
    the transactional API.  ``independent=False`` commits scale-ups
    sequentially (capacity depletes within a cycle).
    """
    report = HitRateReport(engine=engine)
    workloads = {w.name: w for w in table3_workloads()}
    for cycle in range(cycles):
        cluster = build_saturated_cluster(
            dataclasses.replace(cfg, seed=cfg.seed + cycle))
        sched = TopoScheduler(cluster, engine=engine, alpha=cfg.alpha)
        rng = random.Random(10_000 + cfg.seed + cycle)
        for _ in range(scaleups_per_cycle):
            wl = workloads[rng.choice(preemptor_names)]
            txn = sched.plan(wl)
            dec = txn.commit() if not independent else txn.decision
            if dec.preempted:
                report.preemptions += 1
                report.hits += int(dec.hit)
                report.sourcing_us.append(dec.sourcing_us)
            elif dec.rejected:
                report.failures += 1
            else:
                # Table 4's hit rate is over preemptions only, but placed
                # outcomes are still counted so the independent and
                # committed protocols report the same decision totals
                report.placements += 1
    return report


def run_latency_experiment(
    cfg: SimConfig,
    engine: EngineName,
    preemptor_name: str,
    samples: int = 50,
) -> HitRateReport:
    """Paper Table 5: candidate-sourcing latency for one preemptor class."""
    report = HitRateReport(engine=engine)
    workloads = {w.name: w for w in table3_workloads()}
    wl = workloads[preemptor_name]
    cycle = 0
    while len(report.sourcing_us) < samples:
        cluster = build_saturated_cluster(
            dataclasses.replace(cfg, seed=cfg.seed + cycle))
        sched = TopoScheduler(cluster, engine=engine, alpha=cfg.alpha)
        watch = CompileWatch.get()
        for _ in range(min(samples - len(report.sourcing_us), 10)):
            m = watch.mark()
            dec = sched.schedule_or_preempt(wl)
            if dec.preempted:
                report.preemptions += 1
                report.hits += int(dec.hit)
                report.sourcing_us.append(dec.sourcing_us)
                report.compiled.append(watch.delta(m) > 0)
            elif dec.rejected:
                report.failures += 1
                break
            else:
                report.placements += 1
        cycle += 1
        if cycle > samples:  # safety: cannot source enough preemptions
            break
    return report


def run_plan_latency_experiment(
    cfg: SimConfig,
    engine: EngineName,
    preemptor_name: str,
    samples: int = 50,
    warmup: bool = False,
) -> HitRateReport:
    """Filtering-INCLUSIVE end-to-end ``plan()`` latency for one preemptor.

    Unlike `run_latency_experiment` (which reports the engine's own
    sourcing phase), this times the whole transactional ``plan()`` call —
    normal cycle, Guaranteed Filtering, Sorting, and Eq. 2 selection — so
    engines that fuse Filtering into the sourcing dispatch are compared
    end-to-end with engines that filter on the host.  ``sourcing_us``
    holds the plan wall times of preempted decisions.
    """
    report = HitRateReport(engine=engine)
    workloads = {w.name: w for w in table3_workloads()}
    wl = workloads[preemptor_name]
    cycle = 0
    while len(report.sourcing_us) < samples:
        cluster = build_saturated_cluster(
            dataclasses.replace(cfg, seed=cfg.seed + cycle))
        sched = TopoScheduler(cluster, engine=engine, alpha=cfg.alpha,
                              warmup=warmup)
        watch = CompileWatch.get()
        for _ in range(min(samples - len(report.sourcing_us), 10)):
            m = watch.mark()
            t0 = time.perf_counter()
            txn = sched.plan(wl)
            plan_us = (time.perf_counter() - t0) * 1e6
            dec = txn.commit()
            if dec.preempted:
                report.preemptions += 1
                report.hits += int(dec.hit)
                report.sourcing_us.append(plan_us)
                report.compiled.append(watch.delta(m) > 0)
            elif dec.rejected:
                report.failures += 1
                break
            else:
                report.placements += 1
        cycle += 1
        if cycle > samples:  # safety: cannot source enough preemptions
            break
    return report


def run_plan_normal_latency(
    cfg: SimConfig,
    engine: EngineName,
    preemptor_name: str,
    samples: int = 50,
    fill: float = 0.6,
) -> HitRateReport:
    """Normal-cycle (no-preemption) end-to-end ``plan()`` latency.

    The cluster is filled to ``fill`` of the Table 3 saturation mix so the
    request resolves in the normal scheduling cycle — the diurnal-valley
    admission path.  Every sample is a pure ``plan()`` read (never
    committed), so the state is identical across samples; ``sourcing_us``
    holds the plan wall times of PLACED decisions.  For ``fused_place``
    engines this is the single chained dispatch; for host engines it is
    the python node loop + ``place()``.
    """
    report = HitRateReport(engine=engine)
    workloads = table3_workloads()
    wl = {w.name: w for w in workloads}[preemptor_name]
    scale = cfg.num_nodes / 100.0 * fill
    counts = {k: max(0, round(v * scale))
              for k, v in TABLE3_INITIAL_INSTANCES.items()}
    cluster = build_saturated_cluster(cfg, workloads, counts)
    sched = TopoScheduler(cluster, engine=engine, alpha=cfg.alpha)
    dec = sched.plan(wl).decision          # jit warm-up, excluded
    if not dec.placed:
        raise RuntimeError(
            f"fill={fill} leaves no room for {preemptor_name}: "
            "normal-cycle protocol needs a placeable request")
    watch = CompileWatch.get()
    for _ in range(samples):
        m = watch.mark()
        t0 = time.perf_counter()
        txn = sched.plan(wl)
        plan_us = (time.perf_counter() - t0) * 1e6
        if txn.decision.placed:
            report.placements += 1
            report.hits += int(txn.decision.hit)
            report.sourcing_us.append(plan_us)
            report.compiled.append(watch.delta(m) > 0)
        else:
            report.failures += 1
    return report


def run_plan_batch_latency(
    cfg: SimConfig,
    engine: EngineName,
    preemptor_name: str,
    batch: int = 8,
    rounds: int = 5,
) -> HitRateReport:
    """Per-request end-to-end latency of ``plan_batch`` (one snapshot).

    Plans ``batch`` identical preemptors per round as pure reads (never
    committed, so every round sees the same saturated state); the first
    round warms the jit caches and is excluded.  ``sourcing_us`` holds the
    amortized per-request wall time of each timed round.
    """
    report = HitRateReport(engine=engine)
    workloads = {w.name: w for w in table3_workloads()}
    wl = workloads[preemptor_name]
    cluster = build_saturated_cluster(cfg)
    sched = TopoScheduler(cluster, engine=engine, alpha=cfg.alpha)
    sched.plan_batch([wl] * batch)          # jit warm-up round
    watch = CompileWatch.get()
    for _ in range(rounds):
        m = watch.mark()
        t0 = time.perf_counter()
        txns = sched.plan_batch([wl] * batch)
        report.sourcing_us.append(
            (time.perf_counter() - t0) * 1e6 / batch)
        report.compiled.append(watch.delta(m) > 0)
        for t in txns:
            if t.decision.preempted:
                report.preemptions += 1
                report.hits += int(t.decision.hit)
            elif t.decision.rejected:
                report.failures += 1
            else:
                # placed outcomes were silently dropped here before:
                # count them so batch totals match the per-plan protocols
                report.placements += 1
    return report


def _view_sim(cfg: SimConfig, engine: EngineName,
              scale_events: list[tuple[float, WorkloadSpec]]):
    """A co-location event loop seeded ONLY with explicit scale events over
    the saturated Table 3 state: the legacy episodic protocol (victims are
    dropped, not requeued) expressed as a day-cycle run — the Fig. 8/9
    experiments are views of this."""
    from .colocation import ColocationConfig, ColocationSim

    horizon = max((t for t, _ in scale_events), default=0.0) + 1.0
    ccfg = ColocationConfig(
        num_nodes=cfg.num_nodes, spec=cfg.spec, seed=cfg.seed,
        alpha=cfg.alpha, engine=engine, horizon_hours=horizon,
        requeue=False, offline_rate_per_hour=0.0, initial_offline_jobs=0)
    return ColocationSim(ccfg, scale_events=scale_events,
                         cluster=build_saturated_cluster(cfg))


def run_timeline(
    cfg: SimConfig,
    engine: EngineName = "imp",
    events: list[tuple[str, int]] | None = None,
) -> list[dict[str, int]]:
    """Paper Fig. 9: instance counts per workload across auto-scaling events.

    Runs on the `repro.core.colocation` event loop: each scale-up is one
    ``scale`` event, and the returned timeline is the sim's Fig. 9 view.
    """
    events = events or [("B", 10), ("A", 5)]
    workloads = {w.name: w for w in table3_workloads()}
    scale_events: list[tuple[float, WorkloadSpec]] = []
    step = 0
    for name, count in events:
        for _ in range(count):
            step += 1
            scale_events.append((float(step), workloads[name]))
    sim = _view_sim(cfg, engine, scale_events)
    sim.run()
    return sim.timeline


def run_allocation_snapshot(
    cfg: SimConfig,
    engine: EngineName,
    churn: int = 30,
) -> dict:
    """Paper Fig. 8: cross-socket mis-allocations before/after churn.

    The churn is a seeded stream of ``scale`` events on the co-location
    event loop; before/after snapshots bracket the run.
    """
    workloads = {w.name: w for w in table3_workloads()}
    rng = random.Random(cfg.seed + 777)
    scale_events = [(float(i + 1), workloads[rng.choice(("B", "C"))])
                    for i in range(churn)]
    sim = _view_sim(cfg, engine, scale_events)
    before = sim.cluster.cross_socket_instances()
    report = sim.run()
    return {
        "engine": engine,
        "cross_socket_before": before,
        "cross_socket_after": sim.cluster.cross_socket_instances(),
        "instances": len(sim.cluster.instances),
        "preemptions": report.preemptions,
        "snapshot": sim.cluster.allocation_snapshot(),
    }
