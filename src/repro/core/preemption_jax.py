"""Vectorized victim-subset evaluation (TPU adaptation of the paper's hot loop).

The paper's candidate sourcing is a branchy per-subset CPU loop (Table 5: up
to 417 ms P90).  Here every subset of one size is evaluated in a single dense
sweep: victim resources are int32 bitmasks, feasibility is
``popcount(freed & numa_mask)`` lane math, and the subset axis is a vector
axis.  The same math is retiled as a Pallas TPU kernel in
``repro.kernels.topo_score`` — this module is its jit'd reference engine and
is also what ``cluster_parallel`` shard_maps across the device mesh.

Two cluster-wide engines share the math:

* ``imp_batched`` (default, *fused*): ONE jit dispatch per victim-bucket
  group evaluates every subset of every size — a subset is its slot-bitmask
  id, so ``k`` is just ``popcount(id)`` — and the per-node
  smallest-feasible-``k`` plus the global Eq. 2 argmax reduce on device.
  In the common case (all nodes <= 8 victims) that is exactly one dispatch;
  only the winner's indices (a handful of scalars) cross back to the host,
  and the padded victim rows come from the cluster's
  incrementally-maintained `SourcingContext`.
* ``imp_batched_legacy``: the original multi-dispatch sweep (one jit call
  per subset size, full ``[N, n_comb]`` tier/priority transfers, python
  Candidate construction).  Kept for parity testing and as the reference
  for the fused path's semantics.

Tier convention matches ``placement.best_tier``:
0 = single NUMA, 1 = single socket, 2 = cross-socket, 3 = infeasible.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .cluster import MAX_DENSE_VICTIMS, Cluster, encode_row
from .engines import register_engine
from .scoring import DEFAULT_ALPHA, TIER_SCORES, Candidate
from .topology import ServerSpec
from .workload import TopoPolicy, WorkloadSpec


@lru_cache(maxsize=None)
def combo_table(m: int, k: int) -> np.ndarray:
    """int32[C(m,k), k] — all size-k index combinations of range(m)."""
    import itertools

    if k == 0:
        return np.zeros((1, 0), dtype=np.int32)
    combos = list(itertools.combinations(range(m), k))
    return np.asarray(combos, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Request:
    """Static (trace-time) description of the preemptor's resource ask."""

    need_gpus: int
    need_cgs: int
    bundle_locality: bool

    @property
    def cgs_per_bundle(self) -> int:
        if not self.need_gpus:
            return 0
        return self.need_cgs // self.need_gpus if self.bundle_locality else 0


def spec_constants(spec: ServerSpec) -> dict[str, jnp.ndarray]:
    """Static mask tensors for one server SKU."""
    sock_onehot = np.zeros((spec.num_numa, spec.num_sockets), dtype=np.int32)
    for u in range(spec.num_numa):
        sock_onehot[u, spec.socket_of_numa(u)] = 1
    return {
        "numa_gpu_masks": jnp.asarray(spec.numa_gpu_masks),
        "numa_cg_masks": jnp.asarray(spec.numa_cg_masks),
        "sock_onehot": jnp.asarray(sock_onehot),
    }


def _evaluate_subsets_core(
    free_gpu: jnp.ndarray,        # int32[] or int32[N]
    free_cg: jnp.ndarray,
    victim_gpu: jnp.ndarray,      # int32[M] (or [N, M])
    victim_cg: jnp.ndarray,
    victim_prio: jnp.ndarray,     # int32[M]
    victim_valid: jnp.ndarray,    # bool[M]  (padding rows -> False)
    table: jnp.ndarray,           # int32[n_comb, k]
    numa_gpu_masks: jnp.ndarray,  # int32[U]
    numa_cg_masks: jnp.ndarray,   # int32[U]
    sock_onehot: jnp.ndarray,     # int32[U, S]
    request: Request,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Evaluate every subset in `table` at once.

    Returns (tier int32[n_comb], prio_sum int32[n_comb], valid bool[n_comb]).
    Supports one leading batch axis on the dynamic state via vmap from callers.
    """
    k = table.shape[1]
    combo_gpu = jnp.zeros(table.shape[0], jnp.int32)
    combo_cg = jnp.zeros(table.shape[0], jnp.int32)
    prio_sum = jnp.zeros(table.shape[0], jnp.int32)
    valid = jnp.ones(table.shape[0], bool)
    for j in range(k):  # k is small and static: unrolled fold
        idx = table[:, j]
        combo_gpu |= victim_gpu[idx]
        combo_cg |= victim_cg[idx]
        prio_sum += victim_prio[idx]
        valid &= victim_valid[idx]

    freed_gpu = free_gpu | combo_gpu        # [n_comb]
    freed_cg = free_cg | combo_cg

    # per-NUMA availability: popcount(freed & numa_mask)   -> [n_comb, U]
    cnt_gpu = jax.lax.population_count(freed_gpu[:, None] & numa_gpu_masks[None, :])
    cnt_cg = jax.lax.population_count(freed_cg[:, None] & numa_cg_masks[None, :])
    tier = _tier_from_counts(cnt_gpu, cnt_cg, sock_onehot, request)
    tier = jnp.where(valid, tier, 3).astype(jnp.int32)
    return tier, prio_sum, valid


def _tier_from_counts(cnt_gpu, cnt_cg, sock_onehot, request: Request):
    """Tier of each subset from its per-NUMA availability counts.

    ``cnt_gpu``/``cnt_cg`` are ``[..., U]`` (any leading batch shape; the
    NUMA axis is last) — the single tier-semantics implementation shared by
    the per-size evaluator and the fused single-dispatch evaluator.
    """
    if request.need_gpus == 0:
        numa_ok = jnp.any(cnt_cg >= request.need_cgs, axis=-1)
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(sock_cg >= request.need_cgs, axis=-1)
        glob_ok = jnp.sum(cnt_cg, axis=-1) >= request.need_cgs
    else:
        if request.bundle_locality and request.cgs_per_bundle > 0:
            units = jnp.minimum(cnt_gpu, cnt_cg // request.cgs_per_bundle)
        else:
            units = cnt_gpu
        numa_ok = jnp.any(
            (units >= request.need_gpus) & (cnt_cg >= request.need_cgs),
            axis=-1)
        sock_units = units @ sock_onehot    # [..., S]
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(
            (sock_units >= request.need_gpus) & (sock_cg >= request.need_cgs),
            axis=-1)
        glob_ok = (jnp.sum(units, axis=-1) >= request.need_gpus) & (
            jnp.sum(cnt_cg, axis=-1) >= request.need_cgs)
    return jnp.where(numa_ok, 0, jnp.where(sock_ok, 1, jnp.where(glob_ok, 2, 3)))


evaluate_subsets = partial(jax.jit, static_argnames=("request",))(
    _evaluate_subsets_core
)


@lru_cache(maxsize=None)
def evaluate_subsets_batched(request: Request):
    """jit(vmap) of the core evaluator over a leading node axis.

    Dynamic state (free masks, victim arrays) is batched [N, ...]; the combo
    table and SKU constants are shared.  Returns (tier[N, n_comb],
    prio_sum[N, n_comb], valid[N, n_comb]).
    """
    fn = partial(_evaluate_subsets_core, request=request)
    return jax.jit(
        jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None))
    )


def _bucket(m: int) -> int:
    """Pad victim count to a small set of buckets to bound jit recompiles.

    Callers must partition away nodes holding more than `MAX_DENSE_VICTIMS`
    victims first (``split_dense_nodes``): those fall back to the per-node
    python engine instead of tripping this guard.
    """
    for b in (4, 8, 16):
        if m <= b:
            return b
    raise ValueError(f"too many victims on one node: {m}")


def split_dense_nodes(
    cluster, workload: WorkloadSpec, nodes: list[int],
) -> tuple[list[int], list[int], dict[int, list]]:
    """Partition nodes into (dense, overflow) by victim-row capacity.

    Overflow nodes (> `MAX_DENSE_VICTIMS` potential victims) cannot be
    encoded in the padded arrays; the batched engines source them through
    the per-node python IMP instead of raising (old ``_bucket`` crash).
    """
    per_node = {n: cluster.victims_on(n, workload.priority) for n in nodes}
    dense = [n for n in nodes if len(per_node[n]) <= MAX_DENSE_VICTIMS]
    overflow = [n for n in nodes if len(per_node[n]) > MAX_DENSE_VICTIMS]
    return dense, overflow, per_node


def _overflow_candidates(cluster, workload: WorkloadSpec,
                         nodes: list[int]) -> list[Candidate]:
    from .preemption import flextopo_imp

    out: list[Candidate] = []
    for node in nodes:
        out.extend(flextopo_imp(cluster, workload, node))
    return out


def cluster_victim_arrays(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
    per_node: dict[int, list] | None = None,
):
    """Padded per-node victim arrays for the batched/sharded engines.

    Returns (free_gpu[N], free_cg[N], vg[N,M], vc[N,M], vp[N,M], valid[N,M],
    victims_per_node list-of-lists).  ``per_node`` lets callers reuse the
    victim scan from ``split_dense_nodes``.
    """
    if per_node is not None:
        per_node = [per_node[n] for n in nodes]
    else:
        per_node = [cluster.victims_on(n, workload.priority) for n in nodes]
    m = _bucket(max((len(v) for v in per_node), default=1) or 1)
    n = len(nodes)
    free_gpu = np.zeros(n, np.int32)
    free_cg = np.zeros(n, np.int32)
    vg = np.zeros((n, m), np.int32)
    vc = np.zeros((n, m), np.int32)
    vp = np.zeros((n, m), np.int32)
    valid = np.zeros((n, m), bool)
    for i, node in enumerate(nodes):
        fg, fc = cluster.free_masks(node)
        free_gpu[i], free_cg[i] = fg, fc
        for j, v in enumerate(per_node[i]):
            vg[i, j] = v.gpu_mask
            vc[i, j] = v.cg_mask
            vp[i, j] = v.priority
            valid[i, j] = True
    return free_gpu, free_cg, vg, vc, vp, valid, per_node


@register_engine("imp_batched_legacy", batched=True)
def source_candidates_batched(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
) -> list[Candidate]:
    """Cluster-wide IMP: one vmapped sweep per subset size k over ALL nodes.

    Per-node IMP semantics are preserved: a node contributes candidates only
    at ITS smallest feasible k (tracked with done flags); the sweep continues
    until every node is done or k exceeds the largest victim count.

    This is the legacy multi-dispatch path (one jit call + device→host
    transfer per subset size); the fused single-dispatch rewrite is
    registered as ``imp_batched``.
    """
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    nodes, overflow, victims_by_node = split_dense_nodes(
        cluster, workload, nodes)
    extra = _overflow_candidates(cluster, workload, overflow)
    if not nodes:
        return extra
    free_gpu, free_cg, vg, vc, vp, valid, per_node = cluster_victim_arrays(
        cluster, workload, nodes, per_node=victims_by_node)
    m = vg.shape[1]
    fn = evaluate_subsets_batched(request)
    done = np.zeros(len(nodes), bool)
    out: list[Candidate] = []
    # counting lower bound (paper Fig 10 'quick failures'): sizes below the
    # cluster-wide minimum cannot be feasible anywhere
    from .preemption import min_feasible_k

    start_k = min((min_feasible_k(cluster, workload, n, per_node[i])
                   for i, n in enumerate(nodes)), default=0)
    for k in range(start_k, m + 1):
        if done.all():
            break
        table = combo_table(m, k)
        tier, prio, _ = fn(
            jnp.asarray(free_gpu), jnp.asarray(free_cg), jnp.asarray(vg),
            jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"],
            consts["numa_cg_masks"], consts["sock_onehot"],
        )
        tier = np.asarray(tier)
        prio = np.asarray(prio)
        for i, node in enumerate(nodes):
            if done[i] or k > len(per_node[i]):
                done[i] = done[i] or k > len(per_node[i])
                continue
            feasible = np.nonzero(tier[i] < 3)[0]
            if feasible.size:
                done[i] = True
                for idx in feasible:
                    out.append(Candidate(
                        node=node,
                        victims=tuple(sorted(
                            per_node[i][j].uid for j in table[idx])),
                        tier=int(tier[i, idx]),
                        priority_sum=int(prio[i, idx]),
                    ))
    return out + extra


def _victim_arrays(cluster: Cluster, workload: WorkloadSpec, node: int):
    victims = cluster.victims_on(node, workload.priority)
    m = len(victims)
    vg = np.array([v.gpu_mask for v in victims], dtype=np.int32).reshape(m)
    vc = np.array([v.cg_mask for v in victims], dtype=np.int32).reshape(m)
    vp = np.array([v.priority for v in victims], dtype=np.int32).reshape(m)
    return victims, vg, vc, vp


@register_engine("imp_jax")
def flextopo_imp_vectorized(cluster: Cluster, workload: WorkloadSpec, node: int
                            ) -> list[Candidate]:
    """IMP with the inner subset sweep vectorized (same results as python IMP)."""
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    victims, vg, vc, vp = _victim_arrays(cluster, workload, node)
    m = len(victims)
    free_gpu, free_cg = cluster.free_masks(node)
    valid = np.ones(max(m, 1), dtype=bool)
    if m == 0:
        vg = np.zeros(1, np.int32)
        vc = np.zeros(1, np.int32)
        vp = np.zeros(1, np.int32)
        valid = np.zeros(1, dtype=bool)

    for k in range(0, m + 1):
        table = combo_table(max(m, 1), k)
        tier, prio, _ = evaluate_subsets(
            jnp.int32(free_gpu), jnp.int32(free_cg),
            jnp.asarray(vg), jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"], consts["numa_cg_masks"],
            consts["sock_onehot"], request,
        )
        tier = np.asarray(tier)
        feasible = np.nonzero(tier < 3)[0]
        if feasible.size:
            prio = np.asarray(prio)
            return [
                Candidate(
                    node=node,
                    victims=tuple(sorted(victims[j].uid for j in table[i])),
                    tier=int(tier[i]),
                    priority_sum=int(prio[i]),
                )
                for i in feasible
            ]
    return []


# ---------------------------------------------------------------------------------
# Fused single-dispatch sourcing (engine "imp_batched")
# ---------------------------------------------------------------------------------
#
# A victim subset is its slot-bitmask id c in [0, 2^m): member slots are the
# set bits of c and the subset size is popcount(c), so every size k=0..m is
# evaluated in ONE device program with no ragged tables.  The program also
# reduces to the final Eq. 2 winner on device, reproducing
# `scoring.select_best`'s ordering:
#
#   maximize  (Eq. 1 score, fewer victims, lower node id,
#              lexicographically smallest sorted victim-uid tuple)
#
# The uid tie-break uses the rank trick: slot j's uid-rank r_j (from the
# SourcingContext) contributes bit (m-1-r_j) to a combo "uid mask", and for
# equal-size subsets of one node, larger uid mask == lexicographically
# smaller sorted uid tuple.  Scores are compared in f32 on device with an
# exact integer priority-sum refinement between ties, which matches the
# host's f64 ordering whenever distinct candidate scores are at least a few
# f32 ulps apart — true for realistic priority scales (the per-class gap is
# alpha*|1/p1 - 1/p2| >= alpha/p^2 which stays above f32 resolution for
# priorities up to tens of thousands); `imp_batched_legacy` keeps the exact
# host-side semantics for adversarial inputs.

_INT32_MAX = np.int32(2**31 - 1)

# rows of the stacked fused inputs (see `_fused_select_core`)
NODE_FIELDS = 3      # free_gpu, free_cg, node_id
VICTIM_FIELDS = 5    # gpu_mask, cg_mask, priority, uid_rank, stored


def _fused_select_core(
    nodestate: jnp.ndarray,  # int32[3, N]: free_gpu | free_cg | node_id
    victims: jnp.ndarray,    # int32[5, N, m]: gpu | cg | prio | rank | stored
    thresh: jnp.ndarray,     # int32[]     preemptor priority
    *,
    spec: ServerSpec,
    request: Request,
    alpha: float,
    m: int,
):
    """Evaluate all 2^m victim subsets of N nodes and reduce to the Eq. 2
    winner in one program.

    Inputs arrive as two stacked tensors (one host→device transfer each).
    Victim masks of one node are pairwise disjoint and disjoint from the
    free mask (the allocator guarantees it), so every per-subset fold —
    freed-GPU/CG masks, priority sum, and the uid-rank tie-break mask — is a
    single int32 matmul against the static subset-membership bit table
    instead of an unrolled OR loop.  Padding rows use node_id = INT32_MAX
    and stored = 0 and can never win.

    Returns int32[7]: (found, row, tier, combo_id, prio_sum, k,
    n_candidates): ``row`` indexes the input batch, ``combo_id``'s set bits
    are the winning victim slots, and ``n_candidates`` counts the feasible
    subsets at each node's own smallest feasible size (the legacy engine's
    candidate count).
    """
    free_gpu, free_cg, node_ids = nodestate[0], nodestate[1], nodestate[2]
    vg, vc, vp, rank = victims[0], victims[1], victims[2], victims[3]
    stored = victims[4] != 0
    n_comb = 1 << m
    cids = jnp.arange(n_comb, dtype=jnp.int32)
    kk = jax.lax.population_count(cids)                       # [n_comb]
    bits = ((cids[None, :] >> jnp.arange(m, dtype=jnp.int32)[:, None])
            & 1)                                              # [m, n_comb]

    # victims valid under this preemptor: stored & strictly lower priority
    valid_slot = stored & (vp < thresh)                       # [N, m]
    slot_bits = jnp.left_shift(
        jnp.int32(1), jnp.arange(m, dtype=jnp.int32))         # [m]
    valid_mask = valid_slot.astype(jnp.int32) @ slot_bits      # [N]
    combo_ok = (cids[None, :] & ~valid_mask[:, None]) == 0     # [N, n_comb]

    # all per-subset folds in one [4, N, m] @ [m, n_comb] contraction.
    # rank bits use the full cap width: truncated rows carry uid-ranks over
    # the whole stored prefix, which can exceed the sliced bucket m.
    rankbit = jnp.left_shift(jnp.int32(1), MAX_DENSE_VICTIMS - 1 - rank)
    payload = jnp.stack([vg, vc, vp, rankbit])                 # [4, N, m]
    sums = jax.lax.dot_general(payload, bits,
                               (((2,), (0,)), ((), ())))       # [4, N, n_comb]
    combo_gpu = free_gpu[:, None] + sums[0]    # disjoint masks: sum == OR
    combo_cg = free_cg[:, None] + sums[1]
    prio_sum = sums[2]
    umask = sums[3]

    # per-NUMA availability: popcount(freed & numa_mask) -> [N, n_comb, U];
    # SKU constants shared with the legacy evaluator
    consts = spec_constants(spec)
    numa_g = consts["numa_gpu_masks"]
    numa_c = consts["numa_cg_masks"]
    sock_onehot = consts["sock_onehot"]
    cnt_gpu = jax.lax.population_count(
        combo_gpu[:, :, None] & numa_g[None, None, :])
    cnt_cg = jax.lax.population_count(
        combo_cg[:, :, None] & numa_c[None, None, :])
    tier = _tier_from_counts(cnt_gpu, cnt_cg, sock_onehot, request)
    tier = jnp.where(combo_ok, tier, 3).astype(jnp.int32)

    # ---- per-node smallest feasible k (IMP early stop, on device) ---------------
    feasible = tier < 3
    big_k = jnp.int32(m + 1)
    k_node = jnp.min(jnp.where(feasible, kk[None, :], big_k), axis=1)   # [N]
    atmin = feasible & (kk[None, :] == k_node[:, None])
    n_candidates = jnp.sum(atmin.astype(jnp.int32))

    # ---- per-(node, tier) winner via exact integer keys -------------------------
    # within one node all candidates share k, so the Eq. 2 order inside a
    # (node, tier) class is: smaller priority sum (when alpha > 0), then the
    # uid tie-break (always) — tensorized over the three tier classes.
    p_eff = prio_sum if alpha > 0 else jnp.zeros_like(prio_sum)
    big_p = jnp.int32(_INT32_MAX)
    t3 = jnp.arange(3, dtype=jnp.int32)
    sel = atmin[:, :, None] & (tier[:, :, None] == t3)         # [N, n_comb, 3]
    anyc = jnp.any(sel, axis=1)                                # [N, 3]
    pmin = jnp.min(jnp.where(sel, p_eff[:, :, None], big_p), axis=1)
    sel = sel & (p_eff[:, :, None] == pmin[:, None, :])
    umax = jnp.max(jnp.where(sel, umask[:, :, None], -1), axis=1)
    sel = sel & (umask[:, :, None] == umax[:, None, :])
    cb = jnp.argmax(sel, axis=1).astype(jnp.int32)             # [N, 3]
    pp = jnp.take_along_axis(prio_sum, cb, axis=1)             # [N, 3]
    ppe = pp if alpha > 0 else jnp.zeros_like(pp)

    # ---- global Eq. 2 argmax over the <= 3N class winners -----------------------
    tier_vals = jnp.asarray(tuple(TIER_SCORES), jnp.float32)
    prio_term = jnp.where(pp > 0,
                          1.0 / jnp.maximum(pp, 1).astype(jnp.float32), 1.0)
    score = alpha * prio_term + (1.0 - alpha) * tier_vals[None, :]
    score = jnp.where(anyc, score, -jnp.inf)
    sel = anyc & (score == jnp.max(score))
    # Exact refinement between f32 score ties, then the host tie-break
    # chain: fewer victims, lower node, uid order.  When every survivor
    # shares one tier, an f32 tie with distinct priority sums means f32
    # merely conflated scores f64 distinguishes — refine by lower priority
    # sum (the f64 order).  Survivors from DIFFERENT tiers are treated as a
    # genuine Eq. 1 tie and skip the refinement so the victim-count break
    # applies first, as in `select_best`.  The one case left behind is a
    # cross-tier pair whose f64 scores differ by less than f32 resolution —
    # that needs single-digit priority sums; `imp_batched_legacy` keeps
    # exact host-side semantics for such adversarial inputs.
    tcol = jnp.broadcast_to(t3[None, :], sel.shape)
    same_tier = (jnp.min(jnp.where(sel, tcol, 3))
                 == jnp.max(jnp.where(sel, tcol, -1)))
    ppe_key = jnp.where(same_tier, ppe, 0)
    sel = sel & (ppe_key == jnp.min(jnp.where(sel, ppe_key, big_p)))
    kn = jnp.broadcast_to(k_node[:, None], sel.shape)
    sel = sel & (kn == jnp.min(jnp.where(sel, kn, big_k)))
    nid = jnp.broadcast_to(node_ids[:, None], sel.shape)
    sel = sel & (nid == jnp.min(jnp.where(sel, nid, big_p)))
    um = jnp.take_along_axis(umask, cb, axis=1)
    sel = sel & (um == jnp.max(jnp.where(sel, um, -1)))
    flat = jnp.argmax(sel.reshape(-1)).astype(jnp.int32)
    row = flat // 3
    return jnp.stack([
        jnp.any(anyc).astype(jnp.int32),     # found
        row,                                 # batch row of the winner
        flat % 3,                            # tier
        cb.reshape(-1)[flat],                # combo id (victim-slot bitmask)
        pp.reshape(-1)[flat],                # priority sum
        k_node[row],                         # subset size
        n_candidates,
    ])


@lru_cache(maxsize=None)
def fused_evaluator(spec: ServerSpec, request: Request, alpha: float, m: int):
    """jit of the fused evaluator with SKU constants baked in."""
    return jax.jit(partial(_fused_select_core, spec=spec, request=request,
                           alpha=alpha, m=m))


def _pad_rows(n: int) -> int:
    """Pad the node axis to a few buckets so jit caches stay warm."""
    b = 8
    while b < n:
        b *= 2
    return b


#: node-axis chunk size for the widest (m=16) victim bucket: keeps the
#: [chunk, 2^16, U] popcount intermediates to tens of MB per dispatch.
MAX_ROWS_WIDE = 16


class CandidateShortlist(list):
    """``list[Candidate]`` that also reports the TRUE candidate count.

    The fused engine returns only per-dispatch winners, but the device
    already counted every feasible min-k subset; ``n_candidates`` carries
    that count so ``SchedulingDecision.num_candidates`` stays comparable
    with the exhaustive-listing engines.
    """

    n_candidates: int = 0


def _assemble_group(ctx, sel_nodes: list[int], patches: dict, m: int):
    """Stacked dense inputs for one dispatch over ``sel_nodes`` at victim
    bucket ``m``: (nodestate int32[3, n_pad], victims int32[5, n_pad, m],
    uids int64[n_sel, m])."""
    idx = np.asarray(sel_nodes, np.int64)
    n = len(sel_nodes)
    n_pad = _pad_rows(n)
    nodestate = np.zeros((NODE_FIELDS, n_pad), np.int32)
    nodestate[2] = _INT32_MAX          # pad rows: unreachable node id
    nodestate[0, :n] = ctx.free_gpu[idx]
    nodestate[1, :n] = ctx.free_cg[idx]
    nodestate[2, :n] = sel_nodes
    victims = np.zeros((VICTIM_FIELDS, n_pad, m), np.int32)
    victims[0, :n] = ctx.vg[idx, :m]
    victims[1, :n] = ctx.vc[idx, :m]
    victims[2, :n] = ctx.vp[idx, :m]
    victims[3, :n] = ctx.rank[idx, :m]
    victims[4, :n] = ctx.stored[idx, :m]
    uids = ctx.vu[idx, :m]
    for pos, node in enumerate(sel_nodes):   # O(view delta) row patches
        row = patches.get(node)
        if row is None:
            continue
        nodestate[0, pos] = row.free_gpu
        nodestate[1, pos] = row.free_cg
        victims[0, pos] = row.vg[:m]
        victims[1, pos] = row.vc[:m]
        victims[2, pos] = row.vp[:m]
        victims[3, pos] = row.rank[:m]
        victims[4, pos] = row.stored[:m]
        uids[pos] = row.vu[:m]
    return nodestate, victims, uids


def fused_rows(cluster, workload: WorkloadSpec, nodes: list[int]):
    """Per-dispatch input groups for ``nodes``, served from the base
    cluster's `SourcingContext` with copy-on-write view deltas patched at
    O(delta) cost (only changed nodes are re-encoded; the base rows are
    never copied wholesale).

    Nodes are grouped by their ELIGIBLE-victim bucket so the common narrow
    rows (<= 8 eligible victims, <= 256 subsets) never pay the wide
    2^16-subset program: one group covers every narrow node, and nodes
    with 9..16 eligible victims go to m=16 dispatches chunked to
    `MAX_ROWS_WIDE` rows.  Truncated rows (> cap preemptible instances)
    stay on the fast path while the preemptor's eligible victims fit the
    stored prefix.  Returns (groups, overflow_nodes) with each group =
    (sel_nodes, nodestate, victims, uids).
    """
    base = getattr(cluster, "base", cluster)
    ctx = base.sourcing_context()
    ctx.refresh()
    delta = cluster.delta_nodes() if hasattr(cluster, "delta_nodes") else ()
    patches = {d: encode_row(cluster, d, ctx.cap)
               for d in set(delta) & set(nodes)}
    idx = np.asarray(nodes, np.int64)
    thresh = workload.priority
    # bucket by the ELIGIBLE victim count (priority < preemptor) — eligible
    # victims are a prefix of each (priority, uid)-sorted row, so slicing to
    # the eligible bucket keeps every victim this preemptor may evict
    elig = ((ctx.vp[idx] < thresh) & ctx.stored[idx]).sum(axis=1)
    trunc = ctx.overflow[idx].copy()
    next_p = ctx.next_prio[idx].copy()
    for pos, node in enumerate(nodes):
        row = patches.get(node)
        if row is not None:
            elig[pos] = int(((row.vp < thresh) & row.stored).sum())
            trunc[pos] = row.overflow
            next_p[pos] = row.next_priority
    # a truncated row falls back only if eligible victims extend past it
    over = trunc & (next_p < thresh)
    overflow = [n for n, o in zip(nodes, over) if o]
    narrow = [i for i in range(len(nodes)) if not over[i] and elig[i] <= 8]
    wide = [i for i in range(len(nodes))
            if not over[i] and 8 < elig[i] <= MAX_DENSE_VICTIMS]
    groups = []
    if narrow:
        m = _bucket(max(int(elig[narrow].max()), 1))
        sel = [nodes[i] for i in narrow]
        groups.append((sel,) + _assemble_group(ctx, sel, patches, m))
    for lo in range(0, len(wide), MAX_ROWS_WIDE):
        sel = [nodes[i] for i in wide[lo:lo + MAX_ROWS_WIDE]]
        groups.append((sel,) + _assemble_group(ctx, sel, patches, 16))
    return groups, overflow


@register_engine("imp_batched", batched=True, needs_alpha=True)
def source_candidates_fused(
    cluster, workload: WorkloadSpec, nodes: list[int],
    alpha: float = DEFAULT_ALPHA,
) -> list[Candidate]:
    """Fused cluster-wide IMP: candidate sourcing AND Eq. 2 selection in one
    jit dispatch per victim-bucket group (exactly one dispatch in the
    common all-narrow case), fed by incrementally-cached victim arrays.

    Returns the winning `Candidate` per dispatch (plus per-node python
    candidates for overflow nodes the dense rows cannot encode) as a
    `CandidateShortlist` carrying the true evaluated-candidate count; the
    scheduler's ``select`` then reduces this shortlist with the exact
    host-side Eq. 2.  Winner parity with ``imp_batched_legacy`` +
    ``select_best`` is covered by tests/test_fused_sourcing.py.
    """
    if not nodes:
        return CandidateShortlist()
    spec = cluster.spec
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    groups, overflow = fused_rows(cluster, workload, nodes)
    out = CandidateShortlist(_overflow_candidates(cluster, workload, overflow))
    out.n_candidates = len(out)
    for sel_nodes, nodestate, victims, uids in groups:
        m = victims.shape[2]
        fn = fused_evaluator(spec, request, float(alpha), m)
        res = fn(jnp.asarray(nodestate), jnp.asarray(victims),
                 jnp.int32(workload.priority))
        found, row, tier, combo, prio, _k, ncand = (int(v) for v in
                                                    jax.device_get(res))
        out.n_candidates += ncand
        if found:
            victim_uids = [int(uids[row, j]) for j in range(m)
                           if (combo >> j) & 1]
            out.append(Candidate(
                node=sel_nodes[row],
                victims=tuple(sorted(victim_uids)),
                tier=tier,
                priority_sum=prio,
            ))
    return out
