"""Vectorized victim-subset evaluation (TPU adaptation of the paper's hot loop).

The paper's candidate sourcing is a branchy per-subset CPU loop (Table 5: up
to 417 ms P90).  Here every subset of one size is evaluated in a single dense
sweep: victim resources are int32 bitmasks, feasibility is
``popcount(freed & numa_mask)`` lane math, and the subset axis is a vector
axis.  The same math is retiled as a Pallas TPU kernel in
``repro.kernels.topo_score`` — this module is its jit'd reference engine and
is also what ``cluster_parallel`` shard_maps across the device mesh.

Tier convention matches ``placement.best_tier``:
0 = single NUMA, 1 = single socket, 2 = cross-socket, 3 = infeasible.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .cluster import Cluster
from .engines import register_engine
from .scoring import Candidate
from .topology import ServerSpec
from .workload import TopoPolicy, WorkloadSpec


@lru_cache(maxsize=None)
def combo_table(m: int, k: int) -> np.ndarray:
    """int32[C(m,k), k] — all size-k index combinations of range(m)."""
    import itertools

    if k == 0:
        return np.zeros((1, 0), dtype=np.int32)
    combos = list(itertools.combinations(range(m), k))
    return np.asarray(combos, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Request:
    """Static (trace-time) description of the preemptor's resource ask."""

    need_gpus: int
    need_cgs: int
    bundle_locality: bool

    @property
    def cgs_per_bundle(self) -> int:
        if not self.need_gpus:
            return 0
        return self.need_cgs // self.need_gpus if self.bundle_locality else 0


def spec_constants(spec: ServerSpec) -> dict[str, jnp.ndarray]:
    """Static mask tensors for one server SKU."""
    sock_onehot = np.zeros((spec.num_numa, spec.num_sockets), dtype=np.int32)
    for u in range(spec.num_numa):
        sock_onehot[u, spec.socket_of_numa(u)] = 1
    return {
        "numa_gpu_masks": jnp.asarray(spec.numa_gpu_masks),
        "numa_cg_masks": jnp.asarray(spec.numa_cg_masks),
        "sock_onehot": jnp.asarray(sock_onehot),
    }


def _evaluate_subsets_core(
    free_gpu: jnp.ndarray,        # int32[] or int32[N]
    free_cg: jnp.ndarray,
    victim_gpu: jnp.ndarray,      # int32[M] (or [N, M])
    victim_cg: jnp.ndarray,
    victim_prio: jnp.ndarray,     # int32[M]
    victim_valid: jnp.ndarray,    # bool[M]  (padding rows -> False)
    table: jnp.ndarray,           # int32[n_comb, k]
    numa_gpu_masks: jnp.ndarray,  # int32[U]
    numa_cg_masks: jnp.ndarray,   # int32[U]
    sock_onehot: jnp.ndarray,     # int32[U, S]
    request: Request,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Evaluate every subset in `table` at once.

    Returns (tier int32[n_comb], prio_sum int32[n_comb], valid bool[n_comb]).
    Supports one leading batch axis on the dynamic state via vmap from callers.
    """
    k = table.shape[1]
    combo_gpu = jnp.zeros(table.shape[0], jnp.int32)
    combo_cg = jnp.zeros(table.shape[0], jnp.int32)
    prio_sum = jnp.zeros(table.shape[0], jnp.int32)
    valid = jnp.ones(table.shape[0], bool)
    for j in range(k):  # k is small and static: unrolled fold
        idx = table[:, j]
        combo_gpu |= victim_gpu[idx]
        combo_cg |= victim_cg[idx]
        prio_sum += victim_prio[idx]
        valid &= victim_valid[idx]

    freed_gpu = free_gpu | combo_gpu        # [n_comb]
    freed_cg = free_cg | combo_cg

    # per-NUMA availability: popcount(freed & numa_mask)   -> [n_comb, U]
    cnt_gpu = jax.lax.population_count(freed_gpu[:, None] & numa_gpu_masks[None, :])
    cnt_cg = jax.lax.population_count(freed_cg[:, None] & numa_cg_masks[None, :])

    if request.need_gpus == 0:
        numa_ok = jnp.any(cnt_cg >= request.need_cgs, axis=1)
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(sock_cg >= request.need_cgs, axis=1)
        glob_ok = jnp.sum(cnt_cg, axis=1) >= request.need_cgs
    else:
        if request.bundle_locality:
            units = jnp.minimum(cnt_gpu, cnt_cg // max(request.cgs_per_bundle, 1))
            if request.cgs_per_bundle == 0:
                units = cnt_gpu
        else:
            units = cnt_gpu
        numa_ok = jnp.any(
            (units >= request.need_gpus) & (cnt_cg >= request.need_cgs), axis=1
        )
        sock_units = units @ sock_onehot    # [n_comb, S]
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(
            (sock_units >= request.need_gpus) & (sock_cg >= request.need_cgs), axis=1
        )
        glob_ok = (jnp.sum(units, axis=1) >= request.need_gpus) & (
            jnp.sum(cnt_cg, axis=1) >= request.need_cgs
        )

    tier = jnp.where(numa_ok, 0, jnp.where(sock_ok, 1, jnp.where(glob_ok, 2, 3)))
    tier = jnp.where(valid, tier, 3).astype(jnp.int32)
    return tier, prio_sum, valid


evaluate_subsets = partial(jax.jit, static_argnames=("request",))(
    _evaluate_subsets_core
)


@lru_cache(maxsize=None)
def evaluate_subsets_batched(request: Request):
    """jit(vmap) of the core evaluator over a leading node axis.

    Dynamic state (free masks, victim arrays) is batched [N, ...]; the combo
    table and SKU constants are shared.  Returns (tier[N, n_comb],
    prio_sum[N, n_comb], valid[N, n_comb]).
    """
    fn = partial(_evaluate_subsets_core, request=request)
    return jax.jit(
        jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None))
    )


def _bucket(m: int) -> int:
    """Pad victim count to a small set of buckets to bound jit recompiles."""
    for b in (4, 8, 16):
        if m <= b:
            return b
    raise ValueError(f"too many victims on one node: {m}")


def cluster_victim_arrays(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
):
    """Padded per-node victim arrays for the batched/sharded engines.

    Returns (free_gpu[N], free_cg[N], vg[N,M], vc[N,M], vp[N,M], valid[N,M],
    victims_per_node list-of-lists).
    """
    per_node = [cluster.victims_on(n, workload.priority) for n in nodes]
    m = _bucket(max((len(v) for v in per_node), default=1) or 1)
    n = len(nodes)
    free_gpu = np.zeros(n, np.int32)
    free_cg = np.zeros(n, np.int32)
    vg = np.zeros((n, m), np.int32)
    vc = np.zeros((n, m), np.int32)
    vp = np.zeros((n, m), np.int32)
    valid = np.zeros((n, m), bool)
    for i, node in enumerate(nodes):
        fg, fc = cluster.free_masks(node)
        free_gpu[i], free_cg[i] = fg, fc
        for j, v in enumerate(per_node[i]):
            vg[i, j] = v.gpu_mask
            vc[i, j] = v.cg_mask
            vp[i, j] = v.priority
            valid[i, j] = True
    return free_gpu, free_cg, vg, vc, vp, valid, per_node


@register_engine("imp_batched", batched=True)
def source_candidates_batched(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
) -> list[Candidate]:
    """Cluster-wide IMP: one vmapped sweep per subset size k over ALL nodes.

    Per-node IMP semantics are preserved: a node contributes candidates only
    at ITS smallest feasible k (tracked with done flags); the sweep continues
    until every node is done or k exceeds the largest victim count.
    """
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    free_gpu, free_cg, vg, vc, vp, valid, per_node = cluster_victim_arrays(
        cluster, workload, nodes)
    m = vg.shape[1]
    fn = evaluate_subsets_batched(request)
    done = np.zeros(len(nodes), bool)
    out: list[Candidate] = []
    # counting lower bound (paper Fig 10 'quick failures'): sizes below the
    # cluster-wide minimum cannot be feasible anywhere
    from .preemption import min_feasible_k

    start_k = min((min_feasible_k(cluster, workload, n, per_node[i])
                   for i, n in enumerate(nodes)), default=0)
    for k in range(start_k, m + 1):
        if done.all():
            break
        table = combo_table(m, k)
        tier, prio, _ = fn(
            jnp.asarray(free_gpu), jnp.asarray(free_cg), jnp.asarray(vg),
            jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"],
            consts["numa_cg_masks"], consts["sock_onehot"],
        )
        tier = np.asarray(tier)
        prio = np.asarray(prio)
        for i, node in enumerate(nodes):
            if done[i] or k > len(per_node[i]):
                done[i] = done[i] or k > len(per_node[i])
                continue
            feasible = np.nonzero(tier[i] < 3)[0]
            if feasible.size:
                done[i] = True
                for idx in feasible:
                    out.append(Candidate(
                        node=node,
                        victims=tuple(sorted(
                            per_node[i][j].uid for j in table[idx])),
                        tier=int(tier[i, idx]),
                        priority_sum=int(prio[i, idx]),
                    ))
    return out


def _victim_arrays(cluster: Cluster, workload: WorkloadSpec, node: int):
    victims = cluster.victims_on(node, workload.priority)
    m = len(victims)
    vg = np.array([v.gpu_mask for v in victims], dtype=np.int32).reshape(m)
    vc = np.array([v.cg_mask for v in victims], dtype=np.int32).reshape(m)
    vp = np.array([v.priority for v in victims], dtype=np.int32).reshape(m)
    return victims, vg, vc, vp


@register_engine("imp_jax")
def flextopo_imp_vectorized(cluster: Cluster, workload: WorkloadSpec, node: int
                            ) -> list[Candidate]:
    """IMP with the inner subset sweep vectorized (same results as python IMP)."""
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    victims, vg, vc, vp = _victim_arrays(cluster, workload, node)
    m = len(victims)
    free_gpu, free_cg = cluster.free_masks(node)
    valid = np.ones(max(m, 1), dtype=bool)
    if m == 0:
        vg = np.zeros(1, np.int32)
        vc = np.zeros(1, np.int32)
        vp = np.zeros(1, np.int32)
        valid = np.zeros(1, dtype=bool)

    for k in range(0, m + 1):
        table = combo_table(max(m, 1), k)
        tier, prio, _ = evaluate_subsets(
            jnp.int32(free_gpu), jnp.int32(free_cg),
            jnp.asarray(vg), jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"], consts["numa_cg_masks"],
            consts["sock_onehot"], request,
        )
        tier = np.asarray(tier)
        feasible = np.nonzero(tier < 3)[0]
        if feasible.size:
            prio = np.asarray(prio)
            return [
                Candidate(
                    node=node,
                    victims=tuple(sorted(victims[j].uid for j in table[i])),
                    tier=int(tier[i]),
                    priority_sum=int(prio[i]),
                )
                for i in feasible
            ]
    return []
