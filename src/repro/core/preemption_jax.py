"""Vectorized victim-subset evaluation (TPU adaptation of the paper's hot loop).

The paper's candidate sourcing is a branchy per-subset CPU loop (Table 5: up
to 417 ms P90).  Here every subset of one size is evaluated in a single dense
sweep: victim resources are int32 bitmasks, feasibility is
``popcount(freed & numa_mask)`` lane math, and the subset axis is a vector
axis.  The same math is retiled as a Pallas TPU kernel in
``repro.kernels.topo_score`` — this module is its jit'd reference engine and
is also what ``cluster_parallel`` shard_maps across the device mesh.

Two cluster-wide engines share the math:

* ``imp_batched`` (default, *fused*): ONE jit dispatch over the cluster's
  DEVICE-RESIDENT state (`DeviceClusterState`) runs Guaranteed Filtering
  (full-drain popcount feasibility), every victim subset of every size — a
  subset is its slot-bitmask id, so ``k`` is just ``popcount(id)`` — and the
  per-node smallest-feasible-``k`` plus the global Eq. 2 argmax.  No node
  list crosses host→device: the scheduler skips its host Filtering loop
  entirely (``fused_filter``), copy-on-write `ClusterView` deltas are
  overlaid inside the dispatch as scattered patch rows, and only the
  winner's indices AND its concrete placement masks (an
  ``int32[WIN_FIELDS]``, placed by the `placement_jax` §3.4 scorer) cross
  back.  Nodes with more than `NARROW_M` eligible victims are gated out
  in-device and re-dispatched through chunked 2^16-subset programs fed
  device-side gather indices.

The engine also registers ``fused_place``: `plan_fused` chains the NORMAL
scheduling cycle (`placement_jax.normal_cycle_core` — per-node placement
tiers, the host's exact ``(tier, leftover, node)`` argmin, and the winner's
masks) in front of the preemptive chain under ``lax.cond``, so the whole of
Algorithm 1 — both cycles, Filtering, Sorting, Eq. 2 AND placement — is one
device program and one small readback (`plan_evaluator`), with the subset
sweep never executed when the normal cycle succeeds.
* ``imp_batched_legacy``: the original multi-dispatch sweep (one jit call
  per subset size, full ``[N, n_comb]`` tier/priority transfers, python
  Candidate construction).  Kept for parity testing and as the reference
  for the fused path's semantics.

``plan_batch`` requests ride a `BatchSourcingSession`: one *vmapped*
dispatch evaluates ALL requests' per-node class winners against one
snapshot, and the sequential planned-eviction semantics are preserved by
masking each plan's delta nodes out of the precomputed tensors on device
and re-sourcing only those rows against the view.

Tier convention matches ``placement.best_tier``:
0 = single NUMA, 1 = single socket, 2 = cross-socket, 3 = infeasible.
"""
from __future__ import annotations

import dataclasses
import sys
from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .cluster import (DRAIN_FIELDS, IDX_SENTINEL, MAX_DENSE_VICTIMS,
                      NODE_FIELDS, NS_FREE_CG, NS_FREE_GPU, NS_NEXT_PRIO,
                      NS_NODE_ID, NS_OVERFLOW, VF_CG, VF_GPU, VF_PRIO,
                      VF_RANK, VF_STORED, VICTIM_FIELDS, Cluster,
                      DeviceClusterState, VictimRow, ViewDelta, _pad_pow2,
                      apply_rows, encode_row, flatten_rows,
                      pack_context_rows, pack_rows, pad_idx, unflatten_rows)
from .engines import register_engine
from .placement import Placement
from .placement_jax import (normal_cycle_core, spec_constants,
                            tier_from_counts_dyn, winner_place)
from .scoring import DEFAULT_ALPHA, TIER_SCORES, Candidate, select_best
from .topology import ServerSpec
from .workload import TopoPolicy, WorkloadSpec

#: compat alias — the dynamic-request tier math now lives in
#: `placement_jax` (shared with the placement scorer); sharded and test
#: callers keep importing it from here
_tier_from_counts_dyn = tier_from_counts_dyn


@lru_cache(maxsize=None)
def combo_table(m: int, k: int) -> np.ndarray:
    """int32[C(m,k), k] — all size-k index combinations of range(m)."""
    import itertools

    if k == 0:
        return np.zeros((1, 0), dtype=np.int32)
    combos = list(itertools.combinations(range(m), k))
    return np.asarray(combos, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Request:
    """Static (trace-time) description of the preemptor's resource ask."""

    need_gpus: int
    need_cgs: int
    bundle_locality: bool

    @property
    def cgs_per_bundle(self) -> int:
        if not self.need_gpus:
            return 0
        return self.need_cgs // self.need_gpus if self.bundle_locality else 0


def _evaluate_subsets_core(
    free_gpu: jnp.ndarray,        # int32[] or int32[N]
    free_cg: jnp.ndarray,
    victim_gpu: jnp.ndarray,      # int32[M] (or [N, M])
    victim_cg: jnp.ndarray,
    victim_prio: jnp.ndarray,     # int32[M]
    victim_valid: jnp.ndarray,    # bool[M]  (padding rows -> False)
    table: jnp.ndarray,           # int32[n_comb, k]
    numa_gpu_masks: jnp.ndarray,  # int32[U]
    numa_cg_masks: jnp.ndarray,   # int32[U]
    sock_onehot: jnp.ndarray,     # int32[U, S]
    request: Request,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Evaluate every subset in `table` at once.

    Returns (tier int32[n_comb], prio_sum int32[n_comb], valid bool[n_comb]).
    Supports one leading batch axis on the dynamic state via vmap from callers.
    """
    k = table.shape[1]
    combo_gpu = jnp.zeros(table.shape[0], jnp.int32)
    combo_cg = jnp.zeros(table.shape[0], jnp.int32)
    prio_sum = jnp.zeros(table.shape[0], jnp.int32)
    valid = jnp.ones(table.shape[0], bool)
    for j in range(k):  # k is small and static: unrolled fold
        idx = table[:, j]
        combo_gpu |= victim_gpu[idx]
        combo_cg |= victim_cg[idx]
        prio_sum += victim_prio[idx]
        valid &= victim_valid[idx]

    freed_gpu = free_gpu | combo_gpu        # [n_comb]
    freed_cg = free_cg | combo_cg

    # per-NUMA availability: popcount(freed & numa_mask)   -> [n_comb, U]
    cnt_gpu = jax.lax.population_count(freed_gpu[:, None] & numa_gpu_masks[None, :])
    cnt_cg = jax.lax.population_count(freed_cg[:, None] & numa_cg_masks[None, :])
    tier = _tier_from_counts(cnt_gpu, cnt_cg, sock_onehot, request)
    tier = jnp.where(valid, tier, 3).astype(jnp.int32)
    return tier, prio_sum, valid


def _tier_from_counts(cnt_gpu, cnt_cg, sock_onehot, request: Request):
    """Tier of each subset from its per-NUMA availability counts.

    ``cnt_gpu``/``cnt_cg`` are ``[..., U]`` (any leading batch shape; the
    NUMA axis is last) — the single tier-semantics implementation shared by
    the per-size evaluator and the fused single-dispatch evaluator.
    """
    if request.need_gpus == 0:
        numa_ok = jnp.any(cnt_cg >= request.need_cgs, axis=-1)
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(sock_cg >= request.need_cgs, axis=-1)
        glob_ok = jnp.sum(cnt_cg, axis=-1) >= request.need_cgs
    else:
        if request.bundle_locality and request.cgs_per_bundle > 0:
            units = jnp.minimum(cnt_gpu, cnt_cg // request.cgs_per_bundle)
        else:
            units = cnt_gpu
        numa_ok = jnp.any(
            (units >= request.need_gpus) & (cnt_cg >= request.need_cgs),
            axis=-1)
        sock_units = units @ sock_onehot    # [..., S]
        sock_cg = cnt_cg @ sock_onehot
        sock_ok = jnp.any(
            (sock_units >= request.need_gpus) & (sock_cg >= request.need_cgs),
            axis=-1)
        glob_ok = (jnp.sum(units, axis=-1) >= request.need_gpus) & (
            jnp.sum(cnt_cg, axis=-1) >= request.need_cgs)
    return jnp.where(numa_ok, 0, jnp.where(sock_ok, 1, jnp.where(glob_ok, 2, 3)))


evaluate_subsets = partial(jax.jit, static_argnames=("request",))(
    _evaluate_subsets_core
)


@lru_cache(maxsize=None)
def evaluate_subsets_batched(request: Request):
    """jit(vmap) of the core evaluator over a leading node axis.

    Dynamic state (free masks, victim arrays) is batched [N, ...]; the combo
    table and SKU constants are shared.  Returns (tier[N, n_comb],
    prio_sum[N, n_comb], valid[N, n_comb]).
    """
    fn = partial(_evaluate_subsets_core, request=request)
    return jax.jit(
        jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None, None, None, None))
    )


def _bucket(m: int) -> int:
    """Pad victim count to a small set of buckets to bound jit recompiles.

    Callers must partition away nodes holding more than `MAX_DENSE_VICTIMS`
    victims first (``split_dense_nodes``): those fall back to the per-node
    python engine instead of tripping this guard.
    """
    for b in (4, 8, 16):
        if m <= b:
            return b
    raise ValueError(f"too many victims on one node: {m}")


def split_dense_nodes(
    cluster, workload: WorkloadSpec, nodes: list[int],
) -> tuple[list[int], list[int], dict[int, list]]:
    """Partition nodes into (dense, overflow) by victim-row capacity.

    Overflow nodes (> `MAX_DENSE_VICTIMS` potential victims) cannot be
    encoded in the padded arrays; the batched engines source them through
    the per-node python IMP instead of raising (old ``_bucket`` crash).
    """
    per_node = {n: cluster.victims_on(n, workload.priority) for n in nodes}
    dense = [n for n in nodes if len(per_node[n]) <= MAX_DENSE_VICTIMS]
    overflow = [n for n in nodes if len(per_node[n]) > MAX_DENSE_VICTIMS]
    return dense, overflow, per_node


def _overflow_candidates(cluster, workload: WorkloadSpec,
                         nodes: list[int]) -> list[Candidate]:
    from .preemption import flextopo_imp

    out: list[Candidate] = []
    for node in nodes:
        out.extend(flextopo_imp(cluster, workload, node))
    return out


def cluster_victim_arrays(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
    per_node: dict[int, list] | None = None,
):
    """Padded per-node victim arrays for the batched/sharded engines.

    Returns (free_gpu[N], free_cg[N], vg[N,M], vc[N,M], vp[N,M], valid[N,M],
    victims_per_node list-of-lists).  ``per_node`` lets callers reuse the
    victim scan from ``split_dense_nodes``.
    """
    if per_node is not None:
        per_node = [per_node[n] for n in nodes]
    else:
        per_node = [cluster.victims_on(n, workload.priority) for n in nodes]
    m = _bucket(max((len(v) for v in per_node), default=1) or 1)
    n = len(nodes)
    free_gpu = np.zeros(n, np.int32)
    free_cg = np.zeros(n, np.int32)
    vg = np.zeros((n, m), np.int32)
    vc = np.zeros((n, m), np.int32)
    vp = np.zeros((n, m), np.int32)
    valid = np.zeros((n, m), bool)
    for i, node in enumerate(nodes):
        fg, fc = cluster.free_masks(node)
        free_gpu[i], free_cg[i] = fg, fc
        for j, v in enumerate(per_node[i]):
            vg[i, j] = v.gpu_mask
            vc[i, j] = v.cg_mask
            vp[i, j] = v.priority
            valid[i, j] = True
    return free_gpu, free_cg, vg, vc, vp, valid, per_node


@register_engine("imp_batched_legacy", batched=True)
def source_candidates_batched(
    cluster: Cluster, workload: WorkloadSpec, nodes: list[int],
) -> list[Candidate]:
    """Cluster-wide IMP: one vmapped sweep per subset size k over ALL nodes.

    Per-node IMP semantics are preserved: a node contributes candidates only
    at ITS smallest feasible k (tracked with done flags); the sweep continues
    until every node is done or k exceeds the largest victim count.

    This is the legacy multi-dispatch path (one jit call + device→host
    transfer per subset size); the fused single-dispatch rewrite is
    registered as ``imp_batched``.
    """
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    nodes, overflow, victims_by_node = split_dense_nodes(
        cluster, workload, nodes)
    extra = _overflow_candidates(cluster, workload, overflow)
    if not nodes:
        return extra
    free_gpu, free_cg, vg, vc, vp, valid, per_node = cluster_victim_arrays(
        cluster, workload, nodes, per_node=victims_by_node)
    m = vg.shape[1]
    fn = evaluate_subsets_batched(request)
    done = np.zeros(len(nodes), bool)
    out: list[Candidate] = []
    # counting lower bound (paper Fig 10 'quick failures'): sizes below the
    # cluster-wide minimum cannot be feasible anywhere
    from .preemption import min_feasible_k

    start_k = min((min_feasible_k(cluster, workload, n, per_node[i])
                   for i, n in enumerate(nodes)), default=0)
    for k in range(start_k, m + 1):
        if done.all():
            break
        table = combo_table(m, k)
        tier, prio, _ = fn(
            jnp.asarray(free_gpu), jnp.asarray(free_cg), jnp.asarray(vg),
            jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"],
            consts["numa_cg_masks"], consts["sock_onehot"],
        )
        tier = np.asarray(tier)
        prio = np.asarray(prio)
        for i, node in enumerate(nodes):
            if done[i] or k > len(per_node[i]):
                done[i] = done[i] or k > len(per_node[i])
                continue
            feasible = np.nonzero(tier[i] < 3)[0]
            if feasible.size:
                done[i] = True
                for idx in feasible:
                    out.append(Candidate(
                        node=node,
                        victims=tuple(sorted(
                            per_node[i][j].uid for j in table[idx])),
                        tier=int(tier[i, idx]),
                        priority_sum=int(prio[i, idx]),
                    ))
    return out + extra


def _victim_arrays(cluster: Cluster, workload: WorkloadSpec, node: int):
    victims = cluster.victims_on(node, workload.priority)
    m = len(victims)
    vg = np.array([v.gpu_mask for v in victims], dtype=np.int32).reshape(m)
    vc = np.array([v.cg_mask for v in victims], dtype=np.int32).reshape(m)
    vp = np.array([v.priority for v in victims], dtype=np.int32).reshape(m)
    return victims, vg, vc, vp


@register_engine("imp_jax")
def flextopo_imp_vectorized(cluster: Cluster, workload: WorkloadSpec, node: int
                            ) -> list[Candidate]:
    """IMP with the inner subset sweep vectorized (same results as python IMP)."""
    spec = cluster.spec
    consts = spec_constants(spec)
    request = Request(
        need_gpus=workload.gpus_per_instance,
        need_cgs=workload.coregroups_per_instance(spec.coregroup_size),
        bundle_locality=workload.numa_policy == TopoPolicy.GUARANTEED,
    )
    victims, vg, vc, vp = _victim_arrays(cluster, workload, node)
    m = len(victims)
    free_gpu, free_cg = cluster.free_masks(node)
    valid = np.ones(max(m, 1), dtype=bool)
    if m == 0:
        vg = np.zeros(1, np.int32)
        vc = np.zeros(1, np.int32)
        vp = np.zeros(1, np.int32)
        valid = np.zeros(1, dtype=bool)

    for k in range(0, m + 1):
        table = combo_table(max(m, 1), k)
        tier, prio, _ = evaluate_subsets(
            jnp.int32(free_gpu), jnp.int32(free_cg),
            jnp.asarray(vg), jnp.asarray(vc), jnp.asarray(vp), jnp.asarray(valid),
            jnp.asarray(table), consts["numa_gpu_masks"], consts["numa_cg_masks"],
            consts["sock_onehot"], request,
        )
        tier = np.asarray(tier)
        feasible = np.nonzero(tier < 3)[0]
        if feasible.size:
            prio = np.asarray(prio)
            return [
                Candidate(
                    node=node,
                    victims=tuple(sorted(victims[j].uid for j in table[i])),
                    tier=int(tier[i]),
                    priority_sum=int(prio[i]),
                )
                for i in feasible
            ]
    return []


# ---------------------------------------------------------------------------------
# Fused single-dispatch sourcing (engine "imp_batched")
# ---------------------------------------------------------------------------------
#
# A victim subset is its slot-bitmask id c in [0, 2^m): member slots are the
# set bits of c and the subset size is popcount(c), so every size k=0..m is
# evaluated in ONE device program with no ragged tables.  The same program
# runs Guaranteed Filtering first — the fully-drained masks kept resident in
# `DeviceClusterState.drain` go through the identical popcount tier math, and
# nodes whose drain state is infeasible contribute no candidates (which is
# exactly the host filter's semantics: a subset is feasible only if the
# full drain is) — then reduces to the final Eq. 2 winner on device,
# reproducing `scoring.select_best`'s ordering:
#
#   maximize  (Eq. 1 score, fewer victims, lower node id,
#              lexicographically smallest sorted victim-uid tuple)
#
# The uid tie-break uses the rank trick: slot j's uid-rank r_j (from the
# SourcingContext mirror) contributes bit (cap-1-r_j) to a combo "uid mask",
# and for equal-size subsets of one node, larger uid mask ==
# lexicographically smaller sorted uid tuple.  Scores are compared in f32 on
# device with an exact integer priority-sum refinement between ties, which
# matches the host's f64 ordering whenever distinct candidate scores are at
# least a few f32 ulps apart — true for realistic priority scales (the
# per-class gap is alpha*|1/p1 - 1/p2| >= alpha/p^2 which stays above f32
# resolution for priorities up to tens of thousands); `imp_batched_legacy`
# keeps the exact host-side semantics for adversarial inputs.
#
# The preemptor's resource ask is DYNAMIC (traced int32 scalars), so one
# compiled program serves every workload class — jit variants are keyed only
# by (spec, victim-slot width m, patch bucket p).

_INT32_MAX = np.int32(2**31 - 1)

#: victim-slot width of the resident single-dispatch program; nodes with
#: more eligible victims are gated out in-device and re-dispatched wide
NARROW_M = 8


class ClassWinners(NamedTuple):
    """Per-(node, tier) class-winner tensors produced by `_fused_class_core`.

    ``anyc[N, 3]`` marks classes holding at least one min-k feasible subset;
    ``cb``/``pp``/``um`` are the class winner's combo id, priority sum, and
    uid-rank mask; ``k_node[N]`` is each node's smallest feasible subset
    size and ``cnt[N]`` its feasible min-k subset count (the legacy
    engine's candidate count)."""

    anyc: jnp.ndarray
    cb: jnp.ndarray
    pp: jnp.ndarray
    um: jnp.ndarray
    k_node: jnp.ndarray
    cnt: jnp.ndarray


def _fused_class_core(
    nodestate: jnp.ndarray,  # int32[NODE_FIELDS, N]
    victims: jnp.ndarray,    # int32[VICTIM_FIELDS, N, >= m]
    drain: jnp.ndarray,      # int32[DRAIN_FIELDS, N] fully-drained masks
    thresh: jnp.ndarray,     # int32[]  preemptor priority
    need_gpus: jnp.ndarray,  # int32[]
    need_cgs: jnp.ndarray,   # int32[]
    cgs_per_bundle: jnp.ndarray,  # int32[] (0 = no bundle locality)
    alpha: jnp.ndarray,      # f32[]    Eq. 1 weight
    *,
    spec: ServerSpec,
    m: int,
    narrow_gate: bool,
) -> ClassWinners:
    """Filtering + all-2^m-subset evaluation + per-(node, tier) reduction.

    Guaranteed Filtering runs first on the resident ``drain`` masks — the
    same popcount tier math over the fully-drained state; filtered-out
    nodes contribute nothing (their subsets could never be feasible, so
    this is bitwise-identical to the scheduler's host filter).  With
    ``narrow_gate`` the program additionally gates out rows whose ELIGIBLE
    victims (priority < preemptor, always a prefix of the sorted row)
    exceed ``m`` slots, and truncated rows whose eligible victims extend
    past the stored prefix — the host re-dispatches those wide/overflow.

    Victim masks of one node are pairwise disjoint and disjoint from the
    free mask (the allocator guarantees it), so every per-subset fold —
    freed-GPU/CG masks, priority sum, and the uid-rank tie-break mask — is
    a single int32 matmul against the static subset-membership bit table.
    Rows with node_id = INT32_MAX (gather/pad sentinels) can never win.
    """
    free_gpu = nodestate[NS_FREE_GPU]
    free_cg = nodestate[NS_FREE_CG]
    node_ids = nodestate[NS_NODE_ID]
    vp_full = victims[VF_PRIO]
    stored_full = victims[VF_STORED] != 0
    vg = victims[VF_GPU, :, :m]
    vc = victims[VF_CG, :, :m]
    vp = vp_full[:, :m]
    rank = victims[VF_RANK, :, :m]
    stored = stored_full[:, :m]

    consts = spec_constants(spec)
    numa_g = consts["numa_gpu_masks"]
    numa_c = consts["numa_cg_masks"]
    sock_onehot = consts["sock_onehot"]

    # ---- Guaranteed Filtering, fused: popcounts over the drain masks ------------
    dcnt_g = jax.lax.population_count(drain[0][:, None] & numa_g[None, :])
    dcnt_c = jax.lax.population_count(drain[1][:, None] & numa_c[None, :])
    drain_tier = _tier_from_counts_dyn(dcnt_g, dcnt_c, sock_onehot,
                                       need_gpus, need_cgs, cgs_per_bundle)
    node_ok = (drain_tier < 3) & (node_ids < _INT32_MAX)
    if narrow_gate:
        elig_full = jnp.sum((stored_full & (vp_full < thresh))
                            .astype(jnp.int32), axis=1)
        overflow = nodestate[NS_OVERFLOW] != 0
        next_prio = nodestate[NS_NEXT_PRIO]
        node_ok &= (elig_full <= m) & ~(overflow & (next_prio < thresh))

    n_comb = 1 << m
    cids = jnp.arange(n_comb, dtype=jnp.int32)
    kk = jax.lax.population_count(cids)                       # [n_comb]
    bits = ((cids[None, :] >> jnp.arange(m, dtype=jnp.int32)[:, None])
            & 1)                                              # [m, n_comb]

    # victims valid under this preemptor: stored & strictly lower priority
    valid_slot = stored & (vp < thresh)                       # [N, m]
    slot_bits = jnp.left_shift(
        jnp.int32(1), jnp.arange(m, dtype=jnp.int32))         # [m]
    valid_mask = valid_slot.astype(jnp.int32) @ slot_bits      # [N]
    combo_ok = ((cids[None, :] & ~valid_mask[:, None]) == 0    # [N, n_comb]
                ) & node_ok[:, None]

    # all per-subset folds in one [4, N, m] @ [m, n_comb] contraction.
    # rank bits use the full cap width: truncated rows carry uid-ranks over
    # the whole stored prefix, which can exceed the sliced bucket m.
    rankbit = jnp.left_shift(jnp.int32(1), MAX_DENSE_VICTIMS - 1 - rank)
    payload = jnp.stack([vg, vc, vp, rankbit])                 # [4, N, m]
    sums = jax.lax.dot_general(payload, bits,
                               (((2,), (0,)), ((), ())))       # [4, N, n_comb]
    combo_gpu = free_gpu[:, None] + sums[0]    # disjoint masks: sum == OR
    combo_cg = free_cg[:, None] + sums[1]
    prio_sum = sums[2]
    umask = sums[3]

    # per-NUMA availability: popcount(freed & numa_mask) -> [N, n_comb, U]
    cnt_gpu = jax.lax.population_count(
        combo_gpu[:, :, None] & numa_g[None, None, :])
    cnt_cg = jax.lax.population_count(
        combo_cg[:, :, None] & numa_c[None, None, :])
    tier = _tier_from_counts_dyn(cnt_gpu, cnt_cg, sock_onehot,
                                 need_gpus, need_cgs, cgs_per_bundle)
    tier = jnp.where(combo_ok, tier, 3).astype(jnp.int32)

    # ---- per-node smallest feasible k (IMP early stop, on device) ---------------
    feasible = tier < 3
    big_k = jnp.int32(m + 1)
    k_node = jnp.min(jnp.where(feasible, kk[None, :], big_k), axis=1)   # [N]
    atmin = feasible & (kk[None, :] == k_node[:, None])
    cnt = jnp.sum(atmin.astype(jnp.int32), axis=1)             # [N]

    # ---- per-(node, tier) winner via exact integer keys -------------------------
    # within one node all candidates share k, so the Eq. 2 order inside a
    # (node, tier) class is: smaller priority sum (when alpha > 0), then the
    # uid tie-break (always) — tensorized over the three tier classes.
    p_eff = jnp.where(alpha > 0, prio_sum, 0)
    big_p = jnp.int32(_INT32_MAX)
    t3 = jnp.arange(3, dtype=jnp.int32)
    sel = atmin[:, :, None] & (tier[:, :, None] == t3)         # [N, n_comb, 3]
    anyc = jnp.any(sel, axis=1)                                # [N, 3]
    pmin = jnp.min(jnp.where(sel, p_eff[:, :, None], big_p), axis=1)
    sel = sel & (p_eff[:, :, None] == pmin[:, None, :])
    umax = jnp.max(jnp.where(sel, umask[:, :, None], -1), axis=1)
    sel = sel & (umask[:, :, None] == umax[:, None, :])
    cb = jnp.argmax(sel, axis=1).astype(jnp.int32)             # [N, 3]
    pp = jnp.take_along_axis(prio_sum, cb, axis=1)             # [N, 3]
    um = jnp.take_along_axis(umask, cb, axis=1)
    return ClassWinners(anyc=anyc, cb=cb, pp=pp, um=um, k_node=k_node,
                        cnt=cnt)


def _fused_argmax_core(node_ids, cls: ClassWinners, alpha):
    """Global Eq. 2 argmax over the <= 3N class winners.

    Returns int32[7]: (found, row, tier, combo_id, prio_sum, k,
    n_candidates): ``row`` indexes the class tensors' node axis and
    ``combo_id``'s set bits are the winning victim slots.
    """
    anyc, cb, pp, um, k_node, cnt = cls
    tier_vals = jnp.asarray(tuple(TIER_SCORES), jnp.float32)
    prio_term = jnp.where(pp > 0,
                          1.0 / jnp.maximum(pp, 1).astype(jnp.float32), 1.0)
    score = alpha * prio_term + (1.0 - alpha) * tier_vals[None, :]
    score = jnp.where(anyc, score, -jnp.inf)
    sel = anyc & (score == jnp.max(score))
    # Exact refinement between f32 score ties, then the host tie-break
    # chain: fewer victims, lower node, uid order.  When every survivor
    # shares one tier, an f32 tie with distinct priority sums means f32
    # merely conflated scores f64 distinguishes — refine by lower priority
    # sum (the f64 order).  Survivors from DIFFERENT tiers are treated as a
    # genuine Eq. 1 tie and skip the refinement so the victim-count break
    # applies first, as in `select_best`.  The one case left behind is a
    # cross-tier pair whose f64 scores differ by less than f32 resolution —
    # that needs single-digit priority sums; `imp_batched_legacy` keeps
    # exact host-side semantics for such adversarial inputs.
    big_p = jnp.int32(_INT32_MAX)
    t3 = jnp.arange(3, dtype=jnp.int32)
    ppe = jnp.where(alpha > 0, pp, 0)
    tcol = jnp.broadcast_to(t3[None, :], sel.shape)
    same_tier = (jnp.min(jnp.where(sel, tcol, 3))
                 == jnp.max(jnp.where(sel, tcol, -1)))
    ppe_key = jnp.where(same_tier, ppe, 0)
    sel = sel & (ppe_key == jnp.min(jnp.where(sel, ppe_key, big_p)))
    kn = jnp.broadcast_to(k_node[:, None], sel.shape)
    sel = sel & (kn == jnp.min(jnp.where(sel, kn, big_p)))
    nid = jnp.broadcast_to(node_ids[:, None], sel.shape)
    sel = sel & (nid == jnp.min(jnp.where(sel, nid, big_p)))
    sel = sel & (um == jnp.max(jnp.where(sel, um, -1)))
    flat = jnp.argmax(sel.reshape(-1)).astype(jnp.int32)
    row = flat // 3
    return jnp.stack([
        jnp.any(anyc).astype(jnp.int32),     # found
        row,                                 # node-axis row of the winner
        flat % 3,                            # tier
        cb.reshape(-1)[flat],                # combo id (victim-slot bitmask)
        pp.reshape(-1)[flat],                # priority sum
        k_node[row],                         # subset size
        jnp.sum(cnt),                        # n_candidates
    ])


def _overlay(nodestate, victims, drain, pidx, pbuf):
    """Apply flattened view-delta patch rows as a device-side overlay
    (the traced twin of the resident-state scatter)."""
    return apply_rows(nodestate, victims, drain, pidx, pbuf)


def _overlay_ns(nodestate, idx, buf):
    """Overlay patch rows onto the nodestate tensor alone (the normal-cycle
    evaluator needs free masks only, not victim/drain rows)."""
    cap = (buf.shape[1] - NODE_FIELDS - DRAIN_FIELDS) // VICTIM_FIELDS
    a, _, _ = unflatten_rows(buf, cap)
    return nodestate.at[:, idx].set(a, mode="drop")


#: width of a decoded preemption winner: the int32[7] Eq. 2 argmax vector
#: plus the winner's (gpu_mask, cg_mask) placement from the device scorer
WIN_FIELDS = 9


def _sorting_winner(nodestate, victims, drain, gidx,
                    thresh, ng, nc, cpb, alpha, *, spec, m, g):
    """Filtering → subset evaluation → Eq. 2 argmax → winner placement.

    Runs over the (already-overlaid) resident tensors at slot width ``m``
    plus a gathered `NARROW_M`-wide section over the ``g`` mid-tier rows
    named by ``gidx``, then places the winner with the §3.4 device scorer
    (`placement_jax.winner_place`) so the host decodes concrete
    GPU/CoreGroup masks instead of re-running ``place()``.  Returns
    int32[`WIN_FIELDS`]."""
    cls = _fused_class_core(nodestate, victims, drain, thresh, ng, nc,
                            cpb, alpha, spec=spec, m=m, narrow_gate=True)
    node_ids = nodestate[NS_NODE_ID]
    fg_cat = nodestate[NS_FREE_GPU]
    fc_cat = nodestate[NS_FREE_CG]
    vg_cat = victims[VF_GPU]
    vc_cat = victims[VF_CG]
    if g:
        ns = jnp.take(nodestate, gidx, axis=1, mode="fill", fill_value=0)
        vv = jnp.take(victims, gidx, axis=1, mode="fill", fill_value=0)
        dd = jnp.take(drain, gidx, axis=1, mode="fill", fill_value=0)
        ns = ns.at[NS_NODE_ID].set(gidx)
        cls_g = _fused_class_core(ns, vv, dd, thresh, ng, nc, cpb,
                                  alpha, spec=spec, m=NARROW_M,
                                  narrow_gate=False)
        cls = ClassWinners(*(jnp.concatenate([a, b])
                             for a, b in zip(cls, cls_g)))
        node_ids = jnp.concatenate([node_ids, ns[NS_NODE_ID]])
        fg_cat = jnp.concatenate([fg_cat, ns[NS_FREE_GPU]])
        fc_cat = jnp.concatenate([fc_cat, ns[NS_FREE_CG]])
        vg_cat = jnp.concatenate([vg_cat, vv[VF_GPU]])
        vc_cat = jnp.concatenate([vc_cat, vv[VF_CG]])
    win = _fused_argmax_core(node_ids, cls, alpha)
    return winner_place(win, fg_cat, fc_cat, vg_cat, vc_cat, ng, nc, cpb,
                        spec=spec)


def _plan_pipeline(nodestate, victims, drain, aux, pbuf,
                   thresh, ng, nc, cpb, alpha, *, spec, m, p, g):
    """The preemption phase as one traced pipeline: overlay ``p`` patch
    rows (view deltas + unflushed dirty rows), then `_sorting_winner` —
    a single dispatch and a single int32[`WIN_FIELDS`] readback."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[:p], pbuf)
    return _sorting_winner(nodestate, victims, drain, aux[p:],
                           thresh, ng, nc, cpb, alpha, spec=spec, m=m, g=g)


def _plan2_pipeline(nodestate, victims, drain, aux, pbuf,
                    thresh, ng, nc, cpb, alpha, *, spec, m, p, g):
    """BOTH cycles of Algorithm 1 as one traced program.

    Overlay ``p`` patch rows, then the normal-cycle argmin + winner
    placement (`placement_jax.normal_cycle_core`) over ALL nodes; the
    preemptive `_sorting_winner` chain runs under ``lax.cond`` ONLY when
    the normal cycle found nothing, so the common no-preemption case pays
    the small placement scorer, not the 2^m subset sweep.  (Normal-only
    plans — ``allow_preempt=False`` — take the cheaper `normal_evaluator`
    instead of this program.)  Returns int32[5 + `WIN_FIELDS`]: the
    normal winner (found, node, tier, gpu_mask, cg_mask) followed by the
    preemption winner."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[:p], pbuf)
    norm = normal_cycle_core(nodestate, ng, nc, cpb, spec=spec)

    def _skip(_):
        return jnp.zeros(WIN_FIELDS, jnp.int32)

    def _preempt(_):
        return _sorting_winner(nodestate, victims, drain, aux[p:],
                               thresh, ng, nc, cpb, alpha,
                               spec=spec, m=m, g=g)

    pre = jax.lax.cond(norm[0] > 0, _skip, _preempt, None)
    return jnp.concatenate([norm, pre])


@lru_cache(maxsize=None)
def resident_evaluator(spec: ServerSpec, m: int, p: int, g: int,
                       thresh: int, ng: int, nc: int, cpb: int,
                       alpha: float):
    """jit of `_plan_pipeline` with the REQUEST BAKED IN as python scalars.

    Single-request plans specialize per (preemptor class, alpha) so XLA
    constant-folds the unused tier branches and the Eq. 1 weighting —
    measurably cheaper per dispatch than the traced-scalar variant, and
    workload classes are few so the jit cache stays small.  The vmapped
    `batch_class_evaluator` keeps the request dynamic (it is the vmap
    axis)."""

    def f(nodestate, victims, drain, aux, pbuf):
        return _plan_pipeline(nodestate, victims, drain, aux, pbuf,
                              thresh, ng, nc, cpb, alpha,
                              spec=spec, m=m, p=p, g=g)

    return jax.jit(f)


@lru_cache(maxsize=None)
def plan_evaluator(spec: ServerSpec, m: int, p: int, g: int,
                   thresh: int, ng: int, nc: int, cpb: int,
                   alpha: float):
    """jit of `_plan2_pipeline` (normal cycle chained into sourcing),
    request baked in as in `resident_evaluator` — the whole
    ``schedule_or_preempt`` hot path is this one dispatch."""

    def f(nodestate, victims, drain, aux, pbuf):
        return _plan2_pipeline(nodestate, victims, drain, aux, pbuf,
                               thresh, ng, nc, cpb, alpha,
                               spec=spec, m=m, p=p, g=g)

    return jax.jit(f)


def _normal_pipeline(nodestate, aux, pbuf, ng, nc, cpb, *, spec, p):
    """Nodestate-only patch overlay + the normal-cycle scorer."""
    if p:
        nodestate = _overlay_ns(nodestate, aux[:p], pbuf)
    return normal_cycle_core(nodestate, ng, nc, cpb, spec=spec)


@lru_cache(maxsize=None)
def normal_evaluator(spec: ServerSpec, p: int, ng: int, nc: int, cpb: int):
    """jit of `_normal_pipeline`.

    The batch sessions use this as their per-plan normal cycle (one small
    [NODE_FIELDS, N] dispatch instead of the host python node loop)."""

    def f(nodestate, aux, pbuf):
        return _normal_pipeline(nodestate, aux, pbuf, ng, nc, cpb,
                                spec=spec, p=p)

    return jax.jit(f)


def _gathered_pipeline(nodestate, victims, drain, pidx, pbuf, gidx,
                       thresh, ng, nc, cpb, alpha, *, spec, m, p):
    """Patch overlay, then DEVICE-SIDE gather of the rows named by
    ``gidx`` (wide nodes, or a batch plan's delta nodes) and the fused
    pipeline over just those rows.  ``IDX_SENTINEL`` entries gather zero
    rows whose sentinel node id can never win."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             pidx, pbuf)
    ns = jnp.take(nodestate, gidx, axis=1, mode="fill", fill_value=0)
    vv = jnp.take(victims, gidx, axis=1, mode="fill", fill_value=0)
    dd = jnp.take(drain, gidx, axis=1, mode="fill", fill_value=0)
    ns = ns.at[NS_NODE_ID].set(gidx)
    cls = _fused_class_core(ns, vv, dd, thresh, ng, nc, cpb, alpha,
                            spec=spec, m=m, narrow_gate=False)
    win = _fused_argmax_core(ns[NS_NODE_ID], cls, alpha)
    return winner_place(win, ns[NS_FREE_GPU], ns[NS_FREE_CG],
                        vv[VF_GPU], vv[VF_CG], ng, nc, cpb, spec=spec)


@lru_cache(maxsize=None)
def gathered_evaluator(spec: ServerSpec, m: int, p: int,
                       thresh: int, ng: int, nc: int, cpb: int,
                       alpha: float):
    """jit of `_gathered_pipeline`, request baked in as in
    `resident_evaluator`."""

    def f(nodestate, victims, drain, pidx, pbuf, gidx):
        return _gathered_pipeline(nodestate, victims, drain, pidx, pbuf,
                                  gidx, thresh, ng, nc, cpb, alpha,
                                  spec=spec, m=m, p=p)

    return jax.jit(f)


# ---------------------------------------------------------------------------------
# Two-stage shortlist sourcing: equivalence-class prescreen + top-K exact sweep
# ---------------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShortlistConfig:
    """Knobs of the two-stage shortlist sourcing front-end.

    ``k`` is the number of representative rows the stage-1 prescreen keeps
    for the exact stage-2 subset sweep.  ``mode``:

    * ``"guaranteed"`` — bit-identical decisions to the full sweep: the
      prescreen bound is admissible, and whenever the in-dispatch
      certainty check cannot PROVE the winner beats every excluded row's
      upper bound, the caller re-dispatches the full sweep.
    * ``"best_effort"`` — fixed-K latency cap: the shortlist winner is
      returned even when uncertain (admission control under a latency
      SLO; the winner is still an exactly-evaluated feasible candidate,
      merely not provably the global argmax).
    """

    k: int = 128
    mode: str = "guaranteed"

    def __post_init__(self) -> None:
        if self.k <= 0:
            raise ValueError(f"shortlist k must be positive, got {self.k}")
        if self.mode not in ("guaranteed", "best_effort"):
            raise ValueError(f"unknown shortlist mode {self.mode!r}")


def _prescreen_core(nodestate, victims, drain, rep,
                    thresh, ng, nc, cpb, alpha, *, spec, k):
    """Stage 1: admissible per-row Eq. 2 upper bound + top-K selection.

    Per row, from the resident aggregates alone (no subset axis): free the
    ENTIRE eligible victim prefix at once and tier the result — any real
    subset frees a sub-mask, and the tier score is monotone in freed
    resources; take ``1/max(min eligible priority, 1)`` as the priority
    term — any non-empty subset's priority sum is at least the minimum.
    Both terms therefore upper-bound every subset's exact score (the empty
    subset is bounded by its own EXACT score from the free masks).  The
    same combination of f32 ops as the argmax keeps the bound monotone
    under rounding.

    Rows gated out (`rep` = False non-representatives, wide/overflow rows
    the host re-dispatches, sentinel padding, bound -inf) never enter the
    shortlist.  Returns ``(gidx int32[k], excl_ub f32[])``: the gather
    indices of the top-K surviving rows (sentinel-padded so short fills
    gather dead rows) and the best bound left OUTSIDE the shortlist — the
    stage-2 certainty reference.
    """
    free_gpu = nodestate[NS_FREE_GPU]
    free_cg = nodestate[NS_FREE_CG]
    node_ids = nodestate[NS_NODE_ID]
    overflow = nodestate[NS_OVERFLOW] != 0
    next_prio = nodestate[NS_NEXT_PRIO]
    vg = victims[VF_GPU]
    vc = victims[VF_CG]
    vp = victims[VF_PRIO]
    stored = victims[VF_STORED] != 0

    consts = spec_constants(spec)
    numa_g = consts["numa_gpu_masks"]
    numa_c = consts["numa_cg_masks"]
    sock_onehot = consts["sock_onehot"]

    elig = stored & (vp < thresh)                            # [N, cap]
    elig_n = jnp.sum(elig.astype(jnp.int32), axis=1)         # [N]
    # victim masks are pairwise disjoint and disjoint from free: freeing
    # the whole eligible prefix is a sum, same trick as the subset fold
    eg = free_gpu + jnp.sum(jnp.where(elig, vg, 0), axis=1)
    ec = free_cg + jnp.sum(jnp.where(elig, vc, 0), axis=1)
    cnt_g = jax.lax.population_count(eg[:, None] & numa_g[None, :])
    cnt_c = jax.lax.population_count(ec[:, None] & numa_c[None, :])
    et = _tier_from_counts_dyn(cnt_g, cnt_c, sock_onehot, ng, nc, cpb)
    cnt_fg = jax.lax.population_count(free_gpu[:, None] & numa_g[None, :])
    cnt_fc = jax.lax.population_count(free_cg[:, None] & numa_c[None, :])
    ft = _tier_from_counts_dyn(cnt_fg, cnt_fc, sock_onehot, ng, nc, cpb)

    tier_vals = jnp.asarray(tuple(TIER_SCORES) + (0.0,), jnp.float32)
    min_p = jnp.min(jnp.where(elig, vp, _INT32_MAX), axis=1)
    pterm = jnp.where(min_p > 0,
                      1.0 / jnp.maximum(min_p, 1).astype(jnp.float32), 1.0)
    neg = jnp.float32(-jnp.inf)
    # k=0: the empty subset's score is exact (prio term is 1.0 by
    # definition); k>0: tier of the all-eligible-freed masks + min-prio term
    ub0 = jnp.where(ft < 3, alpha * 1.0 + (1.0 - alpha) * tier_vals[ft], neg)
    ubk = jnp.where((elig_n > 0) & (et < 3),
                    alpha * pterm + (1.0 - alpha) * tier_vals[et], neg)
    ub = jnp.maximum(ub0, ubk)

    ok = ((node_ids < _INT32_MAX) & rep & (elig_n <= NARROW_M)
          & ~(overflow & (next_prio < thresh)) & (ub > neg))
    ubm = jnp.where(ok, ub, neg)
    topv, topi = jax.lax.top_k(ubm, k)      # ties break toward lower index
    live = topv > neg
    gidx = jnp.where(live, topi, _INT32_MAX).astype(jnp.int32)
    selm = jnp.zeros(ubm.shape[0], bool).at[topi].set(live)
    excl_ub = jnp.max(jnp.where(ok & ~selm, ub, neg))
    return gidx, excl_ub


def _shortlist_winner(nodestate, victims, drain, rep,
                      thresh, ng, nc, cpb, alpha, *, spec, k):
    """Prescreen → gather K rows → exact sweep → certainty check.

    Stage 2 is the `NARROW_M`-wide exact pipeline over just the gathered
    rows (the prescreen's ``elig <= NARROW_M`` gate makes the width
    sufficient, so the mid tier needs no separate dispatch).  Returns
    int32[`WIN_FIELDS` + 2]: the placed winner vector followed by the
    winner's REAL node id (the argmax row indexes the gathered axis) and
    the certainty flag — 1 iff the winner's exact score STRICTLY beats
    the best admissible bound left outside the shortlist (or, with no
    winner, iff nothing was left outside), which proves the full sweep
    could not have decided differently.
    """
    gidx, excl_ub = _prescreen_core(nodestate, victims, drain, rep,
                                    thresh, ng, nc, cpb, alpha,
                                    spec=spec, k=k)
    ns = jnp.take(nodestate, gidx, axis=1, mode="fill", fill_value=0)
    vv = jnp.take(victims, gidx, axis=1, mode="fill", fill_value=0)
    dd = jnp.take(drain, gidx, axis=1, mode="fill", fill_value=0)
    ns = ns.at[NS_NODE_ID].set(gidx)
    cls = _fused_class_core(ns, vv, dd, thresh, ng, nc, cpb, alpha,
                            spec=spec, m=NARROW_M, narrow_gate=False)
    win = _fused_argmax_core(ns[NS_NODE_ID], cls, alpha)
    placed = winner_place(win, ns[NS_FREE_GPU], ns[NS_FREE_CG],
                          vv[VF_GPU], vv[VF_CG], ng, nc, cpb, spec=spec)
    found = win[0] > 0
    tier_vals = jnp.asarray(tuple(TIER_SCORES), jnp.float32)
    pp = win[4]
    prio_term = jnp.where(pp > 0,
                          1.0 / jnp.maximum(pp, 1).astype(jnp.float32), 1.0)
    wscore = alpha * prio_term + (1.0 - alpha) * tier_vals[win[2]]
    certain = jnp.where(found, wscore > excl_ub,
                        excl_ub == jnp.float32(-jnp.inf))
    node_id = jnp.where(found, gidx[win[1]], jnp.int32(-1))
    return jnp.concatenate([placed, jnp.stack([node_id,
                                               certain.astype(jnp.int32)])])


def _shortlist_pipeline(nodestate, victims, drain, rep, aux, pbuf,
                        thresh, ng, nc, cpb, alpha, *, spec, k, p, f):
    """Overlay ``p`` patch rows, force ``f`` rep-mask corrections (patched
    rows carry stale fingerprints: the rows themselves plus the promoted
    lowest unpatched member of each patched row's old class), then the
    two-stage `_shortlist_winner` — one dispatch, one small readback."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[:p], pbuf)
    if f:
        rep = rep.at[aux[p:p + f]].set(True, mode="drop")
    return _shortlist_winner(nodestate, victims, drain, rep,
                             thresh, ng, nc, cpb, alpha, spec=spec, k=k)


def _shortlist_plan2_pipeline(nodestate, victims, drain, rep, aux, pbuf,
                              thresh, ng, nc, cpb, alpha, *, spec, k, p, f):
    """`_plan2_pipeline`'s shortlisted twin: normal cycle first, the
    two-stage preemptive chain only under ``lax.cond`` when it found
    nothing.  Returns int32[5 + `WIN_FIELDS` + 2]."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[:p], pbuf)
    if f:
        rep = rep.at[aux[p:p + f]].set(True, mode="drop")
    norm = normal_cycle_core(nodestate, ng, nc, cpb, spec=spec)

    def _skip(_):
        return jnp.zeros(WIN_FIELDS + 2, jnp.int32)

    def _preempt(_):
        return _shortlist_winner(nodestate, victims, drain, rep,
                                 thresh, ng, nc, cpb, alpha,
                                 spec=spec, k=k)

    pre = jax.lax.cond(norm[0] > 0, _skip, _preempt, None)
    return jnp.concatenate([norm, pre])


@lru_cache(maxsize=None)
def shortlist_evaluator(spec: ServerSpec, k: int, p: int, f: int,
                        thresh: int, ng: int, nc: int, cpb: int,
                        alpha: float):
    """jit of `_shortlist_pipeline`, request baked in as in
    `resident_evaluator`."""

    def fn(nodestate, victims, drain, rep, aux, pbuf):
        return _shortlist_pipeline(nodestate, victims, drain, rep, aux,
                                   pbuf, thresh, ng, nc, cpb, alpha,
                                   spec=spec, k=k, p=p, f=f)

    return jax.jit(fn)


@lru_cache(maxsize=None)
def shortlist_plan_evaluator(spec: ServerSpec, k: int, p: int, f: int,
                             thresh: int, ng: int, nc: int, cpb: int,
                             alpha: float):
    """jit of `_shortlist_plan2_pipeline` — the shortlisted
    ``schedule_or_preempt`` hot path in one dispatch."""

    def fn(nodestate, victims, drain, rep, aux, pbuf):
        return _shortlist_plan2_pipeline(nodestate, victims, drain, rep,
                                         aux, pbuf, thresh, ng, nc, cpb,
                                         alpha, spec=spec, k=k, p=p, f=f)

    return jax.jit(fn)


@lru_cache(maxsize=None)
def batch_class_evaluator(spec: ServerSpec, m: int, alpha: float):
    """jit(vmap) of the class core over a REQUEST axis: one dispatch
    computes every request's per-node class winners against one snapshot.
    The request scalars are the vmap axis (necessarily dynamic); alpha is
    shared across the batch and baked in."""

    def f(nodestate, victims, drain, thresh, ng, nc, cpb):
        return _fused_class_core(nodestate, victims, drain, thresh, ng, nc,
                                 cpb, alpha, spec=spec, m=m,
                                 narrow_gate=True)

    return jax.jit(jax.vmap(f, in_axes=(None, None, None, 0, 0, 0, 0)))


def _masked_class_winner(anyc, cb, pp, um, kn, cnt, nodestate, victims,
                         drain, i, didx, gidx,
                         thresh, ng, nc, cpb, alpha, *, spec, m, g):
    """Masked-class merge shared by the batch evaluators.

    Masks the ``didx`` delta rows out of request ``i``'s precomputed class
    tensors, evaluates the ``g`` gathered rows (dense delta rows plus the
    untouched mid-tier rows the gate excluded) at slot width ``m`` against
    the ALREADY-OVERLAID resident tensors, and reduces everything through
    the Eq. 2 argmax + winner placement.  Class-data rows that can win are
    non-delta rows, where the overlaid arrays equal the raw resident state
    — safe placement inputs."""
    n = anyc.shape[1]
    mask = jnp.ones(n, bool).at[didx].set(False, mode="drop")
    cls = ClassWinners(anyc[i] & mask[:, None], cb[i], pp[i], um[i],
                       kn[i], cnt[i] * mask)
    node_ids = jnp.arange(n, dtype=jnp.int32)
    fg_cat = nodestate[NS_FREE_GPU]
    fc_cat = nodestate[NS_FREE_CG]
    vg_cat = victims[VF_GPU]
    vc_cat = victims[VF_CG]
    if g:
        ns = jnp.take(nodestate, gidx, axis=1, mode="fill", fill_value=0)
        vv = jnp.take(victims, gidx, axis=1, mode="fill", fill_value=0)
        dd = jnp.take(drain, gidx, axis=1, mode="fill", fill_value=0)
        ns = ns.at[NS_NODE_ID].set(gidx)
        cls_g = _fused_class_core(ns, vv, dd, thresh, ng, nc, cpb,
                                  alpha, spec=spec, m=m,
                                  narrow_gate=False)
        cls = ClassWinners(*(jnp.concatenate([a, b])
                             for a, b in zip(cls, cls_g)))
        node_ids = jnp.concatenate([node_ids, ns[NS_NODE_ID]])
        fg_cat = jnp.concatenate([fg_cat, ns[NS_FREE_GPU]])
        fc_cat = jnp.concatenate([fc_cat, ns[NS_FREE_CG]])
        vg_cat = jnp.concatenate([vg_cat, vv[VF_GPU]])
        vc_cat = jnp.concatenate([vc_cat, vv[VF_CG]])
    win = _fused_argmax_core(node_ids, cls, alpha)
    return winner_place(win, fg_cat, fc_cat, vg_cat, vc_cat,
                        ng, nc, cpb, spec=spec)


def _batch_merge_pipeline(anyc, cb, pp, um, kn, cnt, nodestate, victims,
                          drain, i, aux, pbuf, thresh, ng, nc, cpb, alpha,
                          *, spec, m, dpad, g):
    """Patch overlay + `_masked_class_winner` (the batch merge body).

    ``aux`` layout: ``[:dpad]`` mask rows, then the patch rows (``pbuf``
    row order matches), then the gather rows."""
    p = pbuf.shape[0]
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[dpad:dpad + p], pbuf)
    return _masked_class_winner(
        anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i,
        aux[:dpad], aux[dpad + p:], thresh, ng, nc, cpb, alpha,
        spec=spec, m=m, g=g)


@lru_cache(maxsize=None)
def batch_merge_evaluator(spec: ServerSpec, m: int, dpad: int, g: int,
                          thresh: int, ng: int, nc: int, cpb: int,
                          alpha: float):
    """Per-request device merge for the batch session, ONE dispatch.

    jit of `_batch_merge_pipeline`: a batched plan whose deltas are all
    narrow costs exactly one dispatch and one int32[`WIN_FIELDS`]
    readback, like a single-request plan."""

    def f(anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i, aux,
          pbuf):
        return _batch_merge_pipeline(
            anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i, aux,
            pbuf, thresh, ng, nc, cpb, alpha, spec=spec, m=m, dpad=dpad,
            g=g)

    return jax.jit(f)


def _batch_plan_pipeline(anyc, cb, pp, um, kn, cnt, nodestate, victims,
                         drain, i, aux, pbuf, thresh, ng, nc, cpb, alpha,
                         *, spec, m, dpad, g, p):
    """`_batch_merge_pipeline` with the NORMAL CYCLE chained in front.

    The ``p`` patch rows cover EVERY delta row of the view (wide and
    overflow rows included) so the normal-cycle scorer sees the plan's
    exact free masks; the masked-class preemptive merge runs under
    ``lax.cond`` only when the normal cycle places nothing.  Returns
    int32[5 + `WIN_FIELDS`]."""
    if p:
        nodestate, victims, drain = _overlay(nodestate, victims, drain,
                                             aux[dpad:dpad + p], pbuf)
    norm = normal_cycle_core(nodestate, ng, nc, cpb, spec=spec)

    def _skip(_):
        return jnp.zeros(WIN_FIELDS, jnp.int32)

    def _pre(_):
        return _masked_class_winner(
            anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i,
            aux[:dpad], aux[dpad + p:], thresh, ng, nc, cpb, alpha,
            spec=spec, m=m, g=g)

    return jnp.concatenate([norm, jax.lax.cond(norm[0] > 0, _skip,
                                               _pre, None)])


@lru_cache(maxsize=None)
def batch_plan_evaluator(spec: ServerSpec, m: int, dpad: int, g: int,
                         p: int, thresh: int, ng: int, nc: int, cpb: int,
                         alpha: float):
    """jit of `_batch_plan_pipeline` — a batched plan is one dispatch end
    to end, same as a single-request plan."""

    def f(anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i, aux,
          pbuf):
        return _batch_plan_pipeline(
            anyc, cb, pp, um, kn, cnt, nodestate, victims, drain, i, aux,
            pbuf, thresh, ng, nc, cpb, alpha, spec=spec, m=m, dpad=dpad,
            g=g, p=p)

    return jax.jit(f)


#: node-axis chunk size for the widest (m=16) victim bucket: keeps the
#: [chunk, 2^16, U] popcount intermediates to tens of MB per dispatch.
MAX_ROWS_WIDE = 16


class CandidateShortlist(list):
    """``list[Candidate]`` that also reports the TRUE candidate count.

    The fused engine returns only per-dispatch winners, but the device
    already counted every feasible min-k subset; ``n_candidates`` carries
    that count so ``SchedulingDecision.num_candidates`` stays comparable
    with the exhaustive-listing engines.

    ``placements`` maps ``(node, victims)`` of device-decoded winners to
    the concrete `Placement` the dispatch's §3.4 scorer committed — the
    scheduler binds those masks directly instead of re-running the host
    ``place()`` on the winning node (python-fallback candidates have no
    entry and keep the host path).
    """

    n_candidates: int = 0

    def __init__(self, *args) -> None:
        super().__init__(*args)
        self.placements: dict[tuple[int, tuple[int, ...]], Placement] = {}


def _req_scalars(spec: ServerSpec, workload: WorkloadSpec):
    """(need_gpus, need_cgs, cgs_per_bundle) for the dynamic-request cores."""
    ng = workload.gpus_per_instance
    nc = workload.coregroups_per_instance(spec.coregroup_size)
    bundle = workload.numa_policy == TopoPolicy.GUARANTEED
    return ng, nc, (nc // ng if (bundle and ng) else 0)


@lru_cache(maxsize=None)
def _empty_patch_args(cap: int):
    """Cached zero-size device patch arrays for the p=0 (no view deltas)
    fast path — the common case allocates nothing per plan."""
    _, pidx, pbuf = _pack_patches({}, cap)
    return jnp.asarray(pidx), jnp.asarray(pbuf)


def _evals(dcs: DeviceClusterState):
    """Evaluator-factory namespace for this device state.

    Single-device states use THIS module's jit factories; a mesh-sharded
    state (`cluster_parallel.ShardedDeviceClusterState`) routes to
    `cluster_parallel.sharded_evaluators`, which jits the SAME pipeline
    bodies with explicit `NamedSharding` constraints — node-axis tensors
    arrive sharded, patch/index uploads replicate, and the winner vector
    comes back replicated, so per-node math stays shard-local and only the
    final argmax chain crosses shards."""
    mesh = getattr(dcs, "mesh", None)
    if mesh is None:
        return sys.modules[__name__]
    from . import cluster_parallel

    return cluster_parallel.sharded_evaluators(mesh)


def _patch_row(patches, node: int) -> VictimRow:
    """Exact host row for one delta node (`ViewDelta` encodes lazily)."""
    if isinstance(patches, ViewDelta):
        return patches.row(node)
    return patches[node]


def _patch_elig(patches, thresh: int):
    """``(eligible-count, truncation-risk)`` dicts over the delta nodes.

    `ViewDelta` computes its dense rows vectorized from the descriptor
    metadata (no per-node host encode); plain `VictimRow` dicts are read
    row by row."""
    if isinstance(patches, ViewDelta):
        return patches.elig_bad(thresh)
    return ({n: int(((r.vp < thresh) & r.stored).sum())
             for n, r in patches.items()},
            {n: bool(r.overflow and r.next_priority < thresh)
             for n, r in patches.items()})


def _patch_args(dcs: DeviceClusterState, patches):
    """One overlay buffer covering the view's delta rows (``patches``) AND
    the device state's unflushed ``pending`` rows (``sync(flush=False)``):
    both classes of stale row ride the same in-dispatch scatter, so the
    plan hot path pays ONE host→device upload and zero standalone scatter
    dispatches.

    ``patches`` is a ``{node: VictimRow}`` dict (legacy / batch-session
    paths) or a `ViewDelta`: its dense rows are then rebuilt ON DEVICE by
    the delta encoder and only fallback rows are host-packed.  Returns
    ``(p, pidx, pbuf)`` — ``pidx`` always host int32 (it travels inside
    the aux upload); ``pbuf`` may already live on device."""
    cap = dcs.cap
    width = NODE_FIELDS + VICTIM_FIELDS * cap + DRAIN_FIELDS
    pending = sorted(set(dcs.pending) - set(patches))
    dense = None
    bufs, ids = [], []
    if isinstance(patches, ViewDelta):
        dense = patches.device_rows(dcs)
        if patches.fallback:
            nodes = sorted(patches.fallback)
            bufs.append(flatten_rows(*pack_rows(
                [patches.fallback[n] for n in nodes], nodes, cap)))
            ids.extend(nodes)
    elif patches:
        nodes = sorted(patches)
        bufs.append(flatten_rows(
            *pack_rows([patches[n] for n in nodes], nodes, cap)))
        ids.extend(nodes)
    if pending:
        bufs.append(flatten_rows(*pack_context_rows(dcs.mirror, pending)))
        ids.extend(pending)
    hidx = hbuf = None
    if ids:
        hidx = _pad_idx(ids)
        hbuf = np.concatenate(bufs) if len(bufs) > 1 else bufs[0]
        if len(hidx) > len(ids):
            hbuf = np.pad(hbuf, ((0, len(hidx) - len(ids)), (0, 0)))
    if dense is None:
        if hbuf is None:
            return 0, np.zeros(0, np.int32), np.zeros((0, width), np.int32)
        return len(hidx), hidx, hbuf
    didx, dbuf = dense
    if hbuf is None:
        return len(didx), didx, dbuf
    # disjoint node sets by construction; sentinel pads drop out of the
    # overlay scatter, so per-section pow2 buckets concatenate directly
    idx = np.concatenate([didx, hidx])
    return len(idx), idx, jnp.concatenate([dbuf, jnp.asarray(hbuf)])


def _pad_idx(ids, floor: int = 4) -> np.ndarray:
    """`cluster.pad_idx` with the dispatch paths' minimum bucket of 4."""
    return pad_idx(ids, floor)


def _pack_patches(patches: dict[int, VictimRow], cap: int):
    """Pack view-delta rows for the in-dispatch overlay.

    Returns ``(p, pidx, pbuf)`` — one flattened int32 upload buffer (see
    `flatten_rows`) padded to a power-of-two bucket (sentinel indices are
    dropped by the scatter); ``p`` = 0 when there are no patches, selecting
    the overlay-free jit variant."""
    width = NODE_FIELDS + VICTIM_FIELDS * cap + DRAIN_FIELDS
    if not patches:
        return 0, np.zeros(0, np.int32), np.zeros((0, width), np.int32)
    nodes = sorted(patches)
    buf = flatten_rows(*pack_rows([patches[n] for n in nodes], nodes, cap))
    idx = _pad_idx(nodes)
    if len(idx) > len(nodes):
        buf = np.pad(buf, ((0, len(idx) - len(nodes)), (0, 0)))
    return len(idx), idx, buf


class FusedSplit(NamedTuple):
    """Host routing decision for one fused sourcing call.

    ``m_res`` is the victim-slot width of the MAIN dispatch (adaptive: 4
    when only a handful of rows hold more than 4 eligible victims — a
    16-combo program is ~4x cheaper than the 256-combo one); ``mid`` holds
    the rows with m_res < eligible <= `NARROW_M` (gathered m=8 chunks),
    ``wide`` the 9..16-eligible rows (gathered 2^16-subset chunks) and
    ``overflow`` the truncated rows whose eligible victims extend past the
    stored prefix (per-node python fallback)."""

    m_res: int
    mid: list
    wide: list
    overflow: list


#: smallest victim-slot width of the adaptive resident program
MIN_M = 4


def split_fused_nodes(dcs: DeviceClusterState, patches: dict, thresh: int,
                      nodes=None, gate: int | None = None):
    """Route rows between the main dispatch and its re-dispatch tiers.

    Eligible victims are a prefix of each (priority, uid)-sorted row, so
    every row is classified by one vectorized count over the host mirror
    (patched rows overridden).  ``gate`` pins the main-dispatch width
    (the batch session precomputes class data at `NARROW_M`); when None,
    the width adapts: `MIN_M` if at most `MAX_ROWS_WIDE` rows exceed it.
    When no node stores more than `MIN_M` victims (``dcs.count_max``) the
    whole scan is skipped.
    """
    ctx = dcs.mirror
    if isinstance(patches, ViewDelta):
        patch_big = [n for n in patches if patches.count(n) > MIN_M]
    else:
        patch_big = [n for n, r in patches.items() if r.count > MIN_M]
    if dcs.count_max <= MIN_M and not patch_big:
        return FusedSplit(MIN_M, [], [], [])
    n = dcs.cluster.num_nodes
    elig = ((ctx.vp < thresh) & ctx.stored).sum(axis=1)
    bad = ctx.overflow & (ctx.next_prio < thresh)
    p_elig, p_bad = _patch_elig(patches, thresh)
    for node, e in p_elig.items():
        elig[node] = e
    for node, b in p_bad.items():
        bad[node] = b
    if nodes is None:
        allowed = np.ones(n, bool)
    else:
        allowed = np.zeros(n, bool)
        allowed[list(nodes)] = True
    ok = allowed & ~bad
    m_res = gate
    if m_res is None:
        m_res = MIN_M if int((ok & (elig > MIN_M)).sum()) <= MAX_ROWS_WIDE \
            else NARROW_M
    mid = np.nonzero(ok & (elig > m_res) & (elig <= NARROW_M))[0].tolist()
    wide = np.nonzero(ok & (elig > NARROW_M))[0].tolist()
    overflow = np.nonzero(allowed & bad)[0].tolist()
    return FusedSplit(m_res, mid, wide, overflow)


def _append_winner(out: CandidateShortlist, res, sel_nodes, patches, ctx):
    """Decode one dispatch's int32[`WIN_FIELDS`] winner into a host
    `Candidate` plus its device-committed `Placement`.

    Dispatches run asynchronously; callers queue (res, sel_nodes) pairs and
    decode them together at the end so one device sync covers all of them.
    """
    found, row, tier, combo, prio, _k, ncand, pgm, pcm = (
        int(x) for x in jax.device_get(res))
    out.n_candidates += ncand
    if not found:
        return
    if sel_nodes is None:
        node = row                        # node axis == resident row index
    elif isinstance(sel_nodes, dict):
        node = int(sel_nodes.get(row, row))   # combined resident+mid rows
    else:
        node = int(sel_nodes[row])        # gathered chunk
    vu = _patch_row(patches, node).vu if node in patches else ctx.vu[node]
    uids = [int(vu[j]) for j in range(len(vu)) if (combo >> j) & 1]
    victims = tuple(sorted(uids))
    out.append(Candidate(node=node, victims=victims, tier=tier,
                         priority_sum=prio))
    out.placements[(node, victims)] = Placement(
        gpu_mask=pgm & 0xFFFFFFFF, cg_mask=pcm & 0xFFFFFFFF, tier=tier)


def _fast_plan_args(dcs: DeviceClusterState, patches: dict, thresh: int,
                    p: int, pidx, pbuf):
    """Routing split + device aux/patch arrays for a nodes=None dispatch.

    The delta-free case (``p`` == 0) caches per preemptor priority on the
    `DeviceClusterState`, keyed by its invalidation ``version``: repeated
    plans against unchanged state skip the host eligibility scan AND the
    per-plan host→device upload of the gather indices — the whole host
    side of a plan is then one dict lookup."""
    cached = dcs.plan_cache.get(thresh) if p == 0 else None
    if cached is not None and cached[0] == dcs.version:
        return cached[1:]
    split = split_fused_nodes(dcs, patches, thresh)
    gidx = _pad_idx(split.mid) if split.mid else np.zeros(0, np.int32)
    g = len(gidx)
    if p == 0 and g == 0:
        aux_d, pbuf_d = _empty_patch_args(dcs.cap)
    else:
        aux_d = jnp.asarray(np.concatenate([pidx, gidx]))
        pbuf_d = jnp.asarray(pbuf)
    if p == 0:
        dcs.plan_cache[thresh] = (dcs.version, split, g, aux_d, pbuf_d)
    return split, g, aux_d, pbuf_d


def _forced_rows(dcs: DeviceClusterState, patches) -> list[int]:
    """Rep-mask corrections for view-delta patch rows.

    The device rep mask is computed from the MIRROR's fingerprints, but
    patch rows are overlaid with different content in-dispatch, so (a)
    every patched row must be treated as its own (possibly new) class —
    forced into the rep set — and (b) a patched row may have been the
    representative of its old class, orphaning the unpatched members:
    promote the lowest unpatched member of each patched row's old class.
    Extra representatives only add rows to the prescreen (harmless for
    exactness); only a MISSING representative could hide the argmax, and
    these two corrections close exactly the ways one can go missing.
    Pending rows (``sync(flush=False)`` leftovers) need nothing: their
    mirror fingerprints are fresh, so the rep assignment already matches
    the content the overlay installs.
    """
    pset = {int(n) for n in patches} if patches else set()
    if not pset:
        return []
    fp = dcs.mirror.fp
    forced = set(pset)
    for d in pset:
        for mbr in np.nonzero(fp == fp[d])[0]:
            if int(mbr) not in pset:
                forced.add(int(mbr))
                break
    return sorted(forced)


def _shortlist_plan_args(dcs: DeviceClusterState, patches, thresh: int,
                         p: int, pidx, pbuf):
    """`_fast_plan_args`'s shortlist twin: wide/overflow routing split +
    forced-rep indices + the combined aux upload, cached per preemptor
    priority while the state version holds (the delta-free steady state
    pays two dict probes per plan: this and `rep_classes`)."""
    cached = dcs.plan_cache.get(("shortlist", thresh)) if p == 0 else None
    if cached is not None and cached[0] == dcs.version:
        return cached[1:]
    # gate=NARROW_M: the shortlist's stage 2 always runs NARROW_M wide, so
    # only genuinely wide (elig > NARROW_M) and overflow rows route out
    split = split_fused_nodes(dcs, patches, thresh, gate=NARROW_M)
    forced = _forced_rows(dcs, patches)
    fidx = _pad_idx(forced) if forced else np.zeros(0, np.int32)
    f = len(fidx)
    if p == 0 and f == 0:
        aux_d, pbuf_d = _empty_patch_args(dcs.cap)
    else:
        aux_d = jnp.asarray(np.concatenate([pidx, fidx]))
        pbuf_d = jnp.asarray(pbuf)
    if p == 0:
        dcs.plan_cache[("shortlist", thresh)] = (dcs.version, split, f,
                                                 aux_d, pbuf_d)
    return split, f, aux_d, pbuf_d


def source_candidates_fused(
    cluster, workload: WorkloadSpec, nodes: list[int] | None = None,
    alpha: float = DEFAULT_ALPHA, shortlist: ShortlistConfig | None = None,
) -> list[Candidate]:
    """Fused cluster-wide IMP over the device-resident state.

    ``nodes=None`` (the scheduler's ``fused_filter`` path) runs Guaranteed
    Filtering + sourcing + Eq. 2 selection over ALL nodes in one dispatch
    against `DeviceClusterState` — zero per-node host work; view deltas ride
    along as in-dispatch patch rows.  An explicit node list (legacy callers,
    per-node ``source``) gathers exactly those rows device-side instead.

    Returns the winning `Candidate` per dispatch (plus per-node python
    candidates for overflow rows) as a `CandidateShortlist` carrying the
    true evaluated-candidate count; the scheduler's ``select`` reduces the
    shortlist with the exact host-side Eq. 2.  Winner parity with ``imp``,
    ``imp_jax`` and ``imp_batched_legacy`` is covered by
    tests/test_fused_sourcing.py.
    """
    if nodes is not None and not nodes:
        return CandidateShortlist()
    spec = cluster.spec
    base = getattr(cluster, "base", cluster)
    # flush=False: small dirty sets stay pending and ride the dispatch's
    # patch overlay instead of paying a standalone scatter dispatch
    dcs = base.device_state().sync(flush=False)
    ev = _evals(dcs)
    ctx = dcs.mirror
    thresh = workload.priority
    ng, nc, cpb = _req_scalars(spec, workload)
    if nodes is None:
        patches = _view_patches_of(cluster, dcs)
    else:
        delta = set(cluster.delta_nodes()) if hasattr(cluster,
                                                      "delta_nodes") \
            else set()
        delta &= set(nodes)
        patches = {d: encode_row(cluster, d, ctx.cap) for d in sorted(delta)}
    p, pidx, pbuf = _patch_args(dcs, patches)
    req = (thresh, ng, nc, cpb, float(alpha))
    pargs = None     # (pidx, pbuf) on device, built on first gathered use
    pending = []     # dispatches are async: launch all, decode once
    if nodes is None:
        sl_vals = None
        if shortlist is not None and dcs.n_rows > shortlist.k:
            # stage 1+2 shortlist dispatch; the decoded certainty flag
            # decides whether the full sweep is still required
            split, f, aux_d, pbuf_d = _shortlist_plan_args(
                dcs, patches, thresh, p, pidx, pbuf)
            rep_dev = dcs.rep_classes()[1]
            res = ev.shortlist_evaluator(spec, shortlist.k, p, f, *req)(
                dcs.nodestate, dcs.victims, dcs.drain, rep_dev,
                aux_d, pbuf_d)
            vals = [int(x) for x in jax.device_get(res)]
            if vals[-1] or shortlist.mode != "guaranteed":
                sl_vals = vals
        if sl_vals is not None:
            mid = []     # absorbed: stage 2 always runs NARROW_M wide
            out = CandidateShortlist(_overflow_candidates(
                cluster, workload, split.overflow))
            out.n_candidates = len(out)
            pending.append((np.asarray(sl_vals[:WIN_FIELDS], np.int32),
                            {sl_vals[1]: sl_vals[WIN_FIELDS]}))
        else:
            # the whole pipeline — overlay, Filtering, m_res-wide subsets
            # over ALL rows, the gathered mid tier, and the Eq. 2 argmax —
            # is ONE dispatch; indices travel as one aux upload (cached
            # with the routing split while the state version holds)
            split, g, aux_d, pbuf_d = _fast_plan_args(dcs, patches, thresh,
                                                      p, pidx, pbuf)
            mid = split.mid
            out = CandidateShortlist(_overflow_candidates(cluster, workload,
                                                          split.overflow))
            out.n_candidates = len(out)
            res = ev.resident_evaluator(spec, split.m_res, p, g, *req)(
                dcs.nodestate, dcs.victims, dcs.drain, aux_d, pbuf_d)
            n = dcs.n_rows
            sel = ({n + j: node for j, node in enumerate(mid)}
                   if mid else None)
            pending.append((res, sel))
            mid = []     # consumed by the combined dispatch
    else:
        split = split_fused_nodes(dcs, patches, thresh, nodes)
        mid = split.mid
        out = CandidateShortlist(_overflow_candidates(cluster, workload,
                                                      split.overflow))
        out.n_candidates = len(out)
        excluded = set(mid) | set(split.wide) | set(split.overflow)
        narrow = [c for c in nodes if c not in excluded]
        if narrow:
            pargs = (jnp.asarray(pidx), jnp.asarray(pbuf))
            res = ev.gathered_evaluator(spec, split.m_res, p, *req)(
                dcs.nodestate, dcs.victims, dcs.drain, *pargs,
                jnp.asarray(_pad_idx(narrow)))
            pending.append((res, narrow))
    for m, rows in ((NARROW_M, mid), (ctx.cap, split.wide)):
        for lo in range(0, len(rows), MAX_ROWS_WIDE):
            chunk = rows[lo:lo + MAX_ROWS_WIDE]
            if pargs is None:
                pargs = (jnp.asarray(pidx), jnp.asarray(pbuf))
            res = ev.gathered_evaluator(spec, m, p, *req)(
                dcs.nodestate, dcs.victims, dcs.drain, *pargs,
                jnp.asarray(_pad_idx(chunk)))
            pending.append((res, chunk))
    for res, sel in pending:
        _append_winner(out, res, sel, patches, ctx)
    return out


# ---------------------------------------------------------------------------------
# End-to-end device-resident Algorithm 1 (normal cycle chained into sourcing)
# ---------------------------------------------------------------------------------

@dataclasses.dataclass
class FusedPlanResult:
    """Decoded outcome of one chained normal+preemptive dispatch.

    ``placement`` carries the dispatch's §3.4 device-scorer masks; a
    ``None`` placement on a preempted result (python-fallback winner)
    tells the scheduler to place on the host instead."""

    kind: str                               # placed | preempted | rejected
    node: int = -1
    placement: Placement | None = None
    victims: tuple[int, ...] = ()
    n_candidates: int = 0


def _view_patches_of(cluster, dcs: DeviceClusterState):
    """Delta-row descriptors for a ClusterView ({} for the base cluster).

    The fused ``nodes=None`` paths get a `ViewDelta`: dense rows are
    rebuilt by the IN-DISPATCH delta encoder straight from the planned
    bind/evict/restore masks the view carries, so the per-plan host work
    is O(delta instances) descriptor math — no ``encode_row`` victim sort
    per dirty row, and patch rows never round-trip through python."""
    if not hasattr(cluster, "delta_nodes"):
        return {}
    return ViewDelta(cluster, dcs.mirror, dcs.pending)


def plan_normal_fused(cluster, workload: WorkloadSpec):
    """The normal scheduling cycle as ONE small device dispatch.

    `placement_jax.normal_cycle_core` over the resident nodestate (view
    deltas and unflushed dirty rows overlaid in-dispatch): the host's
    ``_plan_normal`` python node loop and per-node ``place()`` collapse to
    a [NODE_FIELDS, N] program returning the winner's node and concrete
    masks.  Returns ``(node, Placement)`` or ``None`` — the batch sessions'
    per-plan normal cycle.
    """
    spec = cluster.spec
    base = getattr(cluster, "base", cluster)
    dcs = base.device_state().sync(flush=False)
    ev = _evals(dcs)
    patches = _view_patches_of(cluster, dcs)
    p, pidx, pbuf = _patch_args(dcs, patches)
    ng, nc, cpb = _req_scalars(spec, workload)
    if p == 0:
        aux_d, pbuf_d = _empty_patch_args(dcs.cap)
    else:
        aux_d, pbuf_d = jnp.asarray(pidx), jnp.asarray(pbuf)
    res = ev.normal_evaluator(spec, p, ng, nc, cpb)(dcs.nodestate, aux_d,
                                                    pbuf_d)
    found, node, tier, gm, cm = (int(x) for x in jax.device_get(res))
    if not found:
        return None
    return node, Placement(gpu_mask=gm & 0xFFFFFFFF,
                           cg_mask=cm & 0xFFFFFFFF, tier=tier)


def _finalize_plan(vals, sel, patches, ctx, shortlist_fn, wide_chunks_fn,
                   alpha: float) -> FusedPlanResult:
    """Shared decode of a chained dispatch's int32[5 + WIN_FIELDS] readback.

    ``shortlist_fn`` builds the base `CandidateShortlist` (python-fallback
    overflow candidates) and ``wide_chunks_fn`` yields the chunked wide-row
    re-dispatches as ``(res, chunk)`` pairs — both LAZY, consumed only when
    the normal cycle placed nothing, so a placed plan never pays for them.
    """
    nfound, nnode, ntier, ngm, ncm = vals[:5]
    if nfound:
        return FusedPlanResult("placed", nnode, Placement(
            gpu_mask=ngm & 0xFFFFFFFF, cg_mask=ncm & 0xFFFFFFFF,
            tier=ntier))
    out = shortlist_fn()
    _append_winner(out, np.asarray(vals[5:], np.int32), sel, patches, ctx)
    for res, chunk in wide_chunks_fn():
        _append_winner(out, res, chunk, patches, ctx)
    if not out:
        return FusedPlanResult("rejected", n_candidates=out.n_candidates)
    chosen = select_best(out, alpha)
    return FusedPlanResult(
        "preempted", chosen.node,
        out.placements.get((chosen.node, chosen.victims)),
        chosen.victims, out.n_candidates)


def _plan_fused_shortlist(cluster, workload: WorkloadSpec,
                          dcs: DeviceClusterState, ev, ctx, patches,
                          p: int, pidx, pbuf, alpha: float,
                          shortlist: ShortlistConfig):
    """The shortlisted chained plan: one `_shortlist_plan2_pipeline`
    dispatch + decode.  Returns None when the certainty check failed in
    guaranteed mode — the caller then re-dispatches the full sweep (the
    resident tensors and patch buffers are already in place, so the
    fallback costs one extra dispatch, no host rework)."""
    spec = cluster.spec
    thresh = workload.priority
    ng, nc, cpb = _req_scalars(spec, workload)
    req = (thresh, ng, nc, cpb, float(alpha))
    split, f, aux_d, pbuf_d = _shortlist_plan_args(dcs, patches, thresh,
                                                   p, pidx, pbuf)
    rep_dev = dcs.rep_classes()[1]
    res = ev.shortlist_plan_evaluator(spec, shortlist.k, p, f, *req)(
        dcs.nodestate, dcs.victims, dcs.drain, rep_dev, aux_d, pbuf_d)
    vals = [int(x) for x in jax.device_get(res)]
    if (not vals[0] and not vals[-1]
            and shortlist.mode == "guaranteed"):
        return None
    # the argmax row indexes the gathered K axis; the readback carries the
    # real node id alongside
    sel = {vals[6]: vals[5 + WIN_FIELDS]}

    def shortlist_out():
        out = CandidateShortlist(_overflow_candidates(cluster, workload,
                                                      split.overflow))
        out.n_candidates = len(out)
        return out

    def wide_chunks():
        for lo in range(0, len(split.wide), MAX_ROWS_WIDE):
            chunk = split.wide[lo:lo + MAX_ROWS_WIDE]
            yield ev.gathered_evaluator(spec, ctx.cap, p, *req)(
                dcs.nodestate, dcs.victims, dcs.drain,
                jnp.asarray(pidx), jnp.asarray(pbuf),
                jnp.asarray(_pad_idx(chunk))), chunk

    return _finalize_plan(vals[:5 + WIN_FIELDS], sel, patches, ctx,
                          shortlist_out, wide_chunks, float(alpha))


def plan_fused(cluster, workload: WorkloadSpec, alpha: float = DEFAULT_ALPHA,
               allow_preempt: bool = True,
               shortlist: ShortlistConfig | None = None) -> FusedPlanResult:
    """BOTH cycles of Algorithm 1 as one device dispatch (engine hook for
    ``fused_place`` scheduling).

    The chained program (`plan_evaluator`) overlays view deltas, runs the
    normal-cycle argmin + placement scorer over ALL nodes and — only when
    that finds nothing, via ``lax.cond`` — Guaranteed Filtering, the
    subset sweep, the Eq. 2 argmax, and the winner's placement.  One
    ``int32[5 + WIN_FIELDS]`` readback decides the whole plan; rare wide
    (9..16-eligible) rows re-dispatch chunked afterwards and truncated
    overflow rows fall back to per-node python, exactly like
    `source_candidates_fused`.

    With a `ShortlistConfig` (and more rows than ``k``) the preemptive
    chain runs the two-stage shortlist program instead: equivalence-class
    + top-K prescreen, exact sweep over K gathered rows.  In guaranteed
    mode a failed certainty check falls back to the full sweep below, so
    decisions stay bit-identical to ``shortlist=None``.
    """
    if not allow_preempt:
        got = plan_normal_fused(cluster, workload)
        if got is None:
            return FusedPlanResult("rejected")
        return FusedPlanResult("placed", got[0], got[1])
    spec = cluster.spec
    base = getattr(cluster, "base", cluster)
    dcs = base.device_state().sync(flush=False)
    ev = _evals(dcs)
    ctx = dcs.mirror
    thresh = workload.priority
    ng, nc, cpb = _req_scalars(spec, workload)
    patches = _view_patches_of(cluster, dcs)
    p, pidx, pbuf = _patch_args(dcs, patches)
    if shortlist is not None and dcs.n_rows > shortlist.k:
        got = _plan_fused_shortlist(cluster, workload, dcs, ev, ctx,
                                    patches, p, pidx, pbuf, alpha,
                                    shortlist)
        if got is not None:
            return got
        # guaranteed-mode certainty check failed: full sweep decides
    split, g, aux_d, pbuf_d = _fast_plan_args(dcs, patches, thresh,
                                              p, pidx, pbuf)
    mid = split.mid
    req = (thresh, ng, nc, cpb, float(alpha))
    res = ev.plan_evaluator(spec, split.m_res, p, g, *req)(
        dcs.nodestate, dcs.victims, dcs.drain, aux_d, pbuf_d)
    vals = [int(x) for x in jax.device_get(res)]
    n = dcs.n_rows
    sel = {n + j: node for j, node in enumerate(mid)} if mid else None

    def shortlist():
        out = CandidateShortlist(_overflow_candidates(cluster, workload,
                                                      split.overflow))
        out.n_candidates = len(out)
        return out

    def wide_chunks():
        # wide rows re-dispatch only now that the normal cycle is known
        # to have failed — they are unreachable work otherwise
        for lo in range(0, len(split.wide), MAX_ROWS_WIDE):
            chunk = split.wide[lo:lo + MAX_ROWS_WIDE]
            yield ev.gathered_evaluator(spec, ctx.cap, p, *req)(
                dcs.nodestate, dcs.victims, dcs.drain,
                jnp.asarray(pidx), jnp.asarray(pbuf),
                jnp.asarray(_pad_idx(chunk))), chunk

    return _finalize_plan(vals, sel, patches, ctx, shortlist, wide_chunks,
                          alpha)


class BatchSourcingSession:
    """`plan_batch` sourcing: ALL requests vmapped in one dispatch.

    At construction, ONE jit dispatch evaluates every request's per-node
    class winners against the shared snapshot (`batch_class_evaluator`:
    the request axis is a vmap axis of dynamic (priority, need) scalars) —
    the tensors stay on device.  ``source(view, workload, i)`` then
    preserves the sequential planned-eviction semantics exactly: request
    *i*'s winner is the device merge of (a) the precomputed class data with
    the view's delta rows masked out and (b) a small gathered re-dispatch
    of just those delta rows patched to the view state.  Untouched rows are
    never re-evaluated or re-uploaded.

    Sessions PERSIST across ``plan_batch`` calls (`persistent_batch_session`):
    the snapshot tensors and precomputed class data stay valid until a
    cluster mutation arrives through ``invalidate_node``, so bursty
    admission of the same request classes pays the big vmapped dispatch
    once per burst, not once per call.  ``reset_view_caches()`` drops the
    per-view row-encode cache on reuse (a fresh view restarts its
    node-version counters).
    """

    def __init__(self, cluster: Cluster, workloads, alpha: float) -> None:
        self.cluster = cluster
        self.spec = cluster.spec
        self.alpha = float(alpha)
        self.dcs = cluster.device_state().sync()
        self.ev = _evals(self.dcs)
        self.ctx = self.dcs.mirror
        self._row_cache: dict[int, tuple[int, VictimRow]] = {}
        self.reqs = [(wl.priority,) + _req_scalars(self.spec, wl)
                     for wl in workloads]
        #: reuse key of `persistent_batch_session` (alpha + request scalars)
        self.cache_key = (self.alpha, tuple(self.reqs))
        # adaptive gate, like the single-request path: precompute the class
        # data at MIN_M when every request leaves at most MAX_ROWS_WIDE
        # rows above it (those ride each merge dispatch's gather section).
        # The snapshot is fixed, so all per-thresh scans dedupe.
        self.gate = MIN_M
        self._split_cache: dict[int, FusedSplit] = {}
        if self.dcs.count_max > MIN_M:
            for t in {t for t, _, _, _ in self.reqs}:
                elig = ((self.ctx.vp < t) & self.ctx.stored).sum(axis=1)
                if int((elig > MIN_M).sum()) > MAX_ROWS_WIDE:
                    self.gate = NARROW_M
                    break
        rp = _pad_pow2(len(self.reqs))
        th = np.zeros(rp, np.int32)           # pad: nothing eligible ...
        ng = np.full(rp, _INT32_MAX, np.int32)   # ... and nothing feasible
        nc = np.full(rp, _INT32_MAX, np.int32)
        cpb = np.zeros(rp, np.int32)
        for j, (t, g, c, b) in enumerate(self.reqs):
            th[j], ng[j], nc[j], cpb[j] = t, g, c, b
        self.class_data = self.ev.batch_class_evaluator(self.spec, self.gate,
                                                        self.alpha)(
            self.dcs.nodestate, self.dcs.victims, self.dcs.drain,
            jnp.asarray(th), jnp.asarray(ng), jnp.asarray(nc),
            jnp.asarray(cpb))

    def reset_view_caches(self) -> None:
        """Drop per-view state before serving a new ``plan_batch`` call
        (row encodings are keyed by `ClusterView.node_version`, which a
        fresh view restarts at zero)."""
        self._row_cache.clear()

    def _view_patches(self, view, delta) -> dict:
        """Encode the view's delta rows, re-encoding ONLY rows a later plan
        touched since they were last cached (`ClusterView.node_version`)."""
        patches = {}
        for d in delta:
            ver = view.node_version(d)
            hit = self._row_cache.get(d)
            if hit is None or hit[0] != ver:
                hit = (ver, encode_row(view, d, self.ctx.cap))
                self._row_cache[d] = hit
            patches[d] = hit[1]
        return patches

    def _route(self, view, thresh: int):
        """Delta routing shared by ``source`` and ``plan``.

        Encodes the view's delta rows and classifies every row against the
        session split for this preemptor priority (cached: the snapshot is
        fixed): untouched mid/wide/overflow rows minus the deltas, plus the
        delta rows partitioned into overflow (python fallback), wide
        (chunked 2^cap re-dispatch) and dense (merged-dispatch gather)."""
        delta = sorted(view.delta_nodes())
        patches = self._view_patches(view, delta)
        dset = set(delta)
        split = self._split_cache.get(thresh)
        if split is None:
            split = split_fused_nodes(self.dcs, {}, thresh, gate=self.gate)
            self._split_cache[thresh] = split
        mid = [w for w in split.mid if w not in dset]
        wide = [w for w in split.wide if w not in dset]
        overflow = [o for o in split.overflow if o not in dset]
        over = {d for d in delta if patches[d].overflow
                and patches[d].next_priority < thresh}
        elig = {d: int(((patches[d].vp < thresh) & patches[d].stored).sum())
                for d in delta if d not in over}
        return (delta, patches, mid, wide, overflow, sorted(over),
                [d for d in elig if elig[d] > NARROW_M],
                [d for d in elig if elig[d] <= NARROW_M])

    def source(self, view, workload: WorkloadSpec,
               i: int) -> CandidateShortlist:
        thresh, ng, nc, cpb = self.reqs[i]
        ctx = self.ctx
        cap = ctx.cap
        n = self.dcs.n_rows
        # class data was precomputed at ``self.gate``: rows above the gate
        # (minus this plan's delta rows) ride the merge dispatch's gather
        # section (mid) or the chunked 2^cap re-dispatch (wide)
        (delta, patches, mid, wide, overflow, d_over, d_wide,
         d_dense) = self._route(view, thresh)
        out = CandidateShortlist(_overflow_candidates(view, workload,
                                                      overflow))
        out.n_candidates = len(out)
        req = (thresh, ng, nc, cpb, self.alpha)
        pending = []     # dispatches are async: launch all, decode once
        if d_over:       # delta rows that cannot ride the merged dispatch
            extra = _overflow_candidates(view, workload, d_over)
            out.extend(extra)
            out.n_candidates += len(extra)
        # ONE dispatch: request i's class tensors minus its delta rows,
        # merged with a NARROW_M-wide pass over the patched dense delta
        # rows AND the untouched mid-tier rows the gate excluded
        p, pidx, pbuf = _pack_patches({d: patches[d] for d in d_dense}, cap)
        gather = sorted(d_dense) + mid
        didx = _pad_idx(delta) if delta else np.zeros(0, np.int32)
        gidx = _pad_idx(gather) if gather else np.zeros(0, np.int32)
        if len(didx) == 0 and len(gidx) == 0:
            aux_d, pbuf_d = _empty_patch_args(cap)
        else:
            aux_d = jnp.asarray(np.concatenate([didx, pidx, gidx]))
            pbuf_d = jnp.asarray(pbuf)
        res = self.ev.batch_merge_evaluator(self.spec, NARROW_M, len(didx),
                                            len(gidx), *req)(
            *self.class_data, self.dcs.nodestate, self.dcs.victims,
            self.dcs.drain, jnp.int32(i), aux_d, pbuf_d)
        sel = {n + j: node for j, node in enumerate(gather)}
        pending.append((res, sel))
        # wide rows (9..16 eligible victims): chunked 2^cap dispatches —
        # patched delta rows and untouched rows alike
        if d_wide or wide:
            pw, pwidx, pwbuf = _pack_patches(
                {d: patches[d] for d in d_wide}, cap)
            pargs = (jnp.asarray(pwidx), jnp.asarray(pwbuf))
            rows = d_wide + wide
            for lo in range(0, len(rows), MAX_ROWS_WIDE):
                chunk = rows[lo:lo + MAX_ROWS_WIDE]
                res = self.ev.gathered_evaluator(self.spec, cap, pw, *req)(
                    self.dcs.nodestate, self.dcs.victims, self.dcs.drain,
                    *pargs, jnp.asarray(_pad_idx(chunk)))
                pending.append((res, chunk))
        for res, sel in pending:
            _append_winner(out, res, sel, patches, ctx)
        return out

    def plan(self, view, workload: WorkloadSpec,
             i: int) -> FusedPlanResult:
        """Both Algorithm 1 cycles for batched request ``i``, ONE dispatch.

        `batch_plan_evaluator`: the normal-cycle scorer runs over the
        view-overlaid resident nodestate (EVERY delta row patched, so the
        plan sees its exact free masks), and the masked-class preemptive
        merge runs under ``lax.cond`` only when it places nothing —
        placement masks decoded either way, sequential planned-eviction
        semantics preserved exactly as in ``source``."""
        thresh, ng, nc, cpb = self.reqs[i]
        ctx = self.ctx
        cap = ctx.cap
        n = self.dcs.n_rows
        (delta, patches, mid, wide, overflow, d_over, d_wide,
         d_dense) = self._route(view, thresh)
        # ALL delta rows ride the overlay (wide/overflow included): the
        # normal cycle needs the view's exact free masks everywhere
        p, pidx, pbuf = _pack_patches(patches, cap)
        gather = sorted(d_dense) + mid
        didx = _pad_idx(delta) if delta else np.zeros(0, np.int32)
        gidx = _pad_idx(gather) if gather else np.zeros(0, np.int32)
        if len(didx) == 0 and len(gidx) == 0 and p == 0:
            aux_d, pbuf_d = _empty_patch_args(cap)
        else:
            aux_d = jnp.asarray(np.concatenate([didx, pidx, gidx]))
            pbuf_d = jnp.asarray(pbuf)
        req = (thresh, ng, nc, cpb, self.alpha)
        res = self.ev.batch_plan_evaluator(self.spec, NARROW_M, len(didx),
                                           len(gidx), p, *req)(
            *self.class_data, self.dcs.nodestate, self.dcs.victims,
            self.dcs.drain, jnp.int32(i), aux_d, pbuf_d)
        vals = [int(x) for x in jax.device_get(res)]
        sel = {n + j: node for j, node in enumerate(gather)}

        def shortlist():
            out = CandidateShortlist(_overflow_candidates(view, workload,
                                                          overflow))
            out.n_candidates = len(out)
            if d_over:
                extra = _overflow_candidates(view, workload, d_over)
                out.extend(extra)
                out.n_candidates += len(extra)
            return out

        def wide_chunks():
            # wide rows re-dispatch only now that the normal cycle is
            # known to have failed
            if not d_wide and not wide:
                return
            pw, pwidx, pwbuf = _pack_patches(
                {d: patches[d] for d in d_wide}, cap)
            pargs = (jnp.asarray(pwidx), jnp.asarray(pwbuf))
            rows = d_wide + wide
            for lo in range(0, len(rows), MAX_ROWS_WIDE):
                chunk = rows[lo:lo + MAX_ROWS_WIDE]
                yield self.ev.gathered_evaluator(self.spec, cap, pw, *req)(
                    self.dcs.nodestate, self.dcs.victims, self.dcs.drain,
                    *pargs, jnp.asarray(_pad_idx(chunk))), chunk

        return _finalize_plan(vals, sel, patches, ctx, shortlist,
                              wide_chunks, self.alpha)


def persistent_batch_session(cluster: Cluster, workloads,
                             alpha: float) -> BatchSourcingSession:
    """``batch_factory`` hook with cross-call session reuse.

    The first call on a cluster registers one ``invalidate_node`` listener
    that voids the cached session on ANY mutation (bind/evict/restore) —
    the same choke point that keeps the resident device state coherent.
    A ``plan_batch`` burst with unchanged cluster state and the same
    request classes (and alpha) then reuses the session: the precomputed
    vmapped class tensors are served again and only the per-plan merge
    dispatches run.  Any mismatch or staleness rebuilds transparently.

    The slot lives ON the cluster object (like ``device_state()``), so a
    dropped cluster and its cached session are reference-cycle garbage
    the collector reclaims together — no global registry pins them.
    """
    entry = getattr(cluster, "_batch_session_slot", None)
    if entry is None:
        entry = {"session": None}

        def _void(node, _entry=entry):
            _entry["session"] = None

        cluster.add_dirty_listener(_void)
        cluster._batch_session_slot = entry
    spec = cluster.spec
    key = (float(alpha),
           tuple((wl.priority,) + _req_scalars(spec, wl) for wl in workloads))
    session = entry["session"]
    if session is not None and session.cache_key == key:
        session.reset_view_caches()
        return session
    session = BatchSourcingSession(cluster, workloads, alpha)
    entry["session"] = session
    return session


def warmup_fused(cluster: Cluster, alpha: float = DEFAULT_ALPHA,
                 batch: int = 8, workloads=None,
                 shortlist: ShortlistConfig | None = None) -> None:
    """Pre-compile the fused jit buckets for this cluster's shapes.

    Opt-in via ``TopoScheduler(..., warmup=True)``: drives REAL sourcing
    sweeps (pure reads against copy-on-write views, so cluster state is
    untouched) for each preemptor class — once against the clean state and
    once against a view with a delta — plus one `plan_batch` session, so
    the jit variants a first plan actually hits (resident evaluator with
    and without a patch bucket, the request-vmapped class evaluator, the
    per-request merge) are compiled at construction instead of on the
    first plans (cold P90 is compile-dominated otherwise).

    ``workloads`` defaults to the Table 3 classes; pass the deployment's
    own preemptor classes when they differ (the single-request programs
    specialize per request).
    """
    from .workload import table3_workloads

    if workloads is None:
        workloads = table3_workloads()
    workloads = list(workloads)
    cluster.device_state().sync()
    for wl in workloads:
        source_candidates_fused(cluster, wl, None, alpha=alpha)
        plan_fused(cluster, wl, alpha=alpha)       # chained Algorithm 1
        plan_normal_fused(cluster, wl)             # batch-path normal cycle
        if shortlist is not None:
            plan_fused(cluster, wl, alpha=alpha, shortlist=shortlist)
        view = cluster.view()
        for node in range(cluster.num_nodes):    # fabricate one view delta
            victims = view.victims_on(node, wl.priority)
            if victims:
                view.plan_evict(victims[0].uid)
                source_candidates_fused(view, wl, None, alpha=alpha)
                plan_fused(view, wl, alpha=alpha)
                plan_normal_fused(view, wl)
                if shortlist is not None:
                    plan_fused(view, wl, alpha=alpha, shortlist=shortlist)
                break
    if batch > 1 and workloads:
        session = BatchSourcingSession(
            cluster, tuple((workloads * batch)[:batch]), alpha)
        session.source(cluster.view(), workloads[0], 0)
        session.plan(cluster.view(), workloads[0], 0)


register_engine("imp_batched", batched=True, needs_alpha=True,
                fused_filter=True, fused_place=True, plan_fn=plan_fused,
                normal_fn=plan_normal_fused,
                batch_factory=persistent_batch_session,
                warmup_fn=warmup_fused,
                supports_shortlist=True)(source_candidates_fused)

# full-sweep parity oracle: identical functions, shortlist disabled — the
# scheduler's shortlist kwargs are ignored, every plan runs the all-rows
# subset sweep (tests/benchmarks compare decisions against this engine)
register_engine("imp_batched_full", batched=True, needs_alpha=True,
                fused_filter=True, fused_place=True, plan_fn=plan_fused,
                normal_fn=plan_normal_fused,
                batch_factory=persistent_batch_session,
                warmup_fn=warmup_fused)(source_candidates_fused)
