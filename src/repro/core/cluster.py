"""Cluster state: N servers × FlexTopo + the instance registry.

The scheduler and simulator mutate cluster state exclusively through this
class so that the FlexTopo graphs, the bitmask arrays, and the instance
registry can never diverge.  ``arrays()`` exports the dense engine view used
by the vectorized/Pallas preemption engines.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from .flextopo import FlexTopo
from .placement import Placement
from .topology import ServerSpec
from .workload import Instance, WorkloadSpec


@dataclasses.dataclass
class ClusterArrays:
    """Dense snapshot for the vectorized engines."""

    free_gpu: np.ndarray      # int32[N] free-GPU bitmask per node
    free_cg: np.ndarray       # int32[N] free-CoreGroup bitmask per node
    numa_gpu_masks: np.ndarray    # int32[U]
    numa_cg_masks: np.ndarray     # int32[U]
    socket_of_numa: np.ndarray    # int32[U]


class Cluster:
    def __init__(self, spec: ServerSpec, num_nodes: int,
                 node_index: bool = True) -> None:
        self.spec = spec
        self.num_nodes = num_nodes
        self.topos = [FlexTopo(spec, node_name=f"node-{i}") for i in range(num_nodes)]
        self.instances: dict[int, Instance] = {}
        self._uid = itertools.count()
        # per-node instance index + cached free masks: turns victims_on /
        # free_masks from O(total instances) scans into O(node) lookups
        # (§Perf scheduler hillclimb; node_index=False is the naive baseline)
        self.node_index = node_index
        self._by_node: list[set[int]] = [set() for _ in range(num_nodes)]
        self._mask_cache: list[tuple[int, int] | None] = [None] * num_nodes

    # ---- mutation -----------------------------------------------------------------
    def bind(self, workload: WorkloadSpec, node: int, placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        gpus = [g for g in range(self.spec.num_gpus) if placement.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if placement.cg_mask >> c & 1]
        self.topos[node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[node].add(inst.uid)
        self._mask_cache[node] = None
        return inst

    def evict(self, uid: int) -> Instance:
        inst = self.instances.pop(uid)
        self.topos[inst.node].release(inst.name)
        self._by_node[inst.node].discard(uid)
        self._mask_cache[inst.node] = None
        return inst

    def restore(self, inst: Instance) -> Instance:
        """Re-insert a previously evicted instance with full fidelity.

        Unlike ``bind``, the instance keeps its original uid, node, and
        GPU/CoreGroup masks — this is what ``Transaction.rollback`` uses so
        that reversing a preemption is bitwise-exact.
        """
        if inst.uid in self.instances:
            raise ValueError(f"uid {inst.uid} already bound")
        gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if inst.cg_mask >> c & 1]
        self.topos[inst.node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[inst.node].add(inst.uid)
        self._mask_cache[inst.node] = None
        return inst

    def invalidate_node(self, node: int) -> None:
        self._mask_cache[node] = None

    # ---- queries --------------------------------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        if self.node_index:
            cached = self._mask_cache[node]
            if cached is None:
                m = self.topos[node].as_masks()
                cached = (m.free_gpu_mask, m.free_cg_mask)
                self._mask_cache[node] = cached
            return cached
        m = self.topos[node].as_masks()
        return m.free_gpu_mask, m.free_cg_mask

    def instances_on(self, node: int) -> list[Instance]:
        if self.node_index:
            return [self.instances[u] for u in self._by_node[node]]
        return [i for i in self.instances.values() if i.node == node]

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        """Potential victims: strictly lower priority and preemptible."""
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    def arrays(self) -> ClusterArrays:
        free_gpu = np.zeros(self.num_nodes, dtype=np.int32)
        free_cg = np.zeros(self.num_nodes, dtype=np.int32)
        for n, topo in enumerate(self.topos):
            m = topo.as_masks()
            free_gpu[n] = m.free_gpu_mask
            free_cg[n] = m.free_cg_mask
        return ClusterArrays(
            free_gpu=free_gpu,
            free_cg=free_cg,
            numa_gpu_masks=self.spec.numa_gpu_masks,
            numa_cg_masks=self.spec.numa_cg_masks,
            socket_of_numa=self.spec.socket_of_numa_arr,
        )

    # ---- reporting --------------------------------------------------------------------
    def count_by_workload(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for inst in self.instances.values():
            out[inst.workload.name] = out.get(inst.workload.name, 0) + 1
        return out

    def allocation_snapshot(self) -> list[dict]:
        """Fig. 8-style snapshot: per instance, its node/GPU indices and tier."""
        from .placement import achieved_tier

        rows = []
        for inst in sorted(self.instances.values(), key=lambda i: (i.node, i.uid)):
            gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
            rows.append({
                "instance": inst.name,
                "workload": inst.workload.name,
                "node": inst.node,
                "gpus": gpus,
                "tier": achieved_tier(self.spec, inst.gpu_mask),
            })
        return rows

    def view(self) -> "ClusterView":
        """Copy-on-write planning view over the current state."""
        return ClusterView(self)

    def cross_socket_instances(self) -> int:
        """Fig. 8 headline number: instances whose GPUs span sockets."""
        from .placement import achieved_tier, min_tier_for

        return sum(
            1
            for inst in self.instances.values()
            if inst.gpu_mask
            and achieved_tier(self.spec, inst.gpu_mask)
            > min_tier_for(self.spec, inst.gpu_mask.bit_count())
        )


class ClusterView:
    """Copy-on-write overlay over a `Cluster` for transactional planning.

    Presents the same read interface the sourcing engines and the scheduler
    use (``spec``, ``num_nodes``, ``free_masks``, ``instances_on``,
    ``victims_on``) but records evictions and binds locally instead of
    mutating the base cluster.  Planned binds get *virtual* (negative) uids
    so they can never collide with live instances; ``Transaction.commit``
    later replays the plan onto the base cluster for real.

    One view can host several ``plan()`` calls (``plan_batch``): later plans
    see earlier planned evictions/binds, so a batch of decisions composes
    against a single snapshot.
    """

    def __init__(self, base: Cluster) -> None:
        self.base = base
        self.spec = base.spec
        self.num_nodes = base.num_nodes
        self._evicted: dict[int, Instance] = {}
        self._added: dict[int, Instance] = {}
        self._uid = itertools.count(-1, -1)
        # virtual uid -> real uid, filled as the view's transactions commit so
        # later transactions can resolve victims planned against earlier binds
        self.committed_uids: dict[int, int] = {}

    # -- read interface (mirrors Cluster) ------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        fg, fc = self.base.free_masks(node)
        for inst in self._evicted.values():
            if inst.node == node:
                fg |= inst.gpu_mask
                fc |= inst.cg_mask
        for inst in self._added.values():
            if inst.node == node:
                fg &= ~inst.gpu_mask
                fc &= ~inst.cg_mask
        return fg, fc

    def instances_on(self, node: int) -> list[Instance]:
        live = [i for i in self.base.instances_on(node)
                if i.uid not in self._evicted]
        live.extend(i for i in self._added.values() if i.node == node)
        return live

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    # -- planned mutations ----------------------------------------------------------
    def plan_evict(self, uid: int) -> Instance:
        if uid in self._added:
            return self._added.pop(uid)
        inst = self.base.instances[uid]
        if uid in self._evicted:
            raise ValueError(f"uid {uid} already planned for eviction")
        self._evicted[uid] = inst
        return inst

    def plan_bind(self, workload: WorkloadSpec, node: int,
                  placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        self._added[inst.uid] = inst
        return inst

    def resolve_uid(self, uid: int) -> int:
        """Map a virtual (planned-bind) uid to the real uid it committed as."""
        return self.committed_uids.get(uid, uid)
