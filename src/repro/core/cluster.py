"""Cluster state: N servers × FlexTopo + the instance registry.

The scheduler and simulator mutate cluster state exclusively through this
class so that the FlexTopo graphs, the bitmask arrays, and the instance
registry can never diverge.  ``arrays()`` exports the dense engine view used
by the vectorized/Pallas preemption engines, ``sourcing_context()`` hands
out the incrementally-maintained host `SourcingContext` mirror, and
``device_state()`` hands out the `DeviceClusterState` — the struct-of-arrays
copy of the sourcing rows that stays RESIDENT on the accelerator across
plans.  ``invalidate_node`` marks single rows dirty in both; the device copy
re-uploads only those rows as one ``.at[rows].set()`` scatter per sync, so a
``plan()`` never re-uploads the whole ``[N, M]`` state host→device.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Callable

import numpy as np

from .flextopo import FlexTopo
from .placement import Placement
from .topology import ServerSpec
from .workload import Instance, WorkloadSpec

#: Widest per-node victim row the dense sourcing arrays encode.  Nodes
#: holding more preemptible instances than this overflow the row and are
#: sourced through the per-node python engine instead (see
#: ``preemption_jax``) — the batched engines degrade gracefully rather than
#: crash.
MAX_DENSE_VICTIMS = 16


@dataclasses.dataclass
class ClusterArrays:
    """Dense snapshot for the vectorized engines."""

    free_gpu: np.ndarray      # int32[N] free-GPU bitmask per node
    free_cg: np.ndarray       # int32[N] free-CoreGroup bitmask per node
    numa_gpu_masks: np.ndarray    # int32[U]
    numa_cg_masks: np.ndarray     # int32[U]
    socket_of_numa: np.ndarray    # int32[U]


class Cluster:
    def __init__(self, spec: ServerSpec, num_nodes: int,
                 node_index: bool = True) -> None:
        self.spec = spec
        self.num_nodes = num_nodes
        self.topos = [FlexTopo(spec, node_name=f"node-{i}") for i in range(num_nodes)]
        self.instances: dict[int, Instance] = {}
        self._uid = itertools.count()
        # per-node instance index + cached free masks: turns victims_on /
        # free_masks from O(total instances) scans into O(node) lookups
        # (§Perf scheduler hillclimb; node_index=False is the naive baseline)
        self.node_index = node_index
        self._by_node: list[set[int]] = [set() for _ in range(num_nodes)]
        self._mask_cache: list[tuple[int, int] | None] = [None] * num_nodes
        # node-dirty fan-out: every mutation funnels through invalidate_node,
        # which notifies subscribers (the SourcingContext) so dense engine
        # rows refresh incrementally instead of rebuilding from instance lists
        self._dirty_listeners: list[Callable[[int], None]] = []
        # op fan-out: bind/evict/restore ALSO publish the exact mutation
        # (node, ±1, gpu_mask, cg_mask, priority, uid, preemptible) so the
        # sourcing mirror can replay dirty rows vectorized instead of
        # re-encoding each one from the instance lists (`encode_row`)
        self._op_listeners: list[Callable[[tuple], None]] = []
        # instance fan-out: the same bind/evict/restore stream with the
        # WHOLE Instance attached (workload identity included, which the
        # mask-level op tuple deliberately omits) — what the O(delta)
        # simulation layer maintains its aggregate rate accumulators,
        # replica indexes, and free-count feasibility gates from
        self._inst_listeners: list[Callable[[int, "Instance"], None]] = []
        self._sourcing_ctx: "SourcingContext | None" = None
        self._device_state: "DeviceClusterState | None" = None

    # ---- mutation -----------------------------------------------------------------
    def bind(self, workload: WorkloadSpec, node: int, placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        gpus = [g for g in range(self.spec.num_gpus) if placement.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if placement.cg_mask >> c & 1]
        self.topos[node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[node].add(inst.uid)
        self._emit_op(node, +1, inst)
        self._emit_inst(+1, inst)
        self.invalidate_node(node)
        return inst

    def evict(self, uid: int) -> Instance:
        inst = self.instances.pop(uid)
        self.topos[inst.node].release(inst.name)
        self._by_node[inst.node].discard(uid)
        self._emit_op(inst.node, -1, inst)
        self._emit_inst(-1, inst)
        self.invalidate_node(inst.node)
        return inst

    def restore(self, inst: Instance) -> Instance:
        """Re-insert a previously evicted instance with full fidelity.

        Unlike ``bind``, the instance keeps its original uid, node, and
        GPU/CoreGroup masks — this is what ``Transaction.rollback`` uses so
        that reversing a preemption is bitwise-exact.
        """
        if inst.uid in self.instances:
            raise ValueError(f"uid {inst.uid} already bound")
        gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if inst.cg_mask >> c & 1]
        self.topos[inst.node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[inst.node].add(inst.uid)
        self._emit_op(inst.node, +1, inst)
        self._emit_inst(+1, inst)
        self.invalidate_node(inst.node)
        return inst

    def invalidate_node(self, node: int) -> None:
        """Single choke point for node-state changes: drops the free-mask
        cache and notifies dirty listeners (incremental sourcing arrays)."""
        self._mask_cache[node] = None
        for fn in self._dirty_listeners:
            fn(node)

    def add_dirty_listener(self, fn: Callable[[int], None]) -> None:
        """Subscribe to per-node invalidation events (bind/evict/restore)."""
        self._dirty_listeners.append(fn)

    def _emit_op(self, node: int, delta: int, inst: Instance) -> None:
        if self._op_listeners:
            op = (node, delta, inst.gpu_mask, inst.cg_mask, inst.priority,
                  inst.uid, inst.preemptible)
            for fn in self._op_listeners:
                fn(op)

    def _emit_inst(self, delta: int, inst: Instance) -> None:
        for fn in self._inst_listeners:
            fn(delta, inst)

    def add_inst_listener(self, fn: Callable[[int, Instance], None]) -> None:
        """Subscribe to ``(±1, Instance)`` for every bind/evict/restore —
        the workload-aware sibling of `add_op_listener`.  A rollback's
        ``restore`` emits ``+1`` with the ORIGINAL instance (same uid and
        masks), so a consumer's ±1 bookkeeping is exactly reversible."""
        self._inst_listeners.append(fn)

    def add_op_listener(self, fn: Callable[[tuple], None]) -> None:
        """Subscribe to the exact mutation stream behind ``invalidate_node``:
        one ``(node, ±1, gpu_mask, cg_mask, priority, uid, preemptible)``
        tuple per bind/evict/restore.  External ``invalidate_node`` calls do
        NOT produce ops — consumers must cross-check dirty marks against op
        counts (see `SourcingContext.refresh`)."""
        self._op_listeners.append(fn)

    def sourcing_context(self) -> "SourcingContext":
        """The lazily-created incremental array cache for fused sourcing."""
        if self._sourcing_ctx is None:
            self._sourcing_ctx = SourcingContext(self)
        return self._sourcing_ctx

    def device_state(self, sharded: bool = False) -> "DeviceClusterState":
        """The lazily-created device-resident struct-of-arrays state.

        ``sharded=True`` returns (creating or replacing as needed) a
        `repro.core.cluster_parallel.ShardedDeviceClusterState`: the same
        three stacked tensors, node axis padded to a multiple of the device
        count and laid out with a `NamedSharding` over a 1-D mesh of every
        local device.  The fused evaluators then compile to SPMD programs
        where the per-node class math runs shard-local and only the final
        argmax chain crosses shards (the `imp_sharded` engine)."""
        if sharded:
            from .cluster_parallel import ShardedDeviceClusterState

            if not isinstance(self._device_state, ShardedDeviceClusterState):
                self._device_state = ShardedDeviceClusterState(self)
            return self._device_state
        if self._device_state is None:
            self._device_state = DeviceClusterState(self)
        return self._device_state

    # ---- queries --------------------------------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        if self.node_index:
            cached = self._mask_cache[node]
            if cached is None:
                m = self.topos[node].as_masks()
                cached = (m.free_gpu_mask, m.free_cg_mask)
                self._mask_cache[node] = cached
            return cached
        m = self.topos[node].as_masks()
        return m.free_gpu_mask, m.free_cg_mask

    def instances_on(self, node: int) -> list[Instance]:
        if self.node_index:
            return [self.instances[u] for u in self._by_node[node]]
        return [i for i in self.instances.values() if i.node == node]

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        """Potential victims: strictly lower priority and preemptible."""
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    def arrays(self) -> ClusterArrays:
        free_gpu = np.zeros(self.num_nodes, dtype=np.int32)
        free_cg = np.zeros(self.num_nodes, dtype=np.int32)
        for n, topo in enumerate(self.topos):
            m = topo.as_masks()
            free_gpu[n] = m.free_gpu_mask
            free_cg[n] = m.free_cg_mask
        return ClusterArrays(
            free_gpu=free_gpu,
            free_cg=free_cg,
            numa_gpu_masks=self.spec.numa_gpu_masks,
            numa_cg_masks=self.spec.numa_cg_masks,
            socket_of_numa=self.spec.socket_of_numa_arr,
        )

    # ---- reporting --------------------------------------------------------------------
    def count_by_workload(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for inst in self.instances.values():
            out[inst.workload.name] = out.get(inst.workload.name, 0) + 1
        return out

    def allocation_snapshot(self) -> list[dict]:
        """Fig. 8-style snapshot: per instance, its node/GPU indices and tier."""
        from .placement import achieved_tier

        rows = []
        for inst in sorted(self.instances.values(), key=lambda i: (i.node, i.uid)):
            gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
            rows.append({
                "instance": inst.name,
                "workload": inst.workload.name,
                "node": inst.node,
                "gpus": gpus,
                "tier": achieved_tier(self.spec, inst.gpu_mask),
            })
        return rows

    def view(self) -> "ClusterView":
        """Copy-on-write planning view over the current state."""
        return ClusterView(self)

    def cross_socket_instances(self) -> int:
        """Fig. 8 headline number: instances whose GPUs span sockets."""
        from .placement import achieved_tier, min_tier_for

        return sum(
            1
            for inst in self.instances.values()
            if inst.gpu_mask
            and achieved_tier(self.spec, inst.gpu_mask)
            > min_tier_for(self.spec, inst.gpu_mask.bit_count())
        )


class ClusterView:
    """Copy-on-write overlay over a `Cluster` for transactional planning.

    Presents the same read interface the sourcing engines and the scheduler
    use (``spec``, ``num_nodes``, ``free_masks``, ``instances_on``,
    ``victims_on``) but records evictions and binds locally instead of
    mutating the base cluster.  Planned binds get *virtual* (negative) uids
    so they can never collide with live instances; ``Transaction.commit``
    later replays the plan onto the base cluster for real.

    One view can host several ``plan()`` calls (``plan_batch``): later plans
    see earlier planned evictions/binds, so a batch of decisions composes
    against a single snapshot.
    """

    def __init__(self, base: Cluster) -> None:
        self.base = base
        self.spec = base.spec
        self.num_nodes = base.num_nodes
        self._evicted: dict[int, Instance] = {}
        self._added: dict[int, Instance] = {}
        self._uid = itertools.count(-1, -1)
        # virtual uid -> real uid, filled as the view's transactions commit so
        # later transactions can resolve victims planned against earlier binds
        self.committed_uids: dict[int, int] = {}
        # per-node planned-mutation counter: lets callers (the batch
        # sourcing session) cache row encodings across plans sharing this
        # view and re-encode only rows a later plan actually touched
        self._node_version: dict[int, int] = {}

    # -- read interface (mirrors Cluster) ------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        fg, fc = self.base.free_masks(node)
        for inst in self._evicted.values():
            if inst.node == node:
                fg |= inst.gpu_mask
                fc |= inst.cg_mask
        for inst in self._added.values():
            if inst.node == node:
                fg &= ~inst.gpu_mask
                fc &= ~inst.cg_mask
        return fg, fc

    def instances_on(self, node: int) -> list[Instance]:
        live = [i for i in self.base.instances_on(node)
                if i.uid not in self._evicted]
        live.extend(i for i in self._added.values() if i.node == node)
        return live

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    # -- planned mutations ----------------------------------------------------------
    def _bump(self, node: int) -> None:
        self._node_version[node] = self._node_version.get(node, 0) + 1

    def node_version(self, node: int) -> int:
        """Planned-mutation counter for one node (0 = untouched)."""
        return self._node_version.get(node, 0)

    def plan_evict(self, uid: int) -> Instance:
        if uid in self._added:
            inst = self._added.pop(uid)
            self._bump(inst.node)
            return inst
        inst = self.base.instances[uid]
        if uid in self._evicted:
            raise ValueError(f"uid {uid} already planned for eviction")
        self._evicted[uid] = inst
        self._bump(inst.node)
        return inst

    def plan_bind(self, workload: WorkloadSpec, node: int,
                  placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        self._added[inst.uid] = inst
        self._bump(node)
        return inst

    def resolve_uid(self, uid: int) -> int:
        """Map a virtual (planned-bind) uid to the real uid it committed as."""
        return self.committed_uids.get(uid, uid)

    def delta_nodes(self) -> set[int]:
        """Nodes whose state differs from the base cluster (planned deltas)."""
        return ({i.node for i in self._evicted.values()}
                | {i.node for i in self._added.values()})


class SourcingContext:
    """Incrementally-maintained dense arrays for the fused sourcing path.

    One row per node holds the padded bitmask/priority/uid arrays of ALL
    preemptible instances on that node (sorted by ``(priority, uid)``, the
    same order ``victims_on`` yields).  The preemptor-priority filter is NOT
    baked in: the fused evaluator masks victims by ``priority < preemptor``
    on device, so one cache serves every preemptor class.

    Invalidation semantics: the context subscribes to the cluster's
    ``invalidate_node`` choke point (hit by every ``bind``/``evict``/
    ``restore``/explicit invalidation) and marks rows dirty; ``refresh()``
    rebuilds only the dirty rows lazily before the next read.  A full
    ``plan()`` therefore touches O(dirty nodes) python state instead of
    reconstructing ``[N, M]`` arrays from instance lists.

    ``rank`` is each victim's uid-rank within its node's stored victims —
    the fused evaluator packs a combo's ranks into a bitmask whose integer
    order equals the lexicographic order of the combo's sorted uid tuple,
    reproducing ``select_best``'s victim-uid tie-break on device.

    Rows with more than `MAX_DENSE_VICTIMS` preemptible instances are marked
    ``overflow`` but still store the first `cap` victims (the lowest
    ``(priority, uid)`` prefix) plus ``next_prio``, the priority of the
    first victim NOT stored.  Because any preemptor's eligible victims
    (``priority < preemptor``) are a prefix of that order, a truncated row
    stays on the fused fast path whenever ``next_prio >= preemptor``;
    callers fall back to per-node sourcing only when eligible victims
    genuinely exceed the row (the old ``_bucket`` ValueError now degrades
    instead of crashing).
    """

    def __init__(self, cluster: Cluster, cap: int = MAX_DENSE_VICTIMS) -> None:
        self.cluster = cluster
        self.cap = cap
        n = cluster.num_nodes
        self.free_gpu = np.zeros(n, np.int32)
        self.free_cg = np.zeros(n, np.int32)
        self.vg = np.zeros((n, cap), np.int32)      # victim GPU bitmasks
        self.vc = np.zeros((n, cap), np.int32)      # victim CoreGroup bitmasks
        self.vp = np.zeros((n, cap), np.int32)      # victim priorities
        self.vu = np.zeros((n, cap), np.int64)      # victim uids
        self.rank = np.zeros((n, cap), np.int32)    # uid-rank within the node
        self.stored = np.zeros((n, cap), bool)      # slot holds an instance
        self.count = np.zeros(n, np.int32)          # preemptible instances
        self.overflow = np.zeros(n, bool)           # count > cap: truncated
        self.next_prio = np.full(n, 2**31 - 1, np.int32)  # 1st unstored prio
        self.fp = np.zeros(n, np.int64)             # equivalence-class hash
        self._dirty: set[int] = set(range(n))
        # journal-driven refresh: the exact mutation stream since the last
        # refresh, plus a per-node dirty-mark counter.  A dirty row whose
        # mark count equals its op count was mutated ONLY through
        # bind/evict/restore and replays vectorized; anything else (external
        # invalidation, truncated base row, giant op bursts) falls back to
        # `encode_row`.  Rows never encoded at all (`_fresh`) always do.
        self._journal: list[tuple] = []
        self._marks: dict[int, int] = {}
        self._fresh: set[int] = set(range(n))
        cluster.add_dirty_listener(self._mark)
        cluster.add_op_listener(self._journal.append)

    def _mark(self, node: int) -> None:
        self._dirty.add(node)
        self._marks[node] = self._marks.get(node, 0) + 1

    def refresh(self) -> None:
        """Bring every dirty row up to date.

        Rows whose dirt is fully explained by the op journal are replayed
        in ONE vectorized numpy merge (`_replay_journal`) — a ``plan()``
        after a burst of commits costs O(dirty rows) numpy instead of an
        `encode_row` python loop (victim sort + instance-list scan per
        row).  The rest fall back to `refresh_row`."""
        if not self._dirty:
            self._journal.clear()
            self._marks.clear()
            return
        for node in self._replay_journal():
            self.refresh_row(node, self.cluster)
        self._dirty.clear()
        self._journal.clear()
        self._marks.clear()

    #: replay gate: a single row accumulating more preemptible additions
    #: than this between refreshes re-encodes instead (bounds the merge
    #: scratch width)
    MAX_REPLAY_ADDS = 64

    def _replay_journal(self) -> set[int]:
        """Vectorized journal replay over the replay-safe dirty rows.
        Returns the rows that still need a full `encode_row` rebuild."""
        ops_by_node: dict[int, list[tuple]] = {}
        for op in self._journal:
            ops_by_node.setdefault(op[0], []).append(op)
        bad: set[int] = set()
        rows: list[int] = []
        descs: list[tuple] = []     # (keep bool[cap], adds list)
        max_adds = 1
        for node in self._dirty:
            ops = ops_by_node.get(node, ())
            if (node in self._fresh or self.overflow[node]
                    or self._marks.get(node, 0) != len(ops)):
                bad.add(node)
                continue
            # net out the ops: a bind+evict (or evict+restore) of the same
            # uid inside one window cancels exactly
            present: dict[int, tuple] = {}
            removed: set[int] = set()
            fg, fc = int(self.free_gpu[node]), int(self.free_cg[node])
            ok = True
            for _, delta, gm, cm, prio, uid, preempt in ops:
                if delta > 0:
                    fg &= ~gm
                    fc &= ~cm
                    if preempt:
                        if uid in removed:
                            # evict -> restore cancels: the victim never
                            # left the base row
                            removed.discard(uid)
                        else:
                            present[uid] = (prio, uid, gm, cm)
                else:
                    fg |= gm
                    fc |= cm
                    if preempt:
                        if uid in present:
                            del present[uid]
                        else:
                            removed.add(uid)
            if removed:
                slot_uids = self.vu[node][self.stored[node]]
                if not removed.issubset(set(int(u) for u in slot_uids)):
                    ok = False      # removal outside the stored prefix
            adds = sorted(present.values())
            if not ok or len(adds) > self.MAX_REPLAY_ADDS:
                bad.add(node)
                continue
            keep = self.stored[node] & ~np.isin(
                self.vu[node], np.fromiter(removed, np.int64, len(removed)))
            rows.append(node)
            descs.append((fg, fc, keep, adds))
            max_adds = max(max_adds, len(adds))
        if rows:
            self._replay_rows(rows, descs, max_adds)
        return bad

    def _replay_rows(self, rows: list[int], descs: list[tuple],
                     a: int) -> None:
        """One batched (priority, uid) lexsort merge for all replayed rows:
        surviving base victims + net-new additions, exact int64 uids."""
        cap, r = self.cap, len(rows)
        idx = np.asarray(rows, np.int64)
        s = cap + a
        prio = np.full((r, s), 2**31 - 1, np.int32)
        uid = np.full((r, s), np.iinfo(np.int64).max, np.int64)
        gm = np.zeros((r, s), np.int32)
        cm = np.zeros((r, s), np.int32)
        valid = np.zeros((r, s), bool)
        fg = np.zeros(r, np.int32)
        fc = np.zeros(r, np.int32)
        for i, (node, (nfg, nfc, keep, adds)) in enumerate(zip(rows, descs)):
            fg[i], fc[i] = nfg, nfc
            valid[i, :cap] = keep
            prio[i, :cap][keep] = self.vp[node][keep]
            uid[i, :cap][keep] = self.vu[node][keep]
            gm[i, :cap][keep] = self.vg[node][keep]
            cm[i, :cap][keep] = self.vc[node][keep]
            for j, (p_, u_, g_, c_) in enumerate(adds):
                prio[i, cap + j] = p_
                uid[i, cap + j] = u_
                gm[i, cap + j] = g_
                cm[i, cap + j] = c_
                valid[i, cap + j] = True
        order = np.lexsort((uid, prio), axis=-1)
        take = np.take_along_axis
        sv = take(valid, order, 1)
        sp = take(prio, order, 1)
        su = take(uid, order, 1)
        count = valid.sum(axis=1).astype(np.int32)
        overflow = count > cap
        self.free_gpu[idx] = fg
        self.free_cg[idx] = fc
        self.count[idx] = count
        self.overflow[idx] = overflow
        self.next_prio[idx] = np.where(overflow, sp[:, cap], 2**31 - 1)
        st = sv[:, :cap]
        self.stored[idx] = st
        self.vg[idx] = np.where(st, take(gm, order, 1)[:, :cap], 0)
        self.vc[idx] = np.where(st, take(cm, order, 1)[:, :cap], 0)
        self.vp[idx] = np.where(st, sp[:, :cap], 0)
        self.vu[idx] = np.where(st, su[:, :cap], 0)
        ukey = np.where(st, su[:, :cap], np.iinfo(np.int64).max)
        rank = np.argsort(np.argsort(ukey, axis=1, kind="stable"), axis=1)
        self.rank[idx] = np.where(st, rank, 0)
        self.refingerprint(rows)

    def refingerprint(self, rows) -> None:
        """Recompute the 64-bit equivalence-class fingerprint of ``rows``.

        The fingerprint covers exactly the fields the fused evaluators
        score — free masks, victim GPU/CG/priority columns, stored flags,
        count/overflow/next-priority routing state (the drain masks are
        derived from free|victims and add nothing) — and deliberately
        EXCLUDES uids and uid-ranks: nodes differing only in WHICH
        instances occupy the slots are interchangeable up to the winner
        argmax's uid tie-break, which fires after the node-id refinement
        and therefore never distinguishes across nodes.  Maintained
        incrementally at the same refresh choke points as the rows
        themselves, so the cost is O(dirty rows) per commit window.
        """
        for node in rows:
            h = hashlib.blake2b(digest_size=8)
            h.update(self.free_gpu[node].tobytes())
            h.update(self.free_cg[node].tobytes())
            h.update(self.count[node].tobytes())
            h.update(self.overflow[node].tobytes())
            h.update(self.next_prio[node].tobytes())
            h.update(self.vg[node].tobytes())
            h.update(self.vc[node].tobytes())
            h.update(self.vp[node].tobytes())
            h.update(self.stored[node].tobytes())
            self.fp[node] = np.frombuffer(h.digest(), np.int64)[0]

    def refresh_row(self, node: int, source) -> None:
        """Fill one row from ``source`` (the base cluster or a ClusterView)."""
        self._fresh.discard(node)
        row = encode_row(source, node, self.cap)
        self.free_gpu[node] = row.free_gpu
        self.free_cg[node] = row.free_cg
        self.count[node] = row.count
        self.overflow[node] = row.overflow
        self.next_prio[node] = row.next_priority
        self.stored[node] = row.stored
        self.vg[node] = row.vg
        self.vc[node] = row.vc
        self.vp[node] = row.vp
        self.vu[node] = row.vu
        self.rank[node] = row.rank
        self.refingerprint((node,))


@dataclasses.dataclass
class VictimRow:
    """One node's encoded dense sourcing row (padded to ``cap`` slots)."""

    free_gpu: int
    free_cg: int
    count: int
    overflow: bool           # count > cap: only the prefix is stored
    next_priority: int       # priority of the first victim NOT stored
    vg: np.ndarray           # int32[cap]
    vc: np.ndarray
    vp: np.ndarray
    vu: np.ndarray           # int64[cap]
    rank: np.ndarray
    stored: np.ndarray       # bool[cap]


def encode_row(source, node: int, cap: int) -> VictimRow:
    """Shared row encoder over any Cluster-like read interface (the base
    cluster for `SourcingContext` rows, a `ClusterView` for per-plan
    delta patches).

    When a node holds more than ``cap`` preemptible instances only the
    lowest ``(priority, uid)`` prefix is stored; ``next_priority`` lets
    callers decide per preemptor whether the eligible victims still fit.
    """
    fg, fc = source.free_masks(node)
    victims = sorted((i for i in source.instances_on(node) if i.preemptible),
                     key=lambda i: (i.priority, i.uid))
    row = VictimRow(
        free_gpu=fg, free_cg=fc, count=len(victims),
        overflow=len(victims) > cap,
        next_priority=victims[cap].priority if len(victims) > cap
        else 2**31 - 1,
        vg=np.zeros(cap, np.int32), vc=np.zeros(cap, np.int32),
        vp=np.zeros(cap, np.int32), vu=np.zeros(cap, np.int64),
        rank=np.zeros(cap, np.int32), stored=np.zeros(cap, bool),
    )
    victims = victims[:cap]
    for j, v in enumerate(victims):
        row.vg[j] = v.gpu_mask
        row.vc[j] = v.cg_mask
        row.vp[j] = v.priority
        row.vu[j] = v.uid
        row.stored[j] = True
    if victims:
        uids = np.asarray([v.uid for v in victims])
        row.rank[: len(victims)] = np.argsort(np.argsort(uids))
    return row


# ---------------------------------------------------------------------------------
# Device-resident cluster state (struct-of-arrays on the accelerator)
# ---------------------------------------------------------------------------------

#: rows of the stacked node-state tensor (``DeviceClusterState.nodestate``)
NODE_FIELDS = 5
NS_FREE_GPU, NS_FREE_CG, NS_NODE_ID, NS_OVERFLOW, NS_NEXT_PRIO = range(NODE_FIELDS)

#: rows of the stacked victim tensor (``DeviceClusterState.victims``)
VICTIM_FIELDS = 5
VF_GPU, VF_CG, VF_PRIO, VF_RANK, VF_STORED = range(VICTIM_FIELDS)

#: rows of the stacked drain tensor: free ∪ every stored victim mask — the
#: fully-drained masks Guaranteed Filtering popcounts on device
DRAIN_FIELDS = 2

#: out-of-range row index used to pad scatter/gather index vectors; dropped
#: by ``mode="drop"`` scatters and filled with zero rows by gathers
IDX_SENTINEL = 2**31 - 1

#: largest dirty set ``sync(flush=False)`` may leave pending for
#: in-dispatch overlay before forcing a real scatter (floor — see
#: `max_pending_rows` for the node-count-scaled cap)
MAX_PENDING_ROWS = 16


def max_pending_rows(num_nodes: int) -> int:
    """Node-count-scaled pending-overlay cap (power of two).

    A fixed 16-row cap forces a full-flush scatter after almost every
    commit burst at 10k nodes; scaling the cap with the node axis (~n/64,
    clamped to [`MAX_PENDING_ROWS`, 1024]) keeps overlay uploads amortized
    while the pow2 bucketing still bounds the jit-cache key space."""
    return max(MAX_PENDING_ROWS, min(1024, _pad_pow2(max(1, num_nodes // 64))))


def pack_rows(rows: list[VictimRow], node_ids, cap: int):
    """Stack encoded `VictimRow`s into the device layout.

    Returns ``(nodestate int32[NODE_FIELDS, P], victims
    int32[VICTIM_FIELDS, P, cap], drain int32[DRAIN_FIELDS, P])`` — the same
    column layout `DeviceClusterState` keeps resident, so view deltas can be
    scattered straight onto the resident arrays as a device-side overlay.
    """
    p = len(rows)
    ns = np.zeros((NODE_FIELDS, p), np.int32)
    v = np.zeros((VICTIM_FIELDS, p, cap), np.int32)
    dr = np.zeros((DRAIN_FIELDS, p), np.int32)
    for j, (node, row) in enumerate(zip(node_ids, rows)):
        ns[NS_FREE_GPU, j] = row.free_gpu
        ns[NS_FREE_CG, j] = row.free_cg
        ns[NS_NODE_ID, j] = node
        ns[NS_OVERFLOW, j] = int(row.overflow)
        ns[NS_NEXT_PRIO, j] = row.next_priority
        v[VF_GPU, j] = row.vg
        v[VF_CG, j] = row.vc
        v[VF_PRIO, j] = row.vp
        v[VF_RANK, j] = row.rank
        v[VF_STORED, j] = row.stored
        dr[0, j] = row.free_gpu | int(
            np.bitwise_or.reduce(np.where(row.stored, row.vg, 0)))
        dr[1, j] = row.free_cg | int(
            np.bitwise_or.reduce(np.where(row.stored, row.vc, 0)))
    return ns, v, dr


def pack_context_rows(ctx: "SourcingContext", idx):
    """Vectorized `pack_rows` over `SourcingContext` rows ``idx``."""
    idx = np.asarray(idx, np.int64)
    ns = np.zeros((NODE_FIELDS, len(idx)), np.int32)
    ns[NS_FREE_GPU] = ctx.free_gpu[idx]
    ns[NS_FREE_CG] = ctx.free_cg[idx]
    ns[NS_NODE_ID] = idx
    ns[NS_OVERFLOW] = ctx.overflow[idx]
    ns[NS_NEXT_PRIO] = ctx.next_prio[idx]
    stored = ctx.stored[idx]
    v = np.stack([
        ctx.vg[idx], ctx.vc[idx], ctx.vp[idx], ctx.rank[idx],
        stored.astype(np.int32),
    ]).astype(np.int32)
    dr = np.zeros((DRAIN_FIELDS, len(idx)), np.int32)
    dr[0] = ctx.free_gpu[idx] | np.bitwise_or.reduce(
        np.where(stored, ctx.vg[idx], 0), axis=1)
    dr[1] = ctx.free_cg[idx] | np.bitwise_or.reduce(
        np.where(stored, ctx.vc[idx], 0), axis=1)
    return ns, v, dr


def flatten_rows(ns, v, dr) -> np.ndarray:
    """Concatenate packed rows into ONE int32 row-major buffer.

    Host→device traffic on the plan hot path is dominated by per-array
    upload overhead, not bytes — dirty-row scatters and view-delta patches
    therefore travel as a single ``int32[P, NODE_FIELDS + VICTIM_FIELDS*cap
    + DRAIN_FIELDS]`` buffer and are split again inside the jit."""
    p = ns.shape[1]
    return np.concatenate(
        [ns.T, v.transpose(1, 0, 2).reshape(p, -1), dr.T],
        axis=1).astype(np.int32)


def unflatten_rows(buf, cap: int):
    """Inverse of `flatten_rows`; works on numpy and traced jnp arrays."""
    p = buf.shape[0]
    ns = buf[:, :NODE_FIELDS].T
    v = buf[:, NODE_FIELDS:NODE_FIELDS + VICTIM_FIELDS * cap]
    v = v.reshape(p, VICTIM_FIELDS, cap).transpose(1, 0, 2)
    dr = buf[:, NODE_FIELDS + VICTIM_FIELDS * cap:].T
    return ns, v, dr


def apply_rows(ns, v, dr, idx, buf):
    """Scatter flattened rows onto the three stacked tensors (jnp ``.at``
    semantics; `IDX_SENTINEL` pad entries are dropped).  The single shared
    implementation behind both the resident-state scatter and the fused
    evaluators' in-dispatch view-delta overlay."""
    a, b, c = unflatten_rows(buf, v.shape[2])
    return (ns.at[:, idx].set(a, mode="drop"),
            v.at[:, idx, :].set(b, mode="drop"),
            dr.at[:, idx].set(c, mode="drop"))


_SCATTER_JIT = None


def _scatter_rows(ns, v, dr, idx, buf):
    """One jitted scatter updating every dirty row of all three tensors
    from a single flattened upload buffer."""
    global _SCATTER_JIT
    if _SCATTER_JIT is None:
        import jax

        _SCATTER_JIT = jax.jit(apply_rows)
    return _SCATTER_JIT(ns, v, dr, idx, buf)


def _pad_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def pad_idx(ids, floor: int = 1) -> np.ndarray:
    """Pad a row-index list to a power-of-two bucket with `IDX_SENTINEL`
    (bounds jit-cache variants; sentinels drop out of scatters/gathers)."""
    p = max(floor, _pad_pow2(len(ids)))
    out = np.full(p, IDX_SENTINEL, np.int32)
    out[: len(ids)] = ids
    return out


# ---------------------------------------------------------------------------------
# Device-side view-delta encoder
# ---------------------------------------------------------------------------------

def encode_delta_core(nodestate, victims, didx, rem, fog, foc, og, oc,
                      addg, addc, addp, addv, *, cap: int, a: int):
    """Traced twin of ``pack_rows([encode_row(view, node, cap), ...])``.

    Instead of re-encoding each view-delta node's row on the host (victim
    sort + O(delta) ``free_masks`` overlay per node, then a python pack
    loop), the planned evictions/binds travel as tiny per-node descriptors
    and the patch rows are rebuilt ON DEVICE from the resident base rows:

    * gather the base row by ``didx`` (`IDX_SENTINEL` pads gather zeros),
    * drop removed base victims (``rem`` slot bitmask), apply the freed /
      newly-occupied mask deltas (``fog``/``foc`` | base, ``& ~og``/``oc``),
    * merge up to ``a`` net-new victims (``add*``, pre-sorted by uid
      ascending — planned binds carry NEGATIVE virtual uids, so every add
      orders before every base victim) via a two-pass stable argsort on
      ``(priority, uid-order)``, which reproduces ``encode_row``'s
      ``(priority, uid)`` victim sort bit-exactly without int64 uids ever
      touching the device.

    Returns the flattened patch buffer ``int32[D, NODE_FIELDS +
    VICTIM_FIELDS*cap + DRAIN_FIELDS]`` ready for the fused evaluators'
    in-dispatch overlay (`apply_rows`) — the rows never round-trip through
    python.  Rows whose merge could truncate (base overflow, > ``cap``
    final victims, > ``a`` adds) are host-encoded by the caller instead.
    """
    import jax
    import jax.numpy as jnp

    big = jnp.int32(2**31 - 1)
    ns = jnp.take(nodestate, didx, axis=1, mode="fill", fill_value=0)
    vv = jnp.take(victims, didx, axis=1, mode="fill", fill_value=0)
    fg = (ns[NS_FREE_GPU] | fog) & ~og
    fc = (ns[NS_FREE_CG] | foc) & ~oc
    slot = jnp.arange(cap, dtype=jnp.int32)
    keep = (vv[VF_STORED] != 0) & (((rem[:, None] >> slot[None, :]) & 1) == 0)
    mg = jnp.concatenate([vv[VF_GPU], addg], axis=1)
    mc = jnp.concatenate([vv[VF_CG], addc], axis=1)
    mp = jnp.concatenate([vv[VF_PRIO], addp], axis=1)
    d = didx.shape[0]
    akey = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None, :], (d, a))
    mkey = jnp.concatenate([a + vv[VF_RANK], akey], axis=1)
    mvalid = jnp.concatenate([keep, addv != 0], axis=1)
    # two stable argsorts = one (priority, uid-order) lexsort
    o1 = jnp.argsort(jnp.where(mvalid, mkey, big), axis=1)
    p1 = jnp.where(jnp.take_along_axis(mvalid, o1, axis=1),
                   jnp.take_along_axis(mp, o1, axis=1), big)
    order = jnp.take_along_axis(o1, jnp.argsort(p1, axis=1), axis=1)

    def srt(x):
        return jnp.take_along_axis(x, order, axis=1)[:, :cap]

    st = srt(mvalid)
    sti = st.astype(jnp.int32)
    vg = jnp.where(st, srt(mg), 0)
    vc = jnp.where(st, srt(mc), 0)
    vp = jnp.where(st, srt(mp), 0)
    skey = jnp.where(st, srt(mkey), big)
    rnk = jnp.sum((skey[:, None, :] < skey[:, :, None]) & st[:, None, :],
                  axis=2, dtype=jnp.int32)
    rank = jnp.where(st, rnk, 0)
    new_ns = jnp.stack([fg, fc, didx,
                        jnp.zeros_like(fg), jnp.full_like(fg, big)])
    drg = fg | jax.lax.reduce(vg, np.int32(0), jax.lax.bitwise_or, (1,))
    drc = fc | jax.lax.reduce(vc, np.int32(0), jax.lax.bitwise_or, (1,))
    new_v = jnp.stack([vg, vc, vp, rank, sti])
    new_dr = jnp.stack([drg, drc])
    return jnp.concatenate(
        [new_ns.T, new_v.transpose(1, 0, 2).reshape(d, -1), new_dr.T], axis=1)


_DELTA_ENCODERS: dict = {}


def delta_encoder(cap: int, a: int):
    """Jitted `encode_delta_core` keyed by (victim cap, add bucket); the
    descriptor length ``D`` stays dynamic (pow2-padded by the caller), so
    variants are bounded by the few (cap, a) combinations in play."""
    key = (cap, a)
    fn = _DELTA_ENCODERS.get(key)
    if fn is None:
        import functools

        import jax

        fn = jax.jit(functools.partial(encode_delta_core, cap=cap, a=a))
        _DELTA_ENCODERS[key] = fn
    return fn


class ViewDelta:
    """Per-plan descriptor set for the device-side delta encoder.

    Built once per fused ``plan()`` from the `ClusterView`'s planned
    evictions/binds (O(delta instances) host work, no per-node victim
    sort): nodes whose patch row the device can rebuild exactly carry tiny
    descriptor columns (`device_rows` feeds them to `delta_encoder`);
    nodes behind a replay gate — resident row still pending-stale, base
    row truncated, more than ``a_max`` adds, or a post-merge victim count
    above ``cap`` — fall back to host `encode_row` (the ``fallback``
    dict).  Winner uid decode stays lazy and host-side: `row(node)`
    encodes ONE node on demand (uids are int64 and never on device).
    """

    def __init__(self, view, ctx: "SourcingContext", pending,
                 a_max: int = 8) -> None:
        self.view = view
        self.cap = cap = ctx.cap
        self.fallback: dict[int, VictimRow] = {}
        self._rows: dict[int, VictimRow] = {}
        dense: list[int] = []
        descs: list[tuple] = []
        max_adds = 1
        per: dict[int, list] = {}
        for inst in view._evicted.values():
            per.setdefault(inst.node, []).append((False, inst))
        for inst in view._added.values():
            per.setdefault(inst.node, []).append((True, inst))
        for node, insts in per.items():
            bad = (node in pending or bool(ctx.overflow[node])
                   or node in ctx._fresh)
            fog = foc = og = oc = 0
            removed: set[int] = set()
            adds: list[tuple] = []
            for is_add, inst in insts:
                if is_add:
                    og |= inst.gpu_mask
                    oc |= inst.cg_mask
                    if inst.preemptible:
                        adds.append((inst.uid, inst.priority,
                                     inst.gpu_mask, inst.cg_mask))
                else:
                    fog |= inst.gpu_mask
                    foc |= inst.cg_mask
                    if inst.preemptible:
                        removed.add(inst.uid)
            keep = ctx.stored[node] & ~np.isin(
                ctx.vu[node], np.fromiter(removed, np.int64, len(removed)))
            count = int(keep.sum()) + len(adds)
            if bad or len(adds) > a_max or count > cap:
                self.fallback[node] = self.row(node)
                continue
            rem = int(np.bitwise_or.reduce(
                np.where(ctx.stored[node] & ~keep, 1 << np.arange(cap), 0)))
            adds.sort()     # uid ascending == global (priority, uid) prep
            dense.append(node)
            descs.append((rem, fog, foc, og, oc, adds, keep))
            max_adds = max(max_adds, len(adds))
        self.a = _pad_pow2(max_adds)
        d = len(dense)
        self.dense = np.asarray(dense, np.int32)
        self.rem = np.zeros(d, np.int32)
        self.fog = np.zeros(d, np.int32)
        self.foc = np.zeros(d, np.int32)
        self.og = np.zeros(d, np.int32)
        self.oc = np.zeros(d, np.int32)
        self.addg = np.zeros((d, self.a), np.int32)
        self.addc = np.zeros((d, self.a), np.int32)
        self.addp = np.zeros((d, self.a), np.int32)
        self.addv = np.zeros((d, self.a), np.int32)
        # host routing metadata (no device round-trip): surviving base
        # priorities + add priorities per dense node
        self._vp = np.full((d, cap), 2**31 - 1, np.int32)
        self._count = np.zeros(d, np.int32)
        for i, (node, (rem, fog, foc, og, oc, adds, keep)) in enumerate(
                zip(dense, descs)):
            self.rem[i] = rem
            self.fog[i], self.foc[i] = fog, foc
            self.og[i], self.oc[i] = og, oc
            for j, (_, prio, gm, cm) in enumerate(adds):
                self.addg[i, j] = gm
                self.addc[i, j] = cm
                self.addp[i, j] = prio
                self.addv[i, j] = 1
            self._vp[i][keep] = ctx.vp[node][keep]
            self._count[i] = keep.sum() + len(adds)
        self._addp_m = np.where(self.addv != 0, self.addp, 2**31 - 1)
        self._pos = {int(n): i for i, n in enumerate(dense)}

    # -- container interface (the delta-node set) ---------------------------------
    def __len__(self) -> int:
        return len(self._pos) + len(self.fallback)

    def __contains__(self, node: int) -> bool:
        return node in self._pos or node in self.fallback

    def __iter__(self):
        yield from self._pos
        yield from self.fallback

    # -- host routing metadata ----------------------------------------------------
    def elig_bad(self, thresh: int):
        """Per delta node: eligible stored victims under ``thresh`` and
        whether truncation could hide eligible victims (dense rows never
        truncate by construction)."""
        elig = {node: int(((row.vp < thresh) & row.stored).sum())
                for node, row in self.fallback.items()}
        bad = {node: bool(row.overflow) and row.next_priority < thresh
               for node, row in self.fallback.items()}
        if len(self._pos):
            cnt = ((self._vp < thresh).sum(axis=1)
                   + (self._addp_m < thresh).sum(axis=1))
            for node, i in self._pos.items():
                elig[node] = int(cnt[i])
                bad[node] = False
        return elig, bad

    def count(self, node: int) -> int:
        i = self._pos.get(node)
        if i is not None:
            return int(self._count[i])
        return self.fallback[node].count

    def row(self, node: int) -> VictimRow:
        """Exact host row for one delta node (winner uid decode / wide
        fallbacks) — lazy, cached, O(1) nodes per plan."""
        row = self._rows.get(node)
        if row is None:
            row = self._rows[node] = encode_row(self.view, node, self.cap)
        return row

    # -- device path ---------------------------------------------------------------
    def device_rows(self, dcs: "DeviceClusterState"):
        """Encode every dense delta row on device: returns ``(didx
        int32[Dp], buf int32[Dp, width])`` pow2-padded, buf still on
        device.  Empty when all delta nodes fell back."""
        import jax.numpy as jnp

        d = len(self.dense)
        if d == 0:
            return None
        didx = pad_idx(self.dense)
        dp = len(didx)

        def pad(x):
            if len(x) == dp:
                return x
            width = ((0, dp - d),) + ((0, 0),) * (x.ndim - 1)
            return np.pad(x, width)

        buf = dcs.delta_encode(
            self.a, jnp.asarray(didx),
            jnp.asarray(pad(self.rem)), jnp.asarray(pad(self.fog)),
            jnp.asarray(pad(self.foc)), jnp.asarray(pad(self.og)),
            jnp.asarray(pad(self.oc)), jnp.asarray(pad(self.addg)),
            jnp.asarray(pad(self.addc)), jnp.asarray(pad(self.addp)),
            jnp.asarray(pad(self.addv)))
        return didx, buf


class DeviceClusterState:
    """Device-resident struct-of-arrays view of the cluster's sourcing state.

    Three stacked int32 tensors live ON DEVICE across plans:

    * ``nodestate [NODE_FIELDS, N]`` — free-GPU/CG slot masks, node id,
      overflow flag, first-unstored priority;
    * ``victims   [VICTIM_FIELDS, N, cap]`` — per-slot victim GPU/CG masks,
      priorities, uid-ranks, stored flags (the ``(priority, uid)``-sorted
      rows of the host `SourcingContext` mirror);
    * ``drain     [DRAIN_FIELDS, N]`` — per-node fully-drained masks
      (free ∪ all stored victim masks), the popcount input of the fused
      Guaranteed-Filtering step.

    ``slices`` exposes the static NUMA/socket slice layout of the SKU
    (`placement_jax.SpecSlices`) that the device-side placement scorer —
    the normal cycle and the winner's §3.4 mask selection, both fused into
    the sourcing dispatch — popcounts these tensors against.

    The host `SourcingContext` stays as the *mirror*: it keeps the int64
    victim uids (decoded only for the winner) and the counts the host needs
    for wide/overflow routing.  Both subscribe to ``invalidate_node``, so a
    ``bind``/``evict``/``restore`` marks single rows dirty; ``sync()``
    refreshes the mirror lazily and pushes ONLY the dirty rows to the device
    as one ``.at[rows].set()`` scatter — no per-plan host rebuild/upload.
    Copy-on-write `ClusterView` deltas never touch these arrays: the fused
    evaluators overlay patch rows inside the dispatch (``pack_rows``).
    """

    #: device mesh the stacked tensors are sharded over (None = single
    #: device; `ShardedDeviceClusterState` overrides)
    mesh = None

    def __init__(self, cluster: Cluster, cap: int | None = None) -> None:
        self.cluster = cluster
        self.mirror = cluster.sourcing_context()
        if cap is not None and cap != self.mirror.cap:
            raise ValueError("device cap must match the mirror's cap")
        self.cap = self.mirror.cap
        self.max_pending = max_pending_rows(cluster.num_nodes)
        self.nodestate = None   # jnp.int32[NODE_FIELDS, n_rows]
        self.victims = None     # jnp.int32[VICTIM_FIELDS, n_rows, cap]
        self.drain = None       # jnp.int32[DRAIN_FIELDS, n_rows]
        #: host fast-path: when no node stores more than NARROW_M victims,
        #: per-plan wide/overflow routing is skipped entirely
        self.count_max = 0
        #: monotonic state counter, bumped by every invalidation: entries
        #: of ``plan_cache`` (per-preemptor routing splits + uploaded
        #: index/patch device arrays for the delta-free fast path) record
        #: the version they were built at and are ignored once it moves
        self.version = 0
        self.plan_cache: dict = {}
        #: per-version equivalence-class cache: (version, rep bool[n_rows]
        #: host mask, device copy).  Rebuilt lazily by ``rep_classes`` —
        #: a plan window with no commits reuses both arrays untouched.
        self._rep_cache: tuple | None = None
        self._dirty: set[int] = set(range(cluster.num_nodes))
        cluster.add_dirty_listener(self._mark_dirty)

    def _mark_dirty(self, node: int) -> None:
        self._dirty.add(node)
        self.version += 1

    def sync(self, flush: bool = True) -> "DeviceClusterState":
        """Bring the device view up to date with the live cluster.

        Dirty rows are packed host-side (O(dirty) python) and applied as a
        single scatter; a majority-dirty state falls back to one full
        upload.  Index vectors are padded to power-of-two buckets with
        `IDX_SENTINEL` so the scatter jit-cache stays small.

        ``flush=False`` refreshes the host mirror but leaves a SMALL dirty
        set resident-stale in ``pending``: the fused evaluators overlay
        those rows in-dispatch exactly like view-delta patches, saving the
        separate scatter dispatch on the plan hot path.  Large pending sets
        are flushed regardless so the overlay bucket stays small.
        """
        self.mirror.refresh()
        n = self.cluster.num_nodes
        if self.nodestate is None or 2 * len(self._dirty) >= max(n, 2):
            ns, v, dr = pack_context_rows(self.mirror, np.arange(n))
            self.nodestate, self.victims, self.drain = self._upload_full(
                ns, v, dr)
            self._dirty.clear()
        elif self._dirty and (flush or len(self._dirty) > self.max_pending):
            rows = sorted(self._dirty)
            buf = flatten_rows(*pack_context_rows(self.mirror, rows))
            idx = pad_idx(rows)
            if len(idx) > len(rows):
                buf = np.pad(buf, ((0, len(idx) - len(rows)), (0, 0)))
            self.nodestate, self.victims, self.drain = self._scatter(idx, buf)
            self._dirty.clear()
        self.count_max = int(self.mirror.count.max()) if n else 0
        return self

    @property
    def n_rows(self) -> int:
        """Length of the device node axis (== ``num_nodes`` here; the
        sharded subclass pads to a multiple of the device count)."""
        return self.cluster.num_nodes

    def rep_classes(self):
        """Equivalence-class representative mask over the node axis.

        Returns ``(rep_host, rep_dev)``: a ``bool[n_rows]`` mask that is
        True exactly for the LOWEST-index member of every fingerprint
        class (`SourcingContext.refingerprint`), host- and device-side.
        Because the fused winner argmax breaks score ties by lower node
        id before any uid comparison, the full-sweep winner inside a
        class is always its lowest-id member — sweeping representatives
        only is exact.  Call after ``sync()`` (the mirror fingerprints
        must be fresh); cached per ``version`` so plan-only windows pay
        a dict probe.  Rows past ``num_nodes`` (sharded padding) carry
        sentinel node ids and stay False.
        """
        cache = self._rep_cache
        if cache is not None and cache[0] == self.version:
            return cache[1], cache[2]
        n = self.cluster.num_nodes
        rep = np.zeros(self.n_rows, bool)
        _, first = np.unique(self.mirror.fp[:n], return_index=True)
        rep[first] = True
        rep_dev = self._upload_rep(rep)
        self._rep_cache = (self.version, rep, rep_dev)
        return rep, rep_dev

    def _upload_rep(self, rep):
        """Representative-mask upload hook (sharded subclass lays the row
        axis out over the mesh to match the resident tensors)."""
        import jax.numpy as jnp

        return jnp.asarray(rep)

    def _upload_full(self, ns, v, dr):
        """Full-rebuild upload hook (subclasses re-layout/shard here)."""
        import jax.numpy as jnp

        return jnp.asarray(ns), jnp.asarray(v), jnp.asarray(dr)

    def _scatter(self, idx, buf):
        """Dirty-row scatter hook (subclasses keep the output sharded)."""
        import jax.numpy as jnp

        return _scatter_rows(self.nodestate, self.victims, self.drain,
                             jnp.asarray(idx), jnp.asarray(buf))

    def delta_encode(self, a: int, didx, *descs):
        """Run the device-side view-delta encoder against the resident
        base tensors (`ViewDelta.device_rows` feeds the descriptors).  The
        sharded subclass overrides to pin the descriptor inputs and the
        tiny patch-row output replicated across the mesh."""
        return delta_encoder(self.cap, a)(self.nodestate, self.victims,
                                          didx, *descs)

    @property
    def pending(self) -> set[int]:
        """Rows whose device copy is stale (mirror is fresh after sync):
        deferred by ``sync(flush=False)`` for in-dispatch overlay."""
        return self._dirty

    @property
    def slices(self):
        """The SKU's static NUMA/socket slice layout, device-resident.

        Convenience accessor for the `repro.core.placement_jax.SpecSlices`
        of this cluster's spec (per-NUMA GPU/CoreGroup mask columns, socket
        one-hot, placement scope-membership matrix, lowest-bit selector
        tables) — the layout the fused placement scorers are traced
        against.  The jit evaluators resolve it per-spec via
        ``spec_slices`` internally; this property returns the SAME cached
        object for introspection and tests."""
        from .placement_jax import spec_slices

        return spec_slices(self.cluster.spec)
