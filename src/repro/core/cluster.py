"""Cluster state: N servers × FlexTopo + the instance registry.

The scheduler and simulator mutate cluster state exclusively through this
class so that the FlexTopo graphs, the bitmask arrays, and the instance
registry can never diverge.  ``arrays()`` exports the dense engine view used
by the vectorized/Pallas preemption engines, ``sourcing_context()`` hands
out the incrementally-maintained host `SourcingContext` mirror, and
``device_state()`` hands out the `DeviceClusterState` — the struct-of-arrays
copy of the sourcing rows that stays RESIDENT on the accelerator across
plans.  ``invalidate_node`` marks single rows dirty in both; the device copy
re-uploads only those rows as one ``.at[rows].set()`` scatter per sync, so a
``plan()`` never re-uploads the whole ``[N, M]`` state host→device.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

import numpy as np

from .flextopo import FlexTopo
from .placement import Placement
from .topology import ServerSpec
from .workload import Instance, WorkloadSpec

#: Widest per-node victim row the dense sourcing arrays encode.  Nodes
#: holding more preemptible instances than this overflow the row and are
#: sourced through the per-node python engine instead (see
#: ``preemption_jax``) — the batched engines degrade gracefully rather than
#: crash.
MAX_DENSE_VICTIMS = 16


@dataclasses.dataclass
class ClusterArrays:
    """Dense snapshot for the vectorized engines."""

    free_gpu: np.ndarray      # int32[N] free-GPU bitmask per node
    free_cg: np.ndarray       # int32[N] free-CoreGroup bitmask per node
    numa_gpu_masks: np.ndarray    # int32[U]
    numa_cg_masks: np.ndarray     # int32[U]
    socket_of_numa: np.ndarray    # int32[U]


class Cluster:
    def __init__(self, spec: ServerSpec, num_nodes: int,
                 node_index: bool = True) -> None:
        self.spec = spec
        self.num_nodes = num_nodes
        self.topos = [FlexTopo(spec, node_name=f"node-{i}") for i in range(num_nodes)]
        self.instances: dict[int, Instance] = {}
        self._uid = itertools.count()
        # per-node instance index + cached free masks: turns victims_on /
        # free_masks from O(total instances) scans into O(node) lookups
        # (§Perf scheduler hillclimb; node_index=False is the naive baseline)
        self.node_index = node_index
        self._by_node: list[set[int]] = [set() for _ in range(num_nodes)]
        self._mask_cache: list[tuple[int, int] | None] = [None] * num_nodes
        # node-dirty fan-out: every mutation funnels through invalidate_node,
        # which notifies subscribers (the SourcingContext) so dense engine
        # rows refresh incrementally instead of rebuilding from instance lists
        self._dirty_listeners: list[Callable[[int], None]] = []
        self._sourcing_ctx: "SourcingContext | None" = None
        self._device_state: "DeviceClusterState | None" = None

    # ---- mutation -----------------------------------------------------------------
    def bind(self, workload: WorkloadSpec, node: int, placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        gpus = [g for g in range(self.spec.num_gpus) if placement.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if placement.cg_mask >> c & 1]
        self.topos[node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[node].add(inst.uid)
        self.invalidate_node(node)
        return inst

    def evict(self, uid: int) -> Instance:
        inst = self.instances.pop(uid)
        self.topos[inst.node].release(inst.name)
        self._by_node[inst.node].discard(uid)
        self.invalidate_node(inst.node)
        return inst

    def restore(self, inst: Instance) -> Instance:
        """Re-insert a previously evicted instance with full fidelity.

        Unlike ``bind``, the instance keeps its original uid, node, and
        GPU/CoreGroup masks — this is what ``Transaction.rollback`` uses so
        that reversing a preemption is bitwise-exact.
        """
        if inst.uid in self.instances:
            raise ValueError(f"uid {inst.uid} already bound")
        gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
        cgs = [c for c in range(self.spec.num_coregroups) if inst.cg_mask >> c & 1]
        self.topos[inst.node].allocate(inst.name, gpus, cgs)
        self.instances[inst.uid] = inst
        self._by_node[inst.node].add(inst.uid)
        self.invalidate_node(inst.node)
        return inst

    def invalidate_node(self, node: int) -> None:
        """Single choke point for node-state changes: drops the free-mask
        cache and notifies dirty listeners (incremental sourcing arrays)."""
        self._mask_cache[node] = None
        for fn in self._dirty_listeners:
            fn(node)

    def add_dirty_listener(self, fn: Callable[[int], None]) -> None:
        """Subscribe to per-node invalidation events (bind/evict/restore)."""
        self._dirty_listeners.append(fn)

    def sourcing_context(self) -> "SourcingContext":
        """The lazily-created incremental array cache for fused sourcing."""
        if self._sourcing_ctx is None:
            self._sourcing_ctx = SourcingContext(self)
        return self._sourcing_ctx

    def device_state(self) -> "DeviceClusterState":
        """The lazily-created device-resident struct-of-arrays state."""
        if self._device_state is None:
            self._device_state = DeviceClusterState(self)
        return self._device_state

    # ---- queries --------------------------------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        if self.node_index:
            cached = self._mask_cache[node]
            if cached is None:
                m = self.topos[node].as_masks()
                cached = (m.free_gpu_mask, m.free_cg_mask)
                self._mask_cache[node] = cached
            return cached
        m = self.topos[node].as_masks()
        return m.free_gpu_mask, m.free_cg_mask

    def instances_on(self, node: int) -> list[Instance]:
        if self.node_index:
            return [self.instances[u] for u in self._by_node[node]]
        return [i for i in self.instances.values() if i.node == node]

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        """Potential victims: strictly lower priority and preemptible."""
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    def arrays(self) -> ClusterArrays:
        free_gpu = np.zeros(self.num_nodes, dtype=np.int32)
        free_cg = np.zeros(self.num_nodes, dtype=np.int32)
        for n, topo in enumerate(self.topos):
            m = topo.as_masks()
            free_gpu[n] = m.free_gpu_mask
            free_cg[n] = m.free_cg_mask
        return ClusterArrays(
            free_gpu=free_gpu,
            free_cg=free_cg,
            numa_gpu_masks=self.spec.numa_gpu_masks,
            numa_cg_masks=self.spec.numa_cg_masks,
            socket_of_numa=self.spec.socket_of_numa_arr,
        )

    # ---- reporting --------------------------------------------------------------------
    def count_by_workload(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for inst in self.instances.values():
            out[inst.workload.name] = out.get(inst.workload.name, 0) + 1
        return out

    def allocation_snapshot(self) -> list[dict]:
        """Fig. 8-style snapshot: per instance, its node/GPU indices and tier."""
        from .placement import achieved_tier

        rows = []
        for inst in sorted(self.instances.values(), key=lambda i: (i.node, i.uid)):
            gpus = [g for g in range(self.spec.num_gpus) if inst.gpu_mask >> g & 1]
            rows.append({
                "instance": inst.name,
                "workload": inst.workload.name,
                "node": inst.node,
                "gpus": gpus,
                "tier": achieved_tier(self.spec, inst.gpu_mask),
            })
        return rows

    def view(self) -> "ClusterView":
        """Copy-on-write planning view over the current state."""
        return ClusterView(self)

    def cross_socket_instances(self) -> int:
        """Fig. 8 headline number: instances whose GPUs span sockets."""
        from .placement import achieved_tier, min_tier_for

        return sum(
            1
            for inst in self.instances.values()
            if inst.gpu_mask
            and achieved_tier(self.spec, inst.gpu_mask)
            > min_tier_for(self.spec, inst.gpu_mask.bit_count())
        )


class ClusterView:
    """Copy-on-write overlay over a `Cluster` for transactional planning.

    Presents the same read interface the sourcing engines and the scheduler
    use (``spec``, ``num_nodes``, ``free_masks``, ``instances_on``,
    ``victims_on``) but records evictions and binds locally instead of
    mutating the base cluster.  Planned binds get *virtual* (negative) uids
    so they can never collide with live instances; ``Transaction.commit``
    later replays the plan onto the base cluster for real.

    One view can host several ``plan()`` calls (``plan_batch``): later plans
    see earlier planned evictions/binds, so a batch of decisions composes
    against a single snapshot.
    """

    def __init__(self, base: Cluster) -> None:
        self.base = base
        self.spec = base.spec
        self.num_nodes = base.num_nodes
        self._evicted: dict[int, Instance] = {}
        self._added: dict[int, Instance] = {}
        self._uid = itertools.count(-1, -1)
        # virtual uid -> real uid, filled as the view's transactions commit so
        # later transactions can resolve victims planned against earlier binds
        self.committed_uids: dict[int, int] = {}
        # per-node planned-mutation counter: lets callers (the batch
        # sourcing session) cache row encodings across plans sharing this
        # view and re-encode only rows a later plan actually touched
        self._node_version: dict[int, int] = {}

    # -- read interface (mirrors Cluster) ------------------------------------------
    def free_masks(self, node: int) -> tuple[int, int]:
        fg, fc = self.base.free_masks(node)
        for inst in self._evicted.values():
            if inst.node == node:
                fg |= inst.gpu_mask
                fc |= inst.cg_mask
        for inst in self._added.values():
            if inst.node == node:
                fg &= ~inst.gpu_mask
                fc &= ~inst.cg_mask
        return fg, fc

    def instances_on(self, node: int) -> list[Instance]:
        live = [i for i in self.base.instances_on(node)
                if i.uid not in self._evicted]
        live.extend(i for i in self._added.values() if i.node == node)
        return live

    def victims_on(self, node: int, preemptor_priority: int) -> list[Instance]:
        return sorted(
            (
                i for i in self.instances_on(node)
                if i.preemptible and i.priority < preemptor_priority
            ),
            key=lambda i: (i.priority, i.uid),
        )

    # -- planned mutations ----------------------------------------------------------
    def _bump(self, node: int) -> None:
        self._node_version[node] = self._node_version.get(node, 0) + 1

    def node_version(self, node: int) -> int:
        """Planned-mutation counter for one node (0 = untouched)."""
        return self._node_version.get(node, 0)

    def plan_evict(self, uid: int) -> Instance:
        if uid in self._added:
            inst = self._added.pop(uid)
            self._bump(inst.node)
            return inst
        inst = self.base.instances[uid]
        if uid in self._evicted:
            raise ValueError(f"uid {uid} already planned for eviction")
        self._evicted[uid] = inst
        self._bump(inst.node)
        return inst

    def plan_bind(self, workload: WorkloadSpec, node: int,
                  placement: Placement) -> Instance:
        inst = Instance(uid=next(self._uid), workload=workload, node=node,
                        gpu_mask=placement.gpu_mask, cg_mask=placement.cg_mask)
        self._added[inst.uid] = inst
        self._bump(node)
        return inst

    def resolve_uid(self, uid: int) -> int:
        """Map a virtual (planned-bind) uid to the real uid it committed as."""
        return self.committed_uids.get(uid, uid)

    def delta_nodes(self) -> set[int]:
        """Nodes whose state differs from the base cluster (planned deltas)."""
        return ({i.node for i in self._evicted.values()}
                | {i.node for i in self._added.values()})


class SourcingContext:
    """Incrementally-maintained dense arrays for the fused sourcing path.

    One row per node holds the padded bitmask/priority/uid arrays of ALL
    preemptible instances on that node (sorted by ``(priority, uid)``, the
    same order ``victims_on`` yields).  The preemptor-priority filter is NOT
    baked in: the fused evaluator masks victims by ``priority < preemptor``
    on device, so one cache serves every preemptor class.

    Invalidation semantics: the context subscribes to the cluster's
    ``invalidate_node`` choke point (hit by every ``bind``/``evict``/
    ``restore``/explicit invalidation) and marks rows dirty; ``refresh()``
    rebuilds only the dirty rows lazily before the next read.  A full
    ``plan()`` therefore touches O(dirty nodes) python state instead of
    reconstructing ``[N, M]`` arrays from instance lists.

    ``rank`` is each victim's uid-rank within its node's stored victims —
    the fused evaluator packs a combo's ranks into a bitmask whose integer
    order equals the lexicographic order of the combo's sorted uid tuple,
    reproducing ``select_best``'s victim-uid tie-break on device.

    Rows with more than `MAX_DENSE_VICTIMS` preemptible instances are marked
    ``overflow`` but still store the first `cap` victims (the lowest
    ``(priority, uid)`` prefix) plus ``next_prio``, the priority of the
    first victim NOT stored.  Because any preemptor's eligible victims
    (``priority < preemptor``) are a prefix of that order, a truncated row
    stays on the fused fast path whenever ``next_prio >= preemptor``;
    callers fall back to per-node sourcing only when eligible victims
    genuinely exceed the row (the old ``_bucket`` ValueError now degrades
    instead of crashing).
    """

    def __init__(self, cluster: Cluster, cap: int = MAX_DENSE_VICTIMS) -> None:
        self.cluster = cluster
        self.cap = cap
        n = cluster.num_nodes
        self.free_gpu = np.zeros(n, np.int32)
        self.free_cg = np.zeros(n, np.int32)
        self.vg = np.zeros((n, cap), np.int32)      # victim GPU bitmasks
        self.vc = np.zeros((n, cap), np.int32)      # victim CoreGroup bitmasks
        self.vp = np.zeros((n, cap), np.int32)      # victim priorities
        self.vu = np.zeros((n, cap), np.int64)      # victim uids
        self.rank = np.zeros((n, cap), np.int32)    # uid-rank within the node
        self.stored = np.zeros((n, cap), bool)      # slot holds an instance
        self.count = np.zeros(n, np.int32)          # preemptible instances
        self.overflow = np.zeros(n, bool)           # count > cap: truncated
        self.next_prio = np.full(n, 2**31 - 1, np.int32)  # 1st unstored prio
        self._dirty: set[int] = set(range(n))
        cluster.add_dirty_listener(self._dirty.add)

    def refresh(self) -> None:
        """Re-derive every dirty row from the live cluster state."""
        for node in self._dirty:
            self.refresh_row(node, self.cluster)
        self._dirty.clear()

    def refresh_row(self, node: int, source) -> None:
        """Fill one row from ``source`` (the base cluster or a ClusterView)."""
        row = encode_row(source, node, self.cap)
        self.free_gpu[node] = row.free_gpu
        self.free_cg[node] = row.free_cg
        self.count[node] = row.count
        self.overflow[node] = row.overflow
        self.next_prio[node] = row.next_priority
        self.stored[node] = row.stored
        self.vg[node] = row.vg
        self.vc[node] = row.vc
        self.vp[node] = row.vp
        self.vu[node] = row.vu
        self.rank[node] = row.rank


@dataclasses.dataclass
class VictimRow:
    """One node's encoded dense sourcing row (padded to ``cap`` slots)."""

    free_gpu: int
    free_cg: int
    count: int
    overflow: bool           # count > cap: only the prefix is stored
    next_priority: int       # priority of the first victim NOT stored
    vg: np.ndarray           # int32[cap]
    vc: np.ndarray
    vp: np.ndarray
    vu: np.ndarray           # int64[cap]
    rank: np.ndarray
    stored: np.ndarray       # bool[cap]


def encode_row(source, node: int, cap: int) -> VictimRow:
    """Shared row encoder over any Cluster-like read interface (the base
    cluster for `SourcingContext` rows, a `ClusterView` for per-plan
    delta patches).

    When a node holds more than ``cap`` preemptible instances only the
    lowest ``(priority, uid)`` prefix is stored; ``next_priority`` lets
    callers decide per preemptor whether the eligible victims still fit.
    """
    fg, fc = source.free_masks(node)
    victims = sorted((i for i in source.instances_on(node) if i.preemptible),
                     key=lambda i: (i.priority, i.uid))
    row = VictimRow(
        free_gpu=fg, free_cg=fc, count=len(victims),
        overflow=len(victims) > cap,
        next_priority=victims[cap].priority if len(victims) > cap
        else 2**31 - 1,
        vg=np.zeros(cap, np.int32), vc=np.zeros(cap, np.int32),
        vp=np.zeros(cap, np.int32), vu=np.zeros(cap, np.int64),
        rank=np.zeros(cap, np.int32), stored=np.zeros(cap, bool),
    )
    victims = victims[:cap]
    for j, v in enumerate(victims):
        row.vg[j] = v.gpu_mask
        row.vc[j] = v.cg_mask
        row.vp[j] = v.priority
        row.vu[j] = v.uid
        row.stored[j] = True
    if victims:
        uids = np.asarray([v.uid for v in victims])
        row.rank[: len(victims)] = np.argsort(np.argsort(uids))
    return row


# ---------------------------------------------------------------------------------
# Device-resident cluster state (struct-of-arrays on the accelerator)
# ---------------------------------------------------------------------------------

#: rows of the stacked node-state tensor (``DeviceClusterState.nodestate``)
NODE_FIELDS = 5
NS_FREE_GPU, NS_FREE_CG, NS_NODE_ID, NS_OVERFLOW, NS_NEXT_PRIO = range(NODE_FIELDS)

#: rows of the stacked victim tensor (``DeviceClusterState.victims``)
VICTIM_FIELDS = 5
VF_GPU, VF_CG, VF_PRIO, VF_RANK, VF_STORED = range(VICTIM_FIELDS)

#: rows of the stacked drain tensor: free ∪ every stored victim mask — the
#: fully-drained masks Guaranteed Filtering popcounts on device
DRAIN_FIELDS = 2

#: out-of-range row index used to pad scatter/gather index vectors; dropped
#: by ``mode="drop"`` scatters and filled with zero rows by gathers
IDX_SENTINEL = 2**31 - 1

#: largest dirty set ``sync(flush=False)`` may leave pending for
#: in-dispatch overlay before forcing a real scatter
MAX_PENDING_ROWS = 16


def pack_rows(rows: list[VictimRow], node_ids, cap: int):
    """Stack encoded `VictimRow`s into the device layout.

    Returns ``(nodestate int32[NODE_FIELDS, P], victims
    int32[VICTIM_FIELDS, P, cap], drain int32[DRAIN_FIELDS, P])`` — the same
    column layout `DeviceClusterState` keeps resident, so view deltas can be
    scattered straight onto the resident arrays as a device-side overlay.
    """
    p = len(rows)
    ns = np.zeros((NODE_FIELDS, p), np.int32)
    v = np.zeros((VICTIM_FIELDS, p, cap), np.int32)
    dr = np.zeros((DRAIN_FIELDS, p), np.int32)
    for j, (node, row) in enumerate(zip(node_ids, rows)):
        ns[NS_FREE_GPU, j] = row.free_gpu
        ns[NS_FREE_CG, j] = row.free_cg
        ns[NS_NODE_ID, j] = node
        ns[NS_OVERFLOW, j] = int(row.overflow)
        ns[NS_NEXT_PRIO, j] = row.next_priority
        v[VF_GPU, j] = row.vg
        v[VF_CG, j] = row.vc
        v[VF_PRIO, j] = row.vp
        v[VF_RANK, j] = row.rank
        v[VF_STORED, j] = row.stored
        dr[0, j] = row.free_gpu | int(
            np.bitwise_or.reduce(np.where(row.stored, row.vg, 0)))
        dr[1, j] = row.free_cg | int(
            np.bitwise_or.reduce(np.where(row.stored, row.vc, 0)))
    return ns, v, dr


def pack_context_rows(ctx: "SourcingContext", idx):
    """Vectorized `pack_rows` over `SourcingContext` rows ``idx``."""
    idx = np.asarray(idx, np.int64)
    ns = np.zeros((NODE_FIELDS, len(idx)), np.int32)
    ns[NS_FREE_GPU] = ctx.free_gpu[idx]
    ns[NS_FREE_CG] = ctx.free_cg[idx]
    ns[NS_NODE_ID] = idx
    ns[NS_OVERFLOW] = ctx.overflow[idx]
    ns[NS_NEXT_PRIO] = ctx.next_prio[idx]
    stored = ctx.stored[idx]
    v = np.stack([
        ctx.vg[idx], ctx.vc[idx], ctx.vp[idx], ctx.rank[idx],
        stored.astype(np.int32),
    ]).astype(np.int32)
    dr = np.zeros((DRAIN_FIELDS, len(idx)), np.int32)
    dr[0] = ctx.free_gpu[idx] | np.bitwise_or.reduce(
        np.where(stored, ctx.vg[idx], 0), axis=1)
    dr[1] = ctx.free_cg[idx] | np.bitwise_or.reduce(
        np.where(stored, ctx.vc[idx], 0), axis=1)
    return ns, v, dr


def flatten_rows(ns, v, dr) -> np.ndarray:
    """Concatenate packed rows into ONE int32 row-major buffer.

    Host→device traffic on the plan hot path is dominated by per-array
    upload overhead, not bytes — dirty-row scatters and view-delta patches
    therefore travel as a single ``int32[P, NODE_FIELDS + VICTIM_FIELDS*cap
    + DRAIN_FIELDS]`` buffer and are split again inside the jit."""
    p = ns.shape[1]
    return np.concatenate(
        [ns.T, v.transpose(1, 0, 2).reshape(p, -1), dr.T],
        axis=1).astype(np.int32)


def unflatten_rows(buf, cap: int):
    """Inverse of `flatten_rows`; works on numpy and traced jnp arrays."""
    p = buf.shape[0]
    ns = buf[:, :NODE_FIELDS].T
    v = buf[:, NODE_FIELDS:NODE_FIELDS + VICTIM_FIELDS * cap]
    v = v.reshape(p, VICTIM_FIELDS, cap).transpose(1, 0, 2)
    dr = buf[:, NODE_FIELDS + VICTIM_FIELDS * cap:].T
    return ns, v, dr


def apply_rows(ns, v, dr, idx, buf):
    """Scatter flattened rows onto the three stacked tensors (jnp ``.at``
    semantics; `IDX_SENTINEL` pad entries are dropped).  The single shared
    implementation behind both the resident-state scatter and the fused
    evaluators' in-dispatch view-delta overlay."""
    a, b, c = unflatten_rows(buf, v.shape[2])
    return (ns.at[:, idx].set(a, mode="drop"),
            v.at[:, idx, :].set(b, mode="drop"),
            dr.at[:, idx].set(c, mode="drop"))


_SCATTER_JIT = None


def _scatter_rows(ns, v, dr, idx, buf):
    """One jitted scatter updating every dirty row of all three tensors
    from a single flattened upload buffer."""
    global _SCATTER_JIT
    if _SCATTER_JIT is None:
        import jax

        _SCATTER_JIT = jax.jit(apply_rows)
    return _SCATTER_JIT(ns, v, dr, idx, buf)


def _pad_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def pad_idx(ids, floor: int = 1) -> np.ndarray:
    """Pad a row-index list to a power-of-two bucket with `IDX_SENTINEL`
    (bounds jit-cache variants; sentinels drop out of scatters/gathers)."""
    p = max(floor, _pad_pow2(len(ids)))
    out = np.full(p, IDX_SENTINEL, np.int32)
    out[: len(ids)] = ids
    return out


class DeviceClusterState:
    """Device-resident struct-of-arrays view of the cluster's sourcing state.

    Three stacked int32 tensors live ON DEVICE across plans:

    * ``nodestate [NODE_FIELDS, N]`` — free-GPU/CG slot masks, node id,
      overflow flag, first-unstored priority;
    * ``victims   [VICTIM_FIELDS, N, cap]`` — per-slot victim GPU/CG masks,
      priorities, uid-ranks, stored flags (the ``(priority, uid)``-sorted
      rows of the host `SourcingContext` mirror);
    * ``drain     [DRAIN_FIELDS, N]`` — per-node fully-drained masks
      (free ∪ all stored victim masks), the popcount input of the fused
      Guaranteed-Filtering step.

    ``slices`` exposes the static NUMA/socket slice layout of the SKU
    (`placement_jax.SpecSlices`) that the device-side placement scorer —
    the normal cycle and the winner's §3.4 mask selection, both fused into
    the sourcing dispatch — popcounts these tensors against.

    The host `SourcingContext` stays as the *mirror*: it keeps the int64
    victim uids (decoded only for the winner) and the counts the host needs
    for wide/overflow routing.  Both subscribe to ``invalidate_node``, so a
    ``bind``/``evict``/``restore`` marks single rows dirty; ``sync()``
    refreshes the mirror lazily and pushes ONLY the dirty rows to the device
    as one ``.at[rows].set()`` scatter — no per-plan host rebuild/upload.
    Copy-on-write `ClusterView` deltas never touch these arrays: the fused
    evaluators overlay patch rows inside the dispatch (``pack_rows``).
    """

    def __init__(self, cluster: Cluster, cap: int | None = None) -> None:
        self.cluster = cluster
        self.mirror = cluster.sourcing_context()
        if cap is not None and cap != self.mirror.cap:
            raise ValueError("device cap must match the mirror's cap")
        self.cap = self.mirror.cap
        self.nodestate = None   # jnp.int32[NODE_FIELDS, N]
        self.victims = None     # jnp.int32[VICTIM_FIELDS, N, cap]
        self.drain = None       # jnp.int32[DRAIN_FIELDS, N]
        #: host fast-path: when no node stores more than NARROW_M victims,
        #: per-plan wide/overflow routing is skipped entirely
        self.count_max = 0
        #: monotonic state counter, bumped by every invalidation: entries
        #: of ``plan_cache`` (per-preemptor routing splits + uploaded
        #: index/patch device arrays for the delta-free fast path) record
        #: the version they were built at and are ignored once it moves
        self.version = 0
        self.plan_cache: dict = {}
        self._dirty: set[int] = set(range(cluster.num_nodes))
        cluster.add_dirty_listener(self._mark_dirty)

    def _mark_dirty(self, node: int) -> None:
        self._dirty.add(node)
        self.version += 1

    def sync(self, flush: bool = True) -> "DeviceClusterState":
        """Bring the device view up to date with the live cluster.

        Dirty rows are packed host-side (O(dirty) python) and applied as a
        single scatter; a majority-dirty state falls back to one full
        upload.  Index vectors are padded to power-of-two buckets with
        `IDX_SENTINEL` so the scatter jit-cache stays small.

        ``flush=False`` refreshes the host mirror but leaves a SMALL dirty
        set resident-stale in ``pending``: the fused evaluators overlay
        those rows in-dispatch exactly like view-delta patches, saving the
        separate scatter dispatch on the plan hot path.  Large pending sets
        are flushed regardless so the overlay bucket stays small.
        """
        import jax.numpy as jnp

        self.mirror.refresh()
        n = self.cluster.num_nodes
        if self.nodestate is None or 2 * len(self._dirty) >= max(n, 2):
            ns, v, dr = pack_context_rows(self.mirror, np.arange(n))
            self.nodestate = jnp.asarray(ns)
            self.victims = jnp.asarray(v)
            self.drain = jnp.asarray(dr)
            self._dirty.clear()
        elif self._dirty and (flush or len(self._dirty) > MAX_PENDING_ROWS):
            rows = sorted(self._dirty)
            buf = flatten_rows(*pack_context_rows(self.mirror, rows))
            idx = pad_idx(rows)
            if len(idx) > len(rows):
                buf = np.pad(buf, ((0, len(idx) - len(rows)), (0, 0)))
            self.nodestate, self.victims, self.drain = _scatter_rows(
                self.nodestate, self.victims, self.drain,
                jnp.asarray(idx), jnp.asarray(buf))
            self._dirty.clear()
        self.count_max = int(self.mirror.count.max()) if n else 0
        return self

    @property
    def pending(self) -> set[int]:
        """Rows whose device copy is stale (mirror is fresh after sync):
        deferred by ``sync(flush=False)`` for in-dispatch overlay."""
        return self._dirty

    @property
    def slices(self):
        """The SKU's static NUMA/socket slice layout, device-resident.

        Convenience accessor for the `repro.core.placement_jax.SpecSlices`
        of this cluster's spec (per-NUMA GPU/CoreGroup mask columns, socket
        one-hot, placement scope-membership matrix, lowest-bit selector
        tables) — the layout the fused placement scorers are traced
        against.  The jit evaluators resolve it per-spec via
        ``spec_slices`` internally; this property returns the SAME cached
        object for introspection and tests."""
        from .placement_jax import spec_slices

        return spec_slices(self.cluster.spec)
