"""Device-side placement scorer (paper §3.4 Sorting + the normal cycle).

The host ``placement`` module walks NUMA nodes and bit-scans GPU/CoreGroup
masks in python; this module is its bitwise twin as vectorized int32 bit
math, so BOTH cycles of Algorithm 1 can run inside the fused sourcing
dispatch (`repro.core.preemption_jax`):

* `best_tier_counts` / `tier_from_counts_dyn` — tier-0/1/2 bundle
  feasibility from per-NUMA popcounts of the free masks (the Filtering /
  candidate tier math, request as traced scalars);
* `place_core` — the CONCRETE GPU/CoreGroup mask selection of
  ``placement.place``: scope choice (per-NUMA → per-socket → global slices
  of the free masks) by the same best-fit key, then lowest-free-bit
  allocation per NUMA in scope order — bitwise-matching the host;
* `place_blind_core` / `achieved_tier_dev` — ``placement.place_blind`` and
  the committed-tier accounting;
* `normal_cycle_core` — the whole ``TopoScheduler._plan_normal`` sweep:
  per-node placement tier (including the kubelet degraded-admission blind
  fallback for count-feasible but topology-infeasible nodes), the
  ``(tier, leftover, node)`` argmin, and the winner's concrete masks;
* `winner_place` — freed-mask reconstruction + placement for a preemption
  winner, so the sourcing dispatch returns placement masks and the host
  never re-runs ``place()`` on the winning node.

`spec_slices` is the static NUMA/socket slice layout every scorer consumes:
per-NUMA mask columns, the socket one-hot, the scope-membership matrix
(one row per NUMA scope, per socket scope, plus the global scope) and the
prefix masks of the lowest-k-bits selector.  It is cached per `ServerSpec`
and lives on the accelerator next to the resident `DeviceClusterState`.

Host-callable wrappers (`device_best_tier`, `device_place`,
`device_place_blind`) exist for the randomized host-vs-device parity suite
in ``tests/test_placement_device.py``.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from .placement import INFEASIBLE, Placement
from .topology import ServerSpec

_INT32_MAX = np.int32(2**31 - 1)


@lru_cache(maxsize=None)
def spec_constants(spec: ServerSpec) -> dict[str, jnp.ndarray]:
    """Static mask tensors for one server SKU (shared by every evaluator).

    Built under ``ensure_compile_time_eval``: the first call may happen
    inside a traced ``lax.cond`` branch, and the cache must hold concrete
    arrays, never that branch's tracers."""
    sock_onehot = np.zeros((spec.num_numa, spec.num_sockets), dtype=np.int32)
    for u in range(spec.num_numa):
        sock_onehot[u, spec.socket_of_numa(u)] = 1
    with jax.ensure_compile_time_eval():
        return {
            "numa_gpu_masks": jnp.asarray(spec.numa_gpu_masks),
            "numa_cg_masks": jnp.asarray(spec.numa_cg_masks),
            "sock_onehot": jnp.asarray(sock_onehot),
        }


@dataclasses.dataclass(frozen=True)
class SpecSlices:
    """Static NUMA/socket slice layout of one SKU, device-resident.

    ``scope_mask [n_scopes, U]`` enumerates the placement scopes in the
    host's order — one row per NUMA node, one per socket, one global —
    and ``scope_tier [n_scopes]`` their tier; within a tier, ascending row
    index equals the host's lexicographic numa-list order, so the best-fit
    argmin over ``(leftover, row)`` reproduces ``placement.place``'s scope
    choice exactly.  ``g_bits``/``g_prefix`` (and the cg twins) drive the
    vectorized lowest-k-set-bits selector."""

    numa_gpu: jnp.ndarray      # int32[U]
    numa_cg: jnp.ndarray       # int32[U]
    sock_onehot: jnp.ndarray   # int32[U, S]
    scope_mask: jnp.ndarray    # int32[n_scopes, U]
    scope_tier: jnp.ndarray    # int32[n_scopes]
    g_bits: jnp.ndarray        # int32[num_gpus]        1 << i
    g_prefix: jnp.ndarray      # int32[num_gpus]        (1 << i) - 1
    c_bits: jnp.ndarray        # int32[num_coregroups]
    c_prefix: jnp.ndarray


@lru_cache(maxsize=None)
def spec_slices(spec: ServerSpec) -> SpecSlices:
    consts = spec_constants(spec)
    u_n, s_n = spec.num_numa, spec.num_sockets
    scopes = np.zeros((u_n + s_n + 1, u_n), np.int32)
    tiers = np.zeros(u_n + s_n + 1, np.int32)
    for u in range(u_n):
        scopes[u, u] = 1
    for s in range(s_n):
        for u in range(u_n):
            if spec.socket_of_numa(u) == s:
                scopes[u_n + s, u] = 1
        tiers[u_n + s] = 1
    scopes[-1, :] = 1
    tiers[-1] = 2

    def bits(n):
        b = (np.int64(1) << np.arange(n, dtype=np.int64)).astype(np.int32)
        p = ((np.int64(1) << np.arange(n, dtype=np.int64)) - 1).astype(np.int32)
        return jnp.asarray(b), jnp.asarray(p)

    # concrete arrays even when first called inside a traced cond branch
    # (the lru cache must never hold another trace's tracers)
    with jax.ensure_compile_time_eval():
        g_bits, g_prefix = bits(spec.num_gpus)
        c_bits, c_prefix = bits(spec.num_coregroups)
        return SpecSlices(
            numa_gpu=consts["numa_gpu_masks"],
            numa_cg=consts["numa_cg_masks"],
            sock_onehot=consts["sock_onehot"],
            scope_mask=jnp.asarray(scopes), scope_tier=jnp.asarray(tiers),
            g_bits=g_bits, g_prefix=g_prefix, c_bits=c_bits,
            c_prefix=c_prefix,
        )


def tier_from_counts_dyn(cnt_gpu, cnt_cg, sock_onehot,
                         need_gpus, need_cgs, cgs_per_bundle):
    """Placement tier from per-NUMA availability counts (request traced).

    ``cnt_gpu``/``cnt_cg`` are ``[..., U]``; one compiled program serves
    every preemptor class: ``cgs_per_bundle`` = 0 encodes both "no bundle
    locality" and CPU-only asks (with ``need_gpus`` = 0 the GPU-unit
    comparisons are trivially true, leaving exactly the host's
    CoreGroup-only conditions).
    """
    units = jnp.where(cgs_per_bundle > 0,
                      jnp.minimum(cnt_gpu,
                                  cnt_cg // jnp.maximum(cgs_per_bundle, 1)),
                      cnt_gpu)
    numa_ok = jnp.any((units >= need_gpus) & (cnt_cg >= need_cgs), axis=-1)
    sock_units = units @ sock_onehot
    sock_cg = cnt_cg @ sock_onehot
    sock_ok = jnp.any((sock_units >= need_gpus) & (sock_cg >= need_cgs),
                      axis=-1)
    glob_ok = (jnp.sum(units, axis=-1) >= need_gpus) & (
        jnp.sum(cnt_cg, axis=-1) >= need_cgs)
    return jnp.where(numa_ok, 0, jnp.where(sock_ok, 1,
                                           jnp.where(glob_ok, 2, 3)))


def _lowest_bits_dev(mask, k, bits, prefix):
    """Lowest ``k`` set bits of ``mask`` (broadcasts over leading axes).

    Bit i is selected iff it is set and fewer than ``k`` set bits lie below
    it; when ``mask`` holds fewer than ``k`` bits every set bit is taken
    (callers' remaining-count checks flag the shortfall, mirroring the
    host's ``_lowest_bits`` returning ``None``)."""
    mask = mask[..., None]
    below = jax.lax.population_count(mask & prefix)
    sel = ((mask & bits) != 0) & (below < k[..., None])
    return jnp.sum(jnp.where(sel, bits, 0), axis=-1)


def achieved_tier_dev(gpu_mask, sl: SpecSlices):
    """``placement.achieved_tier`` (broadcasts over leading axes)."""
    touched = (gpu_mask[..., None] & sl.numa_gpu) != 0          # [..., U]
    n_numa = jnp.sum(touched, axis=-1)
    n_sock = jnp.sum((touched.astype(jnp.int32) @ sl.sock_onehot) > 0,
                     axis=-1)
    return jnp.where(gpu_mask == 0, 0,
                     jnp.where(n_numa <= 1, 0,
                               jnp.where(n_sock <= 1, 1, 2))).astype(jnp.int32)


def best_tier_counts(free_gpu, free_cg, ng, nc, cpb, sl: SpecSlices):
    """Per-NUMA popcounts + tier for free masks of any leading shape."""
    cnt_g = jax.lax.population_count(free_gpu[..., None] & sl.numa_gpu)
    cnt_c = jax.lax.population_count(free_cg[..., None] & sl.numa_cg)
    tier = tier_from_counts_dyn(cnt_g, cnt_c, sl.sock_onehot, ng, nc, cpb)
    return tier.astype(jnp.int32), cnt_g, cnt_c


def place_core(free_gpu, free_cg, ng, nc, cpb, *, spec: ServerSpec):
    """``placement.place`` for ONE node as scalar bit math.

    Returns ``(ok bool[], tier int32[], gpu_mask int32[], cg_mask
    int32[])``; bitwise-matching the host: same best-fit scope choice
    (least leftover bundle capacity, then lowest scope), same
    lowest-free-bit allocation per NUMA in scope index order, same
    leftover-CoreGroup sweep.
    """
    sl = spec_slices(spec)
    u_n = spec.num_numa
    tier, cnt_g, cnt_c = best_tier_counts(free_gpu, free_cg, ng, nc, cpb, sl)
    units_u = jnp.where(cpb > 0,
                        jnp.minimum(cnt_g, cnt_c // jnp.maximum(cpb, 1)),
                        cnt_g)                                   # [U]
    s_units = sl.scope_mask @ units_u                            # [n_scopes]
    s_cg = sl.scope_mask @ cnt_c
    feas = (s_units >= ng) & (s_cg >= nc) & (sl.scope_tier == tier)
    n_scopes = sl.scope_mask.shape[0]
    key = jnp.where(feas,
                    (s_units - ng) * n_scopes
                    + jnp.arange(n_scopes, dtype=jnp.int32), _INT32_MAX)
    si = jnp.argmin(key)
    member = sl.scope_mask[si]                                   # [U]
    ok = (tier < 3) & jnp.any(feas)

    gpu_mask = jnp.int32(0)
    cg_mask = jnp.int32(0)
    rem_g = jnp.int32(ng)
    rem_c = jnp.int32(nc)
    for u in range(u_n):                 # static unroll over NUMA nodes
        u_free_g = free_gpu & sl.numa_gpu[u]
        u_free_c = free_cg & sl.numa_cg[u]
        take = jnp.minimum(rem_g, units_u[u]) * member[u]
        gpu_mask = gpu_mask | _lowest_bits_dev(u_free_g, take,
                                               sl.g_bits, sl.g_prefix)
        rem_g = rem_g - take
        c_take = jnp.minimum(take * cpb, rem_c)
        cg_mask = cg_mask | _lowest_bits_dev(u_free_c, c_take,
                                             sl.c_bits, sl.c_prefix)
        rem_c = rem_c - c_take
    for u in range(u_n):                 # leftover CoreGroups, scope order
        avail = free_cg & sl.numa_cg[u] & ~cg_mask
        take = jnp.minimum(jax.lax.population_count(avail), rem_c) * member[u]
        cg_mask = cg_mask | _lowest_bits_dev(avail, take,
                                             sl.c_bits, sl.c_prefix)
        rem_c = rem_c - take
    ok = ok & (rem_g == 0) & (rem_c == 0)
    return ok, tier, gpu_mask, cg_mask


def place_blind_core(free_gpu, free_cg, ng, nc, *, spec: ServerSpec):
    """``placement.place_blind`` (broadcasts over leading axes)."""
    sl = spec_slices(spec)
    ok = (jax.lax.population_count(free_gpu) >= ng) & (
        jax.lax.population_count(free_cg) >= nc)
    k_g = jnp.broadcast_to(jnp.int32(ng), jnp.shape(free_gpu))
    k_c = jnp.broadcast_to(jnp.int32(nc), jnp.shape(free_cg))
    gpu_mask = _lowest_bits_dev(free_gpu, k_g, sl.g_bits, sl.g_prefix)
    cg_mask = _lowest_bits_dev(free_cg, k_c, sl.c_bits, sl.c_prefix)
    return ok, achieved_tier_dev(gpu_mask, sl), gpu_mask, cg_mask


def normal_cycle_core(nodestate, ng, nc, cpb, *, spec: ServerSpec):
    """``TopoScheduler._plan_normal`` as one device sweep.

    Per node: count pre-screen, placement tier (topology-feasible nodes
    place at ``best_tier``; count-feasible but topology-infeasible nodes
    admit DEGRADED via the blind allocator at its achieved tier — the
    kubelet best-effort branch), then the host's exact ``(tier, leftover,
    node)`` argmin and the winner's concrete masks via `place_core` /
    `place_blind_core`.

    ``nodestate`` rows with node_id = INT32_MAX (pad sentinels) never win.
    Returns int32[5]: (found, node, tier, gpu_mask, cg_mask).
    """
    from .cluster import NS_FREE_CG, NS_FREE_GPU, NS_NODE_ID

    sl = spec_slices(spec)
    free_g = nodestate[NS_FREE_GPU]
    free_c = nodestate[NS_FREE_CG]
    node_ids = nodestate[NS_NODE_ID]
    cnt_g = jax.lax.population_count(free_g)
    cnt_ok = (cnt_g >= ng) & (jax.lax.population_count(free_c) >= nc) & (
        node_ids < _INT32_MAX)
    tier, _, _ = best_tier_counts(free_g, free_c, ng, nc, cpb, sl)   # [N]
    placeable = tier < 3
    b_ok, b_tier, b_g, b_c = place_blind_core(free_g, free_c, ng, nc,
                                              spec=spec)
    eff_tier = jnp.where(placeable, tier, b_tier)
    leftover = cnt_g - ng
    big = _INT32_MAX
    t = jnp.where(cnt_ok, eff_tier, big)
    sel = cnt_ok & (eff_tier == jnp.min(t))
    l = jnp.where(sel, leftover, big)
    sel = sel & (leftover == jnp.min(l))
    nid = jnp.where(sel, node_ids, big)
    row = jnp.argmin(nid)
    found = jnp.any(cnt_ok)
    p_ok, p_tier, p_g, p_c = place_core(free_g[row], free_c[row],
                                        ng, nc, cpb, spec=spec)
    use_place = placeable[row] & p_ok
    return jnp.stack([
        found.astype(jnp.int32),
        node_ids[row],
        jnp.where(use_place, p_tier, b_tier[row]),
        jnp.where(use_place, p_g, b_g[row]),
        jnp.where(use_place, p_c, b_c[row]),
    ])


def winner_place(win, free_gpu, free_cg, victim_gpu, victim_cg,
                 ng, nc, cpb, *, spec: ServerSpec):
    """Placement masks for a preemption winner, inside the dispatch.

    ``win`` is the `int32[7]` Eq. 2 argmax vector (found, row, tier,
    combo_id, prio_sum, k, n_candidates); the winner's freed masks are
    reconstructed from its node row and combo bits (victim masks of one
    node are disjoint, so the fold is a dot product) and placed with
    `place_core` — the host decodes masks instead of re-running
    ``place()``.  Returns int32[9]: ``win`` + (gpu_mask, cg_mask).
    """
    row = win[1]
    combo = win[3]
    cap = victim_gpu.shape[-1]
    bits = ((combo >> jnp.arange(cap, dtype=jnp.int32)) & 1)     # [cap]
    freed_g = free_gpu[row] | jnp.sum(bits * victim_gpu[row])
    freed_c = free_cg[row] | jnp.sum(bits * victim_cg[row])
    _, _, p_g, p_c = place_core(freed_g, freed_c, ng, nc, cpb, spec=spec)
    return jnp.concatenate([win, jnp.stack([p_g, p_c])])


# ---------------------------------------------------------------------------------
# Host-callable wrappers (parity oracle surface for the tests)
# ---------------------------------------------------------------------------------

def _req_of(spec: ServerSpec, need_gpus: int, need_cgs: int,
            bundle_locality: bool) -> tuple[int, int, int]:
    cpb = need_cgs // need_gpus if (bundle_locality and need_gpus) else 0
    return need_gpus, need_cgs, cpb


@lru_cache(maxsize=None)
def _best_tier_jit(spec: ServerSpec):
    sl = spec_slices(spec)

    def f(fg, fc, ng, nc, cpb):
        tier, _, _ = best_tier_counts(fg, fc, ng, nc, cpb, sl)
        return tier

    return jax.jit(f)


@lru_cache(maxsize=None)
def _place_jit(spec: ServerSpec):
    def f(fg, fc, ng, nc, cpb):
        ok, tier, g, c = place_core(fg, fc, ng, nc, cpb, spec=spec)
        return jnp.stack([ok.astype(jnp.int32), tier, g, c])

    return jax.jit(f)


@lru_cache(maxsize=None)
def _place_blind_jit(spec: ServerSpec):
    def f(fg, fc, ng, nc):
        ok, tier, g, c = place_blind_core(fg, fc, ng, nc, spec=spec)
        return jnp.stack([ok.astype(jnp.int32), tier, g, c])

    return jax.jit(f)


def _i32(x: int) -> jnp.ndarray:
    return jnp.int32(np.int64(x).astype(np.int32))


def device_best_tier(spec: ServerSpec, free_gpu: int, free_cg: int,
                     need_gpus: int, need_cgs: int,
                     bundle_locality: bool = True) -> int:
    """Host-callable `best_tier` twin (returns `placement.INFEASIBLE`=3)."""
    ng, nc, cpb = _req_of(spec, need_gpus, need_cgs, bundle_locality)
    tier = _best_tier_jit(spec)(_i32(free_gpu), _i32(free_cg),
                                jnp.int32(ng), jnp.int32(nc), jnp.int32(cpb))
    return int(tier)


def _decode_placement(vec) -> Placement | None:
    ok, tier, g, c = (int(x) for x in np.asarray(vec))
    if not ok or tier >= INFEASIBLE:
        return None
    return Placement(gpu_mask=g & 0xFFFFFFFF, cg_mask=c & 0xFFFFFFFF,
                     tier=tier)


def device_place(spec: ServerSpec, free_gpu: int, free_cg: int,
                 need_gpus: int, need_cgs: int,
                 bundle_locality: bool = True) -> Placement | None:
    """Host-callable `place` twin (bitwise-identical masks)."""
    ng, nc, cpb = _req_of(spec, need_gpus, need_cgs, bundle_locality)
    return _decode_placement(_place_jit(spec)(
        _i32(free_gpu), _i32(free_cg),
        jnp.int32(ng), jnp.int32(nc), jnp.int32(cpb)))


def device_place_blind(spec: ServerSpec, free_gpu: int, free_cg: int,
                       need_gpus: int, need_cgs: int) -> Placement | None:
    """Host-callable `place_blind` twin."""
    vec = _place_blind_jit(spec)(_i32(free_gpu), _i32(free_cg),
                                 jnp.int32(need_gpus), jnp.int32(need_cgs))
    ok, tier, g, c = (int(x) for x in np.asarray(vec))
    if not ok:
        return None
    return Placement(gpu_mask=g & 0xFFFFFFFF, cg_mask=c & 0xFFFFFFFF,
                     tier=tier)
