"""Candidate scoring — paper Eq. 1 / Eq. 2.

S(C) = alpha * 1/sum_priority(C) + (1 - alpha) * T(C_flextopo)

with T the piecewise tier score (high / medium / low) and C = (node, victim
set).  alpha=0 scores purely by topology, alpha=1 purely by priority.
"""
from __future__ import annotations

import dataclasses

# Piecewise linear tier values for T (paper: high / medium / low).
TIER_SCORES = (1.0, 0.5, 0.1)  # index by tier 0/1/2
DEFAULT_ALPHA = 0.5


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One candidate C = (node, victim set) with its evaluation."""

    node: int
    victims: tuple[int, ...]      # instance uids, sorted
    tier: int                     # achievable topology tier after eviction
    priority_sum: int             # sum of victim priorities

    def topo_score(self) -> float:
        return TIER_SCORES[self.tier] if self.tier < len(TIER_SCORES) else 0.0


def score(candidate: Candidate, alpha: float = DEFAULT_ALPHA) -> float:
    """Paper Eq. 1."""
    prio_term = 1.0 / candidate.priority_sum if candidate.priority_sum > 0 else 1.0
    return alpha * prio_term + (1.0 - alpha) * candidate.topo_score()


def select_best(candidates: list[Candidate], alpha: float = DEFAULT_ALPHA
                ) -> Candidate | None:
    """Paper Eq. 2: argmax_S over all (node, victim-set) candidates.

    Deterministic tie-break: fewer victims, then lower node id, then lexical
    victim uids — so simulations are reproducible.
    """
    if not candidates:
        return None
    return max(
        candidates,
        key=lambda c: (score(c, alpha), -len(c.victims), -c.node, tuple(-v for v in c.victims)),
    )
