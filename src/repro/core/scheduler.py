"""Topology-aware scheduler (paper §3.1, Algorithm 1).

Pipeline per scheduling attempt:

1. **Normal cycle** — place the instance on a node with free resources,
   topology-aware (tier-minimizing) for FlexTopo modes, lowest-index blind for
   the baseline mode.
2. **Preemption** (only if the normal cycle fails):
   * *Guaranteed Filtering* — keep candidate nodes that could satisfy the
     preemptor's topology policy if ALL their victims were drained.
   * *Best-effort Sorting* — per node, source victim-set candidates with the
     configured engine (godel | exhaustive | imp | imp_jax | imp_pallas), then
     select the global argmax of Eq. 1/Eq. 2.
   * *Bind* — evict the victims and place the preemptor.

Latency accounting mirrors the paper's overhead analysis: we time the
candidate-sourcing phase ("the primary contributor to time overhead").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Literal

from . import preemption, preemption_jax
from .cluster import Cluster
from .placement import (INFEASIBLE, Placement, best_tier, is_topology_hit,
                        place, place_blind)
from .scoring import DEFAULT_ALPHA, Candidate, select_best
from .workload import Instance, TopoPolicy, WorkloadSpec

EngineName = Literal[
    "godel", "exhaustive", "imp", "imp_jax", "imp_batched", "imp_pallas"
]


@dataclasses.dataclass
class PreemptionResult:
    instance: Instance
    node: int
    victims: tuple[int, ...]
    placement: Placement
    hit: bool
    sourcing_us: float
    num_candidates: int
    evicted: list[Instance] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ScheduleResult:
    instance: Instance
    node: int
    placement: Placement
    hit: bool


class TopoScheduler:
    def __init__(
        self,
        cluster: Cluster,
        engine: EngineName = "imp",
        alpha: float = DEFAULT_ALPHA,
        topology_aware_placement: bool | None = None,
    ) -> None:
        self.cluster = cluster
        self.engine: EngineName = engine
        self.alpha = alpha
        # Local (node-internal) allocation is kubelet-style topology-aware for
        # ALL engines — the paper's baseline miss comes from topology-blind
        # victim/node selection freeing badly-distributed resources, not from
        # a dumb local allocator.  Pass False explicitly for the blind-allocator
        # ablation.
        self.topology_aware = (
            True if topology_aware_placement is None else topology_aware_placement
        )
        self.sourcing_us_log: list[float] = []

    # ---- request helpers -------------------------------------------------------
    def _request(self, workload: WorkloadSpec) -> tuple[int, int, bool]:
        spec = self.cluster.spec
        return (
            workload.gpus_per_instance,
            workload.coregroups_per_instance(spec.coregroup_size),
            workload.numa_policy == TopoPolicy.GUARANTEED,
        )

    def _place_on(self, workload: WorkloadSpec, node: int) -> Placement | None:
        spec = self.cluster.spec
        free_gpu, free_cg = self.cluster.free_masks(node)
        need_gpus, need_cgs, bundle = self._request(workload)
        if self.topology_aware:
            p = place(spec, free_gpu, free_cg, need_gpus, need_cgs, bundle)
            if p is not None:
                return p
            # kubelet best-effort admission: resources fit by count but not by
            # topology — admit degraded (this is the paper's
            # TopologyAffinityError / degraded-performance case, counted as a
            # miss).  FlexTopo engines never reach this branch because their
            # candidates are topology-feasible by construction.
            return place_blind(spec, free_gpu, free_cg, need_gpus, need_cgs)
        return place_blind(spec, free_gpu, free_cg, need_gpus, need_cgs)

    # ---- normal scheduling cycle --------------------------------------------------
    def schedule(self, workload: WorkloadSpec) -> ScheduleResult | None:
        best: tuple[tuple, int, Placement] | None = None
        for node in range(self.cluster.num_nodes):
            p = self._place_on(workload, node)
            if p is None:
                continue
            if self.engine == "godel":
                # default scheduler: first node that fits
                best = ((0,), node, p)
                break
            free_gpu, _ = self.cluster.free_masks(node)
            leftover = free_gpu.bit_count() - workload.gpus_per_instance
            key = (p.tier, leftover, node)   # best tier, then best-fit
            if best is None or key < best[0]:
                best = (key, node, p)
        if best is None:
            return None
        _, node, placement = best
        inst = self.cluster.bind(workload, node, placement)
        need_gpus, need_cgs, bundle = self._request(workload)
        hit = is_topology_hit(self.cluster.spec, placement.gpu_mask,
                              placement.cg_mask, need_gpus, need_cgs, bundle)
        return ScheduleResult(inst, node, placement, hit)

    # ---- preemption --------------------------------------------------------------
    def _guaranteed_filter(self, workload: WorkloadSpec) -> list[int]:
        """Alg. 1 Filtering: nodes feasible under hypothetical full drain."""
        spec = self.cluster.spec
        need_gpus, need_cgs, bundle = self._request(workload)
        nodes = []
        for node in range(self.cluster.num_nodes):
            free_gpu, free_cg = self.cluster.free_masks(node)
            for v in self.cluster.victims_on(node, workload.priority):
                free_gpu |= v.gpu_mask
                free_cg |= v.cg_mask
            if self.engine == "godel":
                ok = (free_gpu.bit_count() >= need_gpus
                      and free_cg.bit_count() >= need_cgs)
            elif workload.numa_policy == TopoPolicy.GUARANTEED:
                ok = best_tier(spec, free_gpu, free_cg, need_gpus, need_cgs,
                               bundle) != INFEASIBLE
            else:  # best-effort QoS: no topology constraint during Filtering
                ok = (free_gpu.bit_count() >= need_gpus
                      and free_cg.bit_count() >= need_cgs)
            if ok:
                nodes.append(node)
        return nodes

    def _source(self, workload: WorkloadSpec, nodes: list[int]) -> list[Candidate]:
        if self.engine == "godel":
            out = []
            for node in nodes:
                c = preemption.godel_standard(self.cluster, workload, node)
                if c is not None:
                    out.append(c)
            return out
        if self.engine == "imp_batched":
            # beyond-paper: all nodes' subsets evaluated in one vmapped sweep
            return preemption_jax.source_candidates_batched(
                self.cluster, workload, nodes)
        if self.engine == "exhaustive":
            fn: Callable = preemption.flextopo_exhaustive
        elif self.engine == "imp":
            fn = preemption.flextopo_imp
        elif self.engine == "imp_jax":
            fn = preemption_jax.flextopo_imp_vectorized
        elif self.engine == "imp_pallas":
            from repro.kernels import topo_score

            fn = topo_score.flextopo_imp_pallas
        else:
            raise ValueError(f"unknown engine {self.engine}")
        out = []
        for node in nodes:
            out.extend(fn(self.cluster, workload, node))
        return out

    def preempt(self, workload: WorkloadSpec) -> PreemptionResult | None:
        nodes = self._guaranteed_filter(workload)
        if not nodes:
            return None
        t0 = time.perf_counter()
        candidates = self._source(workload, nodes)
        sourcing_us = (time.perf_counter() - t0) * 1e6
        self.sourcing_us_log.append(sourcing_us)
        if not candidates:
            return None
        if self.engine == "godel":
            # standard policy: minimize evicted priority, then victim count
            chosen = min(candidates,
                         key=lambda c: (c.priority_sum, len(c.victims), c.node))
        else:
            chosen = select_best(candidates, self.alpha)
        evicted = [self.cluster.evict(uid) for uid in chosen.victims]
        placement = self._place_on(workload, chosen.node)
        if placement is None:  # cannot happen if engines are correct
            raise RuntimeError("victim set freed insufficient resources")
        inst = self.cluster.bind(workload, chosen.node, placement)
        need_gpus, need_cgs, bundle = self._request(workload)
        hit = is_topology_hit(self.cluster.spec, placement.gpu_mask,
                              placement.cg_mask, need_gpus, need_cgs, bundle)
        return PreemptionResult(
            instance=inst, node=chosen.node, victims=chosen.victims,
            placement=placement, hit=hit, sourcing_us=sourcing_us,
            num_candidates=len(candidates), evicted=evicted,
        )

    def schedule_or_preempt(self, workload: WorkloadSpec):
        res = self.schedule(workload)
        if res is not None:
            return res
        return self.preempt(workload)

    # ---- undo (for the paper's "independent preemptions" protocol) ---------------
    def undo(self, result) -> None:
        """Reverse a ScheduleResult/PreemptionResult (Table 4 protocol evaluates
        each of the 50 scale-ups independently on the same saturated state)."""
        self.cluster.evict(result.instance.uid)
        if isinstance(result, PreemptionResult):
            for victim in result.evicted:
                self.cluster.bind(
                    victim.workload, victim.node,
                    Placement(victim.gpu_mask, victim.cg_mask, tier=0),
                )
