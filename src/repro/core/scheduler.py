"""Topology-aware scheduler (paper §3.1, Algorithm 1) — transactional API.

Pipeline per scheduling attempt:

1. **Normal cycle** — place the instance on a node with free resources,
   topology-aware (tier-minimizing) for FlexTopo modes, lowest-index blind for
   the baseline mode.
2. **Preemption** (only if the normal cycle fails):
   * *Guaranteed Filtering* — keep candidate nodes that could satisfy the
     preemptor's topology policy if ALL their victims were drained.
   * *Best-effort Sorting* — source victim-set candidates with the
     configured engine ({engines}), then select the global argmax of
     Eq. 1/Eq. 2.
   * *Bind* — evict the victims and place the preemptor.

For host engines, the normal cycle and Filtering are python loops over the
nodes and Sorting is sourced per node.  For engines registered with
``fused_place=True`` (``imp_batched``, the default fast path) the scheduler
does NO per-node host work at all: the ENTIRE Algorithm 1 — normal-cycle
argmin, Guaranteed Filtering, Sorting, Eq. 2 selection, and the §3.4
placement mask construction (`repro.core.placement_jax`) — runs as ONE jit
dispatch over the cluster's device-resident state (`Cluster.device_state`).
The fully-drained masks are popcounted on device, copy-on-write view deltas
are overlaid in-dispatch, the preemptive subset sweep executes only when
the normal cycle finds nothing (``lax.cond``), and the winner comes back as
a handful of int32s CARRYING ITS CONCRETE GPU/CoreGroup masks — the host
never re-runs ``place()`` on the winning node.  ``fused_filter`` engines
without ``fused_place`` keep the host normal cycle but fuse Filtering into
sourcing (``nodes=None``).  ``invalidate_node`` (hit by every
bind/evict/restore) marks single device rows stale; they re-upload as one
``.at[rows].set()`` scatter on the next plan, so cluster state never leaves
the accelerator wholesale.  Per-plan host work is O(delta), not O(N): the
mutation op journal replays dirty mirror rows vectorized, and view-delta
patch rows are rebuilt ON DEVICE by the delta encoder
(`repro.core.cluster.ViewDelta`) instead of host-encoded per row.

``imp_sharded`` (`repro.core.cluster_parallel`) is ``imp_batched`` with
the device-resident state sharded over a 1-D device mesh
(``Cluster.device_state(sharded=True)``: node axis padded to the mesh
size, `NamedSharding` pinned through scatter/rebuild/delta-encode): the
same fused entry points route to per-mesh jits of the identical traced
pipeline bodies, per-node math stays shard-local, only the final argmax
chain crosses shards, and decisions stay bit-identical — plans, batch
sessions, and the day cycle work unchanged at thousands of nodes.

The engine list above is rendered from the live registry
(``repro.core.engines.registered_engines``); custom engines registered with
``@register_engine("name")`` become valid ``engine=`` arguments automatically.
Pass ``warmup=True`` to pre-compile the engine's jit buckets at construction
(first plans otherwise pay compile time).

Transactional protocol
----------------------
``plan(workload)`` runs Filtering → Sorting against a copy-on-write
`ClusterView` and returns a `Transaction` holding a unified
`SchedulingDecision` (kind ∈ placed | preempted | rejected).  Nothing is
mutated until ``txn.commit()``; dropping or ``rollback()``-ing a planned
transaction is free, which makes the Table 4 "independent preemptions"
protocol a pure read.  ``plan_batch([...])`` plans several pending
preemptors against one shared view so the decisions compose; with a
``batch_factory`` engine (``imp_batched``) ALL requests' sourcing is ONE
dispatch vmapped over a request axis, and each plan's sequential
planned-eviction semantics are preserved by masking its delta nodes out of
the precomputed tensors on device and re-sourcing only those rows.
``plan_batch`` sourcing sessions PERSIST across calls for ``imp_batched``
(invalidated through ``invalidate_node``), so bursty admission reuses the
big vmapped dispatch.  ``schedule`` / ``preempt`` / ``schedule_or_preempt``
are plan-and-commit conveniences, and the deprecated ``undo(decision)``
shim delegates to ``Transaction.rollback()``.

Latency accounting mirrors the paper's overhead analysis: we time the
candidate-sourcing phase ("the primary contributor to time overhead").  For
``fused_filter`` engines the number necessarily INCLUDES Filtering — it
happens inside the same dispatch — and for ``fused_place`` engines it spans
the whole chained dispatch, normal cycle and placement included.
"""
from __future__ import annotations

import inspect
import time
import warnings
from typing import Callable, Iterable

from . import preemption, preemption_jax  # noqa: F401  (self-register engines)
from .cluster import Cluster, ClusterView
from .decisions import SchedulingDecision, Transaction
from .engines import (EngineName, SourcingEngine, get_engine,
                      registered_engines)
from .placement import (INFEASIBLE, Placement, best_tier, is_topology_hit,
                        place, place_blind)
from .preemption_jax import ShortlistConfig
from .scoring import DEFAULT_ALPHA, Candidate
from .workload import TopoPolicy, WorkloadSpec

#: ``engine="auto"`` node-count routing threshold: below it the
#: single-device fused engine wins (the mesh-sharded engine pays a fixed
#: cross-shard dispatch floor — the committed 24-node scale rows show
#: ~9.0ms sharded vs ~1.1ms batched plan-e2e P50); at or above it the
#: sharded node axis pays for itself.  Override per scheduler with
#: ``TopoScheduler(..., auto_threshold=...)``.
AUTO_ENGINE_THRESHOLD = 4096


class _LazyBatchSession:
    """Defers the engine's batch-sourcing session (device snapshot + the
    vmapped all-requests dispatch) until a plan actually reaches the
    preemption phase — a batch fully satisfied by the normal cycle never
    pays for it.  Safe because the session snapshots the BASE cluster,
    which planning never mutates.

    For ``fused_place`` engines, ``plan`` keeps that laziness on the
    device path: while no plan has needed preemption, each plan is one
    cheap standalone normal-cycle dispatch (``normal_fn``); the first
    normal-cycle failure constructs the session, and every plan from then
    on is the session's single merged normal+preemptive dispatch."""

    def __init__(self, factory, normal_fn=None) -> None:
        self._factory = factory
        self._normal_fn = normal_fn
        self._session = None

    def source(self, view, workload, index):
        if self._session is None:
            self._session = self._factory()
        return self._session.source(view, workload, index)

    def plan(self, view, workload, index):
        if self._session is None and self._normal_fn is not None:
            got = self._normal_fn(view, workload)
            if got is not None:
                from .preemption_jax import FusedPlanResult

                return FusedPlanResult("placed", got[0], got[1])
        if self._session is None:
            self._session = self._factory()
        return self._session.plan(view, workload, index)


class TopoScheduler:
    """Algorithm 1 scheduler over a pluggable sourcing engine (module
    docstring above for the pipeline).

    Engine selection: pass a registered engine name, or ``engine="auto"``
    to route by cluster size — ``imp_batched`` below ``auto_threshold``
    nodes (default `AUTO_ENGINE_THRESHOLD`), ``imp_sharded`` at or above
    it.  The resolved name is in ``self.engine``; every decision carries
    the routing in ``sourcing_provenance``.

    Shortlist sourcing knobs (engines registered with
    ``supports_shortlist`` — ``imp_batched``/``imp_sharded``; the
    ``*_full`` oracles and host engines ignore them):

    * ``shortlist_k`` — representative rows the stage-1 equivalence-class
      prescreen keeps for the exact stage-2 subset sweep (0 disables the
      shortlist entirely).  Only active when the cluster has more rows
      than ``k``.
    * ``shortlist_mode`` — ``"guaranteed"`` (default) re-dispatches the
      full sweep whenever the admissible-bound certainty check cannot
      prove the shortlist winner globally optimal, keeping decisions
      bit-identical to the full sweep; ``"best_effort"`` returns the
      fixed-K winner regardless, capping plan latency for admission
      control.
    """

    def __init__(
        self,
        cluster: Cluster,
        engine: EngineName = "imp",
        alpha: float = DEFAULT_ALPHA,
        topology_aware_placement: bool | None = None,
        warmup: bool = False,
        shortlist_k: int = 128,
        shortlist_mode: str = "guaranteed",
        auto_threshold: int | None = None,
    ) -> None:
        self.cluster = cluster
        self.auto_threshold = (AUTO_ENGINE_THRESHOLD if auto_threshold is None
                               else auto_threshold)
        self._auto = engine == "auto"
        if self._auto:
            engine = ("imp_batched"
                      if cluster.num_nodes < self.auto_threshold
                      else "imp_sharded")
        self.engine: EngineName = engine
        self._engine: SourcingEngine = get_engine(engine)
        self.alpha = alpha
        self.shortlist = (
            ShortlistConfig(k=shortlist_k, mode=shortlist_mode)
            if (shortlist_k > 0
                and getattr(self._engine, "supports_shortlist", False))
            else None)
        self._provenance = {
            "engine": engine, "auto": self._auto,
            "auto_threshold": self.auto_threshold,
            "shortlist_k": (self.shortlist.k if self.shortlist else 0),
            "shortlist_mode": (self.shortlist.mode if self.shortlist
                               else None),
        }
        # engines that fuse Guaranteed Filtering into their dispatch get
        # nodes=None and the host filter loop is skipped entirely
        self._fused_filter = bool(getattr(self._engine, "fused_filter",
                                          False))
        # fused engines run the Eq. 2 selection inside sourcing and need the
        # scheduler's alpha; pass it iff the engine's signature accepts it
        # (custom engine objects with the legacy 3-arg source_all still work)
        try:
            sig = inspect.signature(self._engine.source_all)
            self._source_takes_alpha = "alpha" in sig.parameters
        except (TypeError, ValueError):
            self._source_takes_alpha = False
        # Local (node-internal) allocation is kubelet-style topology-aware for
        # ALL engines — the paper's baseline miss comes from topology-blind
        # victim/node selection freeing badly-distributed resources, not from
        # a dumb local allocator.  Pass False explicitly for the blind-allocator
        # ablation.
        self.topology_aware = (
            True if topology_aware_placement is None else topology_aware_placement
        )
        # fused_place engines run BOTH Algorithm 1 cycles (normal-cycle
        # argmin + Sorting + Eq. 2 + §3.4 placement masks) inside one
        # dispatch; the host _plan_normal/_place_on loops are skipped.  The
        # blind-allocator ablation keeps the host path (the device scorer
        # is the topology-aware allocator).
        self._fused_place = (self.topology_aware
                             and bool(getattr(self._engine, "fused_place",
                                              False)))
        self.sourcing_us_log: list[float] = []
        self.listeners: list[Callable[[SchedulingDecision, str], None]] = []
        if warmup:
            warm = getattr(self._engine, "warmup", None)
            if callable(warm):
                if self.shortlist is not None:
                    warm(cluster, self.alpha, shortlist=self.shortlist)
                else:
                    warm(cluster, self.alpha)

    # ---- commit/rollback observers ------------------------------------------------
    def add_listener(self, fn: Callable[[SchedulingDecision, str], None]) -> None:
        """Subscribe to committed/rolled-back decisions (e.g. the agent fleet)."""
        self.listeners.append(fn)

    def remove_listener(self, fn: Callable[[SchedulingDecision, str], None]) -> None:
        """Unsubscribe a decision listener (missing listeners are a no-op) —
        lets transient consumers (a finished co-location run) detach without
        keeping the scheduler alive through the callback."""
        try:
            self.listeners.remove(fn)
        except ValueError:
            pass

    def _notify(self, decision: SchedulingDecision, event: str) -> None:
        for fn in self.listeners:
            fn(decision, event)

    # ---- request helpers -------------------------------------------------------
    def _request(self, workload: WorkloadSpec) -> tuple[int, int, bool]:
        spec = self.cluster.spec
        return (
            workload.gpus_per_instance,
            workload.coregroups_per_instance(spec.coregroup_size),
            workload.numa_policy == TopoPolicy.GUARANTEED,
        )

    def _place_on(self, workload: WorkloadSpec, node: int,
                  view: ClusterView) -> Placement | None:
        spec = self.cluster.spec
        free_gpu, free_cg = view.free_masks(node)
        need_gpus, need_cgs, bundle = self._request(workload)
        if self.topology_aware:
            p = place(spec, free_gpu, free_cg, need_gpus, need_cgs, bundle)
            if p is not None:
                return p
            # kubelet best-effort admission: resources fit by count but not by
            # topology — admit degraded (this is the paper's
            # TopologyAffinityError / degraded-performance case, counted as a
            # miss).  FlexTopo engines never reach this branch because their
            # candidates are topology-feasible by construction.
            return place_blind(spec, free_gpu, free_cg, need_gpus, need_cgs)
        return place_blind(spec, free_gpu, free_cg, need_gpus, need_cgs)

    def _hit(self, workload: WorkloadSpec, placement: Placement) -> bool:
        need_gpus, need_cgs, bundle = self._request(workload)
        return is_topology_hit(self.cluster.spec, placement.gpu_mask,
                               placement.cg_mask, need_gpus, need_cgs, bundle)

    # ---- planning: normal scheduling cycle ----------------------------------------
    def _plan_normal(self, workload: WorkloadSpec,
                     view: ClusterView) -> tuple[int, Placement] | None:
        best: tuple[tuple, int, Placement] | None = None
        need_gpus, need_cgs, _ = self._request(workload)
        for node in range(view.num_nodes):
            free_gpu, free_cg = view.free_masks(node)
            # count pre-screen: placement (topology-aware or blind) can
            # never succeed without enough free bits — skips the expensive
            # per-node placement construction on saturated clusters
            if (free_gpu.bit_count() < need_gpus
                    or free_cg.bit_count() < need_cgs):
                continue
            p = self._place_on(workload, node, view)
            if p is None:
                continue
            if not self._engine.topology_aware:
                # default scheduler: first node that fits
                best = ((0,), node, p)
                break
            free_gpu, _ = view.free_masks(node)
            leftover = free_gpu.bit_count() - workload.gpus_per_instance
            key = (p.tier, leftover, node)   # best tier, then best-fit
            if best is None or key < best[0]:
                best = (key, node, p)
        if best is None:
            return None
        _, node, placement = best
        return node, placement

    # ---- planning: preemption ------------------------------------------------------
    def _guaranteed_filter(self, workload: WorkloadSpec,
                           view: ClusterView) -> list[int]:
        """Alg. 1 Filtering: nodes feasible under hypothetical full drain."""
        spec = self.cluster.spec
        need_gpus, need_cgs, bundle = self._request(workload)
        nodes = []
        for node in range(view.num_nodes):
            free_gpu, free_cg = view.free_masks(node)
            for v in view.victims_on(node, workload.priority):
                free_gpu |= v.gpu_mask
                free_cg |= v.cg_mask
            if not self._engine.topology_aware:
                ok = (free_gpu.bit_count() >= need_gpus
                      and free_cg.bit_count() >= need_cgs)
            elif workload.numa_policy == TopoPolicy.GUARANTEED:
                ok = best_tier(spec, free_gpu, free_cg, need_gpus, need_cgs,
                               bundle) != INFEASIBLE
            else:  # best-effort QoS: no topology constraint during Filtering
                ok = (free_gpu.bit_count() >= need_gpus
                      and free_cg.bit_count() >= need_cgs)
            if ok:
                nodes.append(node)
        return nodes

    def _plan_preempt(
        self, workload: WorkloadSpec, view: ClusterView,
        session=None, index: int = 0,
    ) -> tuple[SchedulingDecision, int | None]:
        if session is not None:
            # plan_batch fast path: sourcing was vmapped over the request
            # axis at session start; this merges request `index`'s result
            # with the view's delta rows (Filtering fused in-dispatch)
            t0 = time.perf_counter()
            candidates: list[Candidate] = session.source(view, workload,
                                                         index)
        elif self._fused_filter:
            # Guaranteed Filtering runs inside the engine's dispatch over
            # the device-resident state: no host node loop, nodes=None
            t0 = time.perf_counter()
            if self.shortlist is not None:
                candidates = self._engine.source_all(
                    view, workload, None, alpha=self.alpha,
                    shortlist=self.shortlist)
            else:
                candidates = self._engine.source_all(view, workload, None,
                                                     alpha=self.alpha)
        else:
            nodes = self._guaranteed_filter(workload, view)
            if not nodes:
                return SchedulingDecision(kind="rejected",
                                          workload=workload), None
            t0 = time.perf_counter()
            if self._source_takes_alpha:
                candidates = self._engine.source_all(
                    view, workload, nodes, alpha=self.alpha)
            else:
                candidates = self._engine.source_all(view, workload, nodes)
        sourcing_us = (time.perf_counter() - t0) * 1e6
        self.sourcing_us_log.append(sourcing_us)
        if not candidates:
            return SchedulingDecision(kind="rejected", workload=workload,
                                      sourcing_us=sourcing_us), None
        chosen = self._engine.select(candidates, self.alpha)
        # fused engines already placed the winner on device (§3.4 scorer in
        # the same dispatch): bind the decoded masks instead of re-running
        # the host place() loops on the winning node
        placement = None
        if self.topology_aware:
            placement = getattr(candidates, "placements", {}).get(
                (chosen.node, chosen.victims))
        return self._bind_preemption(
            workload, view, chosen.node, chosen.victims, placement,
            sourcing_us,
            # fused engines return a winner shortlist but report the true
            # evaluated-candidate count via CandidateShortlist.n_candidates
            getattr(candidates, "n_candidates", len(candidates)))

    def _bind_preemption(
        self, workload: WorkloadSpec, view: ClusterView, node: int,
        victims: tuple[int, ...], placement: Placement | None,
        sourcing_us: float, num_candidates: int,
    ) -> tuple[SchedulingDecision, int | None]:
        """Shared preemption tail: plan the evictions, fall back to the
        host placement loops when no device masks came back, and bind."""
        for uid in victims:
            view.plan_evict(uid)
        if placement is None:
            placement = self._place_on(workload, node, view)
        if placement is None:  # cannot happen if engines are correct
            raise RuntimeError("victim set freed insufficient resources")
        planned = view.plan_bind(workload, node, placement)
        return SchedulingDecision(
            kind="preempted", workload=workload, node=node,
            placement=placement, hit=self._hit(workload, placement),
            victims=tuple(victims), sourcing_us=sourcing_us,
            num_candidates=num_candidates,
        ), planned.uid

    def _plan_fused(
        self, workload: WorkloadSpec, view: ClusterView,
        allow_preempt: bool, session=None, index: int = 0,
    ) -> tuple[SchedulingDecision, int | None]:
        """One-dispatch Algorithm 1 for ``fused_place`` engines.

        The engine's chained program (or the batch session's merged
        per-request dispatch) returns either the normal-cycle winner or
        the preemption winner, both WITH concrete placement masks from the
        device §3.4 scorer — no host node loop, no host ``place()``.  The
        recorded ``sourcing_us`` spans the whole dispatch (normal cycle
        and Filtering included, they are the same program)."""
        t0 = time.perf_counter()
        if session is not None:
            res = session.plan(view, workload, index)
        elif self.shortlist is not None:
            res = self._engine.plan_fused(view, workload, self.alpha,
                                          allow_preempt,
                                          shortlist=self.shortlist)
        else:
            res = self._engine.plan_fused(view, workload, self.alpha,
                                          allow_preempt)
        sourcing_us = (time.perf_counter() - t0) * 1e6
        self.sourcing_us_log.append(sourcing_us)
        if res.kind == "rejected":
            return SchedulingDecision(kind="rejected", workload=workload,
                                      sourcing_us=sourcing_us,
                                      num_candidates=res.n_candidates), None
        if res.kind == "placed":
            planned = view.plan_bind(workload, res.node, res.placement)
            return SchedulingDecision(
                kind="placed", workload=workload, node=res.node,
                placement=res.placement,
                hit=self._hit(workload, res.placement),
                sourcing_us=sourcing_us), planned.uid
        # res.placement is None for python-fallback winners: host place()
        return self._bind_preemption(
            workload, view, res.node, res.victims, res.placement,
            sourcing_us, res.n_candidates)

    # ---- the transactional entry points --------------------------------------------
    def plan(self, workload: WorkloadSpec, *, view: ClusterView | None = None,
             allow_normal: bool = True,
             allow_preempt: bool = True,
             _session=None, _index: int = 0) -> Transaction:
        """Evaluate one request Filtering → Sorting without mutating the cluster.

        Returns a `Transaction` whose ``decision`` is fully evaluated (node,
        placement, victims, topology hit, sourcing latency).  Call
        ``commit()`` to bind it for real, or drop/``rollback()`` it for a
        free independent evaluation.  Pass a shared ``view`` to compose
        several plans against one snapshot (see ``plan_batch``).
        """
        view = view if view is not None else ClusterView(self.cluster)
        decision: SchedulingDecision | None = None
        planned_uid: int | None = None
        if (self._fused_place and allow_normal
                and (_session is None or hasattr(_session, "plan"))):
            # end-to-end device-resident Algorithm 1: BOTH cycles — the
            # normal-cycle argmin, Filtering, Sorting, Eq. 2 selection AND
            # the §3.4 placement masks — run in ONE dispatch (the engine's
            # chained program, or the batch session's merged per-request
            # dispatch)
            decision, planned_uid = self._plan_fused(
                workload, view, allow_preempt, session=_session,
                index=_index)
        else:
            if allow_normal:
                # fused_place engines run the normal cycle on device even
                # when a custom session lacks the merged plan; host
                # engines loop here
                normal = (self._engine.plan_normal(view, workload)
                          if self._fused_place
                          else self._plan_normal(workload, view))
                if normal is not None:
                    node, placement = normal
                    planned_uid = view.plan_bind(workload, node,
                                                 placement).uid
                    decision = SchedulingDecision(
                        kind="placed", workload=workload, node=node,
                        placement=placement,
                        hit=self._hit(workload, placement),
                    )
            if decision is None and allow_preempt:
                decision, planned_uid = self._plan_preempt(
                    workload, view, session=_session, index=_index)
        if decision is None:
            decision = SchedulingDecision(kind="rejected", workload=workload)
        decision.sourcing_provenance = dict(self._provenance)
        return Transaction(cluster=self.cluster, decision=decision,
                           on_event=self._notify, view=view,
                           planned_uid=planned_uid)

    def plan_batch(self, workloads: Iterable[WorkloadSpec],
                   allow_preempt: bool = True) -> list[Transaction]:
        """Plan several pending requests against ONE cluster snapshot.

        All plans share a copy-on-write view: request *i+1* sees request
        *i*'s planned evictions and binds, so the returned transactions can
        be committed together in order.  With a ``batch_factory`` engine
        (``imp_batched``) the whole batch's Filtering + sourcing is ONE jit
        dispatch vmapped over the request axis against the device-resident
        snapshot; each plan then merges its own result with the view's
        delta rows on device, which preserves the sequential semantics
        bitwise (parity with per-request planning is pinned in
        tests/test_fused_sourcing.py).
        """
        workloads = list(workloads)
        view = ClusterView(self.cluster)
        session = None
        if allow_preempt and len(workloads) > 1:
            starter = getattr(self._engine, "start_batch", None)
            if callable(starter):
                if getattr(self._engine, "batch_factory", None) is not None:
                    # defer the snapshot + vmapped dispatch until a plan
                    # actually reaches the preemption phase
                    batch = tuple(workloads)
                    session = _LazyBatchSession(
                        lambda: starter(self.cluster, batch, self.alpha),
                        normal_fn=(self._engine.plan_normal
                                   if self._fused_place else None))
                else:
                    # custom engine object: honor whatever it returns
                    session = starter(self.cluster, tuple(workloads),
                                      self.alpha)
        return [self.plan(wl, view=view, allow_preempt=allow_preempt,
                          _session=session, _index=i)
                for i, wl in enumerate(workloads)]

    # ---- plan-and-commit conveniences ----------------------------------------------
    def schedule(self, workload: WorkloadSpec) -> SchedulingDecision:
        """Normal cycle only; commits immediately (kind placed | rejected)."""
        return self.plan(workload, allow_preempt=False).commit()

    def preempt(self, workload: WorkloadSpec) -> SchedulingDecision:
        """Preemption only; commits immediately (kind preempted | rejected)."""
        return self.plan(workload, allow_normal=False).commit()

    def schedule_or_preempt(self, workload: WorkloadSpec) -> SchedulingDecision:
        """Full Algorithm 1; commits immediately."""
        return self.plan(workload).commit()

    # ---- undo (compat shim over Transaction.rollback) -------------------------------
    def undo(self, decision: SchedulingDecision) -> None:
        """Reverse a committed decision (Table 4 protocol evaluates each of
        the 50 scale-ups independently on the same saturated state).

        .. deprecated:: read ``plan()`` decisions without committing, or
           call ``decision.txn.rollback()`` directly; this shim delegates to
           `Transaction.rollback`, which restores every victim with its
           original uid and full placement.
        """
        warnings.warn(
            "TopoScheduler.undo() is deprecated; use Transaction.rollback() "
            "(decision.txn.rollback()) or read plan() decisions without "
            "committing", DeprecationWarning, stacklevel=2)
        if decision.txn is None:
            raise ValueError("decision has no transaction to roll back")
        decision.txn.rollback()


if __doc__ is not None:  # None under python -OO (docstrings stripped)
    __doc__ = __doc__.format(engines=" | ".join(registered_engines()))
