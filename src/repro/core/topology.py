"""Server hardware topology specs (paper §2.2, Fig. 2).

A ServerSpec statically describes one GPU-server SKU: sockets, NUMA nodes,
CPU cores (grouped into configurable CoreGroups, paper Table 2), GPU devices,
and the communication-cost matrix between NUMA tiers (paper Fig. 2).

Everything downstream (FlexTopo graphs, bitmask arrays, the Pallas scoring
kernel) derives its static masks from this spec.  Bitmask convention: GPU g is
bit g of an int32; CoreGroup c is bit c of a separate int32.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

__all__ = [
    "ServerSpec",
    "RTX4090_SERVER",
    "A100_SERVER",
    "TPU_V5E_HOST",
    "SPECS",
]


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Static hardware topology of one server SKU."""

    name: str
    num_sockets: int
    num_numa: int          # total NUMA nodes (must be divisible by sockets)
    num_cores: int         # total CPU cores
    num_gpus: int          # total accelerator devices
    coregroup_size: int    # cores per CoreGroup (paper: configurable, default 8)
    # Fig. 2 communication-cost matrix (relative units)
    intra_numa_cost: int = 10
    cross_numa_cost: int = 12    # different NUMA, same socket
    cross_socket_cost: int = 32
    gpu_model: str = "NVIDIA RTX 4090"
    gpu_memory_mb: int = 24_000

    def __post_init__(self) -> None:
        if self.num_numa % self.num_sockets:
            raise ValueError("NUMA nodes must divide evenly across sockets")
        if self.num_cores % self.coregroup_size:
            raise ValueError("cores must divide evenly into CoreGroups")
        n_cg = self.num_cores // self.coregroup_size
        if n_cg % self.num_numa:
            raise ValueError("CoreGroups must divide evenly across NUMA nodes")
        if self.num_gpus % self.num_numa and self.num_numa % self.num_gpus:
            raise ValueError("GPUs and NUMA nodes must nest evenly")
        if self.num_gpus > 32 or n_cg > 32:
            raise ValueError("bitmask encoding supports at most 32 GPUs/CoreGroups")

    # ---- derived cardinalities -------------------------------------------------
    @property
    def num_coregroups(self) -> int:
        return self.num_cores // self.coregroup_size

    @property
    def numa_per_socket(self) -> int:
        return self.num_numa // self.num_sockets

    @property
    def gpus_per_numa(self) -> int:
        return max(1, self.num_gpus // self.num_numa)

    @property
    def coregroups_per_numa(self) -> int:
        return self.num_coregroups // self.num_numa

    # ---- locality maps ----------------------------------------------------------
    def socket_of_numa(self, numa: int) -> int:
        return numa // self.numa_per_socket

    def numa_of_gpu(self, gpu: int) -> int:
        if self.num_gpus >= self.num_numa:
            return gpu // (self.num_gpus // self.num_numa)
        # fewer GPUs than NUMA nodes: spread one GPU per leading NUMA
        return gpu * (self.num_numa // self.num_gpus)

    def numa_of_coregroup(self, cg: int) -> int:
        return cg // self.coregroups_per_numa

    def numa_of_core(self, core: int) -> int:
        return self.numa_of_coregroup(core // self.coregroup_size)

    def cores_of_coregroup(self, cg: int) -> range:
        return range(cg * self.coregroup_size, (cg + 1) * self.coregroup_size)

    def socket_of_gpu(self, gpu: int) -> int:
        return self.socket_of_numa(self.numa_of_gpu(gpu))

    # ---- Fig. 2 cost matrix -----------------------------------------------------
    def comm_cost(self, numa_a: int, numa_b: int) -> int:
        """Relative communication cost between two NUMA nodes (paper Fig. 2)."""
        if numa_a == numa_b:
            return self.intra_numa_cost
        if self.socket_of_numa(numa_a) == self.socket_of_numa(numa_b):
            return self.cross_numa_cost
        return self.cross_socket_cost

    # ---- static bitmasks (engine inputs) ----------------------------------------
    @cached_property
    def numa_gpu_masks(self) -> np.ndarray:
        """int32[num_numa] — bit g set iff GPU g is `nearby` NUMA u."""
        masks = np.zeros(self.num_numa, dtype=np.int32)
        for g in range(self.num_gpus):
            masks[self.numa_of_gpu(g)] |= 1 << g
        return masks

    @cached_property
    def numa_cg_masks(self) -> np.ndarray:
        """int32[num_numa] — bit c set iff CoreGroup c is `localized` to NUMA u."""
        masks = np.zeros(self.num_numa, dtype=np.int32)
        for c in range(self.num_coregroups):
            masks[self.numa_of_coregroup(c)] |= 1 << c
        return masks

    @cached_property
    def socket_gpu_masks(self) -> np.ndarray:
        masks = np.zeros(self.num_sockets, dtype=np.int32)
        for g in range(self.num_gpus):
            masks[self.socket_of_gpu(g)] |= 1 << g
        return masks

    @cached_property
    def socket_cg_masks(self) -> np.ndarray:
        masks = np.zeros(self.num_sockets, dtype=np.int32)
        for c in range(self.num_coregroups):
            masks[self.socket_of_numa(self.numa_of_coregroup(c))] |= 1 << c
        return masks

    @cached_property
    def socket_of_numa_arr(self) -> np.ndarray:
        return np.array(
            [self.socket_of_numa(u) for u in range(self.num_numa)], dtype=np.int32
        )

    @property
    def all_gpu_mask(self) -> int:
        return (1 << self.num_gpus) - 1

    @property
    def all_cg_mask(self) -> int:
        return (1 << self.num_coregroups) - 1


# Paper Fig. 2 SKUs ----------------------------------------------------------------
# 4090 server: 2 sockets, 8 NUMA, 64 cores, 8 GPUs; costs 10 / 12 / 32.
RTX4090_SERVER = ServerSpec(
    name="rtx4090",
    num_sockets=2,
    num_numa=8,
    num_cores=64,
    num_gpus=8,
    coregroup_size=8,
    intra_numa_cost=10,
    cross_numa_cost=12,
    cross_socket_cost=32,
    gpu_model="NVIDIA RTX 4090",
    gpu_memory_mb=24_000,
)

# A100 server: 2 sockets, 2 NUMA, 128 cores, 8 GPUs; costs 10 / 20 (one NUMA per
# socket, so cross-NUMA == cross-socket == 20).
A100_SERVER = ServerSpec(
    name="a100",
    num_sockets=2,
    num_numa=2,
    num_cores=128,
    num_gpus=8,
    coregroup_size=8,
    intra_numa_cost=10,
    cross_numa_cost=20,
    cross_socket_cost=20,
    gpu_model="NVIDIA A100-SXM",
    gpu_memory_mb=80_000,
)

# TPU adaptation (DESIGN.md §3): one v5e host = 1 "socket" CPU domain with 4
# chips; NUMA tiers map to {same chip, same host} and cross_socket models the
# ICI hop to a neighbouring host in the same torus slice.
TPU_V5E_HOST = ServerSpec(
    name="tpu_v5e_host",
    num_sockets=2,
    num_numa=4,
    num_cores=112,
    num_gpus=4,
    coregroup_size=28,
    intra_numa_cost=10,
    cross_numa_cost=13,
    cross_socket_cost=25,
    gpu_model="TPU v5e",
    gpu_memory_mb=16_000,
)

SPECS = {s.name: s for s in (RTX4090_SERVER, A100_SERVER, TPU_V5E_HOST)}
