"""Victim-selection engines (paper §3.4, Algorithm 2).

Three engines over the same Cluster state:

* ``godel_standard``       — the baseline re-implementation: per node, greedily
  evict lowest-priority victims until the preemptor *fits by resource count*
  (no topology), choose the node minimizing evicted priority.  This mirrors
  Gödel's standard preemption ("directly selects the first feasible set of
  victims for each node").
* ``flextopo_exhaustive``  — topology-aware, evaluates EVERY victim subset
  (O(2^m) per node) and applies Eq. 1/Eq. 2 scoring.  Upper bound on quality,
  used to validate IMP and to measure the paper's "without IMP" overhead.
* ``flextopo_imp``         — Incremental Minimal Preemption: evaluate subsets
  from size k=1 upward; stop at the smallest k with any feasible group
  (Algorithm 2).  Average-case ≈ polynomial.

Each engine returns per-node `Candidate`s; the Scheduler combines them with
Eq. 2 (`scoring.select_best`).
"""
from __future__ import annotations

import itertools
from typing import Iterable, Sequence

from .cluster import Cluster
from .engines import register_engine
from .placement import INFEASIBLE, best_tier
from .scoring import Candidate
from .workload import Instance, TopoPolicy, WorkloadSpec


def _request(workload: WorkloadSpec, coregroup_size: int) -> tuple[int, int, bool]:
    need_gpus = workload.gpus_per_instance
    need_cgs = workload.coregroups_per_instance(coregroup_size)
    bundle = workload.numa_policy == TopoPolicy.GUARANTEED
    return need_gpus, need_cgs, bundle


def _tier_after_evicting(
    cluster: Cluster,
    node: int,
    victims: Sequence[Instance],
    workload: WorkloadSpec,
) -> int:
    """Best achievable tier on `node` after hypothetically draining `victims`."""
    spec = cluster.spec
    free_gpu, free_cg = cluster.free_masks(node)
    for v in victims:
        free_gpu |= v.gpu_mask
        free_cg |= v.cg_mask
    need_gpus, need_cgs, bundle = _request(workload, spec.coregroup_size)
    return best_tier(spec, free_gpu, free_cg, need_gpus, need_cgs, bundle)


# ---------------------------------------------------------------------------------
# Baseline: Gödel standard preemption (priority-only, first feasible set)
# ---------------------------------------------------------------------------------

def godel_standard(cluster: Cluster, workload: WorkloadSpec, node: int
                   ) -> Candidate | None:
    spec = cluster.spec
    victims = cluster.victims_on(node, workload.priority)  # ascending priority
    free_gpu, free_cg = cluster.free_masks(node)
    need_gpus, need_cgs, _ = _request(workload, spec.coregroup_size)
    chosen: list[Instance] = []
    for v in victims:
        if (free_gpu.bit_count() >= need_gpus and free_cg.bit_count() >= need_cgs):
            break
        free_gpu |= v.gpu_mask
        free_cg |= v.cg_mask
        chosen.append(v)
    if free_gpu.bit_count() < need_gpus or free_cg.bit_count() < need_cgs:
        return None
    # tier recorded for accounting only; the baseline neither filters nor sorts on it
    tier = best_tier(spec, free_gpu, free_cg, need_gpus, need_cgs,
                     bundle_locality=False)
    return Candidate(
        node=node,
        victims=tuple(sorted(v.uid for v in chosen)),
        tier=tier if tier != INFEASIBLE else 2,
        priority_sum=sum(v.priority for v in chosen),
    )


def _godel_select(candidates: list[Candidate], alpha: float) -> Candidate | None:
    """Standard policy: minimize evicted priority, then victim count."""
    if not candidates:
        return None
    return min(candidates, key=lambda c: (c.priority_sum, len(c.victims), c.node))


@register_engine("godel", topology_aware=False, selector=_godel_select)
def godel_source(cluster: Cluster, workload: WorkloadSpec, node: int
                 ) -> list[Candidate]:
    c = godel_standard(cluster, workload, node)
    return [c] if c is not None else []


# ---------------------------------------------------------------------------------
# FlexTopo engines
# ---------------------------------------------------------------------------------

def _evaluate_combos(
    cluster: Cluster,
    node: int,
    workload: WorkloadSpec,
    combos: Iterable[tuple[Instance, ...]],
) -> list[Candidate]:
    out = []
    for combo in combos:
        tier = _tier_after_evicting(cluster, node, combo, workload)
        if tier != INFEASIBLE:
            out.append(
                Candidate(
                    node=node,
                    victims=tuple(sorted(v.uid for v in combo)),
                    tier=tier,
                    priority_sum=sum(v.priority for v in combo),
                )
            )
    return out


@register_engine("exhaustive")
def flextopo_exhaustive(cluster: Cluster, workload: WorkloadSpec, node: int
                        ) -> list[Candidate]:
    """All 2^m - 1 non-empty victim subsets (+ the empty set if it already fits)."""
    victims = cluster.victims_on(node, workload.priority)
    combos: list[tuple[Instance, ...]] = [()]
    for k in range(1, len(victims) + 1):
        combos.extend(itertools.combinations(victims, k))
    return _evaluate_combos(cluster, node, workload, combos)


def min_feasible_k(cluster: Cluster, workload: WorkloadSpec, node: int,
                   victims: Sequence[Instance]) -> int:
    """Counting lower bound on the subset size (the paper's 'quick failures'
    on small combinations, §5 Fig 10: an 8-GPU preemptor skips sizes that
    cannot possibly free enough devices).  Sizes below this bound are
    infeasible by resource count alone, so skipping them cannot change the
    result."""
    if not victims:
        return 0
    spec = cluster.spec
    free_gpu, free_cg = cluster.free_masks(node)
    need_gpus = workload.gpus_per_instance
    need_cgs = workload.coregroups_per_instance(spec.coregroup_size)
    max_g = max(v.gpu_mask.bit_count() for v in victims)
    max_c = max(v.cg_mask.bit_count() for v in victims)
    kg = 0 if free_gpu.bit_count() >= need_gpus else -(
        -(need_gpus - free_gpu.bit_count()) // max(max_g, 1))
    kc = 0 if free_cg.bit_count() >= need_cgs else -(
        -(need_cgs - free_cg.bit_count()) // max(max_c, 1))
    return max(kg, kc)


@register_engine("imp")
def flextopo_imp(cluster: Cluster, workload: WorkloadSpec, node: int
                 ) -> list[Candidate]:
    """Algorithm 2: smallest-subset-first with early stop (+ counting
    lower bound so hopeless sizes fail 'quickly', per the paper's Fig 10)."""
    victims = cluster.victims_on(node, workload.priority)
    k_min = min_feasible_k(cluster, workload, node, victims)
    if k_min == 0:
        feasible = _evaluate_combos(cluster, node, workload, [()])
        if feasible:
            return feasible
        k_min = 1
    for k in range(k_min, len(victims) + 1):
        feasible = _evaluate_combos(
            cluster, node, workload, itertools.combinations(victims, k)
        )
        if feasible:
            return feasible  # early stop: no benefit in evicting more pods
    return []


# ---------------------------------------------------------------------------------
# Oracle for property tests: smallest feasible subset size by definition
# ---------------------------------------------------------------------------------

def brute_force_min_k(cluster: Cluster, workload: WorkloadSpec, node: int
                      ) -> tuple[int, list[Candidate]] | None:
    victims = cluster.victims_on(node, workload.priority)
    for k in range(0, len(victims) + 1):
        combos = [()] if k == 0 else list(itertools.combinations(victims, k))
        feasible = _evaluate_combos(cluster, node, workload, combos)
        if feasible:
            return k, feasible
    return None
