"""Pluggable candidate-sourcing engine registry (scheduler Sorting phase).

A *sourcing engine* implements the Best-effort Sorting step of Algorithm 1:
given the cluster state, a preemptor workload, and the Filtering survivors,
produce the `Candidate` (node, victim-set) evaluations that Eq. 2 selects
over.  Engines register themselves by name::

    @register_engine("my_engine")
    def my_source(cluster, workload, node) -> list[Candidate]: ...

and the scheduler resolves them with ``get_engine(name)``.  Cluster-wide
engines (one sweep over ALL candidate nodes, e.g. the vmapped
``imp_batched``) register with ``batched=True`` and receive the full node
list; per-node engines are looped by the default ``source_all``.

Engines that live in optionally-importable modules (the Pallas kernel)
register *lazily*: ``get_engine`` imports the owning module on first use and
the module's decorators complete the registration.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Protocol, runtime_checkable

from .scoring import Candidate, select_best

#: Backwards-compatible name for the engine identifier.  Engine names are now
#: open-ended registry keys rather than a closed Literal; the canonical list
#: is ``registered_engines()``.
EngineName = str


@runtime_checkable
class SourcingEngine(Protocol):
    """Protocol every registered engine satisfies.

    ``topology_aware=False`` marks baseline engines (Gödel-standard): the
    scheduler then filters by resource count only, scans nodes first-fit in
    the normal cycle, and selects candidates with ``select`` instead of the
    Eq. 2 argmax.
    """

    name: str
    topology_aware: bool

    def source(self, cluster, workload, node: int) -> list[Candidate]:
        """Candidates for one node."""
        ...

    def source_all(self, cluster, workload, nodes: list[int],
                   alpha: float | None = None) -> list[Candidate]:
        """Candidates for all filtered nodes (batched engines do one sweep).

        ``alpha`` is the scheduler's Eq. 1 weight; *fused* engines that run
        the Eq. 2 selection on device (``imp_batched``) consume it during
        sourcing and return only the winning shortlist.  The scheduler
        passes it whenever the engine's signature accepts it.
        """
        ...

    def select(self, candidates: list[Candidate], alpha: float) -> Candidate | None:
        """Pick the winning candidate (Eq. 2 unless the engine overrides)."""
        ...


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Function-backed `SourcingEngine` built by ``register_engine``."""

    name: str
    source_node: Callable | None = None     # fn(cluster, workload, node)
    source_nodes: Callable | None = None    # fn(cluster, workload, nodes)
    topology_aware: bool = True
    selector: Callable | None = None        # fn(candidates, alpha) -> Candidate
    needs_alpha: bool = False               # source_nodes takes alpha= (fused)
    #: the engine runs Guaranteed Filtering inside its own dispatch: the
    #: scheduler skips the host filter loop and calls ``source_all`` with
    #: ``nodes=None`` (evaluate the whole cluster)
    fused_filter: bool = False
    #: the engine runs BOTH cycles of Algorithm 1 — the normal cycle and
    #: §3.4 placement included — inside one dispatch: the scheduler calls
    #: ``plan_fused`` instead of its host ``_plan_normal``/``_place_on``
    #: loops and binds the decoded masks directly
    fused_place: bool = False
    #: fn(cluster_or_view, workload, alpha, allow_preempt) -> FusedPlanResult
    #: (the chained normal+preemptive program behind ``fused_place``)
    plan_fn: Callable | None = None
    #: fn(cluster_or_view, workload) -> (node, Placement) | None — the
    #: normal cycle alone as one device dispatch (the batch-plan path)
    normal_fn: Callable | None = None
    #: fn(cluster, workloads, alpha) -> batch-sourcing session for
    #: ``plan_batch`` (one vmapped dispatch over the request axis); the
    #: session's ``source(view, workload, i)`` replaces ``source_all``
    batch_factory: Callable | None = None
    #: fn(cluster, alpha): pre-compile the engine's jit buckets at
    #: ``TopoScheduler(..., warmup=True)`` construction
    warmup_fn: Callable | None = None
    #: the engine's ``plan_fn``/``source_nodes``/``warmup_fn`` accept a
    #: ``shortlist=`` `preemption_jax.ShortlistConfig`: the two-stage
    #: equivalence-class + top-K sourcing front-end.  Full-sweep oracle
    #: registrations (``*_full``) share the functions with the flag off.
    supports_shortlist: bool = False

    def source(self, cluster, workload, node: int) -> list[Candidate]:
        if self.source_node is not None:
            return list(self.source_node(cluster, workload, node))
        return self.source_all(cluster, workload, [node])

    def start_batch(self, cluster, workloads, alpha: float):
        """A batch-sourcing session for ``plan_batch``, or None."""
        if self.batch_factory is None:
            return None
        return self.batch_factory(cluster, workloads, alpha)

    def plan_fused(self, cluster, workload, alpha: float,
                   allow_preempt: bool = True, shortlist=None):
        """Both Algorithm 1 cycles in one dispatch (``fused_place``)."""
        if self.supports_shortlist and shortlist is not None:
            return self.plan_fn(cluster, workload, alpha, allow_preempt,
                                shortlist=shortlist)
        return self.plan_fn(cluster, workload, alpha, allow_preempt)

    def plan_normal(self, cluster, workload):
        """The normal cycle alone as one device dispatch."""
        return self.normal_fn(cluster, workload)

    def warmup(self, cluster, alpha: float, shortlist=None) -> None:
        """Pre-compile jit buckets (no-op for engines without warmup_fn)."""
        if self.warmup_fn is None:
            return
        if self.supports_shortlist and shortlist is not None:
            self.warmup_fn(cluster, alpha, shortlist=shortlist)
        else:
            self.warmup_fn(cluster, alpha)

    def source_all(self, cluster, workload, nodes: list[int],
                   alpha: float | None = None,
                   shortlist=None) -> list[Candidate]:
        if self.source_nodes is not None:
            kw = {}
            if self.needs_alpha and alpha is not None:
                kw["alpha"] = alpha
            if self.supports_shortlist and shortlist is not None:
                kw["shortlist"] = shortlist
            got = self.source_nodes(cluster, workload, nodes, **kw)
            # keep list subclasses intact (CandidateShortlist.n_candidates)
            return got if isinstance(got, list) else list(got)
        out: list[Candidate] = []
        for node in nodes:
            out.extend(self.source_node(cluster, workload, node))
        return out

    def select(self, candidates: list[Candidate], alpha: float) -> Candidate | None:
        if self.selector is not None:
            return self.selector(candidates, alpha)
        return select_best(candidates, alpha)


class UnknownEngineError(ValueError):
    """Raised for unregistered engine names; lists what IS registered."""

    def __init__(self, name: str) -> None:
        self.name = name
        super().__init__(
            f"unknown scheduling engine {name!r}; registered engines: "
            f"{', '.join(registered_engines())}"
        )


_REGISTRY: dict[str, SourcingEngine] = {}

# name -> module that self-registers it on import (kept out of the eager
# import graph: the Pallas kernel pulls in jax.experimental.pallas).
_LAZY: dict[str, str] = {
    "imp_pallas": "repro.kernels.topo_score",
    "imp_sharded": "repro.core.cluster_parallel",
    "imp_sharded_full": "repro.core.cluster_parallel",
}


def register_engine(
    name: str,
    *,
    batched: bool = False,
    topology_aware: bool = True,
    selector: Callable | None = None,
    needs_alpha: bool = False,
    fused_filter: bool = False,
    fused_place: bool = False,
    plan_fn: Callable | None = None,
    normal_fn: Callable | None = None,
    batch_factory: Callable | None = None,
    warmup_fn: Callable | None = None,
    supports_shortlist: bool = False,
):
    """Decorator: register a sourcing function (or a full engine object).

    Plain functions take ``(cluster, workload, node)`` — or
    ``(cluster, workload, nodes)`` with ``batched=True`` — and return
    `Candidate` lists.  ``needs_alpha=True`` marks a batched function whose
    signature ends in ``alpha=`` because it fuses the Eq. 2 selection into
    sourcing (``imp_batched``).  ``fused_filter=True`` additionally fuses
    Guaranteed Filtering into the dispatch: the scheduler stops filtering on
    the host and passes ``nodes=None``.  ``fused_place=True`` (with
    ``plan_fn``/``normal_fn``) goes further still: the engine runs BOTH
    Algorithm 1 cycles — normal-cycle argmin, Sorting, Eq. 2, and the §3.4
    placement masks — inside its dispatch, so the scheduler's host
    ``_plan_normal``/``_place_on`` loops collapse into the engine call.
    ``batch_factory`` and ``warmup_fn`` wire the ``plan_batch`` vmapped
    session (persistent across calls for ``imp_batched``) and the opt-in
    jit warm-up (see `EngineSpec`).  Objects already satisfying
    `SourcingEngine` are registered as-is.
    """

    def deco(obj):
        if all(hasattr(obj, a) for a in ("source", "source_all", "select")):
            _REGISTRY[name] = obj
        else:
            _REGISTRY[name] = EngineSpec(
                name=name,
                source_node=None if batched else obj,
                source_nodes=obj if batched else None,
                topology_aware=topology_aware,
                selector=selector,
                needs_alpha=needs_alpha,
                fused_filter=fused_filter,
                fused_place=fused_place,
                plan_fn=plan_fn,
                normal_fn=normal_fn,
                batch_factory=batch_factory,
                warmup_fn=warmup_fn,
                supports_shortlist=supports_shortlist,
            )
        _LAZY.pop(name, None)
        return obj

    return deco


def get_engine(name: str) -> SourcingEngine:
    """Resolve an engine by name, importing lazy providers on first use."""
    if name not in _REGISTRY and name in _LAZY:
        importlib.import_module(_LAZY[name])  # module self-registers
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownEngineError(name) from None


def registered_engines() -> tuple[str, ...]:
    """All resolvable engine names (eager and lazy), sorted."""
    return tuple(sorted(set(_REGISTRY) | set(_LAZY)))
