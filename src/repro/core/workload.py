"""Workload and instance models (paper Table 1 / Table 3)."""
from __future__ import annotations

import dataclasses
import enum


class TopoPolicy(str, enum.Enum):
    GUARANTEED = "guaranteed"
    BEST_EFFORT = "best_effort"
    NONE = "none"


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One co-located workload class (≈ a Kubernetes Deployment)."""

    name: str
    priority: int
    gpus_per_instance: int
    cores_per_instance: int
    preemptible: bool
    # Paper Table 1: NUMA affinity (bundle GPU↔local-cores) and socket affinity.
    numa_policy: TopoPolicy = TopoPolicy.GUARANTEED
    socket_policy: TopoPolicy = TopoPolicy.BEST_EFFORT
    critical: bool = True
    kind: str = "online"         # online | offline
    # Optional link to a model architecture served by instances of this workload.
    arch: str | None = None

    def coregroups_per_instance(self, coregroup_size: int) -> int:
        if self.cores_per_instance % coregroup_size:
            raise ValueError(
                f"{self.name}: {self.cores_per_instance} cores not a multiple of "
                f"CoreGroup size {coregroup_size}"
            )
        return self.cores_per_instance // coregroup_size


@dataclasses.dataclass
class Instance:
    """One scheduled instance (≈ a Pod) with its concrete placement."""

    uid: int
    workload: WorkloadSpec
    node: int = -1               # -1 => not scheduled
    gpu_mask: int = 0
    cg_mask: int = 0

    @property
    def name(self) -> str:
        return f"{self.workload.name}-{self.uid}"

    @property
    def priority(self) -> int:
        return self.workload.priority

    @property
    def preemptible(self) -> bool:
        return self.workload.preemptible


# ---- paper presets ------------------------------------------------------------------

def table1_workloads() -> list[WorkloadSpec]:
    """Paper Table 1 (Fig. 3 demonstration): A(32c,4G) B(16c,2G) C(8c,1G)."""
    return [
        WorkloadSpec("A", priority=1000, gpus_per_instance=4, cores_per_instance=32,
                     preemptible=False, kind="online"),
        WorkloadSpec("B", priority=1000, gpus_per_instance=2, cores_per_instance=16,
                     preemptible=False, kind="online"),
        WorkloadSpec("C", priority=100, gpus_per_instance=1, cores_per_instance=8,
                     preemptible=True, numa_policy=TopoPolicy.NONE,
                     socket_policy=TopoPolicy.NONE, critical=False, kind="offline"),
    ]


def table3_workloads() -> list[WorkloadSpec]:
    """Paper Table 3 (KWOK simulation): priorities 1500/1000/500/200."""
    return [
        WorkloadSpec("A", priority=1500, gpus_per_instance=8, cores_per_instance=64,
                     preemptible=False, kind="online"),
        WorkloadSpec("B", priority=1000, gpus_per_instance=4, cores_per_instance=32,
                     preemptible=False, kind="online"),
        WorkloadSpec("C", priority=500, gpus_per_instance=2, cores_per_instance=16,
                     preemptible=True, kind="offline"),
        WorkloadSpec("D", priority=200, gpus_per_instance=1, cores_per_instance=8,
                     preemptible=True, numa_policy=TopoPolicy.NONE,
                     socket_policy=TopoPolicy.NONE, critical=False, kind="offline"),
    ]


# Paper Table 3 initial instance counts for the 100-node saturation allocation.
TABLE3_INITIAL_INSTANCES = {"A": 20, "B": 40, "C": 200, "D": 80}
