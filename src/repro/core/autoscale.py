"""Diurnal-traffic auto-scaling policies driving preemptive scheduling
(paper §1, §2.3).

Online chat traffic follows a diurnal pattern; offline jobs pad the valleys.
`AutoscalePolicy` converts a traffic level into a desired replica count, and
the `Autoscaler` applies those targets through the transactional scheduler:

* **scale-up** is batched admission — the whole delta is planned against ONE
  snapshot via ``plan_batch`` and the feasible transactions commit in order
  (offline victims are evicted by the commits; the co-location event loop
  in `repro.core.colocation` requeues them).
* **scale-down** releases the *worst-achieved-tier* replicas first
  (cross-socket before same-socket before NUMA-local, deterministic by uid
  within a tier), so diurnal down-ramps defragment the cluster instead of
  freeing random well-placed instances.  The reclaimed capacity's tier
  distribution is reported per `AutoscaleEvent`.
* **backfill** admission goes through chunked ``plan_batch`` rounds
  (normal cycle only) instead of a one-at-a-time ``schedule()`` loop — the
  valley refills through the persistent batch session and the loop stops
  the first round nothing places, so it cannot spin when a single
  ``schedule`` flip-flops between placeable and not.

`Autoscaler.step`/`run_day` remain the episodic hour-loop interface; the
event-driven continuous-time day cycle lives in `repro.core.colocation`,
which consumes the same policies as event sources and drives this module's
scale executor from traffic ticks.
"""
from __future__ import annotations

import dataclasses
import math
import time

from .cluster import Cluster
from .placement import achieved_tier
from .scheduler import TopoScheduler
from .workload import Instance, WorkloadSpec


def diurnal_traffic(hour: float, peak: float = 1.0, trough: float = 0.3) -> float:
    """Smooth day curve in [trough, peak], peaking at 14:00."""
    phase = math.cos((hour - 14.0) / 24.0 * 2.0 * math.pi)
    return trough + (peak - trough) * (phase + 1.0) / 2.0


@dataclasses.dataclass
class AutoscalePolicy:
    workload: WorkloadSpec
    min_replicas: int
    max_replicas: int

    def desired(self, load: float) -> int:
        span = self.max_replicas - self.min_replicas
        return self.min_replicas + math.ceil(span * load)


@dataclasses.dataclass
class AutoscaleEvent:
    hour: float
    workload: str
    action: str            # scale_up | scale_down | noop
    delta: int
    preemptions: int
    hits: int
    failures: int
    placements: int = 0    # normal-cycle (non-preemptive) admissions
    #: scale-down only: achieved tier -> number of replicas released at that
    #: tier (the reclaimed-capacity tier distribution; worst tiers first)
    reclaimed_tiers: dict[int, int] = dataclasses.field(default_factory=dict)


class Autoscaler:
    def __init__(self, cluster: Cluster, scheduler: TopoScheduler,
                 policies: list[AutoscalePolicy],
                 backfill: WorkloadSpec | None = None,
                 backfill_chunk: int = 8) -> None:
        self.cluster = cluster
        self.scheduler = scheduler
        self.policies = policies
        self.backfill = backfill
        self.backfill_chunk = backfill_chunk
        self.events: list[AutoscaleEvent] = []
        # replica index: per-class uid -> Instance plus the achieved tier
        # cached at bind time (a placement is immutable for the instance's
        # lifetime, and ``restore`` re-inserts the original masks), kept
        # current through the cluster's instance-op stream.  Turns
        # ``replicas``/``online_reserve_gpus`` and the worst-tier
        # scale-down sort from O(all instances) scans into O(class) work —
        # at 10k nodes the cluster holds tens of thousands of offline
        # instances that a per-policy scan would walk every tick.
        self._by_class: dict[str, dict[int, Instance]] = {}
        self._tier: dict[int, int] = {}
        #: exact committed GPU count over all live instances (ints, so the
        #: incremental sum equals a fresh scan bit-for-bit)
        self.used_gpus = 0
        for inst in cluster.instances.values():
            self._index(+1, inst)
        cluster.add_inst_listener(self._index)
        #: amortized per-request wall time of every ``plan_batch`` issued
        #: through this autoscaler — one entry per planned request, the
        #: SAME metric for host and fused engines (unlike the scheduler's
        #: ``sourcing_us_log``, which host engines append to only on
        #: preemptive plans)
        self.plan_us: list[float] = []

    def _timed_plan_batch(self, workloads, allow_preempt: bool = True,
                          pad_to: int = 0):
        reqs = list(workloads)
        n = len(reqs)
        if pad_to > n:
            # fixed-width device dispatch: the padded tail is planned but
            # NEVER committed — the batch plans sequentially against a
            # shared view, so the first ``n`` decisions are unchanged
            reqs.extend([reqs[-1]] * (pad_to - n))
        t0 = time.perf_counter()
        txns = self.scheduler.plan_batch(reqs, allow_preempt=allow_preempt)
        per_req = (time.perf_counter() - t0) * 1e6 / max(1, n)
        self.plan_us.extend([per_req] * n)
        return txns[:n]

    def _index(self, delta: int, inst: Instance) -> None:
        name = inst.workload.name
        if delta > 0:
            self._by_class.setdefault(name, {})[inst.uid] = inst
            self._tier[inst.uid] = achieved_tier(self.cluster.spec,
                                                 inst.gpu_mask)
            self.used_gpus += inst.workload.gpus_per_instance
        else:
            cls = self._by_class.get(name)
            if cls is not None:
                cls.pop(inst.uid, None)
            self._tier.pop(inst.uid, None)
            self.used_gpus -= inst.workload.gpus_per_instance

    def replicas(self, name: str) -> list[Instance]:
        """Live replicas of one workload class, uid-ordered."""
        cls = self._by_class.get(name, {})
        return [cls[uid] for uid in sorted(cls)]

    _replicas = replicas        # compat alias

    def online_reserve_gpus(self, next_load: float) -> int:
        """GPUs the next tick's online scale-up will claim across all
        policies.  The two-level backfill ladder (`repro.core.colocation`,
        elastic mode) holds this many free GPUs back from whole-instance
        offline spin-up during rising load, so the ramp's online replicas
        land in the normal cycle instead of preempting offline instances
        that were created one tick earlier — shrinking the Eq. 2 victim
        set instead of growing it."""
        total = 0
        for pol in self.policies:
            want = pol.desired(next_load)
            have = len(self._by_class.get(pol.workload.name, {}))
            total += max(0, want - have) * pol.workload.gpus_per_instance
        return total

    # ---- the scale executor (shared with the co-location event loop) ---------------
    def scale_to(self, policy: AutoscalePolicy, want: int,
                 hour: float = 0.0) -> AutoscaleEvent:
        """Bring one policy's replica count to ``want`` and record the event."""
        current = self._replicas(policy.workload.name)
        delta = want - len(current)
        preemptions = hits = failures = placements = 0
        reclaimed: dict[int, int] = {}
        if delta > 0:
            # batched admission in FIXED-width chunks: every preemptive
            # device dispatch is ``backfill_chunk`` wide (final partial
            # chunks pad, single-request remainders take the scalar plan
            # path), so the vmapped batch session reuses ONE compiled
            # program across every scale-up instead of jitting per
            # distinct delta.  Decisions are bit-identical to one
            # whole-delta batch: the batch plans sequentially against a
            # shared view and chunks commit in order (the plan/commit
            # interleave invariant, ``TopoScheduler.plan_batch``)
            chunk = self.backfill_chunk
            done = 0
            while done < delta:
                n = min(chunk, delta - done)
                pad = chunk if 1 < n < chunk else 0
                for txn in self._timed_plan_batch([policy.workload] * n,
                                                  pad_to=pad):
                    dec = txn.commit()
                    if dec.rejected:
                        failures += 1
                    elif dec.preempted:
                        preemptions += 1
                        hits += int(dec.hit)
                    else:
                        placements += 1
                done += n
            action = "scale_up"
        elif delta < 0:
            # release the worst-achieved-tier replicas first (cross-socket,
            # then same-socket, then NUMA-local; uid-deterministic within a
            # tier) so down-ramps reclaim badly-distributed capacity; tiers
            # come from the bind-time cache, so the sort is O(class) instead
            # of recomputing masks across the whole fleet
            tiers = self._tier
            victims = sorted(current,
                             key=lambda i: (-tiers[i.uid], i.uid))
            for inst in victims[:-delta]:
                tier = tiers[inst.uid]
                reclaimed[tier] = reclaimed.get(tier, 0) + 1
                self.cluster.evict(inst.uid)
            action = "scale_down"
        else:
            action = "noop"
        ev = AutoscaleEvent(hour, policy.workload.name, action, delta,
                            preemptions, hits, failures, placements,
                            reclaimed)
        self.events.append(ev)
        return ev

    def backfill_valleys(self) -> tuple[int, int]:
        """Chunked ``plan_batch`` admission of the backfill workload.

        Plans ``backfill_chunk`` instances per round against one snapshot
        (normal cycle only — offline padding never preempts) and commits
        the placed ones; stops the first round in which nothing places, so
        a flip-flopping ``schedule`` can never spin the loop.  Returns
        ``(admitted, rejected_in_final_round)``.
        """
        if self.backfill is None:
            return 0, 0
        admitted = 0
        while True:
            txns = self._timed_plan_batch(
                [self.backfill] * self.backfill_chunk, allow_preempt=False)
            placed = [t for t in txns if t.decision.placed]
            for t in placed:
                t.commit()
            admitted += len(placed)
            if len(placed) < len(txns):
                return admitted, len(txns) - len(placed)

    # ---- the episodic hour-loop interface -------------------------------------------
    def step(self, hour: float) -> list[AutoscaleEvent]:
        load = diurnal_traffic(hour)
        out = [self.scale_to(pol, pol.desired(load), hour)
               for pol in self.policies]
        # co-location: offline work continuously pads whatever is free
        # (valleys between online peaks — paper §1 saturation allocation)
        self.backfill_valleys()
        return out

    def run_day(self, step_hours: float = 1.0) -> list[AutoscaleEvent]:
        t = 0.0
        out = []
        while t < 24.0:
            out.extend(self.step(t))
            t += step_hours
        return out
