"""Diurnal-traffic auto-scaler driving preemptive scheduling (paper §1, §2.3).

Online chat traffic follows a diurnal pattern; offline jobs pad the valleys.
The autoscaler converts a traffic curve into desired replica counts for the
online workloads, scales up via the topology-aware scheduler (preempting
offline instances as needed), and scales down by releasing instances — which
re-opens capacity the simulator back-fills with offline work (saturation).
"""
from __future__ import annotations

import dataclasses
import math
import random

from .cluster import Cluster
from .scheduler import TopoScheduler
from .workload import WorkloadSpec


def diurnal_traffic(hour: float, peak: float = 1.0, trough: float = 0.3) -> float:
    """Smooth day curve in [trough, peak], peaking at 14:00."""
    phase = math.cos((hour - 14.0) / 24.0 * 2.0 * math.pi)
    return trough + (peak - trough) * (phase + 1.0) / 2.0


@dataclasses.dataclass
class AutoscalePolicy:
    workload: WorkloadSpec
    min_replicas: int
    max_replicas: int

    def desired(self, load: float) -> int:
        span = self.max_replicas - self.min_replicas
        return self.min_replicas + math.ceil(span * load)


@dataclasses.dataclass
class AutoscaleEvent:
    hour: float
    workload: str
    action: str            # scale_up | scale_down | noop
    delta: int
    preemptions: int
    hits: int
    failures: int


class Autoscaler:
    def __init__(self, cluster: Cluster, scheduler: TopoScheduler,
                 policies: list[AutoscalePolicy],
                 backfill: WorkloadSpec | None = None,
                 seed: int = 0) -> None:
        self.cluster = cluster
        self.scheduler = scheduler
        self.policies = policies
        self.backfill = backfill
        self.rng = random.Random(seed)
        self.events: list[AutoscaleEvent] = []

    def _replicas(self, name: str) -> list[int]:
        return [i.uid for i in self.cluster.instances.values()
                if i.workload.name == name]

    def step(self, hour: float) -> list[AutoscaleEvent]:
        load = diurnal_traffic(hour)
        out = []
        for pol in self.policies:
            current = self._replicas(pol.workload.name)
            want = pol.desired(load)
            delta = want - len(current)
            preemptions = hits = failures = 0
            if delta > 0:
                # batched admission: plan the whole scale-up against one
                # snapshot, then commit the feasible transactions in order
                for txn in self.scheduler.plan_batch(
                        [pol.workload] * delta):
                    dec = txn.commit()
                    if dec.rejected:
                        failures += 1
                    elif dec.preempted:
                        preemptions += 1
                        hits += int(dec.hit)
                action = "scale_up"
            elif delta < 0:
                for uid in self.rng.sample(current, -delta):
                    self.cluster.evict(uid)
                action = "scale_down"
            else:
                action = "noop"
            ev = AutoscaleEvent(hour, pol.workload.name, action, delta,
                                preemptions, hits, failures)
            self.events.append(ev)
            out.append(ev)
        # co-location: offline work continuously pads whatever is free
        # (valleys between online peaks — paper §1 saturation allocation)
        if self.backfill is not None:
            while self.scheduler.schedule(self.backfill):
                pass
        return out

    def run_day(self, step_hours: float = 1.0) -> list[AutoscaleEvent]:
        t = 0.0
        out = []
        while t < 24.0:
            out.extend(self.step(t))
            t += step_hours
        return out
