"""Deterministic, sharded, resumable synthetic token pipeline.

Production semantics without external data: every (step, host-shard) pair
maps to an independent PRNG stream, so

  * restarting from a checkpoint at step k reproduces the exact batch k
    (fault-tolerant restart sees the same data),
  * each host generates only its shard (no duplicated host work),
  * elastic re-sharding (different host count) keeps the GLOBAL batch
    identical because streams are keyed by global example index.

Tokens follow a Zipfian distribution with short-range repetition structure so
losses move meaningfully during smoke training runs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    prefetch: int = 2


class SyntheticTokenPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1,
                 start_step: int = 0) -> None:
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ---- deterministic generation ---------------------------------------------
    def _example(self, step: int, index: int) -> np.ndarray:
        """Global example `index` of batch `step` — host-independent."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.PCG64(cfg.seed * 1_000_003 + step * 65_537 + index))
        # zipf with clipping into vocab, plus repetition structure
        raw = rng.zipf(cfg.zipf_a, size=cfg.seq_len).astype(np.int64)
        toks = (raw - 1) % cfg.vocab
        # repeat a motif so next-token prediction is learnable
        motif_len = 16
        motif = toks[:motif_len]
        reps = cfg.seq_len // (motif_len * 4)
        for r in range(reps):
            at = (r * 4 + 1) * motif_len
            toks[at:at + motif_len] = motif
        return toks.astype(np.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        per_shard = cfg.global_batch // self.num_shards
        lo = self.shard * per_shard
        toks = np.stack([self._example(step, lo + i) for i in range(per_shard)])
        return {"tokens": toks}

    # ---- iterator + prefetch ----------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            self._start_prefetch()
        return self._q.get()

    def _start_prefetch(self) -> None:
        def worker():
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(self.step), timeout=0.5)
                    self.step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._stop.set()

    # ---- checkpoint integration ----------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])
