"""Fault-tolerant checkpointing: atomic commits, retention, elastic restore.

Layout:  <dir>/step_<k>.tmp-<nonce>/  →  fsync'd  →  rename to <dir>/step_<k>/
The rename is the commit point; a crash mid-write leaves only a .tmp dir that
restore ignores and the next save garbage-collects.  Restore re-shards arrays
onto whatever mesh/sharding the caller passes (elastic scaling: a checkpoint
written on one mesh restores onto any other).
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        flat[_SEP.join(parts)] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, step: int, extra: dict | None = None
                ) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}-{time.time_ns()}"
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore_pytree(template, directory: str, step: int | None = None,
                   shardings=None) -> tuple:
    """Restore into the structure of `template` (shape/dtype tree).

    `shardings` (optional, same structure) re-shards each leaf on load —
    this is the elastic-restore path: the checkpoint is mesh-agnostic.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_t, tdef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_t))
    out = []
    for (kpath, leaf), sh in zip(leaves_t, shard_leaves):
        parts = []
        for p in kpath:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        key = _SEP.join(parts)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {arr.shape} != template "
                             f"{leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
    return tree, meta


class CheckpointManager:
    """Retention + crash-garbage collection + convenience wrappers."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, tree, step: int, extra: dict | None = None) -> str:
        self._gc_tmp()
        path = save_pytree(tree, self.directory, step, extra)
        self._retain()
        return path

    def restore_latest(self, template, shardings=None):
        return restore_pytree(template, self.directory, None, shardings)

    def _retain(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def _gc_tmp(self) -> None:
        for d in os.listdir(self.directory):
            if ".tmp" in d:
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)
