"""Two-level elastic co-location A/B (request-level backfill ladder).

Runs the SAME seeded Table 3 day twice through the event-driven co-location
engine — the backfill ladder at instance granularity only vs the two-level
request+instance ladder (`repro.serving.elastic`) — and writes
``BENCH_elastic.json`` at the repo root:

* ``goodput_uplift``     — offline-goodput uplift of the two-level ladder
  (valley capacity smaller than one instance stops being wasted);
* ``slo_attainment``     — per-mode online SLO attainment under the SAME
  sliding-window monitor (the admission guard must keep the two-level run
  no worse than the instance-only baseline);
* ``preemption_delta``   — two-level minus instance-only preemptions (the
  reserve guard + ramp-time instance demotion must make this negative);
* per-mode day totals (elastic admissions/ejections/demotions/completions,
  requeue counts, per-class goodput-vs-SLO rows).

``benchmarks.check_elastic_regression`` gates CI on this file.

Run: ``PYTHONPATH=src python -m benchmarks.bench_elastic``
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.colocation import ColocationConfig, compare_two_level
from repro.serving.elastic import ElasticConfig

from .common import FULL, emit

BENCH_JSON = Path(__file__).parent.parent / "BENCH_elastic.json"

MODES = ("instance_only", "two_level")

ENGINE = "imp_batched"


def day_config(full: bool = FULL, num_nodes: int | None = None,
               horizon_hours: float = 24.0, seed: int = 0) -> ColocationConfig:
    return ColocationConfig(
        num_nodes=num_nodes if num_nodes is not None else (41 if full else 24),
        seed=seed, engine=ENGINE, horizon_hours=horizon_hours, warmup=True,
        elastic_cfg=ElasticConfig())


def report_payload(rep) -> dict:
    return {
        "scheduled_perf": rep.scheduled_perf,
        "offline_goodput": rep.offline_goodput,
        "elastic_goodput": rep.elastic_goodput,
        "elastic_admitted": rep.elastic_admitted,
        "elastic_ejected": rep.elastic_ejected,
        "elastic_completed": rep.elastic_completed,
        "elastic_demoted": rep.elastic_demoted,
        "preemptions": rep.preemptions,
        "requeued": rep.requeued,
        "requeue_replanned": rep.requeue_replanned,
        "placements": rep.placements,
        "failures": rep.failures,
        "slo_attainment": rep.slo_attainment,
        "slo_violations": rep.slo_violations,
        "slo_by_class": rep.slo_by_class(),
    }


def run(full: bool = FULL, write: bool = True) -> dict:
    cfg = day_config(full)
    ab = compare_two_level(cfg)
    payload = {
        "num_nodes": cfg.num_nodes,
        "seed": cfg.seed,
        "horizon_hours": cfg.horizon_hours,
        "engine": cfg.engine,
        "goodput_uplift": ab["goodput_uplift"],
        "preemption_delta": ab["preemption_delta"],
        "modes": {name: report_payload(rep)
                  for name, rep in ab["reports"].items()},
    }
    if write:
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    io, tl = (payload["modes"][m] for m in MODES)
    emit("elastic_uplift", 0.0,
         f"offline_goodput +{payload['goodput_uplift'] * 100:.1f}% "
         f"preemptions {io['preemptions']}->{tl['preemptions']}")
    emit("elastic_two_level", 0.0,
         f"goodput={tl['offline_goodput']:.0f} "
         f"(elastic {tl['elastic_goodput']:.0f}) "
         f"adm={tl['elastic_admitted']} demote={tl['elastic_demoted']} "
         f"slo={tl['slo_attainment']:.3f}")
    emit("elastic_instance_only", 0.0,
         f"goodput={io['offline_goodput']:.0f} "
         f"requeued={io['requeued']} slo={io['slo_attainment']:.3f}")
    return payload


if __name__ == "__main__":
    run()
