import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb variants for dry-run cells: re-lower + compile with a
config override, recompute roofline terms, record before/after.

Run directly (it manages its own 512 placeholder devices):
  PYTHONPATH=src python -m benchmarks.perf_variants
"""
import dataclasses
import gzip
import json
import sys
import time

from repro.configs import SHAPES, get_config
from repro.launch import hlo as hlo_util
from repro.launch.dryrun import _memory_dict, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models.common import MoEConfig

from . import roofline as rl

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def run_variant(arch: str, shape_name: str, variant: str, overrides: dict,
                force: bool = False) -> dict:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{variant}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh, overrides=overrides)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    txt = compiled.as_text()
    stats = hlo_util.walk_stats(txt)
    cfg = dataclasses.replace(get_config(arch), **overrides)
    shape = SHAPES[shape_name]
    mem_dev = rl.hbm_bytes(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "compile_s": round(compile_s, 1),
        "memory": _memory_dict(compiled.memory_analysis()),
        "flops_dev": stats["flops_scaled"],
        "collective_bytes_dev": stats["collective_bytes_scaled"],
        "terms": {
            "compute_s": stats["flops_scaled"] / rl.PEAK_FLOPS,
            "memory_s": mem_dev / rl.HBM_BW,
            "collective_s": stats["collective_bytes_scaled"] / rl.LINK_BW,
        },
    }
    with gzip.open(os.path.join(
            OUT_DIR, f"{arch}__{shape_name}__{variant}.txt.gz"), "wt") as f:
        f.write(txt)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


VARIANTS = [
    # (arch, shape, variant, overrides)
    # H1: qwen2 28 heads don't divide TP16 -> attention replicated 16x.
    #     Pad to 32 heads: +14% attention FLOPs but 16-way sharded.
    ("qwen2-7b", "train_4k", "pad_heads_32", {"n_heads": 32}),
    # H2: mixtral MoE global dispatch argsorts/gathers across data shards.
    #     Per-sequence dispatch keeps sort + capacity buffers data-local.
    ("mixtral-8x7b", "train_4k", "per_seq_dispatch",
     {"moe": MoEConfig(num_experts=8, top_k=2, dispatch="per_sequence")}),
    # H2b: combine with remat policy 'dots' (save matmul outputs: less
    #      recompute, more memory) — secondary lever on the compute term.
    ("mixtral-8x7b", "train_4k", "per_seq_dispatch_dots",
     {"moe": MoEConfig(num_experts=8, top_k=2, dispatch="per_sequence"),
      "remat": "dots"}),
    # H1b: qwen2 pad + per-shape check on prefill (same uneven-head waste)
    ("qwen2-7b", "prefill_32k", "pad_heads_32", {"n_heads": 32}),
    # --- round 2 (targets chosen from round-1 results) ---
    # H1c: after padding, qwen2 train becomes collective-bound (wo psums) ->
    #      sequence parallelism: bf16 AG/RS instead of f32 all-reduce
    ("qwen2-7b", "train_4k", "pad32_sp", {"n_heads": 32, "seq_shard": True}),
    # H4: prefill cells materialize S^2 scores (490GB/dev!) -> q-chunked
    #     attention bounds live scores to [B, H, 512, S]
    ("qwen2-7b", "prefill_32k", "pad32_chunked",
     {"n_heads": 32, "attn_chunk_q": 512}),
    ("paligemma-3b", "prefill_32k", "chunked", {"attn_chunk_q": 512}),
    # H2c: mixtral — Megatron anchors on expert FFN intermediates (defer the
    #      psum to the d-sized down-proj output)
    ("mixtral-8x7b", "train_4k", "ffn_constrain",
     {"moe": MoEConfig(num_experts=8, top_k=2, constrain_ffn=True)}),
    # H2d: ZeRO-1 for expert weights — params replicated over data, only
    #      optimizer states sharded; removes per-layer gathers + the fp32
    #      backward activation psums (round-1 analysis)
    ("mixtral-8x7b", "train_4k", "zero1_experts",
     {"moe_zero1": True,
      "moe": MoEConfig(num_experts=8, top_k=2, dispatch="per_sequence")}),
    # H2e (BLOCKED): shard_map island — partial-manual shard_map nested in a
    #      lax.scan trips an XLA fatal check ("Invalid binary instruction
    #      opcode copy") at any partition count; the island is validated
    #      standalone (tests) and documented in EXPERIMENTS.md §Perf.
    # H2f: best surviving combination — ZeRO-1 experts + dots remat
    ("mixtral-8x7b", "train_4k", "zero1_dots",
     {"moe_zero1": True, "remat": "dots",
      "moe": MoEConfig(num_experts=8, top_k=2, dispatch="per_sequence")}),
    # H3: olmoe (true EP: 64 experts / 16-way model axis) — does per-seq
    #     dispatch + zero1 help the EP regime too?
    ("olmoe-1b-7b", "train_4k", "per_seq_zero1",
     {"moe_zero1": True,
      "moe": MoEConfig(num_experts=64, top_k=8, dispatch="per_sequence")}),
    # H5: dense ZeRO-1 — qwen3 train is collective-bound (6.6 s) largely on
    #     per-layer FSDP weight gathers; replicate bf16 params over data
    #     (8B/16-way TP = 1 GB/dev params; opt states stay fully sharded)
    ("qwen3-8b", "train_4k", "zero1_dense", {"zero1": True}),
    ("qwen3-8b", "train_4k", "zero1_sp", {"zero1": True, "seq_shard": True}),
]


def main() -> None:
    force = "--force" in sys.argv
    for arch, shape, variant, overrides in VARIANTS:
        base = rl.roofline_row(arch, shape)
        rec = run_variant(arch, shape, variant, overrides, force=force)
        t = rec["terms"]
        print(f"{arch} {shape} [{variant}] compile={rec['compile_s']}s")
        if base:
            print(f"  before: compute={base.compute_s:.2f}s "
                  f"memory={base.memory_s:.2f}s "
                  f"collective={base.collective_s:.2f}s  dominant={base.dominant}")
        print(f"  after:  compute={t['compute_s']:.2f}s "
              f"memory={t['memory_s']:.2f}s "
              f"collective={t['collective_s']:.2f}s", flush=True)


if __name__ == "__main__":
    main()
