"""Shared benchmark plumbing: CSV emission + sizing knobs."""
from __future__ import annotations

import os
import sys

FULL = os.environ.get("BENCH_FULL", "") == "1"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def p(x: list[float], q: float) -> float:
    import numpy as np

    return float(np.percentile(x, q)) if x else 0.0
