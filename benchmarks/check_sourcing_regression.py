"""CI smoke gate for the fused sourcing fast path.

Re-runs the small-protocol Table 5 latency experiment for the fused
``imp_batched`` engine (plus ``imp_batched_legacy`` for the hit-rate
identity check) and fails if

* the fused sourcing P50 — or the filtering-inclusive end-to-end ``plan()``
  P50, the normal-cycle ``plan_normal_e2e`` P50 (the chained
  normal+placement dispatch), or the persistent-session ``plan_batch8``
  P50 — regresses more than ``MAX_REGRESSION``x over the committed
  ``BENCH_sourcing.json`` baseline, or
* the fused hit rate diverges from the legacy engine at the same seed
  (the fused on-device Filtering + Eq. 2 selection must be
  decision-identical), or
* the committed ``scale`` block (written by ``benchmarks.
  bench_scale_sourcing``: plan P50s at 24..10k nodes) is missing, shows
  SUPER-``SUBLINEAR_FRACTION``-linear ``imp_sharded`` plan-P50 growth from
  the smallest to the largest size, or records an ``imp_sharded``-vs-``imp_batched``
  decision divergence at any size — plus a LIVE parity re-check at the two
  smallest sizes (single-process, degenerate one-device mesh: the sharded
  evaluators must stay bit-identical without the 8-device subprocess), or
* the committed scale block violates the shortlist front-end contract:
  any row timed while a jit bucket was still compiling
  (``compiled_n > 0`` — warmup was incomplete, the numbers are invalid),
  any ``shortlist_parity`` flag false (guaranteed mode must be
  bit-identical to the full sweep), the shortlisted ``plan_e2e`` P50 not
  beating its ``*_full`` full-sweep twin at the ``SHORTLIST_GATE_SIZES``
  (modulo the documented ``SHORTLIST_SPEEDUP_CAPS`` exception),
  or the shortlisted ``imp_sharded`` plan P50 at the largest size above
  the ``SHORTLIST_ABS_CAP_US`` absolute budget.

Baseline rows tagged ``"interpret": true`` (Mosaic-interpreter Pallas runs
on CPU) are placeholders, not wall-clock measurements — the gate skips
them.  CI machines are noisy, so the threshold is deliberately loose (2x):
the gate catches structural regressions (a lost jit cache, an accidental
per-k dispatch loop, a host re-upload of the resident state), not
scheduler jitter.

Run: ``PYTHONPATH=src python -m benchmarks.check_sourcing_regression``
"""
from __future__ import annotations

import json
import sys

from repro.core.simulator import (SimConfig, run_latency_experiment,
                                  run_plan_batch_latency,
                                  run_plan_latency_experiment,
                                  run_plan_normal_latency)

from .bench_sourcing_latency import BENCH_JSON
from .common import p

MAX_REGRESSION = 2.0

#: sub-linearity gate for the scale sweep: P50 growth from the smallest to
#: the largest committed size must stay under this fraction of the node
#: -count growth (0.5 = per-node cost at 10k nodes is at most HALF the
#: per-node cost at 24 — comfortably met by the measured ~0.1-0.3, loose
#: enough for machine noise)
SUBLINEAR_FRACTION = 0.5

#: metrics the sub-linearity gate covers (plan_batch8 is recorded in the
#: block but not growth-gated: per-request amortization already makes it
#: the cheapest path and its small per-size round counts are noisier)
SCALE_GATED_METRICS = ("plan_e2e", "plan_normal_e2e")

#: engines the sub-linearity gate covers.  The scaling claim is about the
#: mesh-sharded engine; ``imp_batched`` rows stay in the block as the
#: single-device reference (and are parity-gated at every size) but its
#: growth is printed without gating — its 24-node P50 is noise-dominated
#: (a few samples of ~1ms against a multi-second jit tail) and sits right
#: on the cap, which would make CI a coin flip.
SCALE_GATED_ENGINES = ("imp_sharded",)

#: sizes where the shortlisted plan_e2e P50 must beat the full sweep's
#: (below the default K=128 the prescreen is inactive, so only the two
#: largest committed sizes carry the speedup claim)
SHORTLIST_GATE_SIZES = (1024, 10240)

#: per-(size, engine) cap on shortlisted/full P50.  Strictly < 1.0
#: everywhere the sweep dominates; the one exception is ``imp_sharded``
#: at 1024 nodes, where BOTH paths are dispatch-overhead-dominated on
#: the CPU host-platform mesh (~24ms fixed multi-device dispatch vs a
#: ~4ms single-device sweep) so the prescreen has nothing to cut —
#: there the gate is non-inferiority (<= 1.15x, i.e. the front-end must
#: not cost anything real).  ``engine="auto"`` routes 1024-node
#: clusters to ``imp_batched`` anyway; the sharded speedup claim lives
#: at 10240 where it is gated strictly.
SHORTLIST_SPEEDUP_CAPS = {(1024, "imp_sharded"): 1.15}

#: absolute plan-P50 budget for the shortlisted ``imp_sharded`` engine at
#: the largest committed size — 0.5x the 190ms full-sweep P50 the previous
#: baseline committed at 10240 nodes
SHORTLIST_ABS_CAP_US = 95_000.0


def check_scale(baseline: dict) -> int:
    """Gate the committed scale block + live small-size sharded parity."""
    scale = baseline.get("scale")
    if not scale:
        print("FAIL: no scale block in BENCH_sourcing.json "
              "(run benchmarks.bench_scale_sourcing)")
        return 1
    failures = 0
    rows = {(r["nodes"], r["engine"], r["metric"]): r for r in scale["rows"]}
    sizes = sorted(scale["sizes"])
    n_min, n_max = sizes[0], sizes[-1]
    node_ratio = n_max / n_min
    for engine in ("imp_batched", "imp_sharded"):
        gated = engine in SCALE_GATED_ENGINES
        for metric in SCALE_GATED_METRICS:
            lo = rows.get((n_min, engine, metric))
            hi = rows.get((n_max, engine, metric))
            if not lo or not hi or not lo["p50_us"]:
                print(f"FAIL scale: missing {engine}/{metric} rows")
                failures += 1
                continue
            growth = hi["p50_us"] / lo["p50_us"]
            cap = SUBLINEAR_FRACTION * node_ratio
            if not gated:
                status = "reference, ungated"
            elif growth <= cap:
                status = "ok"
            else:
                status = "REGRESSION"
            print(f"scale {engine}/{metric}: p50 {lo['p50_us']:.0f}us@{n_min}"
                  f" -> {hi['p50_us']:.0f}us@{n_max} = {growth:.1f}x growth "
                  f"(cap {cap:.0f}x, nodes grew {node_ratio:.0f}x) [{status}]")
            if gated and growth > cap:
                failures += 1
    for size in scale["sizes"]:
        if not scale["parity"].get(str(size)):
            print(f"FAIL scale: imp_sharded decisions diverged from "
                  f"imp_batched at {size} nodes in the committed block")
            failures += 1
    # benchmark hygiene: a timed sample that paid a compile is not a
    # latency measurement — refuse the whole committed row
    for r in scale["rows"]:
        if r.get("compiled_n", 0) > 0:
            print(f"FAIL scale: row {r['nodes']}/{r['engine']}/{r['metric']} "
                  f"timed {r['compiled_n']} compiling sample(s) — rerun "
                  f"bench_scale_sourcing with full warmup before committing")
            failures += 1
    # shortlist contract: guaranteed mode is bit-identical to the sweep...
    slp = scale.get("shortlist_parity") or {}
    if not slp:
        print("FAIL scale: no shortlist_parity flags in the committed "
              "block (rerun benchmarks.bench_scale_sourcing)")
        failures += 1
    for key, ok in sorted(slp.items()):
        if not ok:
            print(f"FAIL scale: shortlisted decisions diverged from the "
                  f"full sweep at {key}")
            failures += 1
    # ...and the prescreen must actually pay for itself where it is active
    for size in SHORTLIST_GATE_SIZES:
        for engine in ("imp_batched", "imp_sharded"):
            sl = rows.get((size, engine, "plan_e2e"))
            fw = rows.get((size, engine + "_full", "plan_e2e"))
            if not sl or not fw or not sl["p50_us"] or not fw["p50_us"]:
                print(f"FAIL scale: missing shortlist/full plan_e2e rows "
                      f"for {engine} at {size} nodes")
                failures += 1
                continue
            speedup = fw["p50_us"] / sl["p50_us"]
            cap = SHORTLIST_SPEEDUP_CAPS.get((size, engine), 1.0)
            ok = sl["p50_us"] < fw["p50_us"] * cap
            kind = "beats sweep" if cap == 1.0 else f"non-inferior({cap}x)"
            print(f"scale shortlist {engine}@{size}: p50 "
                  f"{sl['p50_us']:.0f}us vs full sweep {fw['p50_us']:.0f}us "
                  f"({speedup:.2f}x, gate: {kind}) "
                  f"[{'ok' if ok else 'REGRESSION'}]")
            if not ok:
                failures += 1
    cap_row = rows.get((max(scale["sizes"]), "imp_sharded", "plan_e2e"))
    if not cap_row or not cap_row["p50_us"]:
        print("FAIL scale: missing shortlisted imp_sharded plan_e2e row "
              "at the largest size")
        failures += 1
    elif cap_row["p50_us"] > SHORTLIST_ABS_CAP_US:
        print(f"FAIL scale: shortlisted imp_sharded plan_e2e p50 "
              f"{cap_row['p50_us']:.0f}us at {max(scale['sizes'])} nodes "
              f"exceeds the {SHORTLIST_ABS_CAP_US:.0f}us budget")
        failures += 1
    else:
        print(f"scale shortlist abs cap: imp_sharded plan_e2e p50 "
              f"{cap_row['p50_us']:.0f}us @ {max(scale['sizes'])} nodes "
              f"<= {SHORTLIST_ABS_CAP_US:.0f}us [ok]")
    # live parity: rerun the decision sequence at the two smallest sizes
    from repro.core import TopoScheduler, table3_workloads

    from .bench_scale_sourcing import _parity_sequence, build_scaled_cluster

    wl = {w.name: w for w in table3_workloads()}
    for n in sizes[:2]:
        keys = {}
        for engine in ("imp_batched", "imp_sharded"):
            sched = TopoScheduler(build_scaled_cluster(n, seed=0),
                                  engine=engine, alpha=0.5)
            keys[engine] = _parity_sequence(sched, wl, batch=8)
        same = keys["imp_batched"] == keys["imp_sharded"]
        print(f"scale live parity @{n} nodes: "
              f"{'identical' if same else 'DIVERGED'}")
        if not same:
            failures += 1
    return failures


def main() -> int:
    if not BENCH_JSON.exists():
        print(f"FAIL: no committed baseline at {BENCH_JSON}")
        return 1
    baseline = json.loads(BENCH_JSON.read_text())
    base_rows = {(r["workload"], r["engine"], r.get("metric", "sourcing")): r
                 for r in baseline["rows"]}
    skipped = [k for k, r in base_rows.items() if r.get("interpret")]
    for k in skipped:
        print(f"SKIP {k}: interpret-mode placeholder, not gated")
    cfg = SimConfig(num_nodes=int(baseline.get("num_nodes", 50)),
                    seed=int(baseline.get("seed", 0)))
    samples = int(baseline.get("samples", 20))
    failures = 0
    for wl, label in (("B", "high-p-1000-4-card"), ("C", "low-p-500-2-card")):
        ref = base_rows.get((label, "imp_batched", "sourcing"))
        ref_e2e = base_rows.get((label, "imp_batched", "plan_e2e"))
        ref_legacy = base_rows.get((label, "imp_batched_legacy", "sourcing"))
        if ref is None or not ref["p50_us"] or ref.get("interpret"):
            print(f"SKIP {label}: no gateable fused baseline row")
            continue
        fused = run_latency_experiment(cfg, "imp_batched", wl, samples=samples)
        legacy = run_latency_experiment(cfg, "imp_batched_legacy", wl,
                                        samples=samples)
        p50 = p(fused.sourcing_us, 50)
        # normalize away machine speed: when the legacy engine runs slower
        # on THIS machine than in the committed run, relax the baseline by
        # the same factor (clamped to >= 1 so noise never tightens the gate)
        norm = 1.0
        if ref_legacy and ref_legacy["p50_us"]:
            norm = max(1.0, p(legacy.sourcing_us, 50) / ref_legacy["p50_us"])
        ratio = p50 / (ref["p50_us"] * norm)
        status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
        print(f"{label}: fused p50 {p50:.0f}us vs baseline "
              f"{ref['p50_us']:.0f}us (machine norm {norm:.2f}, "
              f"{ratio:.2f}x) [{status}]")
        if ratio > MAX_REGRESSION:
            failures += 1
        if ref_e2e and ref_e2e["p50_us"]:
            e2e = run_plan_latency_experiment(cfg, "imp_batched", wl,
                                              samples=samples)
            e2e_p50 = p(e2e.sourcing_us, 50)
            ratio = e2e_p50 / (ref_e2e["p50_us"] * norm)
            status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
            print(f"{label}: fused plan_e2e p50 {e2e_p50:.0f}us vs baseline "
                  f"{ref_e2e['p50_us']:.0f}us ({ratio:.2f}x) [{status}]")
            if ratio > MAX_REGRESSION:
                failures += 1
        ref_normal = base_rows.get((label, "imp_batched", "plan_normal_e2e"))
        if ref_normal and ref_normal["p50_us"]:
            rep = run_plan_normal_latency(cfg, "imp_batched", wl,
                                          samples=samples)
            n_p50 = p(rep.sourcing_us, 50)
            ratio = n_p50 / (ref_normal["p50_us"] * norm)
            status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
            print(f"{label}: fused plan_normal_e2e p50 {n_p50:.0f}us vs "
                  f"baseline {ref_normal['p50_us']:.0f}us "
                  f"({ratio:.2f}x) [{status}]")
            if ratio > MAX_REGRESSION:
                failures += 1
        ref_batch = base_rows.get((label, "imp_batched", "plan_batch8"))
        if ref_batch and ref_batch["p50_us"]:
            rep = run_plan_batch_latency(cfg, "imp_batched", wl, batch=8)
            b_p50 = p(rep.sourcing_us, 50)
            ratio = b_p50 / (ref_batch["p50_us"] * norm)
            status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
            print(f"{label}: persistent plan_batch8 p50 {b_p50:.0f}us vs "
                  f"baseline {ref_batch['p50_us']:.0f}us "
                  f"({ratio:.2f}x) [{status}]")
            if ratio > MAX_REGRESSION:
                failures += 1
        if (fused.preemptions, fused.hits) != (legacy.preemptions, legacy.hits):
            print(f"FAIL {label}: fused hits {fused.hits}/{fused.preemptions} "
                  f"!= legacy {legacy.hits}/{legacy.preemptions}")
            failures += 1
        else:
            print(f"{label}: hit-rate identical to legacy "
                  f"({fused.hits}/{fused.preemptions})")
    failures += check_scale(baseline)
    if failures:
        print(f"FAIL: {failures} sourcing-latency gate(s) tripped")
        return 1
    print("sourcing fast path within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
