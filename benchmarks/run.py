"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Set BENCH_FULL=1 for the
paper-scale protocol (100 nodes, 100x50 preemptions).

  table4_*  — hit rate (paper Table 4)
  table5_*  — candidate-sourcing latency (paper Table 5 / Fig 11)
  scale_*   — plan-latency scale sweep 24..10k nodes, sharded vs fused
              (8-device subprocess; merges the BENCH_sourcing.json scale block)
  fig10_*   — per-workload sourcing overhead (paper Fig 10)
  fig9_*    — preemption timeline (paper Fig 9)
  fig8_*    — allocation snapshots (paper Fig 8)
  colocation_* — day-cycle co-location A/B (paper §1/§2.3, Fig 2 headline)
  elastic_*  — two-level request+instance backfill ladder A/B
  roofline_* — §Roofline terms per (arch x shape) from the dry-run
"""
from __future__ import annotations

import time


def main() -> None:
    from . import (bench_allocation_snapshot, bench_colocation,
                   bench_elastic, bench_hit_rate, bench_instance_timeline,
                   bench_roofline, bench_scale_sourcing,
                   bench_scheduler_hillclimb, bench_sourcing_latency,
                   bench_workload_overhead)

    print("name,us_per_call,derived")
    # bench_scale_sourcing must follow bench_sourcing_latency: the latter
    # rewrites BENCH_sourcing.json and the former merges its scale block in
    for mod in (bench_hit_rate, bench_sourcing_latency, bench_scale_sourcing,
                bench_workload_overhead, bench_instance_timeline,
                bench_allocation_snapshot, bench_colocation, bench_elastic,
                bench_scheduler_hillclimb, bench_roofline):
        t0 = time.time()
        mod.run()
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
