"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Set BENCH_FULL=1 for the
paper-scale protocol (100 nodes, 100x50 preemptions).

  table4_*  — hit rate (paper Table 4)
  table5_*  — candidate-sourcing latency (paper Table 5 / Fig 11)
  scale_*   — plan-latency scale sweep 24..10k nodes, sharded vs fused
              (8-device subprocess; merges the BENCH_sourcing.json scale block)
  fig10_*   — per-workload sourcing overhead (paper Fig 10)
  fig9_*    — preemption timeline (paper Fig 9)
  fig8_*    — allocation snapshots (paper Fig 8)
  colocation_* — day-cycle co-location A/B (paper §1/§2.3, Fig 2 headline)
  elastic_*  — two-level request+instance backfill ladder A/B
  roofline_* — §Roofline terms per (arch x shape) from the dry-run
"""
from __future__ import annotations

import argparse
import time


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run every benchmark; the co-location day cycle "
                    "accepts size/horizon/seed overrides")
    ap.add_argument("--nodes", type=int, default=None,
                    help="co-location cluster size override (forwarded to "
                         "bench_colocation; overridden runs don't rewrite "
                         "the committed BENCH JSON)")
    ap.add_argument("--hours", type=float, default=24.0,
                    help="co-location day-cycle horizon in simulated hours")
    ap.add_argument("--seed", type=int, default=0,
                    help="co-location arrival-stream / placement seed")
    ap.add_argument("--skip-scale", action="store_true",
                    help="skip the co-location O(delta) scale sweep")
    args = ap.parse_args(argv)
    overridden = (args.nodes is not None or args.hours != 24.0
                  or args.seed != 0)

    from . import (bench_allocation_snapshot, bench_colocation,
                   bench_elastic, bench_hit_rate, bench_instance_timeline,
                   bench_roofline, bench_scale_sourcing,
                   bench_scheduler_hillclimb, bench_sourcing_latency,
                   bench_workload_overhead)

    print("name,us_per_call,derived")
    # bench_scale_sourcing must follow bench_sourcing_latency: the latter
    # rewrites BENCH_sourcing.json and the former merges its scale block in
    for mod in (bench_hit_rate, bench_sourcing_latency, bench_scale_sourcing,
                bench_workload_overhead, bench_instance_timeline,
                bench_allocation_snapshot, bench_colocation, bench_elastic,
                bench_scheduler_hillclimb, bench_roofline):
        t0 = time.time()
        if mod is bench_colocation:
            mod.run(num_nodes=args.nodes, horizon_hours=args.hours,
                    seed=args.seed, write=not overridden,
                    skip_scale=args.skip_scale)
        else:
            mod.run()
        print(f"# {mod.__name__} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
