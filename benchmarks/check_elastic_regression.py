"""CI gate for the two-level elastic co-location A/B.

Re-runs the committed ``BENCH_elastic.json`` protocol (same nodes, seed,
horizon, engine) and fails if

* the two-level ladder no longer strictly increases offline goodput over
  the instance-only baseline (``goodput_uplift <= 0``),
* online SLO attainment under the two-level ladder drops below the
  instance-only baseline (the admission guard stopped guarding),
* the two-level run no longer has strictly fewer instance preemptions
  (``preemption_delta >= 0`` — the reserve guard or the ramp-time
  demotion path stopped working),
* the elastic layer stopped being exercised (nothing admitted into
  request slots, or nothing completed there), or
* either mode's deterministic day metrics drift from the committed
  baseline (both runs are seeded end to end and must reproduce
  bit-for-bit on any machine).

Run: ``PYTHONPATH=src python -m benchmarks.check_elastic_regression``
"""
from __future__ import annotations

import json
import math
import sys

from .bench_elastic import BENCH_JSON, MODES, day_config, report_payload

REL_TOL = 1e-6

FLOAT_METRICS = ("scheduled_perf", "offline_goodput", "elastic_goodput",
                 "slo_attainment")
INT_METRICS = ("elastic_admitted", "elastic_ejected", "elastic_completed",
               "elastic_demoted", "preemptions", "requeued",
               "requeue_replanned", "placements", "failures",
               "slo_violations")


def main() -> int:
    if not BENCH_JSON.exists():
        print(f"FAIL: no committed baseline at {BENCH_JSON}")
        return 1
    base = json.loads(BENCH_JSON.read_text())
    from repro.core.colocation import compare_two_level

    cfg = day_config(num_nodes=int(base["num_nodes"]),
                     horizon_hours=float(base["horizon_hours"]),
                     seed=int(base["seed"]))
    ab = compare_two_level(cfg)
    modes = {name: report_payload(rep) for name, rep in ab["reports"].items()}
    io, tl = (modes[m] for m in MODES)
    failures = 0

    uplift = ab["goodput_uplift"]
    status = "ok" if uplift > 0 else "REGRESSION"
    print(f"offline-goodput uplift two_level vs instance_only: "
          f"{uplift * 100:+.1f}% [{status}]")
    if uplift <= 0:
        failures += 1

    ok = tl["slo_attainment"] >= io["slo_attainment"]
    print(f"online SLO attainment: two_level {tl['slo_attainment']:.4f} vs "
          f"instance_only {io['slo_attainment']:.4f} "
          f"[{'ok' if ok else 'REGRESSION'}]")
    if not ok:
        failures += 1

    delta = ab["preemption_delta"]
    ok = delta < 0
    print(f"instance preemptions: two_level {tl['preemptions']} vs "
          f"instance_only {io['preemptions']} (delta {delta:+d}) "
          f"[{'ok' if ok else 'REGRESSION'}]")
    if not ok:
        failures += 1

    exercised = tl["elastic_admitted"] > 0 and tl["elastic_completed"] > 0
    print(f"elastic layer exercised: admitted={tl['elastic_admitted']} "
          f"completed={tl['elastic_completed']} "
          f"demoted={tl['elastic_demoted']} "
          f"[{'ok' if exercised else 'FAIL'}]")
    if not exercised:
        failures += 1

    for mode in MODES:
        committed = base["modes"][mode]
        for metric in FLOAT_METRICS:
            got, want = modes[mode][metric], committed[metric]
            ok = math.isclose(got, want, rel_tol=REL_TOL)
            print(f"{mode} {metric}: {got:.3f} vs committed {want:.3f} "
                  f"[{'ok' if ok else 'DRIFT'}]")
            if not ok:
                failures += 1
        for metric in INT_METRICS:
            got, want = modes[mode][metric], committed[metric]
            ok = got == want
            print(f"{mode} {metric}: {got} vs committed {want} "
                  f"[{'ok' if ok else 'DRIFT'}]")
            if not ok:
                failures += 1

    if failures:
        print(f"FAIL: {failures} elastic gate(s) tripped")
        return 1
    print("two-level elastic co-location within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
