"""Paper Fig 8 — GPU allocation distribution before/after topology-aware
scheduling: count of instances whose GPUs span sockets beyond the minimum
their size requires."""
from __future__ import annotations

from repro.core.simulator import SimConfig, run_allocation_snapshot

from .common import FULL, emit


def run(full: bool = FULL) -> list[dict]:
    n = 41 if not full else 100     # paper's near-production cluster: 41 nodes
    rows = []
    for engine in ("godel", "imp"):
        snap = run_allocation_snapshot(SimConfig(num_nodes=n, seed=8), engine,
                                       churn=30)
        rows.append(snap)
        emit(f"fig8_cross_socket_{engine}", 0.0,
             f"before={snap['cross_socket_before']} "
             f"after={snap['cross_socket_after']} "
             f"preemptions={snap['preemptions']}")
    godel, imp = rows
    emit("fig8_improvement", 0.0,
         f"flextopo_after={imp['cross_socket_after']} <= "
         f"godel_after={godel['cross_socket_after']}")
    return rows


if __name__ == "__main__":
    run()
