"""Scale sweep — plan latency from 24 to 10k nodes, sharded vs single-device.

The tentpole claim of the mesh-sharded cluster state: end-to-end ``plan()``
P50 must grow SUB-linearly in node count (per-node cost falls as the cluster
grows — fixed dispatch overhead amortizes and the node axis shards across
the device mesh), and the ``imp_sharded`` engine must stay bit-identical to
``imp_batched`` at every size.

Protocol
--------
The parent process re-invokes this module as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
engine gets a real 8-device mesh even on a single-CPU host (the flag must
be set before jax initializes, hence the subprocess).  The child, per size
in ``SIZES``:

* builds one saturated cluster per engine (sizes above the 128-node base
  are TILED — the base's instance pattern replayed per 128-node block —
  because random saturation does an O(instances x nodes) feasibility scan
  that is prohibitive at 10k nodes, and bind-replay is O(instances));
* runs a deterministic decision sequence (preemptive plans, commits, one
  ``plan_batch``) on BOTH engines and compares decision keys — the
  ``parity`` flag per size — and the same sequence on the ``*_full``
  oracle twins (shortlist front-end off) — the per-engine
  ``shortlist_parity`` flags;
* times ``plan_e2e`` for the full-sweep oracles first (hot jit buckets
  for any guaranteed-mode fallback), then ``plan_e2e`` (alternating B/C
  preemptors, pure reads), ``plan_batch8`` (persistent session,
  per-request, TWO untimed warm rounds), and ``plan_normal_e2e``
  (60%-filled cluster, normal-cycle admission) for the production
  engines, tagging any sample that still compiles (`CompileWatch`).

The production engines run with `TopoScheduler`'s default shortlist
front-end (top-K=128 representatives, guaranteed mode), so sizes above K
measure the two-stage path and the ``*_full`` rows are the all-nodes
sweep reference the CI speedup gate compares against.

The parent merges the result as the ``scale`` block of
``BENCH_sourcing.json``; ``benchmarks.check_sourcing_regression`` gates the
committed block (sub-linear growth, parity at every size, shortlist
parity + speedup vs the full sweep, no compiled timed samples) plus a
live small-size parity re-check.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import FULL, emit, p

try:  # parent-only import cycle guard: the child imports this module too
    from .bench_sourcing_latency import BENCH_JSON
except ImportError:  # pragma: no cover - running as a script
    import pathlib

    BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sourcing.json"

SIZES = (24, 128, 1024, 10240)
DEVICES = 8
BASE_NODES = 128          # tiling block for sizes above it
ENGINES = ("imp_batched", "imp_sharded")
#: full-sweep oracle twin per production engine (shortlist front-end off)
FULL_ENGINES = {"imp_batched": "imp_batched_full",
                "imp_sharded": "imp_sharded_full"}

#: per-size sample counts: (plan_e2e samples, batch rounds, normal samples)
_SAMPLES_FULL = {24: (20, 10, 20), 128: (20, 10, 20),
                 1024: (12, 6, 12), 10240: (6, 3, 6)}
_SAMPLES_SMALL = {24: (10, 6, 10), 128: (10, 6, 10),
                  1024: (6, 4, 6), 10240: (4, 2, 4)}

_CHILD_FLAG = "--child"
_MARK = "SCALE_RESULT_JSON:"


# ---------------------------------------------------------------------------
# child: runs under the forced 8-device host platform
# ---------------------------------------------------------------------------

def _decision_key(dec):
    return (str(dec.kind), dec.node, tuple(dec.victims),
            None if dec.placement is None else dec.placement.tier, dec.hit)


def build_scaled_cluster(num_nodes: int, seed: int = 0, fill: float = 1.0):
    """A saturated (or ``fill``-fraction) cluster at any node count.

    Up to `BASE_NODES` the regular seeded random saturation runs directly;
    larger sizes replay a BASE_NODES-sized base pattern per block so
    construction stays O(num_nodes) instead of O(num_nodes^2).
    """
    from repro.core.cluster import Cluster
    from repro.core.placement import Placement
    from repro.core.simulator import SimConfig, build_saturated_cluster
    from repro.core.workload import TABLE3_INITIAL_INSTANCES, table3_workloads

    base_nodes = min(num_nodes, BASE_NODES)
    cfg = SimConfig(num_nodes=base_nodes, seed=seed)
    if fill >= 1.0:
        base = build_saturated_cluster(cfg)
    else:
        workloads = table3_workloads()
        scale = base_nodes / 100.0 * fill
        counts = {k: max(0, round(v * scale))
                  for k, v in TABLE3_INITIAL_INSTANCES.items()}
        base = build_saturated_cluster(cfg, workloads, counts)
    if num_nodes == base_nodes:
        return base
    big = Cluster(base.spec, num_nodes)
    for blk in range(num_nodes // base_nodes):
        off = blk * base_nodes
        for inst in base.instances.values():
            big.bind(inst.workload, inst.node + off,
                     Placement(gpu_mask=inst.gpu_mask,
                               cg_mask=inst.cg_mask, tier=0))
    return big


def _parity_sequence(sched, wl, batch: int):
    """Deterministic mixed plan/commit/batch sequence; returns decision keys.

    Commits mutate the cluster, so the same sequence on two engines'
    clusters exercises the delta-encoder path between plans.
    """
    keys = []
    for name in ("B", "C", "B"):
        txn = sched.plan(wl[name], allow_normal=True)
        keys.append(_decision_key(txn.decision))
        if txn.decision.kind != "reject":
            txn.commit()
    txns = sched.plan_batch([wl["B"]] * batch)
    for i, t in enumerate(txns):
        keys.append(_decision_key(t.decision))
        if i == 0 and t.decision.kind != "reject":
            t.commit()
    return keys


def _child_main() -> None:
    import time

    from repro.core import TopoScheduler, table3_workloads
    from repro.core.simulator import CompileWatch

    protocol = os.environ.get("SCALE_PROTOCOL", "small")
    per_size = _SAMPLES_FULL if protocol == "full" else _SAMPLES_SMALL
    wl = {w.name: w for w in table3_workloads()}
    watch = CompileWatch.get()
    rows: list[dict] = []
    parity: dict[str, bool] = {}
    shortlist_parity: dict[str, bool] = {}
    shortlist_meta: dict = {}

    import jax
    assert len(jax.devices()) == DEVICES, jax.devices()

    for n in SIZES:
        samples, rounds, n_samples = per_size[n]
        keys: dict[str, list] = {}
        scheds: dict[str, TopoScheduler] = {}
        batch = 8 if n <= 1024 else 4
        for engine in ENGINES:
            cluster = build_scaled_cluster(n, seed=0)
            sched = TopoScheduler(cluster, engine=engine, alpha=0.5)
            keys[engine] = _parity_sequence(sched, wl, batch)
            scheds[engine] = sched
        parity[str(n)] = keys[ENGINES[0]] == keys[ENGINES[1]]
        sl = scheds[ENGINES[0]].shortlist
        shortlist_meta = {"k": sl.k if sl else 0,
                          "mode": sl.mode if sl else None}

        # full-sweep oracles: same deterministic sequence on fresh clusters
        # must be decision-identical to the shortlisted production engines
        for engine, full in FULL_ENGINES.items():
            cluster = build_scaled_cluster(n, seed=0)
            fsched = TopoScheduler(cluster, engine=full, alpha=0.5)
            shortlist_parity[f"{n}:{engine}"] = (
                keys[engine] == _parity_sequence(fsched, wl, batch))
            scheds[full] = fsched

        # time the oracles FIRST: their jit buckets then sit hot, so a
        # guaranteed-mode certainty fallback inside the production timing
        # loops below re-uses the compiled sweep instead of compiling
        # mid-sample (which the CI gate now refuses)
        for engine in ENGINES:
            fsched = scheds[FULL_ENGINES[engine]]
            for _ in range(2):      # untimed double warm
                fsched.plan(wl["B"])
                fsched.plan(wl["C"])
            times, compiled = [], 0
            for i in range(samples):
                m = watch.mark()
                t0 = time.perf_counter()
                fsched.plan(wl["B"] if i % 2 == 0 else wl["C"])
                times.append((time.perf_counter() - t0) * 1e6)
                compiled += watch.delta(m) > 0
            rows.append({"nodes": n, "engine": FULL_ENGINES[engine],
                         "metric": "plan_e2e",
                         "p50_us": p(times, 50), "p90_us": p(times, 90),
                         "n": samples, "compiled_n": compiled})

        for engine in ENGINES:
            sched = scheds[engine]
            # warm both preemptor programs at this size's buckets (twice:
            # the second round proves steady state before timing starts)
            for _ in range(2):
                sched.plan(wl["B"])
                sched.plan(wl["C"])
            times, compiled = [], 0
            for i in range(samples):
                m = watch.mark()
                t0 = time.perf_counter()
                sched.plan(wl["B"] if i % 2 == 0 else wl["C"])
                times.append((time.perf_counter() - t0) * 1e6)
                compiled += watch.delta(m) > 0
            rows.append({"nodes": n, "engine": engine, "metric": "plan_e2e",
                         "p50_us": p(times, 50), "p90_us": p(times, 90),
                         "n": samples, "compiled_n": compiled})

            sched.plan_batch([wl["B"]] * 8)      # warm rounds (excluded):
            sched.plan_batch([wl["B"]] * 8)      # two, so the second proves
            times, compiled = [], 0              # the session is steady
            for _ in range(rounds):
                m = watch.mark()
                t0 = time.perf_counter()
                sched.plan_batch([wl["B"]] * 8)
                times.append((time.perf_counter() - t0) * 1e6 / 8)
                compiled += watch.delta(m) > 0
            rows.append({"nodes": n, "engine": engine,
                         "metric": "plan_batch8",
                         "p50_us": p(times, 50), "p90_us": p(times, 90),
                         "n": rounds, "compiled_n": compiled})

            cluster = build_scaled_cluster(n, seed=1, fill=0.6)
            sched = TopoScheduler(cluster, engine=engine, alpha=0.5)
            dec = sched.plan(wl["B"]).decision   # warm x2, excluded
            assert dec.placed, f"60% fill not placeable at n={n}"
            sched.plan(wl["B"])
            times, compiled = [], 0
            for _ in range(n_samples):
                m = watch.mark()
                t0 = time.perf_counter()
                sched.plan(wl["B"])
                times.append((time.perf_counter() - t0) * 1e6)
                compiled += watch.delta(m) > 0
            rows.append({"nodes": n, "engine": engine,
                         "metric": "plan_normal_e2e",
                         "p50_us": p(times, 50), "p90_us": p(times, 90),
                         "n": n_samples, "compiled_n": compiled})
        print(f"# scale n={n} done (parity={parity[str(n)]})",
              file=sys.stderr, flush=True)

    print(_MARK + json.dumps(
        {"protocol": protocol, "devices": DEVICES, "sizes": list(SIZES),
         "base_nodes": BASE_NODES, "rows": rows, "parity": parity,
         "shortlist": shortlist_meta, "shortlist_parity": shortlist_parity}))


# ---------------------------------------------------------------------------
# parent: spawn the 8-device child, merge + emit
# ---------------------------------------------------------------------------

def run(full: bool = FULL) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={DEVICES}").strip()
    env["SCALE_PROTOCOL"] = "full" if full else "small"
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    repo_root = BENCH_JSON.parent
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_scale_sourcing", _CHILD_FLAG],
        cwd=repo_root, env=env, capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scale child failed ({proc.returncode}):\n{proc.stderr[-4000:]}")
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            payload = json.loads(line[len(_MARK):])
    if payload is None:
        raise RuntimeError(f"no scale result in child output:\n"
                           f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    for row in payload["rows"]:
        emit(f"scale_{row['nodes']}_{row['engine']}_{row['metric']}",
             row["p50_us"],
             f"p90={row['p90_us']:.1f}us compiled_n={row['compiled_n']}")
    for size, ok in payload["parity"].items():
        emit(f"scale_{size}_sharded_parity", 0.0,
             "identical" if ok else "DIVERGED")
    for key, ok in payload.get("shortlist_parity", {}).items():
        emit(f"scale_{key.replace(':', '_')}_shortlist_parity", 0.0,
             "identical" if ok else "DIVERGED")
    doc = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else {}
    doc["scale"] = payload
    BENCH_JSON.write_text(json.dumps(doc, indent=2) + "\n")
    return payload


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        _child_main()
    else:
        run()
