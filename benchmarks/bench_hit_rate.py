"""Paper Table 4 — topology affinity hit rate over cycles × scale-ups.

Paper: Gödel standard 44.5%, Gödel+FlexTopo 100% (=> "55% improvement").
Full protocol (BENCH_FULL=1): 100 cycles × 50 scale-ups on 100 nodes.
Default: 20 × 25 on 50 nodes (same statistics, CPU-friendly).
"""
from __future__ import annotations

from repro.core.simulator import SimConfig, run_hit_rate_experiment

from .common import FULL, emit, p


def run(full: bool = FULL) -> list[dict]:
    if full:
        cfg = SimConfig(num_nodes=100, seed=0)
        cycles, ups = 100, 50
    else:
        cfg = SimConfig(num_nodes=50, seed=0)
        cycles, ups = 20, 25
    rows = []
    for engine in ("godel", "imp"):
        rep = run_hit_rate_experiment(cfg, engine, cycles=cycles,
                                      scaleups_per_cycle=ups)
        rows.append({
            "engine": engine, "preemptions": rep.preemptions,
            "hits": rep.hits, "hit_rate": rep.hit_rate,
            "failures": rep.failures,
            "p50_us": p(rep.sourcing_us, 50), "p90_us": p(rep.sourcing_us, 90),
        })
        emit(f"table4_hit_rate_{engine}", p(rep.sourcing_us, 50),
             f"hit_rate={rep.hit_rate:.3f} n={rep.preemptions}")
    godel, imp = rows
    emit("table4_improvement", 0.0,
         f"delta_hit_rate={imp['hit_rate'] - godel['hit_rate']:.3f} "
         f"(paper: 0.555)")
    return rows


if __name__ == "__main__":
    run()
