"""Paper Fig 9 — instance counts during preemption (2 auto-scaling events)."""
from __future__ import annotations

from repro.core.simulator import SimConfig, run_timeline

from .common import FULL, emit


def run(full: bool = FULL) -> list[dict]:
    cfg = SimConfig(num_nodes=100 if full else 50, seed=4)
    scale = cfg.num_nodes / 100.0
    events = [("B", max(2, round(10 * scale))), ("A", max(1, round(5 * scale)))]
    tl = run_timeline(cfg, engine="imp", events=events)
    first, last = tl[0], tl[-1]
    for name in ("A", "B", "C", "D"):
        emit(f"fig9_{name}", 0.0,
             f"start={first.get(name, 0)} end={last.get(name, 0)}")
    emit("fig9_offline_shrinks", 0.0,
         f"{last.get('C', 0) + last.get('D', 0)} < "
         f"{first.get('C', 0) + first.get('D', 0)}")
    return tl


if __name__ == "__main__":
    run()
