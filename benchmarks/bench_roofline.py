"""§Roofline — assemble the full (arch × shape) baseline table from the
dry-run artifacts and emit the markdown table EXPERIMENTS.md embeds."""
from __future__ import annotations

import json
import os

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable

from . import roofline as rl
from .common import emit

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def run(full: bool = False) -> list[dict]:
    rows = []
    skipped = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                skipped.append((arch, shape_name, why))
                continue
            row = rl.roofline_row(arch, shape_name)
            if row is None:
                emit(f"roofline_{arch}_{shape_name}", 0.0, "MISSING dry-run")
                continue
            rows.append(row.as_dict())
            emit(f"roofline_{arch}_{shape_name}",
                 max(row.compute_s, row.memory_s, row.collective_s) * 1e6,
                 f"dominant={row.dominant} useful={row.useful_ratio:.2f}")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "roofline.json"), "w") as f:
        json.dump({"rows": rows, "skipped": skipped}, f, indent=1)
    with open(os.path.join(OUT_DIR, "roofline.md"), "w") as f:
        f.write(markdown_table(rows, skipped))
    return rows


def markdown_table(rows: list[dict], skipped) -> str:
    lines = [
        "| arch | shape | kind | compute (s) | memory (s) | collective (s) "
        "| dominant | MODEL_FLOPS | HLO_FLOPs (global) | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['hlo_flops_global']:.2e} "
            f"| {r['useful_ratio']:.2f} |")
    if skipped:
        lines.append("")
        lines.append("Skipped cells (documented in DESIGN.md §5):")
        for arch, shape, why in skipped:
            lines.append(f"- `{arch} × {shape}` — {why}")
    return "\n".join(lines) + "\n"


if __name__ == "__main__":
    run()
