"""Paper Fig 10 — candidate-sourcing overhead across workload classes.

Five preemptions per workload type from Table 3.  The paper's observation:
B (4-GPU) is the most expensive (many combinations), C (2-GPU) cheap,
A (8-GPU) cheaper than B (fast failures on small subsets), D near-zero
(nothing below it to preempt).
"""
from __future__ import annotations

import time

from repro.core.scheduler import TopoScheduler
from repro.core.simulator import SimConfig, build_saturated_cluster
from repro.core.workload import table3_workloads

from .common import FULL, emit


def run(full: bool = FULL) -> list[dict]:
    cfg = SimConfig(num_nodes=100 if full else 50, seed=2)
    wls = {w.name: w for w in table3_workloads()}
    rows = []
    for name in ("A", "B", "C", "D"):
        cluster = build_saturated_cluster(cfg)
        sched = TopoScheduler(cluster, engine="imp")
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            sched.plan(wls[name], allow_normal=False)   # rollback-free read
            dt = (time.perf_counter() - t0) * 1e6
            times.append(dt)
        mean = sum(times) / len(times)
        rows.append({"workload": name, "mean_us": mean, "times_us": times})
        emit(f"fig10_sourcing_{name}", mean,
             f"five_runs={[round(t) for t in times]}")
    # the paper's ordering claim
    byname = {r["workload"]: r["mean_us"] for r in rows}
    emit("fig10_ordering", 0.0,
         f"B>C={byname['B'] > byname['C']} B>A={byname['B'] > byname['A']} "
         f"D_min={byname['D'] == min(byname.values())}")
    return rows


if __name__ == "__main__":
    run()
