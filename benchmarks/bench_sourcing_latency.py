"""Paper Table 5 / Fig 11 — candidate-sourcing latency P50/P90 by method.

Paper methods: Gödel standard | FlexTopo (exhaustive) | FlexTopo-IMP.
Beyond-paper engines: imp_batched_legacy (vectorized cluster-wide sweep, one
jit dispatch per subset size), imp_batched (the FUSED single-dispatch path:
all sizes + on-device Eq. 2 argmax over incrementally-cached arrays) and
imp_pallas (TPU kernel, included when importable — interpret mode is NOT
wall-clock-representative on CPU, reported for completeness).

Workload classes match the paper: high-p-1000-4-card (B), low-p-500-2-card (C).

Results are also written to ``BENCH_sourcing.json`` at the repo root so the
perf trajectory is tracked across PRs; CI's regression smoke step
(``benchmarks.check_sourcing_regression``) compares a fresh small-protocol
run of the fused engine against the committed numbers.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.simulator import SimConfig, run_latency_experiment

from .common import FULL, emit, p

ENGINES = ("godel", "exhaustive", "imp", "imp_batched_legacy", "imp_batched")

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sourcing.json"


def _optional_engines() -> tuple[str, ...]:
    """Engines that need optional deps (Pallas): include iff importable."""
    try:
        from repro.core.engines import get_engine

        get_engine("imp_pallas")
        return ("imp_pallas",)
    except Exception:
        return ()


def run(full: bool = FULL) -> list[dict]:
    cfg = SimConfig(num_nodes=100 if full else 50, seed=0)
    samples = 50 if full else 20
    rows = []
    for wl, label in (("B", "high-p-1000-4-card"), ("C", "low-p-500-2-card")):
        base = {}
        for engine in ENGINES + _optional_engines():
            # interpret-mode Pallas is orders slower on CPU; keep its sample
            # count small so the smoke protocol stays quick
            n_samples = samples if engine != "imp_pallas" else min(samples, 5)
            rep = run_latency_experiment(cfg, engine, wl, samples=n_samples)
            p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
            base[engine] = (p50, p90)
            rows.append({"workload": label, "engine": engine, "p50_us": p50,
                         "p90_us": p90, "n": rep.preemptions,
                         "hit_rate": rep.hit_rate})
            emit(f"table5_{label}_{engine}", p50, f"p90={p90:.1f}us "
                 f"hit={rep.hit_rate:.2f}")
        if "exhaustive" in base and "imp" in base and base["exhaustive"][0]:
            opt50 = 1 - base["imp"][0] / base["exhaustive"][0]
            opt90 = 1 - base["imp"][1] / base["exhaustive"][1]
            emit(f"table5_{label}_imp_opt", 0.0,
                 f"p50_saving={opt50:.1%} p90_saving={opt90:.1%} "
                 f"(paper: 7.3-76.5%)")
        if base.get("imp_batched_legacy", (0,))[0]:
            speedup = base["imp_batched_legacy"][0] / max(
                base["imp_batched"][0], 1e-9)
            emit(f"table5_{label}_fused_speedup", 0.0,
                 f"fused_p50_over_legacy={speedup:.2f}x")
    BENCH_JSON.write_text(json.dumps(
        {"protocol": "full" if full else "small",
         "num_nodes": cfg.num_nodes, "seed": cfg.seed, "samples": samples,
         "rows": rows}, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    run()
