"""Paper Table 5 / Fig 11 — candidate-sourcing latency P50/P90 by method.

Paper methods: Gödel standard | FlexTopo (exhaustive) | FlexTopo-IMP.
Beyond-paper engines: imp_batched (vectorized cluster-wide sweep) and
imp_pallas (TPU kernel in interpret mode — NOT wall-clock-representative on
CPU, reported for completeness).

Workload classes match the paper: high-p-1000-4-card (B), low-p-500-2-card (C).
"""
from __future__ import annotations

from repro.core.simulator import SimConfig, run_latency_experiment

from .common import FULL, emit, p

ENGINES = ("godel", "exhaustive", "imp", "imp_batched")


def run(full: bool = FULL) -> list[dict]:
    cfg = SimConfig(num_nodes=100 if full else 50, seed=0)
    samples = 50 if full else 20
    rows = []
    for wl, label in (("B", "high-p-1000-4-card"), ("C", "low-p-500-2-card")):
        base = {}
        for engine in ENGINES:
            rep = run_latency_experiment(cfg, engine, wl, samples=samples)
            p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
            base[engine] = (p50, p90)
            rows.append({"workload": label, "engine": engine, "p50_us": p50,
                         "p90_us": p90, "n": rep.preemptions,
                         "hit_rate": rep.hit_rate})
            emit(f"table5_{label}_{engine}", p50, f"p90={p90:.1f}us "
                 f"hit={rep.hit_rate:.2f}")
        if "exhaustive" in base and "imp" in base and base["exhaustive"][0]:
            opt50 = 1 - base["imp"][0] / base["exhaustive"][0]
            opt90 = 1 - base["imp"][1] / base["exhaustive"][1]
            emit(f"table5_{label}_imp_opt", 0.0,
                 f"p50_saving={opt50:.1%} p90_saving={opt90:.1%} "
                 f"(paper: 7.3-76.5%)")
    return rows


if __name__ == "__main__":
    run()
