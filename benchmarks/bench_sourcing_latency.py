"""Paper Table 5 / Fig 11 — candidate-sourcing latency P50/P90 by method.

Paper methods: Gödel standard | FlexTopo (exhaustive) | FlexTopo-IMP.
Beyond-paper engines: imp_batched_legacy (vectorized cluster-wide sweep, one
jit dispatch per subset size), imp_batched (the FUSED path: Guaranteed
Filtering + all subset sizes + the Eq. 2 argmax in ONE dispatch over the
device-resident cluster state) and imp_pallas (TPU kernel, included when
importable — interpret mode is NOT wall-clock-representative on CPU; its
rows are tagged ``"interpret": true`` and the CI gate skips them).

Workload classes match the paper: high-p-1000-4-card (B), low-p-500-2-card (C).

Beyond the per-engine sourcing phase, four fused-path rows are recorded per
workload (``metric`` field):

* ``sourcing``        — the engine's sourcing phase (default, paper Table 5);
* ``plan_e2e``        — filtering-INCLUSIVE end-to-end ``plan()`` wall time;
* ``plan_normal_e2e`` — end-to-end ``plan()`` on a 60%-filled cluster where
  the NORMAL cycle places the request (the diurnal-valley admission path;
  one chained dispatch for the fused engine, recorded for ``imp`` too as
  the host-loop reference);
* ``plan_batch8``     — amortized per-request wall time of an 8-request
  ``plan_batch`` (one vmapped dispatch against one snapshot, with the
  PERSISTENT session reused across rounds).

A ``warmup`` block tracks cold vs ``TopoScheduler(warmup=True)`` first-plan
latency (cold P90 is compile-dominated; the warm numbers show construction
-time pre-compilation removing it).

Timed runs of the jit engines are preceded by an identical untimed pass:
the experiment runners are seeded-deterministic, so the warm pass compiles
every (patch-bucket, gather-bucket) jit variant the timed pass will hit —
without it the P90s measured XLA compiles (seconds) instead of dispatches
(microseconds).  Any timed sample that STILL triggers a compile is counted
in the row's ``compiled_n`` field (via `repro.core.simulator.CompileWatch`)
so a polluted distribution is visible in the committed baseline rather
than silently folded into P90.  Results go to ``BENCH_sourcing.json``
at the repo root so the perf trajectory is tracked across PRs; CI's
regression step (``benchmarks.check_sourcing_regression``) compares a fresh
small-protocol run of the fused engine against the committed numbers.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.core.simulator import (SimConfig, build_saturated_cluster,
                                  run_latency_experiment,
                                  run_plan_batch_latency,
                                  run_plan_latency_experiment,
                                  run_plan_normal_latency)

from .common import FULL, emit, p

ENGINES = ("godel", "exhaustive", "imp", "imp_batched_legacy", "imp_batched")

#: engines whose dispatches are jit-compiled: their timed experiments get an
#: identical untimed pass first so every jit bucket is warm (host engines
#: have no compile caches to warm — a second pass would just double runtime)
JIT_ENGINES = ("imp_batched_legacy", "imp_batched", "imp_sharded", "imp_jax")


def _warmed(runner, cfg, engine, *args, **kwargs):
    """Run ``runner`` twice, discarding the first pass, for jit engines.

    The runners rebuild their clusters from ``cfg.seed`` deterministically,
    so the warm pass hits exactly the (patch-bucket, gather-bucket) variants
    the timed pass will — its report is thrown away and only the warm-cache
    rerun is returned.
    """
    if engine in JIT_ENGINES:
        runner(cfg, engine, *args, **kwargs)
    return runner(cfg, engine, *args, **kwargs)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sourcing.json"


def _optional_engines() -> tuple[str, ...]:
    """Engines that need optional deps (Pallas): include iff importable."""
    try:
        from repro.core.engines import get_engine

        get_engine("imp_pallas")
        return ("imp_pallas",)
    except Exception:
        return ()


def _interpret_mode() -> bool:
    try:
        from repro.kernels.topo_score import _interpret_default

        return bool(_interpret_default())
    except Exception:
        return True


def _measure_warmup(cfg: SimConfig, warm_samples: int = 5) -> dict:
    """Cold vs warmed-up first-plan latency for the fused engine.

    Must run BEFORE anything else touches ``imp_batched`` at this protocol's
    shapes so the first dispatch genuinely pays compile time.  Warm
    schedulers pre-compile at construction (``warmup=True``); their first
    plans then hit the in-process jit caches — which is exactly what the
    warm-up buys every later scheduler of the same shapes.
    """
    from repro.core import TopoScheduler, table3_workloads

    wl = {w.name: w for w in table3_workloads()}["B"]

    def first_plan_us(warmup: bool, seed: int) -> float:
        cluster = build_saturated_cluster(
            SimConfig(num_nodes=cfg.num_nodes, seed=seed))
        sched = TopoScheduler(cluster, engine="imp_batched", warmup=warmup)
        t0 = time.perf_counter()
        sched.plan(wl)
        return (time.perf_counter() - t0) * 1e6

    cold = first_plan_us(False, cfg.seed)
    warm = [first_plan_us(True, cfg.seed + 1 + i)
            for i in range(warm_samples)]
    return {
        "cold_first_plan_us": cold,
        "warm_first_plan_us_p50": p(warm, 50),
        "warm_first_plan_us_p90": p(warm, 90),
        "n_warm": warm_samples,
    }


def run(full: bool = FULL) -> list[dict]:
    cfg = SimConfig(num_nodes=100 if full else 50, seed=0)
    samples = 50 if full else 20
    # cold-vs-warm FIRST: afterwards the process jit caches are hot
    warmup = _measure_warmup(cfg)
    emit("table5_warmup_cold_first_plan", warmup["cold_first_plan_us"],
         f"warm_p90={warmup['warm_first_plan_us_p90']:.0f}us "
         f"n={warmup['n_warm']}")
    rows = []
    for wl, label in (("B", "high-p-1000-4-card"), ("C", "low-p-500-2-card")):
        base = {}
        for engine in ENGINES + _optional_engines():
            # interpret-mode Pallas is orders slower on CPU; keep its sample
            # count small so the smoke protocol stays quick
            n_samples = samples if engine != "imp_pallas" else min(samples, 5)
            rep = _warmed(run_latency_experiment, cfg, engine, wl,
                          samples=n_samples)
            p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
            base[engine] = (p50, p90)
            row = {"workload": label, "engine": engine, "metric": "sourcing",
                   "p50_us": p50, "p90_us": p90, "n": rep.preemptions,
                   "hit_rate": rep.hit_rate,
                   "compiled_n": rep.compiled_samples}
            if engine == "imp_pallas":
                row["interpret"] = _interpret_mode()
            rows.append(row)
            emit(f"table5_{label}_{engine}", p50, f"p90={p90:.1f}us "
                 f"hit={rep.hit_rate:.2f}")
        if "exhaustive" in base and "imp" in base and base["exhaustive"][0]:
            opt50 = 1 - base["imp"][0] / base["exhaustive"][0]
            opt90 = 1 - base["imp"][1] / base["exhaustive"][1]
            emit(f"table5_{label}_imp_opt", 0.0,
                 f"p50_saving={opt50:.1%} p90_saving={opt90:.1%} "
                 f"(paper: 7.3-76.5%)")
        if base.get("imp_batched_legacy", (0,))[0]:
            speedup = base["imp_batched_legacy"][0] / max(
                base["imp_batched"][0], 1e-9)
            emit(f"table5_{label}_fused_speedup", 0.0,
                 f"fused_p50_over_legacy={speedup:.2f}x")
        # filtering-inclusive end-to-end plan() + batched planning (fused)
        rep = _warmed(run_plan_latency_experiment, cfg, "imp_batched", wl,
                      samples=samples)
        p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
        rows.append({"workload": label, "engine": "imp_batched",
                     "metric": "plan_e2e", "p50_us": p50, "p90_us": p90,
                     "n": rep.preemptions, "hit_rate": rep.hit_rate,
                     "compiled_n": rep.compiled_samples})
        emit(f"table5_{label}_fused_plan_e2e", p50, f"p90={p90:.1f}us "
             f"hit={rep.hit_rate:.2f}")
        rep = _warmed(run_plan_batch_latency, cfg, "imp_batched", wl, batch=8,
                      rounds=5 if not full else 10)
        p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
        rows.append({"workload": label, "engine": "imp_batched",
                     "metric": "plan_batch8", "p50_us": p50, "p90_us": p90,
                     "n": rep.preemptions, "hit_rate": rep.hit_rate,
                     "compiled_n": rep.compiled_samples})
        emit(f"table5_{label}_fused_plan_batch8", p50,
             f"per_request p90={p90:.1f}us")
        # normal-cycle admission: fused chained dispatch vs the host loop
        normal_base = {}
        for engine in ("imp", "imp_batched"):
            rep = _warmed(run_plan_normal_latency, cfg, engine, wl,
                          samples=samples)
            p50, p90 = p(rep.sourcing_us, 50), p(rep.sourcing_us, 90)
            normal_base[engine] = p50
            rows.append({"workload": label, "engine": engine,
                         "metric": "plan_normal_e2e", "p50_us": p50,
                         "p90_us": p90, "n": len(rep.sourcing_us),
                         # placed-decision topology-hit rate (preemptions
                         # are 0 on this protocol, so the report property
                         # would read 0)
                         "hit_rate": rep.hits / max(1, len(rep.sourcing_us)),
                         "compiled_n": rep.compiled_samples})
            emit(f"table5_{label}_{engine}_plan_normal_e2e", p50,
                 f"p90={p90:.1f}us")
        if normal_base.get("imp_batched"):
            emit(f"table5_{label}_normal_fused_speedup", 0.0,
                 f"fused_over_host={normal_base['imp'] / normal_base['imp_batched']:.2f}x")
    payload = {"protocol": "full" if full else "small",
               "num_nodes": cfg.num_nodes, "seed": cfg.seed,
               "samples": samples, "warmup": warmup, "rows": rows}
    if BENCH_JSON.exists():
        try:    # keep the scale-sweep block (written by bench_scale_sourcing)
            old = json.loads(BENCH_JSON.read_text())
            if "scale" in old:
                payload["scale"] = old["scale"]
        except Exception:
            pass
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    run()
