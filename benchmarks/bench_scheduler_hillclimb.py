"""§Perf hillclimb (paper-representative cell): candidate-sourcing latency.

The paper's own bottleneck metric (Table 5).  Wall-clock measured on this
host, 100-node saturated cluster, preemptor B (high-p-1000-4-card),
independent preemptions.  Iterations:

  it0  paper-faithful python IMP, naive O(instances) cluster scans
  it1  + per-node instance index & free-mask cache (host-side data structure)
  it2  per-node vectorized subset evaluation (imp_jax)  [hypothesis: slower —
       per-node dispatch overhead dominates at m<=8]
  it3  cluster-batched sweep: ONE vmapped evaluation per subset size over all
       candidate nodes (imp_batched_legacy)
  it4  plan_batch: 8 pending preemptors planned against one snapshot through
       the batched engine (per-request amortized latency)
  it5  fused single dispatch: all subset sizes + on-device Eq. 2 argmax in
       one jit call over incrementally-cached victim rows (imp_batched)

Independent samples are rollback-free: each is a pure ``plan()`` read
against the saturated state — no mutate-then-undo.  Each iteration records
P50/P90 sourcing latency + end-to-end plan() latency.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.scheduler import TopoScheduler
from repro.core.simulator import SimConfig
from repro.core.workload import table3_workloads

from .common import FULL, emit


def _saturated(nodes: int, node_index: bool = True, seed: int = 11):
    """The shared measurement fixture: one saturated Table 3 cluster."""
    import random

    import repro.core.simulator as sim
    from repro.core.cluster import Cluster

    cfg = SimConfig(num_nodes=nodes, seed=seed)
    cluster = Cluster(cfg.spec, cfg.num_nodes, node_index=node_index)
    sim.saturate(cluster, table3_workloads(),
                 {k: round(v * nodes / 100) for k, v in
                  sim.TABLE3_INITIAL_INSTANCES.items()},
                 random.Random(cfg.seed))
    return cluster


def _measure(engine: str, node_index: bool, nodes: int = 100,
             samples: int = 30, preemptor: str = "B") -> dict:
    wls = {w.name: w for w in table3_workloads()}
    cluster = _saturated(nodes, node_index=node_index)
    sched = TopoScheduler(cluster, engine=engine)
    sourcing, total = [], []
    # warm up jit caches so compile time isn't counted as scheduling latency
    sched.plan(wls[preemptor])
    sched.sourcing_us_log.clear()
    for _ in range(samples):
        t0 = time.perf_counter()
        dec = sched.plan(wls[preemptor]).decision   # rollback-free read
        total.append((time.perf_counter() - t0) * 1e6)
        if dec.rejected:
            break
        if dec.preempted:
            sourcing.append(dec.sourcing_us)
    return {
        "engine": engine, "node_index": node_index,
        "sourcing_p50": float(np.percentile(sourcing, 50)) if sourcing else 0,
        "sourcing_p90": float(np.percentile(sourcing, 90)) if sourcing else 0,
        "total_p50": float(np.percentile(total, 50)),
        "total_p90": float(np.percentile(total, 90)),
        "n": len(sourcing),
    }


def _measure_plan_batch(engine: str, nodes: int = 100, batch: int = 8,
                        rounds: int = 4, preemptor: str = "B") -> dict:
    """it4: amortized per-request planning latency of one batched plan.

    Reports END-TO-END plan time per request (total_*); the sourcing_*
    fields stay zero because a batched plan interleaves filtering,
    sourcing, and selection per request — a per-phase split would not be
    comparable with it0-it3's sourcing numbers.
    """
    wls = {w.name: w for w in table3_workloads()}
    cluster = _saturated(nodes)
    sched = TopoScheduler(cluster, engine=engine)
    sched.plan_batch([wls[preemptor]] * batch)      # jit warm-up
    per_req = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        txns = sched.plan_batch([wls[preemptor]] * batch)
        per_req.append((time.perf_counter() - t0) * 1e6 / batch)
        assert all(t.decision for t in txns)
    return {
        "engine": engine, "node_index": True,
        "sourcing_p50": 0.0,
        "sourcing_p90": 0.0,
        "total_p50": float(np.percentile(per_req, 50)),
        "total_p90": float(np.percentile(per_req, 90)),
        "n": len(per_req) * batch,
    }


ITERATIONS = [
    ("it0_python_imp_naive", "imp", False),
    ("it1_python_imp_indexed", "imp", True),
    ("it2_pernode_vectorized", "imp_jax", True),
    ("it3_cluster_batched", "imp_batched_legacy", True),
    ("it5_fused_single_dispatch", "imp_batched", True),
]


def run(full: bool = FULL) -> list[dict]:
    nodes = 100
    samples = 50 if full else 25
    rows = []
    for name, engine, idx in ITERATIONS:
        r = _measure(engine, idx, nodes=nodes, samples=samples)
        r["iteration"] = name
        rows.append(r)
        emit(f"perf_sched_{name}", r["sourcing_p50"],
             f"sourcing_p90={r['sourcing_p90']:.0f}us "
             f"total_p50={r['total_p50']:.0f}us "
             f"total_p90={r['total_p90']:.0f}us n={r['n']}")
    r = _measure_plan_batch("imp_batched", nodes=nodes,
                            batch=8 if full else 4)
    r["iteration"] = "it4_plan_batch"
    rows.append(r)
    emit("perf_sched_it4_plan_batch", r["total_p50"],
         f"end_to_end_per_request_p90={r['total_p90']:.0f}us n={r['n']}")
    return rows


if __name__ == "__main__":
    run()
