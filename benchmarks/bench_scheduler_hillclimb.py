"""§Perf hillclimb (paper-representative cell): candidate-sourcing latency.

The paper's own bottleneck metric (Table 5).  Wall-clock measured on this
host, 100-node saturated cluster, preemptor B (high-p-1000-4-card),
independent preemptions.  Iterations:

  it0  paper-faithful python IMP, naive O(instances) cluster scans
  it1  + per-node instance index & free-mask cache (host-side data structure)
  it2  per-node vectorized subset evaluation (imp_jax)  [hypothesis: slower —
       per-node dispatch overhead dominates at m<=8]
  it3  cluster-batched sweep: ONE vmapped evaluation per subset size over all
       candidate nodes (imp_batched)

Each records P50/P90 sourcing latency + end-to-end preempt() latency.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.scheduler import TopoScheduler
from repro.core.simulator import SimConfig, build_saturated_cluster
from repro.core.workload import table3_workloads

from .common import FULL, emit


def _measure(engine: str, node_index: bool, nodes: int = 100,
             samples: int = 30, preemptor: str = "B") -> dict:
    import repro.core.simulator as sim
    from repro.core.cluster import Cluster

    cfg = SimConfig(num_nodes=nodes, seed=11)
    wls = {w.name: w for w in table3_workloads()}
    cluster = Cluster(cfg.spec, cfg.num_nodes, node_index=node_index)
    import random

    sim.saturate(cluster, table3_workloads(),
                 {k: round(v * nodes / 100) for k, v in
                  sim.TABLE3_INITIAL_INSTANCES.items()},
                 random.Random(cfg.seed))
    sched = TopoScheduler(cluster, engine=engine)
    sourcing, total = [], []
    # warm up jit caches so compile time isn't counted as scheduling latency
    res = sched.schedule_or_preempt(wls[preemptor])
    if res is not None:
        sched.undo(res)
        if hasattr(res, "sourcing_us"):
            sched.sourcing_us_log.clear()
    for _ in range(samples):
        t0 = time.perf_counter()
        res = sched.schedule_or_preempt(wls[preemptor])
        total.append((time.perf_counter() - t0) * 1e6)
        if res is None:
            break
        if hasattr(res, "sourcing_us"):
            sourcing.append(res.sourcing_us)
        sched.undo(res)
    return {
        "engine": engine, "node_index": node_index,
        "sourcing_p50": float(np.percentile(sourcing, 50)) if sourcing else 0,
        "sourcing_p90": float(np.percentile(sourcing, 90)) if sourcing else 0,
        "total_p50": float(np.percentile(total, 50)),
        "total_p90": float(np.percentile(total, 90)),
        "n": len(sourcing),
    }


ITERATIONS = [
    ("it0_python_imp_naive", "imp", False),
    ("it1_python_imp_indexed", "imp", True),
    ("it2_pernode_vectorized", "imp_jax", True),
    ("it3_cluster_batched", "imp_batched", True),
]


def run(full: bool = FULL) -> list[dict]:
    nodes = 100
    samples = 50 if full else 25
    rows = []
    for name, engine, idx in ITERATIONS:
        r = _measure(engine, idx, nodes=nodes, samples=samples)
        r["iteration"] = name
        rows.append(r)
        emit(f"perf_sched_{name}", r["sourcing_p50"],
             f"sourcing_p90={r['sourcing_p90']:.0f}us "
             f"total_p50={r['total_p50']:.0f}us "
             f"total_p90={r['total_p90']:.0f}us n={r['n']}")
    return rows


if __name__ == "__main__":
    run()
