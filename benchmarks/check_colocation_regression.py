"""CI gate for the co-location day-cycle A/B (next to the sourcing gate).

Re-runs the committed ``BENCH_colocation.json`` protocol (same nodes, seed,
horizon) and fails if

* the topology-aware engine no longer beats the topology-unaware baseline
  on the scheduled-performance integral (``uplift <= 0``),
* the victim requeue lifecycle stopped being exercised (no preempted
  offline job was requeued AND successfully replanned),
* the aware engine's deterministic day metrics drift from the committed
  baseline (the day cycle is seeded end to end: decisions, and therefore
  the integrals, must reproduce bit-for-bit on any machine), or
* the per-hour P50 plan latency regresses more than ``MAX_REGRESSION``x
  over the committed run, machine-normed via the baseline engine's host
  sourcing latency (clamped >= 1 so a fast machine never tightens the
  gate).

Run: ``PYTHONPATH=src python -m benchmarks.check_colocation_regression``
"""
from __future__ import annotations

import json
import math
import sys

from .bench_colocation import BENCH_JSON, ENGINES, day_config, report_payload

MAX_REGRESSION = 2.0
REL_TOL = 1e-6


def main() -> int:
    if not BENCH_JSON.exists():
        print(f"FAIL: no committed baseline at {BENCH_JSON}")
        return 1
    base = json.loads(BENCH_JSON.read_text())
    from repro.core.colocation import compare_day_cycle

    cfg = day_config(num_nodes=int(base["num_nodes"]),
                     horizon_hours=float(base["horizon_hours"]),
                     seed=int(base["seed"]))
    ab = compare_day_cycle(cfg, engines=ENGINES)
    aware_name, baseline_name = ENGINES
    aware = report_payload(ab["reports"][aware_name])
    failures = 0

    uplift = ab["uplift"]
    status = "ok" if uplift > 0 else "REGRESSION"
    print(f"scheduled-performance uplift {aware_name} vs {baseline_name}: "
          f"{uplift * 100:+.1f}% (preemptor slice "
          f"{ab['preemptor_uplift'] * 100:+.1f}%) [{status}]")
    if uplift <= 0:
        failures += 1

    rq, rp = aware["requeued"], aware["requeue_replanned"]
    status = "ok" if (rq > 0 and rp > 0) else "FAIL"
    print(f"requeue lifecycle: {rp}/{rq} victims replanned [{status}]")
    if not (rq > 0 and rp > 0):
        failures += 1

    committed = base["engines"][aware_name]
    for metric in ("scheduled_perf", "preemptor_perf", "offline_goodput"):
        got, want = aware[metric], committed[metric]
        ok = math.isclose(got, want, rel_tol=REL_TOL)
        print(f"{aware_name} {metric}: {got:.3f} vs committed {want:.3f} "
              f"[{'ok' if ok else 'DRIFT'}]")
        if not ok:
            failures += 1
    for metric in ("preemptions", "hits", "requeued", "requeue_replanned",
                   "placements", "failures"):
        got, want = aware[metric], committed[metric]
        ok = got == want
        print(f"{aware_name} {metric}: {got} vs committed {want} "
              f"[{'ok' if ok else 'DRIFT'}]")
        if not ok:
            failures += 1

    # latency: machine-normed via the host baseline engine
    base_ref = base["engines"][baseline_name].get("plan_p50_us", 0.0)
    base_now = report_payload(ab["reports"][baseline_name])["plan_p50_us"]
    ref = committed.get("plan_p50_us", 0.0)
    if ref and base_ref:
        norm = max(1.0, base_now / base_ref)
        ratio = aware["plan_p50_us"] / (ref * norm)
        status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
        print(f"{aware_name} plan p50 {aware['plan_p50_us']:.0f}us vs "
              f"committed {ref:.0f}us (machine norm {norm:.2f}, "
              f"{ratio:.2f}x) [{status}]")
        if ratio > MAX_REGRESSION:
            failures += 1

    if failures:
        print(f"FAIL: {failures} colocation gate(s) tripped")
        return 1
    print("co-location day cycle within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
