"""CI gate for the co-location day-cycle A/B (next to the sourcing gate).

Re-runs the committed ``BENCH_colocation.json`` protocol (same nodes, seed,
horizon) and fails if

* the topology-aware engine no longer beats the topology-unaware baseline
  on the scheduled-performance integral (``uplift <= 0``),
* the victim requeue lifecycle stopped being exercised (no preempted
  offline job was requeued AND successfully replanned),
* the aware engine's deterministic day metrics drift from the committed
  baseline (the day cycle is seeded end to end: decisions, and therefore
  the integrals, must reproduce bit-for-bit on any machine),
* the per-hour P50 plan latency regresses more than ``MAX_REGRESSION``x
  over the committed run, machine-normed via the baseline engine's host
  sourcing latency (clamped >= 1 so a fast machine never tightens the
  gate).  Hours that paid XLA compile time (``compiled_per_hour`` from
  `simulator.CompileWatch`) are excluded on BOTH sides, so cold-jit noise
  no longer spends gate headroom,
* the committed ``scale`` block (the O(delta) event-loop sweep) is
  missing, ran the small protocol, lost bit-exact parity vs the legacy
  loop at any parity size, fell under ``MIN_EVPS_RATIO``x the legacy
  loop's events/sec, or blew the 10240-node wall-clock budget, or
* a LIVE legacy-vs-O(delta) day (small, host engine, in-process) stops
  being bit-exact — the committed parity flags prove the sweep machine
  saw exactness; this proves THIS checkout still has it.

Run: ``PYTHONPATH=src python -m benchmarks.check_colocation_regression``
"""
from __future__ import annotations

import json
import math
import statistics
import sys

from .bench_colocation import (BENCH_JSON, ENGINES, SCALE_BUDGET_S, SIZES,
                               day_config, report_payload)

MAX_REGRESSION = 2.0
REL_TOL = 1e-6
#: O(delta) events/sec over the legacy loop's (committed scale block)
MIN_EVPS_RATIO = 5.0
#: live legacy-vs-O(delta) parity re-check protocol (host engine: cheap)
LIVE_PARITY = dict(num_nodes=16, horizon_hours=8.0, seed=3, engine="imp")


def _clean_p50(payload: dict) -> float:
    """Median per-hour plan P50 over compile-free hours.

    Falls back to all nonzero hours (the pre-``compiled_per_hour``
    baseline shape), then to the whole-day ``plan_p50_us``."""
    per_hour = payload.get("plan_p50_us_per_hour", [])
    compiled = payload.get("compiled_per_hour") or [0] * len(per_hour)
    vals = [v for v, c in zip(per_hour, compiled) if v > 0 and not c]
    if not vals:
        vals = [v for v in per_hour if v > 0]
    return statistics.median(vals) if vals else payload.get("plan_p50_us",
                                                            0.0)


def _check_scale_block(base: dict) -> int:
    failures = 0
    scale = base.get("scale")
    if not scale:
        print("FAIL: no committed `scale` block in BENCH_colocation.json")
        return 1
    if scale.get("protocol") != "full":
        print(f"scale protocol: {scale.get('protocol')} [FAIL: the "
              f"committed sweep must be the full {list(SIZES)} protocol]")
        failures += 1
    rows = {(r["nodes"], r["loop"]): r for r in scale.get("rows", [])}

    for size in scale.get("parity_sizes", []):
        ok = scale.get("parity", {}).get(str(size), False)
        print(f"scale {size}-node day metrics odelta vs legacy: "
              f"[{'bit-exact' if ok else 'DIVERGED'}]")
        if not ok:
            failures += 1

    ratio = scale.get("evps_ratio", 0.0)
    od_n, lg_n = scale.get("evps_ratio_nodes", (0, 0))
    ok = ratio >= MIN_EVPS_RATIO
    print(f"scale events/sec odelta@{od_n} / legacy@{lg_n}: {ratio:.1f}x "
          f"(floor {MIN_EVPS_RATIO:.0f}x) [{'ok' if ok else 'REGRESSION'}]")
    if not ok:
        failures += 1

    big = rows.get((max(SIZES), "odelta"))
    if big is None:
        print(f"FAIL: no {max(SIZES)}-node odelta row in the scale block")
        failures += 1
    else:
        budget = scale.get("budget_s", SCALE_BUDGET_S)
        ok = big["wall_s"] <= budget
        print(f"scale {max(SIZES)}-node day: {big['wall_s']:.0f}s wall, "
              f"{big['events']} events, {big['events_per_sec']:.0f} ev/s "
              f"(budget {budget:.0f}s) [{'ok' if ok else 'OVER BUDGET'}]")
        if not ok:
            failures += 1
    return failures


def _check_live_parity() -> int:
    import dataclasses

    from repro.core.colocation import run_day_cycle

    cfg = day_config(**LIVE_PARITY)
    new = run_day_cycle(cfg)
    old = run_day_cycle(dataclasses.replace(cfg, legacy_loop=True))
    ok = new.key_metrics() == old.key_metrics()
    print(f"live O(delta) vs legacy loop ({LIVE_PARITY['num_nodes']} nodes, "
          f"{LIVE_PARITY['horizon_hours']:.0f}h, "
          f"engine={LIVE_PARITY['engine']}): "
          f"[{'bit-exact' if ok else 'DIVERGED'}]")
    return 0 if ok else 1


def main() -> int:
    if not BENCH_JSON.exists():
        print(f"FAIL: no committed baseline at {BENCH_JSON}")
        return 1
    base = json.loads(BENCH_JSON.read_text())
    from repro.core.colocation import compare_day_cycle

    cfg = day_config(num_nodes=int(base["num_nodes"]),
                     horizon_hours=float(base["horizon_hours"]),
                     seed=int(base["seed"]))
    ab = compare_day_cycle(cfg, engines=ENGINES)
    aware_name, baseline_name = ENGINES
    aware = report_payload(ab["reports"][aware_name])
    failures = 0

    uplift = ab["uplift"]
    status = "ok" if uplift > 0 else "REGRESSION"
    print(f"scheduled-performance uplift {aware_name} vs {baseline_name}: "
          f"{uplift * 100:+.1f}% (preemptor slice "
          f"{ab['preemptor_uplift'] * 100:+.1f}%) [{status}]")
    if uplift <= 0:
        failures += 1

    rq, rp = aware["requeued"], aware["requeue_replanned"]
    status = "ok" if (rq > 0 and rp > 0) else "FAIL"
    print(f"requeue lifecycle: {rp}/{rq} victims replanned [{status}]")
    if not (rq > 0 and rp > 0):
        failures += 1

    committed = base["engines"][aware_name]
    for metric in ("scheduled_perf", "preemptor_perf", "offline_goodput"):
        got, want = aware[metric], committed[metric]
        ok = math.isclose(got, want, rel_tol=REL_TOL)
        print(f"{aware_name} {metric}: {got:.3f} vs committed {want:.3f} "
              f"[{'ok' if ok else 'DRIFT'}]")
        if not ok:
            failures += 1
    for metric in ("preemptions", "hits", "requeued", "requeue_replanned",
                   "placements", "failures"):
        got, want = aware[metric], committed[metric]
        ok = got == want
        print(f"{aware_name} {metric}: {got} vs committed {want} "
              f"[{'ok' if ok else 'DRIFT'}]")
        if not ok:
            failures += 1

    # latency: machine-normed via the host baseline engine, on
    # compile-free hours only (both sides of both ratios)
    base_ref = _clean_p50(base["engines"][baseline_name])
    base_now = _clean_p50(report_payload(ab["reports"][baseline_name]))
    ref = _clean_p50(committed)
    now = _clean_p50(aware)
    if ref and base_ref:
        norm = max(1.0, base_now / base_ref)
        ratio = now / (ref * norm)
        status = "ok" if ratio <= MAX_REGRESSION else "REGRESSION"
        print(f"{aware_name} clean plan p50 {now:.0f}us vs "
              f"committed {ref:.0f}us (machine norm {norm:.2f}, "
              f"{ratio:.2f}x) [{status}]")
        if ratio > MAX_REGRESSION:
            failures += 1

    failures += _check_scale_block(base)
    failures += _check_live_parity()

    if failures:
        print(f"FAIL: {failures} colocation gate(s) tripped")
        return 1
    print("co-location day cycle within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
